//! Open-loop bursty-arrival serving under overload: requests arrive at
//! 2× the engine's measured service rate, and we compare the tail
//! latency experienced by live streams with the robustness machinery
//! (chunked prefill + priority classes) off vs on.
//!
//! Three records, written to `BENCH_overload.json`:
//!
//! * `plain`   — monolithic prefills, single-class FIFO queue;
//! * `robust`  — `prefill_chunk_tokens` slices the long prompts across
//!   admission slots and the short interactive requests ride the
//!   latency class. The p99 inter-token latency of live streams must
//!   drop: a 192-token prefill no longer stalls a whole decode round;
//! * `preempt_recovery` — a small arena is drained behind the
//!   admission gate's back (the shared-device scenario), forcing a
//!   mid-stream preemption; the victims are requeued, resume after the
//!   outside holder releases, and finish **bit-identical** to an
//!   unpreempted control run — zero client-visible errors.
//!
//! TTFT = submit → first token event; ITL = gap between consecutive
//! token events of one stream, both observed at round boundaries (the
//! granularity a thin client actually sees). Each overload scenario is
//! the median of 3 runs.
//!
//! `cargo bench --bench overload`

use std::time::Instant;

use edgellm::coordinator::engine::{Engine, EngineConfig, Event, Priority, RequestHandle};
use edgellm::coordinator::sampler::Sampling;
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::util::json::Json;

const N_REQUESTS: usize = 32;
const MAX_NEW: usize = 16;
/// every LONG_EVERY-th request carries a long prompt
const LONG_EVERY: usize = 4;
const LONG_PROMPT_TOKENS: usize = 192;
const SHORT_PROMPT_TOKENS: usize = 16;
const PREFILL_CHUNK: usize = 32;
const RUNS: usize = 3;

/// (prompt, max_new, class) — the class is only honored by the robust
/// scenario; `plain` submits everything as batch class.
fn workload(use_priority: bool) -> Vec<(String, usize, Priority)> {
    (0..N_REQUESTS)
        .map(|i| {
            if i % LONG_EVERY == LONG_EVERY - 1 {
                let p = format!("{:<LONG_PROMPT_TOKENS$}", format!("long document {i}"));
                (p, MAX_NEW, Priority::Batch)
            } else {
                let p = format!("{:<SHORT_PROMPT_TOKENS$}", format!("chat {i}"));
                let class = if use_priority { Priority::Latency } else { Priority::Batch };
                (p, MAX_NEW, class)
            }
        })
        .collect()
}

/// Engine over the reference backend with a pool generous enough that
/// the overload scenarios never preempt — they isolate the *scheduling*
/// effects (prefill stalls, queue jumps), not memory pressure.
fn overload_engine(chunk: usize) -> Engine {
    let runtime = LlmRuntime::reference(ReferenceConfig {
        max_tokens: 256,
        kv_block_tokens: 16,
        kv_pool_blocks: 96,
        ..ReferenceConfig::default()
    });
    Engine::new(
        runtime,
        EngineConfig {
            max_active: 4,
            prefill_chunk_tokens: chunk,
            ..EngineConfig::default()
        },
    )
}

struct StreamState {
    handle: RequestHandle,
    submitted: Instant,
    last_token: Option<Instant>,
    done: bool,
}

struct Observed {
    ttfts_ms: Vec<f64>,
    itls_ms: Vec<f64>,
    completed: usize,
    requeued: u64,
}

/// Open-loop driver: requests become visible to the engine on their
/// arrival clock regardless of how backed up it is (the defining
/// property of overload — a closed loop would throttle itself).
fn drive(engine: &mut Engine, arrivals: &[(String, usize, Priority)], interval_s: f64) -> Observed {
    let t0 = Instant::now();
    let mut streams: Vec<StreamState> = Vec::with_capacity(arrivals.len());
    let mut next = 0usize;
    let mut obs = Observed {
        ttfts_ms: Vec::new(),
        itls_ms: Vec::new(),
        completed: 0,
        requeued: 0,
    };
    loop {
        while next < arrivals.len() && t0.elapsed().as_secs_f64() >= next as f64 * interval_s {
            let (prompt, max_new, class) = &arrivals[next];
            let handle =
                engine.submit_with_priority(prompt, *max_new, Sampling::Greedy, *class);
            streams.push(StreamState {
                handle,
                submitted: Instant::now(),
                last_token: None,
                done: false,
            });
            next += 1;
        }
        if next >= arrivals.len() && !engine.has_work() {
            break;
        }
        if engine.has_work() {
            engine.step_round().expect("overload round");
        } else {
            // idle before the next arrival tick
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let now = Instant::now();
        for (i, s) in streams.iter_mut().enumerate() {
            while let Some(ev) = s.handle.try_recv() {
                match ev {
                    Event::Token(_) => {
                        match s.last_token {
                            None => obs
                                .ttfts_ms
                                .push(now.duration_since(s.submitted).as_secs_f64() * 1e3),
                            Some(prev) => obs
                                .itls_ms
                                .push(now.duration_since(prev).as_secs_f64() * 1e3),
                        }
                        s.last_token = Some(now);
                    }
                    Event::Done(_) => {
                        s.done = true;
                        obs.completed += 1;
                    }
                    Event::Error(msg) => {
                        panic!("request {i} saw a client-visible error under overload: {msg}")
                    }
                }
            }
        }
    }
    assert!(
        streams.iter().all(|s| s.done),
        "every stream must finish under overload"
    );
    obs.requeued = engine.metrics().requeued;
    obs
}

fn percentile(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    xs[((xs.len() - 1) as f64 * p).round() as usize]
}

fn median3(mut xs: [f64; RUNS]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    xs[RUNS / 2]
}

struct Scenario {
    p50_ttft_ms: f64,
    p99_ttft_ms: f64,
    p50_itl_ms: f64,
    p99_itl_ms: f64,
    completed: usize,
    requeued: u64,
    /// the engine's own TTFT histogram p99 (median across runs) — the
    /// pull-based obs view of the same workload the bench measured
    /// externally, cross-checked in `main`
    engine_p99_ttft_ms: f64,
    engine_ttft_count: u64,
}

/// Median-of-RUNS overload scenario: fresh engine per run, same
/// arrival schedule.
fn run_scenario(chunk: usize, use_priority: bool, interval_s: f64) -> Scenario {
    let mut p50_ttft = [0.0; RUNS];
    let mut p99_ttft = [0.0; RUNS];
    let mut p50_itl = [0.0; RUNS];
    let mut p99_itl = [0.0; RUNS];
    let mut eng_p99_ttft = [0.0; RUNS];
    let mut completed = 0;
    let mut requeued = 0;
    let mut engine_ttft_count = 0;
    for run in 0..RUNS {
        let mut engine = overload_engine(chunk);
        let mut obs = drive(&mut engine, &workload(use_priority), interval_s);
        assert_eq!(obs.completed, N_REQUESTS, "all requests complete");
        p50_ttft[run] = percentile(&mut obs.ttfts_ms, 0.50);
        p99_ttft[run] = percentile(&mut obs.ttfts_ms, 0.99);
        p50_itl[run] = percentile(&mut obs.itls_ms, 0.50);
        p99_itl[run] = percentile(&mut obs.itls_ms, 0.99);
        let hist = engine.obs().ttft_us.summary();
        eng_p99_ttft[run] = hist.p99 / 1e3;
        engine_ttft_count = hist.count;
        completed = obs.completed;
        requeued = obs.requeued;
    }
    Scenario {
        p50_ttft_ms: median3(p50_ttft),
        p99_ttft_ms: median3(p99_ttft),
        p50_itl_ms: median3(p50_itl),
        p99_itl_ms: median3(p99_itl),
        completed,
        requeued,
        engine_p99_ttft_ms: median3(eng_p99_ttft),
        engine_ttft_count,
    }
}

fn scenario_json(s: &Scenario) -> Json {
    Json::obj(vec![
        ("p50_ttft_ms", Json::Num(s.p50_ttft_ms)),
        ("p99_ttft_ms", Json::Num(s.p99_ttft_ms)),
        ("p50_itl_ms", Json::Num(s.p50_itl_ms)),
        ("p99_itl_ms", Json::Num(s.p99_itl_ms)),
        ("completed", Json::Num(s.completed as f64)),
        ("requeued", Json::Num(s.requeued as f64)),
        ("errors", Json::Num(0.0)), // drive() panics on any Error event
        ("engine_hist_p99_ttft_ms", Json::Num(s.engine_p99_ttft_ms)),
        ("engine_hist_ttft_count", Json::Num(s.engine_ttft_count as f64)),
    ])
}

/// The engine's histogram p99 must track the bench's externally
/// measured p99. They are not the same estimator — the histogram has
/// log2 buckets and its TTFT ends at prefill completion while the
/// bench's ends when the driver *observes* the token event one round
/// later — so the bound is relative (the smaller must stay within 4×
/// of the larger: one bucket of quantization plus one round of skew)
/// with a 25 ms floor for scheduling noise on loaded CI machines.
fn assert_hist_tracks_bench(name: &str, s: &Scenario) {
    assert_eq!(
        s.engine_ttft_count as usize, N_REQUESTS,
        "{name}: engine TTFT histogram must see every admission"
    );
    let (a, b) = (s.engine_p99_ttft_ms, s.p99_ttft_ms);
    let tol = (a.max(b) * 0.75).max(25.0);
    assert!(
        (a - b).abs() <= tol,
        "{name}: engine histogram p99 TTFT {a:.2} ms diverges from bench p99 {b:.2} ms \
         beyond tolerance {tol:.2} ms"
    );
}

/// Drain the arena behind the admission gate's back (a second
/// coordinator on a shared device), force a mid-stream preemption, then
/// release the outside holder and let the victims resume. Returns
/// (requeued, preempted) after asserting bit-identical recovery.
fn preempt_recovery_record() -> Json {
    let cfg = ReferenceConfig {
        kv_block_tokens: 8,
        kv_pool_blocks: 24,
        ..ReferenceConfig::default()
    };
    let prompts = ["edge aa1", "edge bb2"];
    const GEN: usize = 24;

    // control: same requests, nobody touches the arena from outside
    let mut control = Engine::new(LlmRuntime::reference(cfg.clone()), EngineConfig::default());
    for p in prompts {
        control.submit(p, GEN, Sampling::Greedy);
    }
    let mut control_texts: Vec<(u64, String)> = control
        .run_all()
        .expect("control run")
        .into_iter()
        .map(|c| (c.id, c.text))
        .collect();
    control_texts.sort();

    let mut engine = Engine::new(LlmRuntime::reference(cfg), EngineConfig::default());
    let handles: Vec<RequestHandle> =
        prompts.iter().map(|p| engine.submit(p, GEN, Sampling::Greedy)).collect();
    engine.step_round().expect("admission round");
    assert_eq!(engine.active_sessions(), 2);

    // the outside holder: unique one-block prompts until the pool is dry
    let mut hogs = Vec::new();
    loop {
        match engine.runtime().prefill(&format!("hog {:04}", hogs.len()).into_bytes()
            .iter().map(|&b| b as i32).collect::<Vec<i32>>())
        {
            Ok((_, s)) => hogs.push(s),
            Err(_) => break,
        }
    }
    let stall_start = Instant::now();
    let mut rounds = 0;
    while engine.metrics().preempted == 0 {
        engine.step_round().expect("pressured round");
        rounds += 1;
        assert!(rounds < 64, "preemption never triggered");
    }
    let requeued = engine.metrics().requeued;
    let preempted = engine.metrics().preempted;
    assert!(requeued >= 1, "the victim must be requeued, not failed");
    for mut s in hogs {
        engine.runtime().end_session(&mut s);
    }
    engine.run_all().expect("recovery run");
    let stall_ms = stall_start.elapsed().as_secs_f64() * 1e3;

    let mut texts: Vec<(u64, String)> = handles
        .iter()
        .map(|h| {
            let c = h.wait().expect("zero client-visible errors through preemption");
            (c.id, c.text)
        })
        .collect();
    texts.sort();
    assert_eq!(
        texts.iter().map(|(_, t)| t).collect::<Vec<_>>(),
        control_texts.iter().map(|(_, t)| t).collect::<Vec<_>>(),
        "resumed completions must be bit-identical to the unpreempted run"
    );
    // the obs trace must tell the same story the metrics counters do:
    // each victim leaves a preempted → requeued → resumed chain with
    // timestamps that never run backwards
    {
        use edgellm::obs::SpanKind;
        let spans = engine.obs().trace.snapshot();
        for h in &handles {
            let mine: Vec<_> = spans.iter().filter(|s| s.req_id == h.id()).collect();
            let pos = |k: SpanKind| mine.iter().position(|s| s.kind == k);
            if let Some(p) = pos(SpanKind::Preempted) {
                let rq = pos(SpanKind::Requeued).expect("preempted but never requeued");
                let rs = pos(SpanKind::Resumed).expect("requeued but never resumed");
                assert!(p < rq && rq < rs, "preemption chain out of order");
                assert!(
                    mine[p].end_ns <= mine[rq].end_ns && mine[rq].end_ns <= mine[rs].end_ns,
                    "preemption chain timestamps regressed"
                );
            }
        }
        let preempted_spans =
            spans.iter().filter(|s| s.kind == SpanKind::Preempted).count() as u64;
        assert_eq!(preempted_spans, preempted, "trace and metrics disagree on preemptions");
    }
    println!(
        "preempt recovery: {preempted} preempted / {requeued} requeued, \
         recovery window {stall_ms:.1} ms, completions bit-identical"
    );
    Json::obj(vec![
        ("preempted", Json::Num(preempted as f64)),
        ("requeued", Json::Num(requeued as f64)),
        ("recovery_window_ms", Json::Num(stall_ms)),
        ("recovered_bit_identical", Json::Bool(true)),
        ("errors", Json::Num(0.0)),
    ])
}

fn main() {
    // calibrate the service rate closed-loop, then arrive at 2× it
    let mut cal = overload_engine(0);
    for (p, n, _) in workload(false) {
        cal.submit(&p, n, Sampling::Greedy);
    }
    let t0 = Instant::now();
    cal.run_all().expect("calibration");
    let service_s = t0.elapsed().as_secs_f64() / N_REQUESTS as f64;
    let interval_s = service_s / 2.0;
    println!(
        "== overload: {N_REQUESTS} requests ({} long x {LONG_PROMPT_TOKENS} tokens), \
         arrivals every {:.2} ms (2x the {:.2} ms service time) ==",
        N_REQUESTS / LONG_EVERY,
        interval_s * 1e3,
        service_s * 1e3,
    );

    let plain = run_scenario(0, false, interval_s);
    let robust = run_scenario(PREFILL_CHUNK, true, interval_s);
    for (name, s) in [("plain", &plain), ("robust", &robust)] {
        println!(
            "{name:>7}: ttft p50 {:>7.2} ms p99 {:>7.2} ms | itl p50 {:>6.2} ms \
             p99 {:>6.2} ms | {} completed | engine hist p99 ttft {:>7.2} ms",
            s.p50_ttft_ms, s.p99_ttft_ms, s.p50_itl_ms, s.p99_itl_ms, s.completed,
            s.engine_p99_ttft_ms
        );
        assert_hist_tracks_bench(name, s);
    }
    assert!(
        robust.p99_itl_ms < plain.p99_itl_ms,
        "chunked prefill must cut the tail inter-token stall: \
         robust p99 {:.2} ms vs plain p99 {:.2} ms",
        robust.p99_itl_ms,
        plain.p99_itl_ms
    );

    let recovery = preempt_recovery_record();
    let r = recovery.get("requeued").and_then(|v| v.as_f64()).unwrap_or(0.0);
    assert!(r >= 1.0, "preempt_recovery must exercise the requeue path");

    let out = Json::obj(vec![
        ("bench", Json::Str("overload".into())),
        ("requests", Json::Num(N_REQUESTS as f64)),
        ("long_prompt_tokens", Json::Num(LONG_PROMPT_TOKENS as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("overload_factor", Json::Num(2.0)),
        ("arrival_interval_ms", Json::Num(interval_s * 1e3)),
        ("prefill_chunk_tokens", Json::Num(PREFILL_CHUNK as f64)),
        ("runs_per_scenario", Json::Num(RUNS as f64)),
        ("plain", scenario_json(&plain)),
        ("robust", scenario_json(&robust)),
        ("preempt_recovery", recovery),
    ]);
    std::fs::write("BENCH_overload.json", format!("{out}\n")).expect("write BENCH_overload.json");
    println!("wrote BENCH_overload.json");
}
