//! Regenerates **Fig. 5**: the weight-package bit budgets, effective
//! bit-widths and performance enhancement at each log-scale sparsity,
//! under both mask encodings.
//!
//! `cargo bench --bench fig5_sparsity_packing`

use edgellm::pack::{best_encoding, mask_bits, package_bits, MaskEncoding};
use edgellm::quant::Sparsity;
use edgellm::util::bench::Table;

fn main() {
    println!("== Fig. 5: weight package budget per 2048 CH_in group ==");
    let mut t = Table::new(&[
        "case", "sparsity", "encoding", "scale bits", "mask bits", "wt bits",
        "total", "eff bit-width", "enhancement", "paper",
    ]);
    let rows = [
        ("1 dense", Sparsity::Dense, MaskEncoding::None, "8448 / 4.125 / 1.00x"),
        ("2 50%", Sparsity::Half, MaskEncoding::OneHot, "6400 / 3.125 / 1.32x"),
        ("3 75%", Sparsity::Quarter, MaskEncoding::AddrInBlock, "3840 / 1.875 / 2.2x"),
        ("4 87.5%", Sparsity::Eighth, MaskEncoding::OneHot, "3328 / 1.625 / 2.54x"),
        ("4 87.5%", Sparsity::Eighth, MaskEncoding::AddrInBlock, "2304 / 1.125 / 3.67x"),
    ];
    for (case, sp, enc, paper) in rows {
        let p = package_bits(sp, enc);
        t.rowv(vec![
            case.to_string(),
            format!("{:.1}%", sp.percent()),
            format!("{enc:?}"),
            p.scale_bits.to_string(),
            p.mask_bits.to_string(),
            p.wt_bits.to_string(),
            p.total().to_string(),
            format!("{:.3}", p.effective_bitwidth()),
            format!("{:.2}x", p.enhancement()),
            paper.to_string(),
        ]);
    }
    t.print();

    println!("\n== hybrid encoding crossover ==");
    for sp in [Sparsity::Half, Sparsity::Quarter, Sparsity::Eighth] {
        println!(
            "{:>6.1}% sparse: one-hot {} bits vs addr-in-block {} bits -> {:?}",
            sp.percent(),
            mask_bits(sp, MaskEncoding::OneHot),
            mask_bits(sp, MaskEncoding::AddrInBlock),
            best_encoding(sp)
        );
    }
}
