//! Regenerates **Table IV**: per-operator power and the normalized
//! average power during sparse GLM-6B decode.
//!
//! `cargo bench --bench table4_power`

use edgellm::models::{GLM_6B, STRATEGY_3};
use edgellm::sim::engine::Simulator;
use edgellm::sim::operators::block_ops;
use edgellm::sim::power::{decode_energy, op_power_w, STANDBY_W};
use edgellm::sim::Memory;
use edgellm::util::bench::Table;

// Paper Table IV rows (W @140/280 MHz).
const PAPER: &[(&str, f64)] = &[
    ("RMSNorm", 41.02),
    ("VMM-BN(Q)", 54.02),
    ("PosEmb(Q)", 40.81),
    ("VMM-BN(K)", 42.79),
    ("PosEmb(K)", 40.63),
    ("KcacheHBM", 40.62),
    ("VMM(Q*K^T)", 41.01),
    ("Softmax", 40.65),
    ("VMM-BN(V)", 42.84),
    ("VcacheHBM", 40.62),
    ("VMM(SFT*V)", 40.92),
    ("VMM-BN-RES(O)", 57.25),
    ("RMSNorm", 40.97),
    ("VMM-BN(gate)", 55.13),
    ("Swiglu", 41.11),
    ("VMM-BN(up)", 58.13),
    ("VMM-BN-RES(4h-h)", 53.23),
];

fn main() {
    println!("== Table IV: operator power (W) ==");
    println!("standby (bitstream loaded): {STANDBY_W} W (paper: 40.36 W)\n");
    let ops = block_ops(&GLM_6B, &STRATEGY_3);
    let mut t = Table::new(&["step", "operator", "ours (W)", "paper (W)"]);
    for (i, op) in ops.iter().enumerate() {
        let p = op_power_w(op);
        let paper = PAPER.get(i).map(|x| format!("{:.2}", x.1)).unwrap_or_default();
        t.rowv(vec![
            (i + 1).to_string(),
            op.name.to_string(),
            format!("{p:.2}"),
            paper,
        ]);
    }
    t.print();

    let sim = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
    let e = decode_energy(&sim, 128);
    println!(
        "\nnormalized average power (duty-cycle weighted): {:.2} W (paper: 56.86 W)\n\
         energy per decoded token: {:.3} J -> {:.2} token/J",
        e.avg_power_w,
        e.energy_j,
        1.0 / e.energy_j
    );
}
