//! Hot-path microbenchmarks for the §Perf optimization pass: wall-time
//! of the L3 components that run per-request or per-table-regeneration.
//!
//! `cargo bench --bench hotpath`

use edgellm::compiler::codegen::compile;
use edgellm::fp::error::{error_rate, Design, Mode};
use edgellm::fp::minifloat::f16_encode;
use edgellm::fp::mixpe::{mac_fp16_int4, PAPER_PE, T_IN};
use edgellm::models::{DENSE, GLM_6B, STRATEGY_3};
use edgellm::pack::layout::{encode_package, port_streams};
use edgellm::quant::{prune_log_scale, quantize, Sparsity};
use edgellm::sim::engine::Simulator;
use edgellm::sim::Memory;
use edgellm::util::bench::{time_it, Table};
use edgellm::util::rng::Rng;

fn main() {
    let mut t = Table::new(&["hot path", "median", "min", "throughput"]);

    // 1. mix-PE MAC (the Table-I harness inner loop)
    let mut rng = Rng::new(1);
    let a: Vec<u16> = (0..T_IN).map(|_| f16_encode(rng.normal())).collect();
    let w: Vec<i8> = (0..T_IN).map(|_| rng.int_in(-8, 7) as i8).collect();
    let one = f16_encode(1.0);
    let tm = time_it(100, 2000, || {
        std::hint::black_box(mac_fp16_int4(&PAPER_PE, &a, &w, one));
    });
    t.rowv(vec![
        "mixpe 128-lane MAC".into(),
        tm.fmt_human(),
        edgellm::util::bench::fmt_secs(tm.min),
        format!("{:.1} M MAC-lane/s", T_IN as f64 / tm.median / 1e6),
    ]);

    // 2. error-rate harness (1000 trials)
    let te = time_it(1, 5, || {
        std::hint::black_box(error_rate(Design::MixPe, Mode::Fp16Int4, &PAPER_PE, 1000, 7));
    });
    t.rowv(vec![
        "error_rate 1k trials".into(),
        te.fmt_human(),
        edgellm::util::bench::fmt_secs(te.min),
        format!("{:.0} trials/s", 1000.0 / te.median),
    ]);

    // 3. quantize + prune a 2048×512 matrix
    let (k, n) = (2048usize, 512usize);
    let w0: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let tq = time_it(1, 10, || {
        let mut w = w0.clone();
        prune_log_scale(&mut w, k, n, 2);
        std::hint::black_box(quantize(&w, k, n));
    });
    t.rowv(vec![
        "prune+quantize 2048x512".into(),
        tq.fmt_human(),
        edgellm::util::bench::fmt_secs(tq.min),
        format!("{:.1} M elem/s", (k * n) as f64 / tq.median / 1e6),
    ]);

    // 4. HBM package encode (one column) + full port-stream assembly
    let mut wq = w0.clone();
    prune_log_scale(&mut wq, k, n, 2);
    let qm = quantize(&wq, k, n);
    let tp = time_it(1, 20, || {
        std::hint::black_box(encode_package(&qm, 0, 0, Sparsity::Quarter));
    });
    t.rowv(vec![
        "encode_package (1 col)".into(),
        tp.fmt_human(),
        edgellm::util::bench::fmt_secs(tp.min),
        String::new(),
    ]);
    let ts = time_it(1, 3, || {
        std::hint::black_box(port_streams(&qm, Sparsity::Quarter));
    });
    t.rowv(vec![
        "port_streams 2048x512".into(),
        ts.fmt_human(),
        edgellm::util::bench::fmt_secs(ts.min),
        format!(
            "{:.1} MB/s packaged",
            (k * n) as f64 / 2.0 / ts.median / 1e6
        ),
    ]);

    // 5. full-model compile (graph + instruction generation)
    let tc = time_it(1, 10, || {
        std::hint::black_box(compile(&GLM_6B, &STRATEGY_3, 256));
    });
    t.rowv(vec![
        "compile GLM-6B program".into(),
        tc.fmt_human(),
        edgellm::util::bench::fmt_secs(tc.min),
        String::new(),
    ]);

    // 6. simulator: one full decode step + a 64-token generation
    let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
    let td = time_it(2, 50, || {
        std::hint::black_box(sim.decode_step(512));
    });
    t.rowv(vec![
        "sim decode_step".into(),
        td.fmt_human(),
        edgellm::util::bench::fmt_secs(td.min),
        format!("{:.0} steps/s", 1.0 / td.median),
    ]);
    let tg = time_it(1, 5, || {
        std::hint::black_box(sim.generate(128, 64));
    });
    t.rowv(vec![
        "sim generate 128+64".into(),
        tg.fmt_human(),
        edgellm::util::bench::fmt_secs(tg.min),
        String::new(),
    ]);

    t.print();
}
