//! Regenerates **Fig. 12**: sparse (strategy-3) GLM-6B — first-token
//! delay, peak decode speed, power, and the speed-vs-context sweep.
//!
//! `cargo bench --bench fig12_sparse_glm`

use edgellm::models::{GLM_6B, STRATEGY_3};
use edgellm::sim::engine::Simulator;
use edgellm::sim::power::decode_energy;
use edgellm::sim::Memory;
use edgellm::util::bench::Table;

fn main() {
    let sim = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);

    println!("== Fig. 12: sparse GLM-6B (strategy-3) ==");
    let gen = sim.generate(1, 64);
    let e = decode_energy(&sim, 64);
    let mut t = Table::new(&["metric", "ours", "paper"]);
    t.rowv(vec![
        "first decode delay (ms)".into(),
        format!("{:.1}", gen.first_token_us / 1e3),
        "10.8".into(),
    ]);
    t.rowv(vec![
        "peak decode speed (tok/s)".into(),
        format!("{:.1}", sim.decode_tokens_per_s(16)),
        "85.8".into(),
    ]);
    t.rowv(vec![
        "power (W)".into(),
        format!("{:.2}", e.avg_power_w),
        "56.86".into(),
    ]);
    t.rowv(vec![
        "vs GPU throughput".into(),
        format!("{:.2}x", sim.decode_tokens_per_s(128) / 45.0),
        "1.91x".into(),
    ]);
    t.rowv(vec![
        "vs GPU energy eff.".into(),
        format!("{:.2}x", (1.0 / e.energy_j) / 0.2),
        "7.55x".into(),
    ]);
    t.print();

    println!("\n== decode speed vs context (sparse) ==");
    let mut t2 = Table::new(&["ctx", "tok/s", "MHA share"]);
    for ctx in [16usize, 128, 512, 1024, 2048] {
        let bd = sim.decode_step(ctx).breakdown;
        t2.rowv(vec![
            ctx.to_string(),
            format!("{:.1}", 1e6 / bd.total_us()),
            format!("{:.0}%", 100.0 * bd.mha_us / bd.total_us()),
        ]);
    }
    t2.print();
    println!(
        "note: sparsity accelerates the weight-bound FFN stream, so the MHA\n\
         share grows faster than in the dense model (Fig. 11 vs 12 contrast)."
    );
}
