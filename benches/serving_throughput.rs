//! Continuous-batching serving throughput: aggregate decode tokens/s as
//! the live-session pool grows.
//!
//! For each `max_active` the same 16-request workload runs through the
//! scheduler; we report measured wall throughput of the functional
//! backend plus the simulated VCU128 aggregate, where each batched round
//! streams the (shared) weights once and only the per-session KV work
//! multiplies (`Simulator::decode_round`). Batch 8 must beat batch 1 on
//! aggregate tokens/s — that is the whole argument for replacing the
//! run-to-completion FIFO.
//!
//! `cargo bench --bench serving_throughput`

use edgellm::coordinator::engine::{Engine, EngineConfig};
use edgellm::coordinator::sampler::Sampling;
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::util::bench::Table;

const N_REQUESTS: usize = 16;
const MAX_NEW: usize = 32;

struct Run {
    wall_tps: f64,
    sim_tps: f64,
    rounds: u64,
    peak: usize,
}

fn run_workload(max_active: usize) -> Run {
    let runtime = LlmRuntime::reference(ReferenceConfig {
        max_tokens: 128,
        ..ReferenceConfig::default()
    });
    let mut engine = Engine::new(
        runtime,
        EngineConfig {
            max_active,
            ..EngineConfig::default()
        },
    );
    for i in 0..N_REQUESTS {
        engine.submit(
            &format!("edge request {i}: report sensor status"),
            MAX_NEW,
            Sampling::Greedy,
        );
    }
    engine.run_all().expect("workload");
    let m = engine.metrics();
    Run {
        wall_tps: m.tokens_per_s(),
        sim_tps: m.sim_tokens_per_s(),
        rounds: m.rounds,
        peak: m.peak_active,
    }
}

fn main() {
    println!(
        "== serving throughput: {N_REQUESTS} requests x {MAX_NEW} new tokens, \
         continuous batching =="
    );
    let mut t = Table::new(&[
        "max_active",
        "rounds",
        "peak live",
        "wall tok/s",
        "sim VCU128 tok/s",
        "sim speedup",
    ]);
    let mut batch1_sim = 0.0;
    let mut batch8 = None;
    for max_active in [1usize, 2, 4, 8, 16] {
        let r = run_workload(max_active);
        if max_active == 1 {
            batch1_sim = r.sim_tps;
        }
        if max_active == 8 {
            batch8 = Some(r.sim_tps);
        }
        t.rowv(vec![
            max_active.to_string(),
            r.rounds.to_string(),
            r.peak.to_string(),
            format!("{:.0}", r.wall_tps),
            format!("{:.1}", r.sim_tps),
            format!("{:.2}x", r.sim_tps / batch1_sim),
        ]);
    }
    t.print();
    let batch8 = batch8.expect("batch-8 run");
    println!(
        "batch 8 vs batch 1 (simulated aggregate): {:.1} vs {:.1} tok/s ({:.2}x)",
        batch8,
        batch1_sim,
        batch8 / batch1_sim
    );
    assert!(
        batch8 > batch1_sim,
        "continuous batching must raise aggregate throughput"
    );
    println!("note: wall tok/s is the functional reference backend (tiny model, \
              truly batched decode since PR 2 — benches/backend_throughput.rs \
              measures it on a cache-overflowing model); the VCU128 column \
              models the shared weight stream of the accelerator datapath.");
}
