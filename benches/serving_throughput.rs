//! Continuous-batching serving throughput: aggregate decode tokens/s as
//! the live-session pool grows.
//!
//! For each `max_active` the same 16-request workload runs through the
//! scheduler; we report measured wall throughput of the functional
//! backend plus the simulated VCU128 aggregate, where each batched round
//! streams the (shared) weights once and only the per-session KV work
//! multiplies (`Simulator::decode_round`). Batch 8 must beat batch 1 on
//! aggregate tokens/s — that is the whole argument for replacing the
//! run-to-completion FIFO.
//!
//! The second section measures the **paged KV arena** on a mixed
//! request-length workload: aggregate tokens/s plus peak resident KV
//! bytes, against the pre-arena per-request allocation baseline (every
//! admitted session pinning a full `max_tokens` cache for its whole
//! lifetime). Written machine-readable to `BENCH_kv.json`; CI archives
//! it next to the other bench records.
//!
//! `cargo bench --bench serving_throughput`

use std::time::Instant;

use edgellm::coordinator::engine::{Engine, EngineConfig};
use edgellm::coordinator::sampler::Sampling;
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::util::bench::Table;
use edgellm::util::json::Json;

const N_REQUESTS: usize = 16;
const MAX_NEW: usize = 32;

struct Run {
    wall_tps: f64,
    sim_tps: f64,
    rounds: u64,
    peak: usize,
}

fn run_workload(max_active: usize) -> Run {
    let runtime = LlmRuntime::reference(ReferenceConfig {
        max_tokens: 128,
        ..ReferenceConfig::default()
    });
    let mut engine = Engine::new(
        runtime,
        EngineConfig {
            max_active,
            ..EngineConfig::default()
        },
    );
    for i in 0..N_REQUESTS {
        engine.submit(
            &format!("edge request {i}: report sensor status"),
            MAX_NEW,
            Sampling::Greedy,
        );
    }
    engine.run_all().expect("workload");
    let m = engine.metrics();
    Run {
        wall_tps: m.tokens_per_s(),
        sim_tps: m.sim_tokens_per_s(),
        rounds: m.rounds,
        peak: m.peak_active,
    }
}

/// Paged-KV serving record: a mixed-length workload through a pool
/// sized for 4 concurrent full-length sessions. Reports tokens/s, peak
/// resident KV bytes (sampled from the arena every round), block reuse,
/// and the per-request-allocation baseline the arena replaces.
fn kv_arena_record() -> Json {
    const MAX_TOKENS: usize = 128;
    const BLOCK_TOKENS: usize = 32;
    const POOL_SESSIONS: usize = 4;
    const REQUESTS: usize = 16;
    // short / medium / long / near-full generation budgets
    const LENGTHS: [usize; 4] = [8, 24, 64, 96];

    let blocks_per_session = MAX_TOKENS / BLOCK_TOKENS;
    let runtime = LlmRuntime::reference(ReferenceConfig {
        max_tokens: MAX_TOKENS,
        kv_block_tokens: BLOCK_TOKENS,
        kv_pool_blocks: POOL_SESSIONS * blocks_per_session,
        ..ReferenceConfig::default()
    });
    let info = runtime.info.clone();
    let mut engine = Engine::new(
        runtime,
        EngineConfig {
            max_active: 8, // cap only; the arena is the allocator
            ..EngineConfig::default()
        },
    );
    for i in 0..REQUESTS {
        engine.submit(
            &format!("kv arena request {i}"),
            LENGTHS[i % LENGTHS.len()],
            Sampling::Greedy,
        );
    }
    let t0 = Instant::now();
    let mut completed = 0usize;
    while engine.has_work() {
        completed += engine.step_round().expect("kv workload").len();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = engine.metrics().clone();
    let mem = engine.runtime().memory().expect("reference backend reports its arena");
    // the arena's own high-water mark — not a post-round sample, which
    // would miss blocks a retiring session released inside the round
    let peak_kv_bytes = mem.peak_reserved_bytes;
    assert_eq!(completed, REQUESTS, "every request must complete");
    assert!(mem.reuse_hits > 0, "the full pool must recycle blocks: {mem:?}");
    assert_eq!(m.preempted, 0, "admission accounting must prevent preemption");
    assert_eq!(mem.blocks_free, mem.blocks_total, "blocks leaked: {mem:?}");

    // pre-arena baseline: every session held L * max_tokens * d K+V f32
    // rows from admission to retirement, so peak bytes = peak concurrent
    // sessions * one full cache
    let full_session_bytes =
        (info.n_layers * info.max_tokens * info.n_kv_heads.max(1) * info.head_dim * 4 * 2) as u64;
    let baseline_peak = m.peak_active as u64 * full_session_bytes;

    println!(
        "kv arena: {REQUESTS} mixed-length requests, pool {} blocks x {BLOCK_TOKENS} tokens — \
         {:.0} tok/s, peak KV {} B vs per-request baseline {} B ({:.2}x), {} reuse hits",
        POOL_SESSIONS * blocks_per_session,
        m.tokens_per_s(),
        peak_kv_bytes,
        baseline_peak,
        baseline_peak as f64 / peak_kv_bytes.max(1) as f64,
        mem.reuse_hits
    );

    Json::obj(vec![
        ("bench", Json::Str("serving_kv_arena".into())),
        ("max_tokens", Json::Num(MAX_TOKENS as f64)),
        ("block_tokens", Json::Num(BLOCK_TOKENS as f64)),
        ("pool_blocks", Json::Num((POOL_SESSIONS * blocks_per_session) as f64)),
        ("requests", Json::Num(REQUESTS as f64)),
        (
            "request_lengths",
            Json::Arr(LENGTHS.iter().map(|&l| Json::Num(l as f64)).collect()),
        ),
        ("wall_s", Json::Num(wall_s)),
        ("tokens_per_s", Json::Num(m.tokens_per_s())),
        ("sim_tokens_per_s", Json::Num(m.sim_tokens_per_s())),
        ("decode_tokens", Json::Num(m.decode_tokens as f64)),
        ("peak_active", Json::Num(m.peak_active as f64)),
        ("peak_kv_bytes_arena", Json::Num(peak_kv_bytes as f64)),
        ("peak_kv_bytes_per_request_baseline", Json::Num(baseline_peak as f64)),
        (
            "baseline_over_arena",
            Json::Num(baseline_peak as f64 / peak_kv_bytes.max(1) as f64),
        ),
        ("kv_reuse_hits", Json::Num(mem.reuse_hits as f64)),
        ("preempted", Json::Num(m.preempted as f64)),
    ])
}

/// Repeated-prompt serving record: the same workload twice — once with
/// every request sharing one system prompt (the prefix index serves the
/// full-block prefix out of a single physical copy, copy-on-write on
/// the boundary block) and once with length-matched unique prompts
/// (nothing shareable). Reports mean time-to-first-token and peak
/// arena bytes per admission for both paths.
fn prefix_sharing_record() -> Json {
    const MAX_TOKENS: usize = 128;
    const BLOCK_TOKENS: usize = 16;
    const POOL_BLOCKS: usize = 64;
    const REQUESTS: usize = 8;
    const MAX_NEW: usize = 16;
    const PROMPT_CHARS: usize = 48; // exactly 3 full 16-token blocks

    // (mean ttft, peak arena bytes, prefix hits)
    let run = |prompts: Vec<String>| -> (f64, u64, u64) {
        let runtime = LlmRuntime::reference(ReferenceConfig {
            max_tokens: MAX_TOKENS,
            kv_block_tokens: BLOCK_TOKENS,
            kv_pool_blocks: POOL_BLOCKS,
            ..ReferenceConfig::default()
        });
        let mut engine = Engine::new(
            runtime,
            EngineConfig { max_active: REQUESTS, ..EngineConfig::default() },
        );
        for p in &prompts {
            assert_eq!(p.len(), PROMPT_CHARS, "workloads must be length-matched");
            engine.submit(p, MAX_NEW, Sampling::Greedy);
        }
        let done = engine.run_all().expect("prefix workload");
        assert_eq!(done.len(), REQUESTS, "every request must complete");
        assert_eq!(engine.metrics().preempted, 0);
        let ttft = done.iter().map(|c| c.first_token_s).sum::<f64>() / done.len() as f64;
        let mem = engine.runtime().memory().expect("reference backend reports its arena");
        assert_eq!(mem.blocks_free, mem.blocks_total, "blocks leaked: {mem:?}");
        (ttft, mem.peak_reserved_bytes, mem.prefix_hits)
    };

    let pad = |s: String| format!("{s:<PROMPT_CHARS$}");
    let (ttft_shared, peak_shared, hits_shared) =
        run(vec![pad("shared system preamble".into()); REQUESTS]);
    let (ttft_unique, peak_unique, hits_unique) =
        run((0..REQUESTS).map(|i| pad(format!("unique request {i}"))).collect());

    assert_eq!(
        hits_shared,
        (REQUESTS - 1) as u64,
        "every warm prefill must adopt the shared prefix"
    );
    assert_eq!(hits_unique, 0, "unique prompts must not share");
    assert!(
        peak_shared < peak_unique,
        "sharing must shrink peak residency: {peak_shared} vs {peak_unique}"
    );

    let per_adm = |peak: u64| peak as f64 / REQUESTS as f64;
    println!(
        "prefix sharing: {REQUESTS} x {PROMPT_CHARS}-token repeated prompt — \
         ttft {:.2} ms vs {:.2} ms unique, {:.0} B/admission vs {:.0} B \
         ({:.2}x), {hits_shared} prefix hits",
        ttft_shared * 1e3,
        ttft_unique * 1e3,
        per_adm(peak_shared),
        per_adm(peak_unique),
        per_adm(peak_unique) / per_adm(peak_shared).max(1.0),
    );

    Json::obj(vec![
        ("bench", Json::Str("serving_kv_prefix_sharing".into())),
        ("requests", Json::Num(REQUESTS as f64)),
        ("prompt_tokens", Json::Num(PROMPT_CHARS as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("block_tokens", Json::Num(BLOCK_TOKENS as f64)),
        ("pool_blocks", Json::Num(POOL_BLOCKS as f64)),
        ("mean_ttft_s_shared", Json::Num(ttft_shared)),
        ("mean_ttft_s_unique", Json::Num(ttft_unique)),
        ("peak_kv_bytes_shared", Json::Num(peak_shared as f64)),
        ("peak_kv_bytes_unique", Json::Num(peak_unique as f64)),
        ("bytes_per_admission_shared", Json::Num(per_adm(peak_shared))),
        ("bytes_per_admission_unique", Json::Num(per_adm(peak_unique))),
        ("prefix_hits", Json::Num(hits_shared as f64)),
    ])
}

fn main() {
    println!(
        "== serving throughput: {N_REQUESTS} requests x {MAX_NEW} new tokens, \
         continuous batching =="
    );
    let mut t = Table::new(&[
        "max_active",
        "rounds",
        "peak live",
        "wall tok/s",
        "sim VCU128 tok/s",
        "sim speedup",
    ]);
    let mut batch1_sim = 0.0;
    let mut batch8 = None;
    for max_active in [1usize, 2, 4, 8, 16] {
        let r = run_workload(max_active);
        if max_active == 1 {
            batch1_sim = r.sim_tps;
        }
        if max_active == 8 {
            batch8 = Some(r.sim_tps);
        }
        t.rowv(vec![
            max_active.to_string(),
            r.rounds.to_string(),
            r.peak.to_string(),
            format!("{:.0}", r.wall_tps),
            format!("{:.1}", r.sim_tps),
            format!("{:.2}x", r.sim_tps / batch1_sim),
        ]);
    }
    t.print();
    let batch8 = batch8.expect("batch-8 run");
    println!(
        "batch 8 vs batch 1 (simulated aggregate): {:.1} vs {:.1} tok/s ({:.2}x)",
        batch8,
        batch1_sim,
        batch8 / batch1_sim
    );
    assert!(
        batch8 > batch1_sim,
        "continuous batching must raise aggregate throughput"
    );
    println!("note: wall tok/s is the functional reference backend (tiny model, \
              truly batched decode since PR 2 — benches/backend_throughput.rs \
              measures it on a cache-overflowing model); the VCU128 column \
              models the shared weight stream of the accelerator datapath.");

    // paged-KV arena record (mixed lengths, memory-aware admission),
    // with the repeated-prompt prefix-sharing workload nested alongside
    let mut kv = kv_arena_record();
    let sharing = prefix_sharing_record();
    if let Json::Obj(m) = &mut kv {
        m.insert("prefix_sharing".to_string(), sharing);
    }
    std::fs::write("BENCH_kv.json", format!("{kv}\n")).expect("write BENCH_kv.json");
    println!("wrote BENCH_kv.json");
}
