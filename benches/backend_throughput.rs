//! Backend throughput: prefill and batched-decode tokens/s of the
//! functional reference backend, at batch 1 / 4 / 8, across the kernel
//! tier matrix — scalar oracle, SIMD, SIMD+multicore — and the repo's
//! recorded perf trajectory.
//!
//! The model is sized so its weights (~80 MB dense f32 attention +
//! nibble-packed INT4 FFN) overflow every cache level: batch-1 decode is
//! then genuinely bound by streaming the weights (plus the per-row
//! nibble decode), which is exactly the cost a batched round amortizes —
//! each weight matrix is walked once per round regardless of batch size.
//! Two headline numbers come out: aggregate tokens/s at batch 8 versus
//! the batch-1 scalar path (batching amortization), and the
//! simd-parallel tier versus the scalar tier at batch 8 (the hardware
//! tier speedup — every tier produces bit-identical logits, so this is
//! pure speed). Both are written, machine-readable, to
//! `BENCH_backend.json` so CI can archive the trajectory; committed
//! snapshots live under `benchmarks/`.
//!
//! `cargo bench --bench backend_throughput`

use std::time::Instant;

use edgellm::runtime::model::{LlmRuntime, Session};
use edgellm::runtime::reference::{KernelTier, ReferenceConfig};
use edgellm::util::bench::{fmt_secs, Table};
use edgellm::util::json::Json;

/// Prompt length fed to every session (fits the 64-token bucket).
const PROMPT_LEN: usize = 64;
/// Decode rounds measured per sample.
const ROUNDS: usize = 48;
/// Measured samples per batch size (plus one warmup).
const SAMPLES: usize = 3;
const BATCHES: [usize; 3] = [1, 4, 8];
/// The tier matrix: the scalar oracle, single-threaded SIMD, and the
/// pool-parallel tier at auto-detected width.
const TIERS: [KernelTier; 3] = [KernelTier::Scalar, KernelTier::Simd, KernelTier::SimdParallel];

fn bench_cfg(tier: KernelTier) -> ReferenceConfig {
    ReferenceConfig {
        name: "ref-bench".to_string(),
        d_model: 640,
        n_layers: 10,
        n_heads: 8,
        max_tokens: 128,
        seed: 0xB0BA,
        kernel_tier: tier,
        ..ReferenceConfig::default()
    }
}

fn prompt(session: usize) -> Vec<i32> {
    (0..PROMPT_LEN)
        .map(|i| ((i * 31 + session * 67 + 5) % 256) as i32)
        .collect()
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Aggregate decode tokens/s over `ROUNDS` batched rounds at batch `b`.
/// Batch 1 *is* the scalar path (`decode` delegates to a batch of one).
///
/// Each sample prefills fresh sessions and retires them afterwards
/// (`end_session` returns their arena blocks, so every sample after the
/// first decodes on *recycled* KV blocks — the serving steady state).
/// Prefill and retirement sit outside the timed region.
fn decode_tps(rt: &LlmRuntime, b: usize) -> (f64, f64) {
    let mut times = Vec::new();
    for sample in 0..SAMPLES + 1 {
        let mut sessions: Vec<Session> =
            (0..b).map(|s| rt.prefill(&prompt(s)).expect("prefill").1).collect();
        let t0 = Instant::now();
        for round in 0..ROUNDS {
            let tokens: Vec<i32> =
                (0..b).map(|s| ((round * 13 + s * 7) % 256) as i32).collect();
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            let logits = rt.decode_batch(&mut refs, &tokens).expect("decode round");
            std::hint::black_box(&logits);
        }
        if sample > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
        for s in sessions.iter_mut() {
            rt.end_session(s);
        }
    }
    let t = median(times);
    let tokens = (b * ROUNDS) as f64;
    (tokens / t, t / ROUNDS as f64)
}

/// Everything measured for one kernel tier.
struct TierResult {
    label: String,
    prefill_s: f64,
    prefill_tps: f64,
    /// (batch, aggregate tok/s, round latency) per batch size
    decode: Vec<(usize, f64, f64)>,
    /// batch-8 aggregate tok/s vs batch 1 within this tier
    batch_speedup: f64,
}

fn bench_tier(tier: KernelTier) -> TierResult {
    let rt = LlmRuntime::reference(bench_cfg(tier));
    let label = rt.kernel_tier().unwrap_or_else(|| "unknown".to_string());
    println!("-- tier {label} --");

    // prefill: single-pass sequence-level GEMM, measured per prompt
    let mut prefill_times = Vec::new();
    for sample in 0..SAMPLES + 1 {
        let t0 = Instant::now();
        let (logits, mut session) = rt.prefill(&prompt(sample)).expect("prefill");
        std::hint::black_box((&logits, &session));
        if sample > 0 {
            prefill_times.push(t0.elapsed().as_secs_f64());
        }
        rt.end_session(&mut session); // return the arena blocks
    }
    let prefill_s = median(prefill_times);
    let prefill_tps = PROMPT_LEN as f64 / prefill_s;

    let mut table = Table::new(&["batch", "round latency", "aggregate tok/s", "vs batch 1"]);
    let mut decode = Vec::new();
    let mut tps1 = 0.0;
    for &b in &BATCHES {
        let (tps, round_s) = decode_tps(&rt, b);
        if b == 1 {
            tps1 = tps;
        }
        table.rowv(vec![
            b.to_string(),
            fmt_secs(round_s),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / tps1),
        ]);
        decode.push((b, tps, round_s));
    }
    table.print();
    println!(
        "prefill: {} / prompt ({prefill_tps:.0} tok/s single-pass GEMM)",
        fmt_secs(prefill_s)
    );
    let batch_speedup = decode
        .iter()
        .find(|(b, _, _)| *b == 8)
        .map(|(_, tps, _)| tps / tps1)
        .expect("batch-8 row");
    TierResult { label, prefill_s, prefill_tps, decode, batch_speedup }
}

fn batch8_tps(t: &TierResult) -> f64 {
    t.decode
        .iter()
        .find(|(b, _, _)| *b == 8)
        .map(|(_, tps, _)| *tps)
        .expect("batch-8 row")
}

fn main() {
    let cfg = bench_cfg(KernelTier::Scalar);
    println!(
        "== backend throughput: d={} L={} ffn={} (INT4), prompt {PROMPT_LEN}, \
         {ROUNDS} rounds, tier matrix ==",
        cfg.d_model,
        cfg.n_layers,
        4 * cfg.d_model
    );
    let build0 = Instant::now();
    let rt = LlmRuntime::reference(cfg);
    println!(
        "model built in {} ({} params)",
        fmt_secs(build0.elapsed().as_secs_f64()),
        rt.info.n_params
    );
    let model_json = Json::obj(vec![
        ("name", Json::Str(rt.info.name.clone())),
        ("d_model", Json::Num(rt.info.d_model as f64)),
        ("n_layers", Json::Num(rt.info.n_layers as f64)),
        ("d_ffn", Json::Num(rt.info.d_ffn as f64)),
        ("vocab", Json::Num(rt.info.vocab as f64)),
        ("n_params", Json::Num(rt.info.n_params as f64)),
        (
            "ffn_weight_bytes",
            Json::Num(rt.ffn_weight_bytes().unwrap_or(0) as f64),
        ),
    ]);
    drop(rt); // each tier builds its own runtime (same seed → same weights)

    let results: Vec<TierResult> = TIERS.iter().map(|&t| bench_tier(t)).collect();
    let scalar = &results[0];
    let parallel = results.last().expect("tier matrix is non-empty");
    let tier_speedup = batch8_tps(parallel) / batch8_tps(scalar);
    println!(
        "{} vs scalar at batch 8: {tier_speedup:.2}x aggregate tokens/s",
        parallel.label
    );
    println!(
        "batch 8 vs batch-1 within {}: {:.2}x",
        parallel.label, parallel.batch_speedup
    );

    // machine-readable trajectory record: the whole tier × batch matrix
    // in one JSON, so the committed snapshots under benchmarks/ carry
    // the scalar baseline and the vector tiers side by side
    let json = Json::obj(vec![
        ("bench", Json::Str("backend_throughput".into())),
        ("model", model_json),
        ("prompt_len", Json::Num(PROMPT_LEN as f64)),
        ("rounds", Json::Num(ROUNDS as f64)),
        (
            "tiers",
            Json::Arr(
                results
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("tier", Json::Str(t.label.clone())),
                            (
                                "prefill",
                                Json::obj(vec![
                                    ("latency_s", Json::Num(t.prefill_s)),
                                    ("tokens_per_s", Json::Num(t.prefill_tps)),
                                ]),
                            ),
                            (
                                "decode",
                                Json::Arr(
                                    t.decode
                                        .iter()
                                        .map(|&(b, tps, round_s)| {
                                            Json::obj(vec![
                                                ("batch", Json::Num(b as f64)),
                                                ("tokens_per_s", Json::Num(tps)),
                                                ("round_latency_s", Json::Num(round_s)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                            ("speedup_batch8_vs_batch1", Json::Num(t.batch_speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_batch8_vs_batch1", Json::Num(parallel.batch_speedup)),
        (
            "speedup_simd_parallel_vs_scalar_batch8",
            Json::Num(tier_speedup),
        ),
    ]);
    std::fs::write("BENCH_backend.json", format!("{json}\n")).expect("write BENCH_backend.json");
    println!("wrote BENCH_backend.json");

    // smoke floors only — the real numbers live in the JSON record; a
    // contended runner must not turn a load dip into a red build. The
    // ≥2x tier-speedup acceptance target is read off the committed
    // snapshot from the multi-core CI runner, not asserted here (a
    // single-core box legitimately reports ~1x).
    assert!(
        parallel.batch_speedup > 1.0,
        "batched decode must amortize the weight stream (got {:.2}x)",
        parallel.batch_speedup
    );
    assert!(
        tier_speedup > 0.5,
        "the vector tier must not be materially slower than scalar (got {tier_speedup:.2}x)"
    );
}
