//! Bridge overhead: the same reference backend driven in-process vs
//! through a loopback device daemon, plus transport bytes/token from
//! the bridge's `TransferMeter` — tokens/s *and* transport traffic,
//! the way the paper reports decode speed next to HBM bandwidth
//! utilization.
//!
//! The model is kept small on purpose: a small model makes compute
//! cheap, so the measured gap is an *upper bound* on the bridge's
//! per-call cost (a production-size model amortizes the same frames
//! over far more FLOPs). Correctness is asserted bitwise — the bridged
//! logits must equal the in-process logits — so the record never
//! reports the speed of a wrong answer.
//!
//! Writes `BENCH_bridge.json` (per-batch tok/s for both paths, the
//! overhead ratio, and tx/rx bytes per token); CI archives it next to
//! `BENCH_backend.json`.
//!
//! `cargo bench --bench bridge_overhead`

use std::net::TcpListener;
use std::time::Instant;

use edgellm::bridge::client::BridgeBackend;
use edgellm::bridge::device::{self, DeviceConfig};
use edgellm::runtime::backend::ReferenceBackend;
use edgellm::runtime::model::{LlmRuntime, Session};
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::util::bench::{fmt_secs, Table};
use edgellm::util::json::Json;

const PROMPT_LEN: usize = 32;
const ROUNDS: usize = 64;
/// measured samples per configuration (plus one warmup)
const SAMPLES: usize = 3;
const BATCHES: [usize; 2] = [1, 4];

fn bench_cfg() -> ReferenceConfig {
    ReferenceConfig {
        name: "ref-bridge-bench".to_string(),
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        max_tokens: 128,
        seed: 0xB71D6E,
        ..ReferenceConfig::default()
    }
}

fn prompt(lane: usize) -> Vec<i32> {
    (0..PROMPT_LEN)
        .map(|i| ((i * 31 + lane * 67 + 5) % 256) as i32)
        .collect()
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Aggregate decode tokens/s over `ROUNDS` batched rounds at batch `b`
/// (backend_throughput methodology, generic over the runtime so both
/// paths run the exact same loop).
///
/// Each sample prefills *fresh* sessions and retires them afterwards —
/// a bridged session's KV state lives on the device (and an in-process
/// session's in the backend's arena), so sessions are not cloneable
/// resets. Prefill and retirement sit outside the timed region.
fn decode_tps(rt: &LlmRuntime, b: usize) -> (f64, f64) {
    let mut times = Vec::new();
    for sample in 0..SAMPLES + 1 {
        let mut sessions: Vec<Session> =
            (0..b).map(|s| rt.prefill(&prompt(s)).expect("prefill").1).collect();
        let t0 = Instant::now();
        for round in 0..ROUNDS {
            let tokens: Vec<i32> =
                (0..b).map(|s| ((round * 13 + s * 7) % 256) as i32).collect();
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            let logits = rt.decode_batch(&mut refs, &tokens).expect("decode round");
            std::hint::black_box(&logits);
        }
        if sample > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
        for s in sessions.iter_mut() {
            rt.end_session(s); // frees the device-side session eagerly
        }
    }
    let t = median(times);
    ((b * ROUNDS) as f64 / t, t / ROUNDS as f64)
}

fn main() {
    let cfg = bench_cfg();
    println!(
        "== bridge overhead: d={} L={} prompt {PROMPT_LEN}, {ROUNDS} rounds, \
         loopback daemon ==",
        cfg.d_model, cfg.n_layers
    );

    // in-process path and the daemon host the *same* weights (same seed)
    let local = LlmRuntime::reference(cfg.clone());
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let dev = device::spawn_on(
        Box::new(ReferenceBackend::new(cfg)),
        listener,
        DeviceConfig::default(),
    )
    .expect("spawn device daemon");
    let bridged = LlmRuntime::from_backend(Box::new(
        BridgeBackend::connect(&dev.addr().to_string()).expect("connect bridge"),
    ));

    // correctness gate: never benchmark a wrong answer
    let (ll, mut sl) = local.prefill(&prompt(0)).expect("local prefill");
    let (lb, mut sb) = bridged.prefill(&prompt(0)).expect("bridged prefill");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&ll), bits(&lb), "bridged logits must be bit-identical");
    local.end_session(&mut sl);
    bridged.end_session(&mut sb);

    // prefill latency, both paths (sessions retired outside the timer)
    let prefill_s = |rt: &LlmRuntime| {
        let mut times = Vec::new();
        for sample in 0..SAMPLES + 1 {
            let t0 = Instant::now();
            let (logits, mut s) = rt.prefill(&prompt(sample)).expect("prefill");
            std::hint::black_box(&logits);
            if sample > 0 {
                times.push(t0.elapsed().as_secs_f64());
            }
            rt.end_session(&mut s);
        }
        median(times)
    };
    let pre_local = prefill_s(&local);
    let pre_bridged = prefill_s(&bridged);

    let mut table = Table::new(&[
        "batch",
        "in-process tok/s",
        "bridged tok/s",
        "bridge/in-proc",
        "tx B/tok",
        "rx B/tok",
    ]);
    let mut rows = Vec::new();
    for &b in &BATCHES {
        let (tps_local, _) = decode_tps(&local, b);
        let m0 = bridged.transfer_meter().expect("bridge meters transfers");
        let (tps_bridged, round_s) = decode_tps(&bridged, b);
        let m1 = bridged.transfer_meter().expect("bridge meters transfers");
        // bytes across every round of this batch size (warmup and the
        // per-sample prefill/close frames included — a few % of the
        // decode traffic at these settings)
        let tokens = ((SAMPLES + 1) * ROUNDS * b) as f64;
        let tx_per_tok = (m1.tx_bytes - m0.tx_bytes) as f64 / tokens;
        let rx_per_tok = (m1.rx_bytes - m0.rx_bytes) as f64 / tokens;
        table.rowv(vec![
            b.to_string(),
            format!("{tps_local:.1}"),
            format!("{tps_bridged:.1}"),
            format!("{:.2}x", tps_bridged / tps_local),
            format!("{tx_per_tok:.1}"),
            format!("{rx_per_tok:.1}"),
        ]);
        rows.push((b, tps_local, tps_bridged, round_s, tx_per_tok, rx_per_tok));
    }
    table.print();
    println!(
        "prefill: {} in-process, {} bridged",
        fmt_secs(pre_local),
        fmt_secs(pre_bridged)
    );
    let meter = bridged.transfer_meter().expect("meter");
    println!(
        "transport total: {} B up, {} B down over {} calls",
        meter.tx_bytes, meter.rx_bytes, meter.calls
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("bridge_overhead".into())),
        (
            "model",
            Json::obj(vec![
                ("name", Json::Str(local.info.name.clone())),
                ("d_model", Json::Num(local.info.d_model as f64)),
                ("n_layers", Json::Num(local.info.n_layers as f64)),
                ("vocab", Json::Num(local.info.vocab as f64)),
            ]),
        ),
        ("prompt_len", Json::Num(PROMPT_LEN as f64)),
        ("rounds", Json::Num(ROUNDS as f64)),
        (
            "prefill",
            Json::obj(vec![
                ("in_process_s", Json::Num(pre_local)),
                ("bridged_s", Json::Num(pre_bridged)),
            ]),
        ),
        (
            "decode",
            Json::Arr(
                rows.iter()
                    .map(|&(b, tl, tb, round_s, tx, rx)| {
                        Json::obj(vec![
                            ("batch", Json::Num(b as f64)),
                            ("in_process_tokens_per_s", Json::Num(tl)),
                            ("bridged_tokens_per_s", Json::Num(tb)),
                            ("bridged_round_latency_s", Json::Num(round_s)),
                            ("overhead_ratio", Json::Num(tb / tl)),
                            ("tx_bytes_per_token", Json::Num(tx)),
                            ("rx_bytes_per_token", Json::Num(rx)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "transport_total",
            Json::obj(vec![
                ("tx_bytes", Json::Num(meter.tx_bytes as f64)),
                ("rx_bytes", Json::Num(meter.rx_bytes as f64)),
                ("calls", Json::Num(meter.calls as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_bridge.json", format!("{json}\n")).expect("write BENCH_bridge.json");
    println!("wrote BENCH_bridge.json");

    // smoke floors only — loopback latency on a contended runner must
    // not turn a load dip into a red build
    for &(b, _tl, tb, _r, tx, rx) in &rows {
        assert!(tb > 0.0, "bridged decode at batch {b} must make progress");
        // every decoded token moved at least its logits row back
        assert!(rx >= (local.info.vocab * 4) as f64, "rx {rx} B/tok at batch {b}");
        assert!(tx > 0.0);
    }
    // every session the bench opened was retired over the wire; closes
    // are pipelined, so one stats round trip flushes the stragglers and
    // proves (by reply ordering) they were applied
    let _ = bridged.memory();
    assert_eq!(dev.active_sessions(), 0, "bench leaked device sessions");
    dev.shutdown();
}
