//! Regenerates **Table I**: error rate + PPA of the mix-precision
//! computing unit vs baseline-1 (FP16 adder tree) and baseline-2 (FP20).
//!
//! `cargo bench --bench table1_pe_accuracy [-- --trials 100000]`

use edgellm::fp::error::{error_rate, Design, Mode};
use edgellm::fp::mixpe::PAPER_PE;
use edgellm::fp::ppa::estimate;
use edgellm::util::bench::Table;
use edgellm::util::Args;

fn main() {
    let args = Args::from_iter(std::env::args().skip(1).filter(|a| a != "--bench"));
    let trials = args.get_usize("trials", 100_000);
    let seed = 42;

    println!("== Table I: computation error rate ({trials} random trials) ==");
    let mut t = Table::new(&[
        "design", "FP16*INT4 (ours)", "paper", "FP16*FP16 (ours)", "paper",
    ]);
    let paper = [
        ("this work", "0.0472%", "0.0044%"),
        ("baseline-1 (FP16 tree)", "2.864%", "14.470%"),
        ("baseline-2 (FP20 tree)", "2.644%", "0.020%"),
    ];
    for (design, (name, p_i4, p_ff)) in [
        Design::MixPe,
        Design::B1Fp16Tree,
        Design::B2Fp20Tree,
    ]
    .iter()
    .zip(paper)
    {
        let e_i4 = error_rate(*design, Mode::Fp16Int4, &PAPER_PE, trials, seed);
        let e_ff = error_rate(*design, Mode::Fp16Fp16, &PAPER_PE, trials, seed + 1);
        t.rowv(vec![
            name.to_string(),
            format!("{e_i4:.4}%"),
            p_i4.to_string(),
            format!("{e_ff:.4}%"),
            p_ff.to_string(),
        ]);
    }
    t.print();
    println!(
        "shape check: ours < both baselines in both modes (paper's ordering).\n\
         absolute %s differ from the paper's unpublished input distribution; see\n\
         rust/src/fp/error.rs for the metric definition.\n"
    );

    println!("== Table I: PPA (structural model calibrated to this work) ==");
    let mut t2 = Table::new(&[
        "design", "area um^2 (ours)", "paper", "power mW", "paper", "fmax GHz", "paper", "LUT", "paper",
    ]);
    let paper_ppa = [
        ("this work", "71664", "50.7", "1.11", "24714"),
        ("baseline-1 (FP16 tree)", "107437", "49.7", "1.03", "30485"),
        ("baseline-2 (FP20 tree)", "140677", "59.5", "1.06", "45190"),
    ];
    for (key, (name, a, p, f, l)) in ["this_work", "baseline1", "baseline2"]
        .iter()
        .zip(paper_ppa)
    {
        let e = estimate(key);
        t2.rowv(vec![
            name.to_string(),
            format!("{:.0}", e.area_um2),
            a.to_string(),
            format!("{:.1}", e.power_mw),
            p.to_string(),
            format!("{:.2}", e.freq_ghz),
            f.to_string(),
            format!("{:.0}", e.luts),
            l.to_string(),
        ]);
    }
    t2.print();
    println!("(paper power column = sum of its two mode powers; ASIC 28nm flow)");
}
