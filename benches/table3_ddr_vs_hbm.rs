//! Regenerates **Table III**: per-step latency of the dense GLM block,
//! HBM vs DDR, decode and prefill at token=128.
//!
//! `cargo bench --bench table3_ddr_vs_hbm`

use edgellm::models::{DENSE, GLM_6B};
use edgellm::sim::engine::Simulator;
use edgellm::sim::Memory;
use edgellm::util::bench::Table;

// Paper Table III (µs): (step, decode HBM, decode DDR, prefill HBM, prefill DDR)
const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    ("LayerNorm", 9.55, 15.84, 533.35, 694.86),
    ("VMM-BN(Q)", 47.12, 181.66, 4770.07, 7840.94),
    ("EMB_Q", 7.79, 13.70, 274.29, 351.03),
    ("VMM-BN(K)", 2.15, 12.61, 476.38, 649.70),
    ("EMB_K", 0.44, 1.57, 24.99, 33.15),
    ("DAT2HBM", 0.23, 1.63, 70.42, 36.46),
    ("TRP", 5.83, 10.06, 672.66, 837.16),
    ("SOFTMAX", 43.38, 48.68, 872.54, 1048.91),
    ("VMM-BN(V)", 1.97, 10.72, 475.36, 650.17),
    ("DAT2HBM", 0.29, 2.23, 69.95, 35.44),
    ("F2W", 5.73, 9.64, 614.95, 837.49),
    ("VMMBNRES0", 48.34, 177.30, 4725.42, 7845.11),
    ("LayerNorm", 9.52, 14.48, 533.76, 694.53),
    ("VMMBN1", 137.98, 596.56, 16063.43, 26306.36),
    ("ACT", 15.36, 33.83, 890.43, 1142.23),
    ("VMMBNRES1", 143.98, 594.59, 16007.04, 26319.11),
    ("VMMBNRES2", 191.41, 707.03, 23429.09, 75931.96),
];

fn main() {
    let hbm = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
    let ddr = Simulator::new(&GLM_6B, &DENSE, Memory::Ddr);

    println!("== Table III: dense GLM step latencies (µs), token=128 ==");
    let mut t = Table::new(&[
        "step", "dec HBM", "paper", "dec DDR", "paper", "pre HBM", "paper", "pre DDR", "paper",
    ]);
    let dec_h = hbm.decode_step(128);
    let dec_d = ddr.decode_step(128);
    let pre_h = hbm.prefill(128);
    let pre_d = ddr.prefill(128);
    for (i, (name, us)) in dec_h.block_steps.iter().take(17).enumerate() {
        let paper = PAPER.get(i);
        t.rowv(vec![
            format!("{} {}", i + 1, name),
            format!("{us:.2}"),
            paper.map(|p| format!("{:.2}", p.1)).unwrap_or_default(),
            format!("{:.2}", dec_d.block_steps[i].1),
            paper.map(|p| format!("{:.2}", p.2)).unwrap_or_default(),
            format!("{:.2}", pre_h.block_steps[i].1),
            paper.map(|p| format!("{:.2}", p.3)).unwrap_or_default(),
            format!("{:.2}", pre_d.block_steps[i].1),
            paper.map(|p| format!("{:.2}", p.4)).unwrap_or_default(),
        ]);
    }
    t.print();

    println!("\n== summary ==");
    let mut t2 = Table::new(&["metric", "ours", "paper"]);
    let block_h: f64 = dec_h.block_steps.iter().take(17).map(|(_, u)| u).sum();
    let block_d: f64 = dec_d.block_steps.iter().take(17).map(|(_, u)| u).sum();
    t2.rowv(vec!["decode block HBM (µs)".into(), format!("{block_h:.1}"), "674.83".into()]);
    t2.rowv(vec!["decode block DDR (µs)".into(), format!("{block_d:.1}"), "2432.12".into()]);
    t2.rowv(vec![
        "decode total HBM (ms)".into(),
        format!("{:.2}", dec_h.breakdown.total_us() / 1e3),
        "19.45".into(),
    ]);
    t2.rowv(vec![
        "decode total DDR (ms)".into(),
        format!("{:.2}", dec_d.breakdown.total_us() / 1e3),
        "70.87".into(),
    ]);
    t2.rowv(vec![
        "prefill total HBM (ms)".into(),
        format!("{:.1}", pre_h.breakdown.total_us() / 1e3),
        "1974.8 (28 blocks)".into(),
    ]);
    t2.rowv(vec![
        "decode speed HBM (tok/s)".into(),
        format!("{:.2}", 1e6 / dec_h.breakdown.total_us()),
        "51.42".into(),
    ]);
    t2.rowv(vec![
        "decode speed DDR (tok/s)".into(),
        format!("{:.2}", 1e6 / dec_d.breakdown.total_us()),
        "14.11".into(),
    ]);
    t2.print();
}
