//! Regenerates **Table V**: platform comparison — A100 GPU, FlightLLM
//! (U280/VHK158), EdgeLLM on GLM-6B and Qwen-7B.
//!
//! `cargo bench --bench table5_platforms`

use edgellm::baselines::{a100_batch1, FLIGHTLLM_U280, FLIGHTLLM_VHK158};
use edgellm::models::{GLM_6B, QWEN_7B, STRATEGY_3};
use edgellm::sim::engine::Simulator;
use edgellm::sim::power::decode_energy;
use edgellm::sim::Memory;
use edgellm::util::bench::Table;

fn main() {
    println!("== Table V: efficiency comparison on different platforms ==");
    let mut t = Table::new(&[
        "platform", "BW util", "decode tok/s", "power W", "token/J",
    ]);

    let a100 = a100_batch1(&GLM_6B);
    t.rowv(vec![
        format!("{} (batch=1)", a100.name),
        format!("~{:.0}%", a100.bandwidth_utilization * 100.0),
        format!("{:.0}", a100.tokens_per_s),
        format!("{:.0}", a100.power_w),
        format!("{:.2}", a100.tokens_per_joule()),
    ]);
    for p in [&FLIGHTLLM_U280, &FLIGHTLLM_VHK158] {
        t.rowv(vec![
            p.name.to_string(),
            format!("{:.1}%", p.bandwidth_utilization * 100.0),
            format!("{:.0}", p.tokens_per_s),
            format!("{:.0}", p.power_w),
            format!("{:.2}", p.tokens_per_joule()),
        ]);
    }
    for arch in [&GLM_6B, &QWEN_7B] {
        let sim = Simulator::new(arch, &STRATEGY_3, Memory::Hbm);
        let tps = sim.decode_tokens_per_s(128);
        let e = decode_energy(&sim, 128);
        t.rowv(vec![
            format!("EdgeLLM VCU128 ({})", arch.name),
            format!("{:.0}%", sim.hw.hbm_utilization * 100.0),
            format!("{tps:.1}"),
            format!("{:.1}", e.avg_power_w),
            format!("{:.2}", 1.0 / e.energy_j),
        ]);
    }
    t.print();

    println!("\npaper row: EdgeLLM ~75% util, 85.8/69.4 tok/s, 56.8 W, 1.51/1.23 tok/J");
    let glm = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
    let e = decode_energy(&glm, 128);
    let ours_tps = glm.decode_tokens_per_s(128);
    let ours_tpj = 1.0 / e.energy_j;
    println!("\n== headline claims ==");
    println!(
        "throughput vs A100 (batch=1): {:.2}x (paper: 1.91x)",
        ours_tps / a100.tokens_per_s
    );
    println!(
        "energy efficiency vs A100:    {:.2}x (paper: 7.55x)",
        ours_tpj / a100.tokens_per_joule()
    );
    println!(
        "energy efficiency vs FlightLLM U280: {:.2}x (paper: up to 1.24x)",
        ours_tpj / FLIGHTLLM_U280.tokens_per_joule()
    );
    println!(
        "bandwidth utilization vs FlightLLM: {:.0}% vs 65.9% (paper: +11%)",
        glm.hw.hbm_utilization * 100.0
    );
}
