//! Regenerates **Table II**: GLM-6B per-matrix weight budgets and
//! speedups under the three sparse strategies, plus the quality proxy
//! (output drift of the functionally-sparsified tiny model).
//!
//! `cargo bench --bench table2_sparse_strategies`

use edgellm::models::{self, SparseStrategy, GLM_6B};
use edgellm::pack::matrix_bytes;
use edgellm::util::bench::Table;


/// §III.C ablation: block size of the sparsity pattern at fixed 50%
/// sparsity — the paper's "our sparse blocks are larger … better
/// performance at the algorithmic level" argument vs GPU 2:4.
fn nm_ablation() {
    use edgellm::quant::nm::{
        mask_bits_per_channel_indexed, mask_bits_per_channel_one_hot,
        reconstruction_error,
    };
    println!("\n== ablation: N:M pattern window size (50% sparsity) ==");
    let mut t = Table::new(&[
        "pattern", "recon error", "mask bits/ch (one-hot)", "mask bits/ch (indexed)",
    ]);
    for (keep, m, label) in [
        (2usize, 4usize, "2:4 (GPU A100)"),
        (4, 8, "4:8 (EdgeLLM)"),
        (8, 16, "8:16 (EdgeLLM)"),
        (32, 64, "32:64 (EdgeLLM)"),
    ] {
        let e = reconstruction_error(keep, m, 4096, 64, 77);
        t.rowv(vec![
            label.to_string(),
            format!("{:.4}", e),
            format!("{:.2}", mask_bits_per_channel_one_hot(keep, m)),
            format!("{:.2}", mask_bits_per_channel_indexed(keep, m)),
        ]);
    }
    t.print();
    println!("larger windows discard less signal at the same kept fraction \u{2713}");
}

fn main() {
    nm_ablation();
    println!("== Table II: GLM-6B weight budget per block ==");
    let strategies = SparseStrategy::all();
    let mut t = Table::new(&["matrix", "dense", "strategy-1", "strategy-2", "strategy-3"]);
    let mb = |b: usize| format!("{:.2} MB", b as f64 / (1024.0 * 1024.0));
    for (name, k, n) in GLM_6B.block_matrices() {
        let mut row = vec![name.to_string()];
        for s in &strategies {
            let sp = s.for_matrix(name);
            let label = if sp == edgellm::quant::Sparsity::Dense {
                format!("dense, {}", mb(matrix_bytes(k, n, sp)))
            } else {
                format!("{:.0}% sparse, {}", sp.percent(), mb(matrix_bytes(k, n, sp)))
            };
            row.push(label);
        }
        t.rowv(row);
    }
    t.print();

    let mut t2 = Table::new(&["", "dense", "strategy-1", "strategy-2", "strategy-3"]);
    let mut totals = vec!["total wt in a Block".to_string()];
    let mut speeds = vec!["speedup".to_string()];
    for s in &strategies {
        totals.push(mb(models::block_weight_bytes(&GLM_6B, s)));
        speeds.push(format!("{:.2}x", models::strategy_speedup(&GLM_6B, s)));
    }
    t2.rowv(totals);
    t2.rowv(speeds);
    t2.print();
    println!(
        "paper: 100.33 / 79.22 / 61.50 / 53.15 MB; speedups 1x / 1.27x / 1.63x / 1.89x\n"
    );

    println!("== Table II (bottom): algorithm quality under sparsity ==");
    println!(
        "paper (GLM-6B): WikiText-2 perplexity 29.92 -> 38.54 -> 59.24 -> 120.87;\n\
         avg zero-shot accuracy 59.6 -> 56.6 -> 54.8 -> 48.0 (monotone degradation).\n\
         We cannot re-evaluate GLM-6B (no checkpoint); the functional proxy —\n\
         logit drift of the tiny model under the same pruning recipe — is\n\
         asserted monotone in python/tests/test_model.py::\n\
         test_sparsity_degrades_quality_monotonically and measured by\n\
         `cargo run --release --example sparsity_explorer`."
    );
}
