//! Regenerates **Fig. 11**: dense GLM-6B — (a) decode speed vs generated
//! tokens, (b) latency breakdown MHA/FFN/other, (c,d) prefill runtime.
//!
//! `cargo bench --bench fig11_dense_glm`

use edgellm::models::{DENSE, GLM_6B};
use edgellm::sim::engine::Simulator;
use edgellm::sim::Memory;
use edgellm::util::bench::Table;

fn main() {
    let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);

    println!("== Fig. 11(a): decode speed vs context length ==");
    let mut t = Table::new(&["ctx tokens", "tok/s", "step ms"]);
    for ctx in [16usize, 64, 128, 256, 512, 1024, 1536, 2048] {
        let us = sim.decode_step(ctx).breakdown.total_us();
        t.rowv(vec![
            ctx.to_string(),
            format!("{:.1}", 1e6 / us),
            format!("{:.2}", us / 1e3),
        ]);
    }
    t.print();
    println!("paper shape: ~stable below 512 tokens, degrading after\n");

    println!("== Fig. 11(b): decode latency breakdown ==");
    let mut t2 = Table::new(&["ctx", "MHA ms", "FFN ms", "other ms", "MHA share"]);
    for ctx in [64usize, 256, 512, 1024, 2048] {
        let bd = sim.decode_step(ctx).breakdown;
        t2.rowv(vec![
            ctx.to_string(),
            format!("{:.2}", bd.mha_us / 1e3),
            format!("{:.2}", bd.ffn_us / 1e3),
            format!("{:.2}", bd.other_us / 1e3),
            format!("{:.0}%", 100.0 * bd.mha_us / bd.total_us()),
        ]);
    }
    t2.print();
    println!("paper shape: FFN flat, MHA grows with token -> dominates at long ctx\n");

    println!("== Fig. 11(c,d): prefill runtime ==");
    let mut t3 = Table::new(&["prompt tokens", "prefill ms", "ms/token"]);
    for t_in in [16usize, 32, 64, 128, 256, 512] {
        let us = sim.prefill(t_in).breakdown.total_us();
        t3.rowv(vec![
            t_in.to_string(),
            format!("{:.1}", us / 1e3),
            format!("{:.2}", us / 1e3 / t_in as f64),
        ]);
    }
    t3.print();
    println!("paper shape: prefill grows ~proportionally (compute-bound regime)");
}
