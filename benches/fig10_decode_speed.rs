//! Regenerates **Fig. 10** (decode speed × sparse strategy, GLM-6B and
//! Qwen-7B) plus the **Fig. 9** latency-hiding ablation.
//!
//! `cargo bench --bench fig10_decode_speed`

use edgellm::compiler::codegen::compile;
use edgellm::compiler::pipeline::run_timeline;
use edgellm::models::{SparseStrategy, GLM_6B, QWEN_7B};
use edgellm::sim::engine::Simulator;
use edgellm::sim::{HwConfig, Memory};
use edgellm::util::bench::Table;

fn main() {
    println!("== Fig. 10: decode speed vs sparse strategy ==");
    // paper: GLM-6B 52.67 / 66.3 / 77.59 / 85.8 token/s; avg zero-shot
    // accuracy 59.6 / 56.6 / 54.8 / 48.0.
    let paper_glm = [52.67, 66.3, 77.59, 85.8];
    let paper_acc = [59.565, 56.63, 54.795, 48.037];
    let mut t = Table::new(&[
        "strategy", "GLM-6B tok/s", "paper", "paper avg acc", "Qwen-7B tok/s", "paper",
    ]);
    let paper_qwen = ["42.5", "-", "-", "69.4"];
    for (i, strat) in SparseStrategy::all().iter().enumerate() {
        let glm = Simulator::new(&GLM_6B, strat, Memory::Hbm).decode_tokens_per_s(128);
        let qwen = Simulator::new(&QWEN_7B, strat, Memory::Hbm).decode_tokens_per_s(128);
        t.rowv(vec![
            strat.name.to_string(),
            format!("{glm:.1}"),
            format!("{:.2}", paper_glm[i]),
            format!("{:.2}", paper_acc[i]),
            format!("{qwen:.1}"),
            paper_qwen[i].to_string(),
        ]);
    }
    t.print();

    println!("\n== Fig. 9 ablation: instruction-pipeline latency hiding ==");
    let p = compile(&GLM_6B, &SparseStrategy::all()[3], 256);
    let hw = HwConfig::default();
    let mut t2 = Table::new(&["mode", "accel ms", "exposed host ms", "total ms", "tok/s"]);
    for (label, piped) in [("pipelined (aux path)", true), ("register-by-register", false)] {
        let tl = run_timeline(&p, &hw, 1, 128, Memory::Hbm, piped);
        t2.rowv(vec![
            label.to_string(),
            format!("{:.2}", tl.accel_us / 1e3),
            format!("{:.2}", tl.exposed_host_us / 1e3),
            format!("{:.2}", tl.total_us() / 1e3),
            format!("{:.1}", 1e6 / tl.total_us()),
        ]);
    }
    t2.print();
    println!("(Fig. 9's claim: dynamic-control updates hide behind accelerator time)");
}
