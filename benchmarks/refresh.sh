#!/usr/bin/env sh
# Regenerate the committed backend-throughput snapshot on THIS machine.
#
#   benchmarks/refresh.sh [label]
#
# Runs the backend bench from the repo root, then copies the fresh
# BENCH_backend.json here with provenance fields appended so the
# snapshot says where its numbers came from. `label` defaults to
# `uname -m` plus the core count (e.g. "x86_64-8core").
set -eu

root=$(cd "$(dirname "$0")/.." && pwd)
cd "$root"

cores=$(nproc 2>/dev/null || getconf _NPROCESSORS_ONLN 2>/dev/null || echo "?")
label=${1:-"$(uname -m)-${cores}core"}

cargo bench --bench backend_throughput

# append provenance without disturbing the bench-written fields
python3 - "$label" <<'EOF'
import json, sys, datetime
with open("BENCH_backend.json") as f:
    rec = json.load(f)
rec["provenance"] = {
    "generated_on": datetime.date.today().isoformat(),
    "generated_by": sys.argv[1],
    "via": "benchmarks/refresh.sh (cargo bench --bench backend_throughput)",
}
with open("benchmarks/BENCH_backend.json", "w") as f:
    json.dump(rec, f, indent=2)
    f.write("\n")
EOF

echo "wrote benchmarks/BENCH_backend.json (provenance: $label)"
