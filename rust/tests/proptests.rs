//! Randomized property tests over the substrates (deterministic seeds;
//! the offline build has no proptest crate, so cases are generated with
//! the in-tree xoshiro RNG).

use edgellm::compiler::expr::Expr;
use edgellm::fp::minifloat::{f16_decode, f16_encode, FP16, FP20};
use edgellm::fp::mixpe::{
    exact_dot_fp16_fp16, exact_dot_fp16_int4, mac_fp16_fp16, mac_fp16_int4, PAPER_PE,
};
use edgellm::pack::layout::{decode_package, encode_package};
use edgellm::pack::CH_GROUP;
use edgellm::quant::sparse::{pack_sparse, sparse_vmm_ref};
use edgellm::quant::{dequantize, prune_log_scale, quantize, Sparsity, QBLOCK};
use edgellm::util::rng::Rng;

const CASES: usize = 50;

#[test]
fn prop_quantize_dequantize_bounded_everywhere() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let k = QBLOCK * (1 + (case % 3));
        let n = 8 + (case % 5) * 8;
        let scale = (2.0f64).powi(rng.int_in(-6, 6) as i32) as f32;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * scale).collect();
        let m = quantize(&w, k, n);
        let dq = dequantize(&m);
        for r in 0..k {
            for c in 0..n {
                let s = f16_decode(m.scales[(r / QBLOCK) * n + c]) as f32;
                let err = (w[r * n + c] - dq[r * n + c]).abs();
                assert!(err <= s * 0.5 + s * 1e-3, "case {case} ({r},{c}): err {err} s {s}");
            }
        }
    }
}

#[test]
fn prop_sparse_pack_is_lossless() {
    let mut rng = Rng::new(202);
    for case in 0..CASES {
        let keep = [1usize, 2, 4][case % 3];
        let k = QBLOCK * (1 + case % 2);
        let n = 8;
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        prune_log_scale(&mut w, k, n, keep);
        let m = quantize(&w, k, n);
        let s = pack_sparse(&m, keep);
        let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let y = sparse_vmm_ref(&s, &x);
        for c in 0..n {
            let dense: f64 = (0..k).map(|r| x[r] * m.dequant(r, c)).sum();
            assert!(
                (dense - y[c]).abs() <= 1e-9 * (1.0 + dense.abs()),
                "case {case} col {c}"
            );
        }
    }
}

#[test]
fn prop_hbm_package_roundtrip_random_matrices() {
    let mut rng = Rng::new(303);
    for case in 0..20 {
        let (keep, sp) = [
            (8usize, Sparsity::Dense),
            (4, Sparsity::Half),
            (2, Sparsity::Quarter),
            (1, Sparsity::Eighth),
        ][case % 4];
        let n = 4;
        let mut w: Vec<f32> = (0..CH_GROUP * n).map(|_| rng.normal() as f32).collect();
        prune_log_scale(&mut w, CH_GROUP, n, keep);
        let m = quantize(&w, CH_GROUP, n);
        let col = case % n;
        let pkg = encode_package(&m, col, 0, sp);
        let (scales, vals) = decode_package(&pkg);
        for b in 0..CH_GROUP / QBLOCK {
            assert_eq!(scales[b], m.scales[b * n + col], "case {case}");
        }
        for r in 0..CH_GROUP {
            assert_eq!(vals[r], m.q[r * n + col], "case {case} row {r}");
        }
    }
}

#[test]
fn prop_mixpe_error_bounded_by_alignment_quantum() {
    // |PE - exact| ≤ lanes · 2^(e_max - 18) style bound, expressed via the
    // absolute-sum norm (robust formulation).
    let mut rng = Rng::new(404);
    for case in 0..CASES {
        let lanes = [8usize, 32, 128][case % 3];
        let a: Vec<u16> = (0..lanes)
            .map(|_| f16_encode(rng.normal() * (rng.int_in(-3, 3) as f64).exp2()))
            .collect();
        let w: Vec<i8> = (0..lanes).map(|_| rng.int_in(-8, 7) as i8).collect();
        let got = f16_decode(mac_fp16_int4(&PAPER_PE, &a, &w, f16_encode(1.0)));
        let exact = exact_dot_fp16_int4(&a, &w, 1.0);
        let norm: f64 = a
            .iter()
            .zip(&w)
            .map(|(&ai, &wi)| (f16_decode(ai) * wi as f64).abs())
            .sum();
        assert!(
            (got - exact).abs() <= 2e-3 * norm.max(1e-20) + 1e-9,
            "case {case}: got {got} exact {exact} norm {norm}"
        );
    }
}

#[test]
fn prop_mixpe_fp16_mode_error_bounded() {
    let mut rng = Rng::new(505);
    for case in 0..CASES {
        let lanes = 32;
        let gen = |rng: &mut Rng| f16_encode(rng.normal() * (rng.int_in(-3, 3) as f64).exp2());
        let a: Vec<u16> = (0..lanes).map(|_| gen(&mut rng)).collect();
        let b: Vec<u16> = (0..lanes).map(|_| gen(&mut rng)).collect();
        let got = f16_decode(mac_fp16_fp16(&PAPER_PE, &a, &b, f16_encode(1.0)));
        let exact = exact_dot_fp16_fp16(&a, &b, 1.0);
        let norm: f64 = a
            .iter()
            .zip(&b)
            .map(|(&ai, &bi)| (f16_decode(ai) * f16_decode(bi)).abs())
            .sum();
        assert!(
            (got - exact).abs() <= 2e-3 * norm.max(1e-20) + 1e-9,
            "case {case}: got {got} exact {exact}"
        );
    }
}

#[test]
fn prop_fp20_refines_fp16() {
    // every FP16-representable value is exactly representable in FP20
    let mut rng = Rng::new(606);
    for _ in 0..500 {
        let bits = (rng.next_u32() & 0xFFFF) as u32;
        if (bits >> 10) & 0x1F == 0x1F {
            continue; // skip inf/nan
        }
        let x = FP16.decode(bits);
        assert_eq!(FP20.round(x), x, "bits {bits:#06x}");
    }
}

#[test]
fn prop_expr_simplify_preserves_semantics() {
    let mut rng = Rng::new(707);
    for case in 0..200 {
        let e = random_expr(&mut rng, 4);
        let s = Expr::simplify(&e);
        for tok in [0i64, 1, 7, 127, 4096] {
            assert_eq!(e.eval(tok), s.eval(tok), "case {case}: {e} vs {s}");
        }
        assert!(s.size() <= e.size(), "simplify grew {e} -> {s}");
    }
}

fn random_expr(rng: &mut Rng, depth: usize) -> std::rc::Rc<Expr> {
    if depth == 0 || rng.below(4) == 0 {
        return if rng.below(2) == 0 {
            Expr::token()
        } else {
            Expr::c(rng.int_in(0, 64))
        };
    }
    let a = random_expr(rng, depth - 1);
    let b = random_expr(rng, depth - 1);
    match rng.below(5) {
        0 => Expr::add(a, b),
        1 => Expr::sub(a, b),
        2 => Expr::mul(a, b),
        // divisor must be non-zero: fold constants away from 0
        3 => Expr::div(a, Expr::c(rng.int_in(1, 16))),
        _ => Expr::max(a, b),
    }
}

#[test]
fn prop_stream_events_reconstruct_completion() {
    // for any engine seed and sampling policy, the streamed token events
    // are a lossless, ordered view of the completion: token ids rebuild
    // the final text, indices are dense, and the terminal event is Done
    // with the same counts
    use edgellm::coordinator::engine::{Engine, EngineConfig, Event};
    use edgellm::coordinator::sampler::Sampling;
    use edgellm::coordinator::tokenizer;
    use edgellm::runtime::model::LlmRuntime;
    use edgellm::runtime::reference::ReferenceConfig;

    for case in 0..6u64 {
        let policy = match case % 3 {
            0 => Sampling::Greedy,
            1 => Sampling::Temperature(1.1),
            _ => Sampling::TopP { p: 0.9, temperature: 1.0 },
        };
        let mut eng = Engine::new(
            LlmRuntime::reference(ReferenceConfig::default()),
            EngineConfig {
                seed: 900 + case,
                max_active: 3,
                ..EngineConfig::default()
            },
        );
        let h = eng.submit("prop stream", 8, Sampling::Greedy);
        let h2 = eng.submit("second session", 5, policy);
        eng.run_all().unwrap();
        for (handle, want_n) in [(h, 8usize), (h2, 5usize)] {
            let mut tokens = Vec::new();
            let mut done = None;
            while let Some(ev) = handle.try_recv() {
                match ev {
                    Event::Token(t) => {
                        assert_eq!(t.index, tokens.len(), "case {case}: dense indices");
                        tokens.push(t.token);
                    }
                    Event::Done(c) => done = Some(c),
                    Event::Error(e) => panic!("case {case}: {e}"),
                }
            }
            let c = done.expect("terminal Done");
            assert_eq!(tokens.len(), want_n, "case {case}");
            assert_eq!(c.n_generated, want_n, "case {case}");
            assert_eq!(
                tokenizer::decode(&tokens),
                c.text,
                "case {case}: token ids must rebuild the text"
            );
        }
    }
}

#[test]
fn prop_kv_arena_interleavings_never_leak_or_double_free() {
    // any interleaving of reserve / grow / release must keep the arena's
    // accounting exact: no block owned by two live handles, in-use +
    // free == total, double release a no-op, and a full drain restores
    // the whole pool
    use edgellm::runtime::kv::{KvArena, KvHandle};
    use std::collections::HashSet;

    let mut rng = Rng::new(909);
    for case in 0..30usize {
        let block_tokens = [4usize, 8, 16][case % 3];
        let max_blocks = 3 + case % 10;
        let mut arena = KvArena::new(2, 4, block_tokens, max_blocks);
        // (handle, tokens it currently addresses)
        let mut live: Vec<(KvHandle, usize)> = Vec::new();

        for step in 0..200usize {
            match rng.below(3) {
                0 => {
                    let t = 1 + rng.below(3 * block_tokens as u64) as usize;
                    match arena.reserve(t) {
                        Ok(h) => {
                            assert!(
                                h.capacity_tokens(block_tokens) >= t,
                                "case {case} step {step}: short reservation"
                            );
                            live.push((h, t));
                        }
                        Err(e) => assert!(
                            arena.blocks_free() < e.needed_blocks,
                            "case {case} step {step}: spurious exhaustion {e}"
                        ),
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (h, t) = &mut live[i];
                        if arena.ensure(h, *t + 1).is_ok() {
                            *t += 1;
                        } else {
                            assert_eq!(arena.blocks_free(), 0, "case {case} step {step}");
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (mut h, _) = live.swap_remove(i);
                        arena.release(&mut h);
                        assert!(h.is_empty());
                        arena.release(&mut h); // double release: no-op
                    }
                }
            }

            // invariants after every step
            let mut seen = HashSet::new();
            for (h, _) in &live {
                for &b in h.blocks() {
                    assert!(
                        seen.insert(b),
                        "case {case} step {step}: block {b} owned twice"
                    );
                    assert!((b as usize) < max_blocks, "block id out of range");
                }
            }
            let stats = arena.stats();
            assert_eq!(
                stats.blocks_total - stats.blocks_free,
                seen.len() as u64,
                "case {case} step {step}: accounting drifted"
            );
            assert_eq!(stats.free_bytes + stats.reserved_bytes, stats.total_bytes);
        }

        for (mut h, _) in live.drain(..) {
            arena.release(&mut h);
        }
        let stats = arena.stats();
        assert_eq!(stats.blocks_free, stats.blocks_total, "case {case}: blocks leaked");
    }
}

#[test]
fn prop_kv_prefix_sharing_interleavings_stay_consistent() {
    // refcounted extension of the interleaving property: any mix of
    // adopt-or-grow prefills, single-token growth with copy-on-write,
    // releases, and prefix re-registration must keep the accounting
    // exact — a block a handle is about to write always ends with
    // exactly one reference (no write-through-shared-block), pinned
    // blocks equal the distinct blocks live handles hold (no leak, no
    // double-free), and draining every handle frees the whole pool
    use edgellm::runtime::kv::{KvArena, KvHandle};
    use std::collections::HashSet;

    // prompts come from 3 families; family p's sequence is
    // p*1000, p*1000+1, ... so equal-family prompts share prefixes and
    // cross-family prompts diverge at token 0
    let toks = |p: i32, t: usize| (0..t as i32).map(|i| p * 1000 + i).collect::<Vec<i32>>();

    let mut rng = Rng::new(1909);
    for case in 0..30usize {
        let bt = [4usize, 8, 16][case % 3];
        let max_blocks = 3 + case % 10;
        let mut arena = KvArena::new(2, 4, bt, max_blocks);
        let mut live: Vec<(KvHandle, Vec<i32>)> = Vec::new();

        for step in 0..200usize {
            match rng.below(4) {
                0 => {
                    // prefill-shaped: adopt what the index holds, grow
                    // to the full prompt, unshare every block we'd write
                    let tokens = toks(rng.below(3) as i32, 1 + rng.below(3 * bt as u64) as usize);
                    let t = tokens.len();
                    let (mut h, start) =
                        arena.adopt_prefix(&tokens).unwrap_or((KvHandle::default(), 0));
                    assert!(start <= t, "case {case} step {step}: adopted past the prompt");
                    assert!(
                        h.capacity_tokens(bt) >= start,
                        "case {case} step {step}: adopted handle shorter than its prefix"
                    );
                    let grown = arena.ensure(&mut h, t).and_then(|()| {
                        for bi in (start / bt)..=((t - 1) / bt) {
                            arena.ensure_writable(&mut h, bi * bt)?;
                            assert_eq!(
                                arena.block_refs(h.blocks()[bi]),
                                1,
                                "case {case} step {step}: writable block still shared"
                            );
                        }
                        Ok(())
                    });
                    match grown {
                        Ok(()) => {
                            arena.register_prefix(&tokens, &h);
                            live.push((h, tokens));
                        }
                        Err(_) => {
                            assert_eq!(arena.blocks_free(), 0, "case {case} step {step}");
                            arena.release(&mut h);
                        }
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (h, tokens) = &mut live[i];
                        let pos = tokens.len();
                        let grown = arena
                            .ensure(h, pos + 1)
                            .and_then(|()| arena.ensure_writable(h, pos));
                        match grown {
                            Ok(()) => {
                                assert_eq!(
                                    arena.block_refs(h.blocks()[pos / bt]),
                                    1,
                                    "case {case} step {step}: decode row still shared"
                                );
                                tokens.push(tokens[0] + pos as i32);
                            }
                            Err(_) => {
                                assert_eq!(arena.blocks_free(), 0, "case {case} step {step}")
                            }
                        }
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (mut h, _) = live.swap_remove(i);
                        arena.release(&mut h);
                        assert!(h.is_empty());
                        arena.release(&mut h); // double release: no-op
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (h, tokens) = &live[i];
                        arena.register_prefix(tokens, h);
                    }
                }
            }

            // refcount-aware invariants after every step: handles never
            // hold a block twice internally, every held block's refcount
            // covers its holders, and pinned == distinct held blocks
            let mut holders: std::collections::HashMap<u32, u32> = Default::default();
            for (h, _) in &live {
                let mut mine = HashSet::new();
                for &b in h.blocks() {
                    assert!(
                        mine.insert(b),
                        "case {case} step {step}: handle holds block {b} twice"
                    );
                    assert!((b as usize) < max_blocks, "block id out of range");
                    *holders.entry(b).or_insert(0) += 1;
                }
            }
            for (&b, &n) in &holders {
                assert!(
                    arena.block_refs(b) >= n,
                    "case {case} step {step}: block {b} refcount {} below its {n} holders",
                    arena.block_refs(b)
                );
            }
            let stats = arena.stats();
            assert_eq!(
                stats.blocks_total - stats.blocks_free,
                holders.len() as u64,
                "case {case} step {step}: pinned blocks drifted from live handles"
            );
            assert_eq!(stats.free_bytes + stats.reserved_bytes, stats.total_bytes);
        }

        for (mut h, _) in live.drain(..) {
            arena.release(&mut h);
        }
        let stats = arena.stats();
        assert_eq!(stats.blocks_free, stats.blocks_total, "case {case}: blocks leaked");
        assert_eq!(stats.reserved_bytes, 0, "case {case}: phantom reservation");
    }
}

#[test]
fn prop_trace_ring_preserves_per_request_order_under_concurrent_recording() {
    // 8 writer threads × 200 spans through rings both larger and much
    // smaller than the total volume: whatever survives the overwrites,
    // each request's retained spans must be a contiguous, in-order tail
    // of what its thread recorded (detail = per-thread sequence number,
    // timestamps strictly increasing per thread). The ring may drop the
    // oldest spans globally, but it must never reorder a request's
    // stream or punch holes in the middle of it.
    use edgellm::obs::{SpanKind, TraceRing};
    use std::sync::Arc;

    const WRITERS: u64 = 8;
    const PER_WRITER: u64 = 200;
    for cap in [4096usize, 64] {
        let ring = Arc::new(TraceRing::new(cap));
        std::thread::scope(|s| {
            for req in 0..WRITERS {
                let ring = Arc::clone(&ring);
                s.spawn(move || {
                    for i in 0..PER_WRITER {
                        let kind = match i % 3 {
                            0 => SpanKind::Queued,
                            1 => SpanKind::DecodeRound,
                            _ => SpanKind::Done,
                        };
                        ring.record(req, kind, i * 10, i * 10 + 5, i);
                    }
                });
            }
        });
        let spans = ring.snapshot();
        assert_eq!(
            spans.len(),
            cap.min((WRITERS * PER_WRITER) as usize),
            "cap {cap}: retained span count"
        );
        for req in 0..WRITERS {
            let mine: Vec<_> = spans.iter().filter(|sp| sp.req_id == req).collect();
            for w in mine.windows(2) {
                assert!(w[0].seq < w[1].seq, "cap {cap} req {req}: seq order broken");
                assert_eq!(
                    w[0].detail + 1,
                    w[1].detail,
                    "cap {cap} req {req}: span dropped or reordered mid-stream"
                );
                assert!(
                    w[0].start_ns < w[1].start_ns,
                    "cap {cap} req {req}: timestamps out of order"
                );
            }
            // the retained subset is a suffix of the recorded stream, so
            // when anything survives, the newest span does
            if let Some(last) = mine.last() {
                assert_eq!(last.detail, PER_WRITER - 1, "cap {cap} req {req}");
            }
        }
    }
}

#[test]
fn prop_parallel_kernels_bit_identical_across_thread_counts() {
    // PR 10 acceptance: the pool-driven kernel tier is bitwise invariant
    // across threads ∈ {1, 2, 8} on hostile shapes — fewer output
    // columns than workers, non-multiple-of-8 widths, partial tail
    // lanes — because stripes partition the output and never change any
    // element's operation order.
    use edgellm::pack::layout::PackedQ4;
    use edgellm::runtime::kernels::{self, par};
    use edgellm::runtime::pool::WorkerPool;
    let pools: Vec<WorkerPool> = [1usize, 2, 8].iter().map(|&t| WorkerPool::new(t)).collect();
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    let mut rng = Rng::new(909);
    for case in 0..CASES {
        let k = 1 + case % 40;
        let n = [2usize, 3, 5, 8, 13, 26, 67][case % 7];
        let b = 1 + case % 4;
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; b * n];
        kernels::gemm_into(&x, b, k, &w, n, &mut want);
        for pool in &pools {
            let mut got = vec![0f32; b * n];
            par::gemm_into(pool, &x, b, k, &w, n, &mut got);
            assert_eq!(
                bits(&want),
                bits(&got),
                "case {case} gemm {k}x{n} b{b} threads {}",
                pool.threads()
            );
        }
        // quantized GEMM: nibble-packed, so widths stay even
        let qk = QBLOCK * (1 + case % 2);
        let qn = [2usize, 4, 10, 26][case % 4];
        let wq: Vec<f32> = (0..qk * qn).map(|_| rng.normal() as f32).collect();
        let p = PackedQ4::from_quant(&quantize(&wq, qk, qn));
        let xq: Vec<f32> = (0..b * qk).map(|_| rng.normal() as f32).collect();
        let mut partial = vec![0f32; b * qn];
        let mut qrow = vec![0f32; qn];
        let mut xcol = vec![0f32; b];
        let mut want = vec![0f32; b * qn];
        kernels::q4_gemm_into(&xq, b, &p, &mut partial, &mut xcol, &mut qrow, &mut want);
        for pool in &pools {
            // per-worker activation gathers, as the engine provisions
            let mut xcolp = vec![0f32; pool.threads() * b];
            let mut got = vec![0f32; b * qn];
            par::q4_gemm_into(pool, &xq, b, &p, &mut partial, &mut xcolp, &mut qrow, &mut got);
            assert_eq!(
                bits(&want),
                bits(&got),
                "case {case} q4 {qk}x{qn} b{b} threads {}",
                pool.threads()
            );
        }
    }
}

#[test]
fn prop_rng_choose_indices_uniformish() {
    // sanity on the test harness itself: chosen index sets cover the range
    let mut rng = Rng::new(808);
    let mut hits = vec![0usize; 64];
    for _ in 0..2000 {
        for i in rng.choose_indices(64, 8) {
            hits[i] += 1;
        }
    }
    let (min, max) = (hits.iter().min().unwrap(), hits.iter().max().unwrap());
    assert!(*min > 150 && *max < 350, "min {min} max {max}");
}
