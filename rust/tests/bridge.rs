//! Bridge subsystem end-to-end: a loopback device daemon driven through
//! `BridgeBackend` must be indistinguishable from the same backend
//! in-process (bit-identical logits and completions), meter its
//! transport, survive malformed/truncated frames without panicking or
//! leaking sessions, and surface backpressure as structured "server
//! busy" errors through both protocol generations.
//!
//! `external_device_e2e` additionally runs the suite's serving check
//! against a daemon started *outside* this process when
//! `EDGELLM_DEVICE_ADDR` is set — CI starts `edgellm device-serve
//! --backend sim` in the background and points the suite at it.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use edgellm::bridge::client::BridgeBackend;
use edgellm::bridge::device::{self, DeviceConfig, DeviceHandle};
use edgellm::bridge::protocol::{self, ErrCode, Frame, PROTOCOL_VERSION};
use edgellm::coordinator::engine::{Engine, EngineConfig};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server;
use edgellm::models::{DENSE, TINY};
use edgellm::runtime::backend::{ReferenceBackend, SimBackend};
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::sim::Memory;
use edgellm::util::json::Json;
use edgellm::util::rng::Rng;

fn spawn_reference_device() -> DeviceHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    device::spawn_on(
        Box::new(ReferenceBackend::new(ReferenceConfig::default())),
        listener,
        DeviceConfig::default(),
    )
    .unwrap()
}

fn bridge_runtime(dev: &DeviceHandle) -> LlmRuntime {
    LlmRuntime::from_backend(Box::new(
        BridgeBackend::connect(&dev.addr().to_string()).unwrap(),
    ))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Poll until the daemon's session gauge drains (connection teardown is
/// asynchronous) — failing loudly instead of hanging.
fn wait_sessions_drained(dev: &DeviceHandle) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while dev.active_sessions() != 0 {
        assert!(
            Instant::now() < deadline,
            "device leaked {} sessions",
            dev.active_sessions()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ------------------------------------------------------------ equivalence

/// Acceptance: the same backend behind the wire and in-process produce
/// bitwise-identical logits — f32 rows cross the transport as raw bits.
#[test]
fn bridge_logits_are_bitwise_identical_to_in_process() {
    let dev = spawn_reference_device();
    let remote = bridge_runtime(&dev);
    let local = LlmRuntime::reference(ReferenceConfig::default());

    // the handshake carried the full architecture + capabilities
    assert_eq!(remote.info.name, local.info.name);
    assert_eq!(remote.info.max_tokens, local.info.max_tokens);
    assert_eq!(remote.prefill_buckets(), local.prefill_buckets());
    assert_eq!(remote.supports_batched_decode(), local.supports_batched_decode());
    assert_eq!(remote.ffn_weight_bytes(), local.ffn_weight_bytes());
    assert!(remote.is_remote() && !local.is_remote());

    let (lr, mut sr) = remote.prefill(&[10, 20, 30]).unwrap();
    let (ll, mut sl) = local.prefill(&[10, 20, 30]).unwrap();
    assert_eq!(sr.pos, sl.pos);
    assert_eq!(bits(&lr), bits(&ll), "prefill logits differ across the wire");

    for t in [7, 250, 0] {
        let dr = remote.decode(&mut sr, t).unwrap();
        let dl = local.decode(&mut sl, t).unwrap();
        assert_eq!(bits(&dr), bits(&dl), "decode logits differ at token {t}");
        assert_eq!(sr.pos, sl.pos);
    }

    // the batched round rides ONE DecodeBatch frame and still matches
    let (_l, mut ra) = remote.prefill(&[1, 2]).unwrap();
    let (_l, mut rb) = remote.prefill(&[3]).unwrap();
    let (_l, mut la) = local.prefill(&[1, 2]).unwrap();
    let (_l, mut lb) = local.prefill(&[3]).unwrap();
    let mut rs = vec![&mut ra, &mut rb];
    let mut ls = vec![&mut la, &mut lb];
    let out_r = remote.decode_batch(&mut rs, &[9, 8]).unwrap();
    let out_l = local.decode_batch(&mut ls, &[9, 8]).unwrap();
    for (r, l) in out_r.iter().zip(&out_l) {
        assert_eq!(bits(r), bits(l));
    }
    dev.shutdown();
}

/// Acceptance: engine completions over `BridgeBackend(ReferenceBackend)`
/// are bit-identical to the in-process engine for the same seeds — and
/// retirement closes every device-side session over the wire.
#[test]
fn bridged_completions_bit_identical_to_in_process() {
    let dev = spawn_reference_device();
    let cfg = || EngineConfig { max_active: 3, ..EngineConfig::default() };
    let mut local = Engine::new(LlmRuntime::reference(ReferenceConfig::default()), cfg());
    let mut bridged = Engine::new(bridge_runtime(&dev), cfg());

    let prompts = ["hello bridge", "a", "the quick brown fox", "zzzz"];
    for (i, p) in prompts.iter().enumerate() {
        local.submit(p, 6 + i, Sampling::Greedy);
        bridged.submit(p, 6 + i, Sampling::Greedy);
    }
    // stochastic sampling too: both engines consume the same seeded RNG
    // stream, so identical logits must give identical draws
    local.submit("sampled tail", 8, Sampling::Temperature(0.8));
    bridged.submit("sampled tail", 8, Sampling::Temperature(0.8));

    let mut a = local.run_all().unwrap();
    let mut b = bridged.run_all().unwrap();
    a.sort_by_key(|c| c.id);
    b.sort_by_key(|c| c.id);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.text, y.text, "request {} diverged across the bridge", x.id);
        assert_eq!(x.n_prompt, y.n_prompt);
        assert_eq!(x.n_generated, y.n_generated);
    }
    // retirement pipelines CloseSession frames; one stats round trip
    // flushes them and proves (by reply ordering) they were applied
    let _ = bridged.runtime().memory();
    assert_eq!(
        dev.active_sessions(),
        0,
        "engine retirement must close device sessions (pipelined closes flushed)"
    );
    dev.shutdown();
}

/// The latency-model backend serves across the bridge too (the CI e2e
/// daemon shape), with the honest stepped-decode capability flag.
#[test]
fn bridge_serves_the_sim_backend() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let dev = device::spawn_on(
        Box::new(SimBackend::new(&TINY, &DENSE, Memory::Hbm, 64, 7)),
        listener,
        DeviceConfig::default(),
    )
    .unwrap();
    let rt = bridge_runtime(&dev);
    assert!(rt.info.name.starts_with("sim-"), "{}", rt.info.name);
    assert!(!rt.supports_batched_decode(), "sim rounds are honestly stepped");
    let mut eng = Engine::new(rt, EngineConfig::default());
    eng.submit("ping", 5, Sampling::Greedy);
    let c = eng.step().unwrap().expect("completion");
    assert_eq!(c.n_generated, 5);
    // flush the pipelined close (sim backend: memory() returns None but
    // the Info round trip still drains the close queue)
    assert!(eng.runtime().memory().is_none(), "sim backend has no arena");
    assert_eq!(dev.active_sessions(), 0);
    dev.shutdown();
}

// ------------------------------------------------------------- the meter

#[test]
fn transfer_meter_counts_both_directions_per_call() {
    let dev = spawn_reference_device();
    let rt = bridge_runtime(&dev);
    let m0 = rt.transfer_meter().expect("bridge backends meter transfers");
    assert!(m0.tx_bytes > 0 && m0.rx_bytes > 0, "handshake is metered: {m0:?}");
    assert_eq!(m0.calls, 1);

    let (_l, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
    let m1 = rt.transfer_meter().unwrap();
    assert!(m1.tx_bytes > m0.tx_bytes && m1.rx_bytes > m0.rx_bytes);
    assert_eq!(m1.calls, 2);

    rt.decode(&mut s, 9).unwrap();
    let m2 = rt.transfer_meter().unwrap();
    // the reply carries at least the vocab row of f32 logits...
    assert!(m2.rx_bytes - m1.rx_bytes >= (rt.info.vocab * 4) as u64);
    // ...while the request is a few bytes of command stream
    let tx_delta = m2.tx_bytes - m1.tx_bytes;
    assert!((13..64).contains(&tx_delta), "decode tx {tx_delta}B");

    // retiring the session costs one metered call, but zero round trips:
    // the CloseSession frame is buffered (pipelined), not yet on the wire
    rt.end_session(&mut s);
    let m3 = rt.transfer_meter().unwrap();
    assert_eq!(m3.calls, 4);
    assert_eq!(m3.rx_bytes, m2.rx_bytes, "no reply awaited at close time");

    // the next request's flush carries the close; its reply is drained in
    // front, so when memory() returns, the device gauge has dropped
    let _ = rt.memory();
    let m4 = rt.transfer_meter().unwrap();
    assert_eq!(m4.calls, 5);
    assert!(m4.rx_bytes > m3.rx_bytes, "close reply + info reply drained");
    assert_eq!(dev.active_sessions(), 0);
    dev.shutdown();
}

/// A prefill the *device* rejects must not consume a session-table slot
/// (the pipelined OpenSession succeeded; the client closes it on the
/// error path) and must leave the connection serviceable.
#[test]
fn failed_prefill_releases_the_device_slot() {
    use edgellm::runtime::backend::Backend;
    let dev = spawn_reference_device();
    let backend = BridgeBackend::connect(&dev.addr().to_string()).unwrap();
    // call the trait directly, bypassing the wrapper's validation, so
    // the device-side runtime is what rejects the oversized prompt
    let err = backend.prefill(&[0; 4096]).unwrap_err();
    assert!(format!("{err:#}").contains("Backend"), "{err:#}");
    assert_eq!(dev.active_sessions(), 0, "failed prefill must not hold a slot");
    // the same connection still serves
    let (_l, mut s) = backend.prefill(&[1, 2, 3]).unwrap();
    assert_eq!(s.pos, 3);
    backend.end_session(&mut s);
    assert_eq!(dev.active_sessions(), 0);
    dev.shutdown();
}

// --------------------------------------------------- restart resilience

/// Acceptance: a device power cycle mid-decode costs latency, not a
/// failed completion. The daemon is torn down between rounds — severing
/// every live connection and wiping all device-side session state — and
/// a fresh daemon (fresh backend, same port) takes its place. The
/// client must reconnect, replay its sessions from token history, and
/// finish every stream bit-identical to an uninterrupted in-process
/// run, with zero client-visible errors.
#[test]
fn device_restart_mid_decode_is_invisible_to_the_client() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    // a clone of the listener keeps the port bound across the restart,
    // so the "rebooted device" comes back at the address the client
    // keeps dialing
    let respawn = listener.try_clone().unwrap();
    let dev = device::spawn_on(
        Box::new(ReferenceBackend::new(ReferenceConfig::default())),
        listener,
        DeviceConfig::default(),
    )
    .unwrap();

    let cfg = || EngineConfig { max_active: 2, ..EngineConfig::default() };
    let mut local = Engine::new(LlmRuntime::reference(ReferenceConfig::default()), cfg());
    let mut bridged = Engine::new(bridge_runtime(&dev), cfg());
    for (i, p) in ["power cycle survivor", "second stream"].iter().enumerate() {
        local.submit(p, 8 + i, Sampling::Greedy);
        bridged.submit(p, 8 + i, Sampling::Greedy);
    }

    // a few decode rounds so the restart lands mid-stream on both
    // sessions, with KV state the replay must reconstruct
    for _ in 0..3 {
        local.step_round().unwrap();
        bridged.step_round().unwrap();
    }

    // power cycle: all connections severed, all device state gone, a
    // *fresh* backend comes up on the same port
    dev.shutdown();
    let dev2 = device::spawn_on(
        Box::new(ReferenceBackend::new(ReferenceConfig::default())),
        respawn,
        DeviceConfig::default(),
    )
    .unwrap();

    let mut a = local.run_all().unwrap();
    let mut b = bridged.run_all().unwrap();
    a.sort_by_key(|c| c.id);
    b.sort_by_key(|c| c.id);
    assert_eq!(a.len(), 2);
    assert_eq!(b.len(), 2, "every stream must complete across the restart");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.text, y.text, "request {} diverged across the device restart", x.id);
        assert_eq!(x.n_prompt, y.n_prompt);
        assert_eq!(x.n_generated, y.n_generated);
    }

    let meter = bridged.runtime().transfer_meter().expect("bridge meters transfers");
    assert!(meter.reconnects >= 1, "the restart must be visible in the meter");

    // retirement closes land on the *new* daemon; flush and check
    let _ = bridged.runtime().memory();
    assert_eq!(dev2.active_sessions(), 0, "replayed sessions must still be retired");
    dev2.shutdown();
}

// ------------------------------------------------------- paged KV arena

/// The device's KV-arena accounting crosses the wire through the
/// backward-compatible `InfoResp` tail, and a pipelined close is
/// observable through it: the `memory()` query that follows retirement
/// already sees the freed blocks (reply ordering guarantees the close
/// was applied first).
#[test]
fn memory_stats_cross_the_bridge() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let dev = device::spawn_on(
        Box::new(ReferenceBackend::new(ReferenceConfig {
            kv_block_tokens: 16,
            kv_pool_blocks: 12,
            ..ReferenceConfig::default()
        })),
        listener,
        DeviceConfig::default(),
    )
    .unwrap();
    let rt = bridge_runtime(&dev);

    let m0 = rt.memory().expect("device reports arena stats over the wire");
    assert_eq!(m0.blocks_total, 12);
    assert_eq!(m0.block_tokens, 16);
    assert_eq!(m0.blocks_free, 12);
    assert_eq!(m0.free_bytes + m0.reserved_bytes, m0.total_bytes);

    let (_l, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
    let m1 = rt.memory().unwrap();
    assert_eq!(m1.blocks_free, 11, "prefill held one device-side block");

    rt.end_session(&mut s);
    let m2 = rt.memory().unwrap();
    assert_eq!(m2.blocks_free, 12, "pipelined close applied before the stats reply");
    assert_eq!(
        m2.peak_reserved_bytes, m1.reserved_bytes,
        "the peak watermark crosses the wire and survives the release"
    );
    assert_eq!(dev.active_sessions(), 0);
    dev.shutdown();
}

/// Acceptance: a device paging its KV across small blocks serves
/// bit-identical completions to a local contiguous-block engine — the
/// block layout is invisible end to end, mixed-length batch included.
#[test]
fn paged_device_blocks_are_bitwise_invisible_end_to_end() {
    let paged_cfg = ReferenceConfig {
        kv_block_tokens: 4, // many blocks per session
        ..ReferenceConfig::default()
    };
    let contiguous_cfg = ReferenceConfig {
        kv_block_tokens: 64, // one block per session (contiguous layout)
        ..ReferenceConfig::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let dev = device::spawn_on(
        Box::new(ReferenceBackend::new(paged_cfg)),
        listener,
        DeviceConfig::default(),
    )
    .unwrap();
    let cfg = || EngineConfig { max_active: 4, ..EngineConfig::default() };
    let mut local = Engine::new(LlmRuntime::reference(contiguous_cfg), cfg());
    let mut bridged = Engine::new(bridge_runtime(&dev), cfg());
    // mixed lengths: prompts and budgets straddle several 4-token blocks
    for (i, p) in ["a", "mixed length", "a considerably longer prompt", "zz"]
        .iter()
        .enumerate()
    {
        local.submit(p, 3 + 4 * i, Sampling::Greedy);
        bridged.submit(p, 3 + 4 * i, Sampling::Greedy);
    }
    let mut a = local.run_all().unwrap();
    let mut b = bridged.run_all().unwrap();
    a.sort_by_key(|c| c.id);
    b.sort_by_key(|c| c.id);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.text, y.text, "request {} diverged under paging", x.id);
        assert_eq!(x.n_generated, y.n_generated);
    }
    let _ = bridged.runtime().memory();
    assert_eq!(dev.active_sessions(), 0);
    dev.shutdown();
}

// ------------------------------------------- malformed / hostile clients

fn raw_conn(dev: &DeviceHandle) -> TcpStream {
    TcpStream::connect(dev.addr()).unwrap()
}

fn ask(stream: &mut TcpStream, f: &Frame) -> Frame {
    protocol::write_frame(stream, f).unwrap();
    protocol::read_frame(stream).unwrap().expect("reply").0
}

#[test]
fn malformed_frames_get_error_replies_and_daemon_survives() {
    let dev = spawn_reference_device();
    let mut c = raw_conn(&dev);
    assert!(matches!(
        ask(&mut c, &Frame::Info { version: PROTOCOL_VERSION }),
        Frame::InfoResp { .. }
    ));

    // unknown opcode under a valid length prefix: structured error,
    // connection keeps working
    c.write_all(&[1u8, 0, 0, 0, 0x7F]).unwrap();
    let (reply, _) = protocol::read_frame(&mut c).unwrap().expect("error frame");
    assert!(
        matches!(reply, Frame::Error { code: ErrCode::Protocol, .. }),
        "{reply:?}"
    );
    assert!(matches!(
        ask(&mut c, &Frame::Info { version: PROTOCOL_VERSION }),
        Frame::InfoResp { .. }
    ));

    // hostile length prefix: one final error frame, then the daemon
    // closes (framing can't be trusted any more)
    let mut c2 = raw_conn(&dev);
    c2.write_all(&u32::MAX.to_le_bytes()).unwrap();
    let (reply, _) = protocol::read_frame(&mut c2).unwrap().expect("final error frame");
    assert!(matches!(reply, Frame::Error { code: ErrCode::Protocol, .. }));
    let mut rest = Vec::new();
    c2.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "daemon must close after a desync");

    // a fresh client is served as if nothing happened
    let mut c3 = raw_conn(&dev);
    assert!(matches!(
        ask(&mut c3, &Frame::Info { version: PROTOCOL_VERSION }),
        Frame::InfoResp { .. }
    ));
    dev.shutdown();
}

/// Property: any mutation of a valid frame — truncation, bit flips,
/// random garbage — may only produce an error frame, a survivable
/// reply, or a closed connection. Never a panic, never a leaked
/// session, and the daemon keeps serving afterwards.
#[test]
fn fuzzed_frames_never_panic_and_never_leak_sessions() {
    let dev = spawn_reference_device();
    let mut rng = Rng::new(0xB41D6E);
    for round in 0u32..24 {
        let mut c = raw_conn(&dev);
        assert!(matches!(
            ask(&mut c, &Frame::OpenSession { session: round }),
            Frame::SessionOpened { .. }
        ));
        assert!(matches!(
            ask(&mut c, &Frame::Prefill { session: round, prompt: vec![1, 2, 3] }),
            Frame::Logits { .. }
        ));

        let mut bytes = Vec::new();
        protocol::write_frame(&mut bytes, &Frame::Decode { session: round, token: 42 })
            .unwrap();
        match rng.next_u64() % 3 {
            0 => {
                // truncate mid-frame, then hang up
                let cut = 1 + (rng.next_u64() as usize) % (bytes.len() - 1);
                bytes.truncate(cut);
            }
            1 => {
                // flip one bit anywhere (length prefix included)
                let i = (rng.next_u64() as usize) % bytes.len();
                bytes[i] ^= 1 << (rng.next_u64() % 8);
            }
            _ => {
                // replace the whole frame with noise
                for b in bytes.iter_mut() {
                    *b = rng.next_u64() as u8;
                }
            }
        }
        let _ = c.write_all(&bytes);
        // drain whatever comes back (an error frame, logits if the
        // mutation happened to stay valid, or an immediate close)
        c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        let mut sink = [0u8; 4096];
        loop {
            match c.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => continue,
            }
        }
        drop(c);
    }
    // every fuzz connection is gone: all of their sessions must be too
    wait_sessions_drained(&dev);
    let mut c = raw_conn(&dev);
    assert!(matches!(
        ask(&mut c, &Frame::Info { version: PROTOCOL_VERSION }),
        Frame::InfoResp { .. }
    ));
    dev.shutdown();
}

// ---------------------------------------------------------- backpressure

/// `EngineConfig::max_queued` bounds the queue; the overflow request's
/// handle carries a structured "server busy" terminal event.
#[test]
fn bounded_queue_rejects_overflow_with_server_busy() {
    let mut eng = Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig { max_queued: 2, ..EngineConfig::default() },
    );
    let h1 = eng.submit("first", 2, Sampling::Greedy);
    let h2 = eng.submit("second", 2, Sampling::Greedy);
    let h3 = eng.submit("straw that breaks", 2, Sampling::Greedy);
    let err = h3.wait().unwrap_err();
    assert!(err.contains("server busy"), "{err}");
    assert!(err.contains("max_queued=2"), "{err}");
    assert_eq!(eng.metrics().rejected, 1);
    assert_eq!(eng.metrics().submitted, 2, "rejected requests are not submitted");

    // accepted work is unaffected
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 2);
    assert!(h1.wait().is_ok() && h2.wait().is_ok());
    // the drained queue accepts again
    let h4 = eng.submit("after the drain", 2, Sampling::Greedy);
    eng.run_all().unwrap();
    assert!(h4.wait().is_ok());
    assert_eq!(eng.metrics().rejected, 1);
}

/// The synchronous v1 path (`process_line`, which also backs the CLI
/// shape) must surface the refusal too — the handle carries it, not
/// `step()`'s return value.
#[test]
fn sync_v1_path_reports_server_busy() {
    use edgellm::coordinator::server::process_line;
    let mut eng = Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig { max_queued: 0, ..EngineConfig::default() },
    );
    let reply = process_line(&mut eng, r#"{"prompt":"x","max_new_tokens":2}"#);
    let msg = reply.get("error").and_then(|v| v.as_str()).unwrap_or_default();
    assert!(msg.contains("server busy"), "{reply}");
    assert_eq!(eng.metrics().rejected, 1);
}

/// The busy error crosses protocol v2 (ack + structured terminal line)
/// and v1 (error object), and the stats line counts rejections.
/// `max_queued: 0` is drain mode — every submit refuses — which makes
/// the TCP test deterministic.
#[test]
fn tcp_both_protocols_surface_server_busy() {
    let eng = Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig { max_queued: 0, ..EngineConfig::default() },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let srv = server::spawn_on(eng, listener).unwrap();

    let read_json = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection closed early");
        Json::parse(line.trim()).unwrap()
    };

    // v2: ack, then the structured terminal error line
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    writeln!(s, r#"{{"prompt": "x", "stream": true}}"#).unwrap();
    let mut r = BufReader::new(s);
    let ack = read_json(&mut r);
    assert_eq!(ack.get("stream").and_then(|v| v.as_bool()), Some(true));
    let terminal = read_json(&mut r);
    let msg = terminal.get("error").and_then(|v| v.as_str()).unwrap_or_default();
    assert!(msg.contains("server busy"), "{terminal}");
    assert_eq!(terminal.get("done").and_then(|v| v.as_bool()), Some(true));

    // v1: a plain error object
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    writeln!(s, r#"{{"prompt": "x"}}"#).unwrap();
    let mut r = BufReader::new(s);
    let reply = read_json(&mut r);
    let msg = reply.get("error").and_then(|v| v.as_str()).unwrap_or_default();
    assert!(msg.contains("server busy"), "{reply}");

    // stats expose the rejection counter
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    writeln!(s, r#"{{"stats": true}}"#).unwrap();
    let mut r = BufReader::new(s);
    let stats = read_json(&mut r);
    assert_eq!(stats.get("rejected").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(stats.get("submitted").and_then(|v| v.as_usize()), Some(0));
    srv.shutdown();
}

// -------------------------------------------------- external daemon e2e

/// End-to-end against a daemon started *outside* this process
/// (`EDGELLM_DEVICE_ADDR=host:port`, see `.github/workflows/ci.yml`).
/// Skips silently when the variable is absent so local `cargo test`
/// needs no running daemon.
#[test]
fn external_device_e2e() {
    let Ok(addr) = std::env::var("EDGELLM_DEVICE_ADDR") else {
        eprintln!("EDGELLM_DEVICE_ADDR not set; skipping external-daemon e2e");
        return;
    };
    let run = || {
        let backend = BridgeBackend::connect(&addr).expect("external daemon reachable");
        let rt = LlmRuntime::from_backend(Box::new(backend));
        assert!(rt.is_remote());
        let mut eng = Engine::new(rt, EngineConfig { max_active: 2, ..EngineConfig::default() });
        for (i, p) in ["external daemon", "second request"].iter().enumerate() {
            eng.submit(p, 4 + i, Sampling::Greedy);
        }
        let mut done = eng.run_all().unwrap();
        done.sort_by_key(|c| c.id);
        let meter = eng.runtime().transfer_meter().expect("bridge meters transfers");
        assert!(meter.tx_bytes > 0 && meter.rx_bytes > 0);
        done.into_iter()
            .map(|c| (c.prompt, c.text, c.n_generated))
            .collect::<Vec<_>>()
    };
    // two fresh connections, same submissions: a deterministic device
    // must serve identical completions
    let a = run();
    let b = run();
    assert_eq!(a, b, "external device must serve deterministically");
    assert!(a.iter().all(|(_, _, n)| *n > 0));
}
