//! Continuous-batching scheduler tests: admission, interleaved decode,
//! retirement, streaming events, cancellation, metrics, and the
//! multi-client TCP server.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;

use edgellm::coordinator::engine::{Engine, EngineConfig, Event, Priority};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server;
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::util::json::Json;

fn engine_with(max_active: usize) -> Engine {
    Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig {
            max_active,
            ..EngineConfig::default()
        },
    )
}

/// Acceptance: ≥8 concurrent requests through the scheduler with
/// max_active ≥ 4; all complete with the exact per-request token counts.
#[test]
fn concurrent_requests_complete_with_correct_token_counts() {
    let mut eng = engine_with(4);
    let prompts = [
        "alpha", "bravo", "charlie", "delta", "echo", "foxtrot", "golf", "hotel", "india",
        "juliett",
    ];
    let mut want = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let max_new = 3 + i; // 3..=12, all within the KV budget
        let id = eng.submit(p, max_new, Sampling::Greedy).id();
        want.push((id, max_new));
    }
    assert_eq!(eng.pending(), 10);

    let mut done = Vec::new();
    while eng.has_work() {
        assert!(eng.active_sessions() <= 4);
        done.extend(eng.step_round().unwrap());
    }
    assert_eq!(done.len(), 10);
    let mut got: Vec<(u64, usize)> = done.iter().map(|c| (c.id, c.n_generated)).collect();
    got.sort_unstable();
    assert_eq!(got, want);
    // every request decoded some text
    assert!(done.iter().all(|c| c.n_generated > 0));
    // the pool was actually shared: peak liveness hit the configured cap
    assert_eq!(eng.metrics().peak_active, 4);
    // and decode rounds were batched: strictly fewer rounds than a
    // run-to-completion FIFO would need (sum of all max_new = 75)
    let total_tokens: u64 = want.iter().map(|(_, n)| *n as u64).sum();
    assert_eq!(eng.metrics().decode_tokens, total_tokens);
    assert!(eng.metrics().rounds < total_tokens);
}

#[test]
fn requests_are_admitted_mid_flight() {
    let mut eng = engine_with(2);
    eng.submit("first", 16, Sampling::Greedy);
    eng.submit("second", 16, Sampling::Greedy);
    eng.submit("third", 4, Sampling::Greedy);
    // first two rounds: pool is full, "third" must wait in the queue
    eng.step_round().unwrap();
    assert_eq!(eng.active_sessions(), 2);
    assert_eq!(eng.pending(), 1);
    // submitting *while sessions are live* is the whole point
    eng.submit("fourth", 4, Sampling::Greedy);
    assert_eq!(eng.pending(), 2);
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 4);
    assert_eq!(eng.metrics().completed, 4);
}

/// Batching must not change greedy results: each session's trajectory
/// depends only on its own logits/KV state.
#[test]
fn batched_greedy_matches_sequential_greedy() {
    let prompts = ["one", "two", "three", "four", "five", "six", "seven", "eight"];
    let run = |max_active: usize| -> Vec<(u64, String)> {
        let mut eng = engine_with(max_active);
        for p in &prompts {
            eng.submit(p, 10, Sampling::Greedy);
        }
        let mut out: Vec<(u64, String)> = eng
            .run_all()
            .unwrap()
            .into_iter()
            .map(|c| (c.id, c.text))
            .collect();
        out.sort();
        out
    };
    assert_eq!(run(1), run(8));
}

#[test]
fn eos_token_retires_session_early() {
    // discover what greedy decoding would emit first…
    let rt = LlmRuntime::reference(ReferenceConfig::default());
    let toks = edgellm::coordinator::tokenizer::encode("stop early");
    let (logits, _s) = rt.prefill(&toks).unwrap();
    let first = edgellm::runtime::model::argmax(&logits);

    // …then declare that token EOS: the session must retire with zero
    // emitted tokens instead of running to max_new
    let mut eng = Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig {
            max_active: 4,
            eos_token: Some(first),
            ..EngineConfig::default()
        },
    );
    eng.submit("stop early", 8, Sampling::Greedy);
    let c = eng.step().unwrap().unwrap();
    assert_eq!(c.n_generated, 0, "eos must stop generation");
}

/// The simulated VCU128 aggregate throughput is what continuous batching
/// buys: one shared weight stream per round across the live pool.
#[test]
fn batching_improves_simulated_aggregate_throughput() {
    let run = |max_active: usize| -> f64 {
        let mut eng = engine_with(max_active);
        for i in 0..8 {
            eng.submit(&format!("request number {i}"), 16, Sampling::Greedy);
        }
        eng.run_all().unwrap();
        eng.metrics().sim_tokens_per_s()
    };
    let seq = run(1);
    let batched = run(8);
    assert!(
        batched > seq * 1.5,
        "batch-8 {batched:.1} tok/s should beat batch-1 {seq:.1} tok/s"
    );
}

#[test]
fn metrics_counters_are_consistent() {
    let mut eng = engine_with(4);
    for i in 0..6 {
        eng.submit("count me", 4 + i, Sampling::Greedy);
    }
    let done = eng.run_all().unwrap();
    let m = eng.metrics();
    assert_eq!(m.submitted, 6);
    assert_eq!(m.completed, 6);
    let toks: u64 = done.iter().map(|c| c.n_generated as u64).sum();
    assert_eq!(m.decode_tokens, toks);
    assert!(m.peak_active <= 4);
    assert!(m.sim_decode_us > 0.0);
    assert_eq!(eng.pending(), 0);
    assert_eq!(eng.active_sessions(), 0);
}

/// Streaming is an *observation* of the same trajectory, not a second
/// code path: the token events reconstruct exactly the non-streaming
/// final text for the same seed/config.
#[test]
fn streaming_events_match_nonstreaming_text() {
    let run_plain = || -> String {
        let mut eng = engine_with(4);
        eng.submit("stream equivalence", 12, Sampling::Greedy);
        eng.run_all().unwrap()[0].text.clone()
    };
    let run_streamed = || -> (Vec<i32>, String, String) {
        let mut eng = engine_with(4);
        let h = eng.submit("stream equivalence", 12, Sampling::Greedy);
        eng.run_all().unwrap();
        let mut tokens = Vec::new();
        let mut done_text = None;
        while let Some(ev) = h.try_recv() {
            match ev {
                Event::Token(t) => {
                    assert_eq!(t.index, tokens.len(), "indices are dense and ordered");
                    tokens.push(t.token);
                }
                Event::Done(c) => done_text = Some(c.text),
                Event::Error(e) => panic!("unexpected error event: {e}"),
            }
        }
        let reconstructed = edgellm::coordinator::tokenizer::decode(&tokens);
        (tokens, reconstructed, done_text.expect("terminal Done event"))
    };
    let plain = run_plain();
    let (tokens, reconstructed, done_text) = run_streamed();
    assert_eq!(tokens.len(), 12);
    assert_eq!(reconstructed, plain, "token events must rebuild the text");
    assert_eq!(done_text, plain, "Done carries the same completion");
}

/// Cancellation on the real reference backend: the KV slot frees up,
/// the `cancelled` counter moves, and the remaining request is unharmed.
#[test]
fn cancellation_frees_kv_slot_for_queued_request() {
    let mut eng = engine_with(1);
    let ha = eng.submit("goes forever", 40, Sampling::Greedy);
    let hb = eng.submit("patiently waiting", 6, Sampling::Greedy);
    for _ in 0..4 {
        eng.step_round().unwrap();
    }
    assert_eq!(eng.active_sessions(), 1);
    assert_eq!(eng.pending(), 1);
    ha.cancel();
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, hb.id());
    assert_eq!(done[0].n_generated, 6);
    let m = eng.metrics();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.completed, 1);
    assert_eq!(m.submitted, 2);
    assert!(matches!(ha.wait(), Err(ref msg) if msg == "cancelled"));
}

/// Acceptance: under a KV pool sized for 4 concurrent full-length
/// requests, a 10-request mixed-length workload completes with retired
/// sessions' blocks observably recycled (`kv_reuse_hits` rises) and
/// zero preemption errors — admission's worst-case accounting holds.
#[test]
fn retired_blocks_are_reused_under_a_full_pool() {
    let max_tokens = 64usize;
    let block_tokens = 16usize;
    let blocks_per_session = max_tokens / block_tokens; // 4
    let rt = LlmRuntime::reference(ReferenceConfig {
        max_tokens,
        kv_block_tokens: block_tokens,
        kv_pool_blocks: 4 * blocks_per_session, // room for 4 long requests
        ..ReferenceConfig::default()
    });
    let mut eng = Engine::new(
        rt,
        EngineConfig {
            max_active: 8, // the cap; the arena is the allocator
            ..EngineConfig::default()
        },
    );
    let mut want = Vec::new();
    for i in 0..10 {
        // mixed lengths: worst cases of 1..4 blocks
        let max_new = [4usize, 12, 25, 40][i % 4];
        let id = eng.submit(&format!("request {i}"), max_new, Sampling::Greedy).id();
        want.push((id, max_new));
    }
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 10, "every request completes");
    let mut got: Vec<(u64, usize)> = done.iter().map(|c| (c.id, c.n_generated)).collect();
    got.sort_unstable();
    assert_eq!(got, want, "full per-request token counts despite the small pool");

    let mem = eng.runtime().memory().expect("reference backend reports its arena");
    assert!(mem.reuse_hits > 0, "retired blocks must be recycled: {mem:?}");
    assert_eq!(eng.metrics().preempted, 0, "admission accounting must prevent preemption");
    assert_eq!(
        mem.blocks_free, mem.blocks_total,
        "all blocks returned to the pool after the workload"
    );
    // the pool (16 blocks) is smaller than 10 requests' summed footprint,
    // so completion at all proves interleaved reuse
    let total_blocks_needed: usize = want
        .iter()
        .map(|(_, n)| (eng.runtime().info.max_tokens.min(n + 10)).div_ceil(block_tokens))
        .sum();
    assert!(total_blocks_needed > mem.blocks_total as usize);
}

/// The admission gate refuses (with a structured terminal error) a
/// request whose worst case exceeds the whole arena, and holds back a
/// request that merely does not fit *yet*.
#[test]
fn admission_is_memory_aware() {
    let rt = LlmRuntime::reference(ReferenceConfig {
        max_tokens: 64,
        kv_block_tokens: 8,
        kv_pool_blocks: 4, // 32 tokens of KV, total
        ..ReferenceConfig::default()
    });
    let mut eng = Engine::new(rt, EngineConfig { max_active: 8, ..EngineConfig::default() });
    // worst case 4 + 40 = 44 tokens = 6 blocks > 4-block arena: refused
    let h = eng.submit("aaaa", 40, Sampling::Greedy);
    eng.step_round().unwrap();
    let err = h.wait().unwrap_err();
    assert!(err.contains("KV blocks"), "{err}");
    assert_eq!(eng.metrics().rejected, 1);
    assert_eq!(eng.active_sessions(), 0);

    // two requests of 3 blocks each: only one fits at a time — the
    // second waits (not errors) and runs after the first retires
    let h1 = eng.submit("bbbb", 20, Sampling::Greedy); // 24 tokens = 3 blocks
    let h2 = eng.submit("cccc", 20, Sampling::Greedy);
    eng.step_round().unwrap();
    assert_eq!(eng.active_sessions(), 1, "arena gates admission below max_active");
    assert_eq!(eng.pending(), 1);
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 2);
    assert!(h1.wait().is_ok() && h2.wait().is_ok());
    assert_eq!(eng.metrics().preempted, 0);
}

/// True exhaustion (blocks consumed behind the admission gate's back by
/// a session the scheduler does not own) preempts the youngest session
/// — but eviction is recovery, not failure: the victim is requeued as a
/// recompute request, resumes off the prefix cache once blocks free up,
/// and both its completion and its token stream are bit-identical to an
/// unpreempted run. Zero client-visible errors.
#[test]
fn kv_exhaustion_preempts_then_resumes_bit_identically() {
    let cfg = ReferenceConfig {
        max_tokens: 64,
        kv_block_tokens: 8,
        kv_pool_blocks: 6,
        ..ReferenceConfig::default()
    };
    // control: the same request with nobody raiding the arena
    let mut control = Engine::new(
        LlmRuntime::reference(cfg.clone()),
        EngineConfig { max_active: 4, ..EngineConfig::default() },
    );
    control.submit("aaaa", 30, Sampling::Greedy);
    let control_text = control.run_all().unwrap()[0].text.clone();

    let mut eng = Engine::new(
        LlmRuntime::reference(cfg),
        EngineConfig { max_active: 4, ..EngineConfig::default() },
    );
    // an out-of-band session (driven directly on the backend, invisible
    // to the scheduler's worst-case accounting) holds one block
    let (mut logits, mut ext) = eng.runtime().prefill(&[1, 2, 3]).unwrap();

    // worst case 4 + 30 = 34 tokens = 5 blocks; 5 of 6 are free → admitted
    let ha = eng.submit("aaaa", 30, Sampling::Greedy);
    eng.step_round().unwrap();
    assert_eq!(eng.active_sessions(), 1);

    // the hog grows until the pool is empty
    while eng.runtime().memory().unwrap().blocks_free > 0 {
        let t = edgellm::runtime::model::argmax(&logits);
        logits = eng.runtime().decode(&mut ext, t).unwrap();
    }

    // the live session crosses its next block boundary → preempted and
    // requeued; its channel and already-streamed tokens survive
    for _ in 0..40 {
        eng.step_round().unwrap();
        if eng.metrics().preempted > 0 {
            break;
        }
    }
    assert_eq!(eng.metrics().preempted, 1);
    assert_eq!(eng.metrics().requeued, 1);
    assert_eq!(eng.active_sessions(), 0, "victim evicted, engine alive");
    assert_eq!(eng.pending(), 1, "victim waits in the queue, not failed");

    // release the hog: the victim re-prefills (prompt + generated so
    // far, adopting whatever the prefix index still holds) and finishes
    eng.runtime().end_session(&mut ext);
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].n_generated, 30);
    assert_eq!(done[0].text, control_text, "resume must be bit-identical");

    // the client-visible stream: dense ordered indices, one Done, and
    // no Error event anywhere near the preemption
    let mut tokens = Vec::new();
    let mut terminal = None;
    while let Some(ev) = ha.try_recv() {
        match ev {
            Event::Token(t) => {
                assert_eq!(t.index, tokens.len(), "indices dense across the preemption");
                tokens.push(t.token);
            }
            Event::Done(c) => terminal = Some(c),
            Event::Error(e) => panic!("preemption leaked a client-visible error: {e}"),
        }
    }
    assert_eq!(tokens.len(), 30, "no token re-emitted, none lost");
    assert_eq!(
        edgellm::coordinator::tokenizer::decode(&tokens),
        control_text,
        "streamed tokens rebuild the unpreempted text"
    );
    assert_eq!(terminal.expect("terminal Done event").text, control_text);
}

/// The observable side of preemption recovery: a forced
/// preempt-then-resume leaves a complete, correctly ordered span chain
/// in the engine's trace ring — submitted → admitted → preempted →
/// requeued → resumed → done, with monotonically non-decreasing
/// timestamps — and the trace exports as valid Chrome trace JSON.
#[test]
fn preemption_leaves_a_complete_span_chain_in_the_trace() {
    use edgellm::obs::SpanKind;

    let cfg = ReferenceConfig {
        max_tokens: 64,
        kv_block_tokens: 8,
        kv_pool_blocks: 6,
        ..ReferenceConfig::default()
    };
    let mut eng = Engine::new(
        LlmRuntime::reference(cfg),
        EngineConfig { max_active: 4, ..EngineConfig::default() },
    );
    // same forcing move as the bit-identical test: an out-of-band
    // session raids the arena behind the admission gate's back
    let (mut logits, mut ext) = eng.runtime().prefill(&[1, 2, 3]).unwrap();
    let ha = eng.submit("aaaa", 30, Sampling::Greedy);
    let victim_id = ha.id();
    eng.step_round().unwrap();
    while eng.runtime().memory().unwrap().blocks_free > 0 {
        let t = edgellm::runtime::model::argmax(&logits);
        logits = eng.runtime().decode(&mut ext, t).unwrap();
    }
    for _ in 0..40 {
        eng.step_round().unwrap();
        if eng.metrics().preempted > 0 {
            break;
        }
    }
    assert_eq!(eng.metrics().preempted, 1, "setup failed to force a preemption");
    eng.runtime().end_session(&mut ext);
    eng.run_all().unwrap();
    assert!(ha.wait().is_ok(), "victim must finish after resume");

    // the victim's lifecycle, in ring order
    let spans: Vec<_> = eng
        .obs()
        .trace
        .snapshot()
        .into_iter()
        .filter(|s| s.req_id == victim_id)
        .collect();
    let kinds: Vec<SpanKind> = spans.iter().map(|s| s.kind).collect();
    for want in [
        SpanKind::Submitted,
        SpanKind::Admitted,
        SpanKind::Preempted,
        SpanKind::Requeued,
        SpanKind::Resumed,
        SpanKind::Done,
    ] {
        assert!(kinds.contains(&want), "missing {want:?} in {kinds:?}");
    }
    let pos = |k: SpanKind| kinds.iter().position(|&x| x == k).unwrap();
    assert!(pos(SpanKind::Submitted) < pos(SpanKind::Admitted));
    assert!(pos(SpanKind::Admitted) < pos(SpanKind::Preempted));
    assert!(pos(SpanKind::Preempted) < pos(SpanKind::Requeued));
    assert!(pos(SpanKind::Requeued) < pos(SpanKind::Resumed));
    assert!(pos(SpanKind::Resumed) < pos(SpanKind::Done));
    // only one preemption episode, and the resume arrives after the
    // requeue on the clock, not just in ring order
    assert_eq!(kinds.iter().filter(|&&k| k == SpanKind::Preempted).count(), 1);
    let requeued = spans[pos(SpanKind::Requeued)];
    let resumed = spans[pos(SpanKind::Resumed)];
    assert!(requeued.end_ns <= resumed.end_ns);
    for w in spans.windows(2) {
        assert!(
            w[0].end_ns <= w[1].end_ns,
            "span timestamps regressed: {:?} then {:?}",
            w[0],
            w[1]
        );
    }
    // TTFT is recorded for the fresh admission only — a resume is a
    // stall, not a second first token
    assert_eq!(eng.obs().ttft_us.summary().count, 1);
    // queue-wait: one fresh-admission episode + one requeue episode
    assert_eq!(eng.obs().queue_wait_us.summary().count, 2);

    // the exported chrome trace parses and names the preemption spans
    let exported = edgellm::obs::chrome_trace_json(&eng.obs().trace.last(4096)).to_string();
    let j = Json::parse(&exported).unwrap();
    let cats: Vec<&str> = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .filter_map(|e| e.get("cat").and_then(|c| c.as_str()))
        .collect();
    assert!(cats.contains(&"preemption"), "exported trace lost the preemption");
}

/// A preempted session that *shares* its prefix frees only its private
/// blocks: the full-block prefix it adopted stays resident for the
/// other sharer (refcount > 1), so preemption must never be counted on
/// to reclaim a shared session's whole footprint.
#[test]
fn preempting_a_prefix_sharer_frees_only_its_private_blocks() {
    let cfg = ReferenceConfig {
        max_tokens: 64,
        kv_block_tokens: 8,
        kv_pool_blocks: 8,
        ..ReferenceConfig::default()
    };
    // control trajectory for the sharer request (sharing and resuming
    // must both be invisible in the output)
    let text = "shared system prompt"; // exactly 20 byte-tokens
    let mut control = Engine::new(
        LlmRuntime::reference(cfg.clone()),
        EngineConfig { max_active: 4, ..EngineConfig::default() },
    );
    control.submit(text, 8, Sampling::Greedy);
    let control_text = control.run_all().unwrap()[0].text.clone();

    let mut eng = Engine::new(
        LlmRuntime::reference(cfg.clone()),
        EngineConfig { max_active: 4, ..EngineConfig::default() },
    );

    // an out-of-band elder sharer: 20 tokens = 2 full blocks + a
    // boundary block, registered in the prefix index by prefill
    let toks = edgellm::coordinator::tokenizer::encode(text);
    assert_eq!(toks.len(), 20);
    let (_, mut elder) = eng.runtime().prefill(&toks).unwrap();
    let pinned = |eng: &Engine| {
        let m = eng.runtime().memory().unwrap();
        m.blocks_total - m.blocks_free
    };
    assert_eq!(pinned(&eng), 3);

    // the scheduled sharer adopts the elder's two full blocks and
    // copy-on-writes the boundary block: one private block
    let ha = eng.submit(text, 8, Sampling::Greedy);
    eng.step_round().unwrap();
    assert_eq!(eng.active_sessions(), 1);
    assert_eq!(
        pinned(&eng),
        4,
        "the sharer must pin only its copy-on-write boundary block"
    );
    assert_eq!(eng.runtime().memory().unwrap().prefix_hits, 1);

    // a hog drains the rest of the pool behind the gate's back
    let (mut hog_logits, mut hog) = eng.runtime().prefill(&[7, 7, 7]).unwrap();
    while eng.runtime().memory().unwrap().blocks_free > 0 {
        let t = edgellm::runtime::model::argmax(&hog_logits);
        hog_logits = eng.runtime().decode(&mut hog, t).unwrap();
    }

    // the sharer crosses its next block boundary -> preempted (youngest
    // and only active session)
    for _ in 0..10 {
        eng.step_round().unwrap();
        if eng.metrics().preempted > 0 {
            break;
        }
    }
    assert_eq!(eng.metrics().preempted, 1);
    assert_eq!(eng.metrics().requeued, 1);
    assert_eq!(eng.active_sessions(), 0);
    assert_eq!(eng.pending(), 1, "the sharer is requeued, not failed");

    // the core claim: eviction returned exactly the sharer's one
    // private block — had the shared prefix been counted reclaimable,
    // three blocks would have come back
    assert_eq!(
        eng.runtime().memory().unwrap().blocks_free,
        1,
        "preemption must free only the victim's private blocks"
    );

    // the elder's adopted-from blocks are untouched: its next decode is
    // bit-identical to an unshared control run
    let control_rt = LlmRuntime::reference(cfg);
    let (_, mut ctrl_elder) = control_rt.prefill(&toks).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let le = eng.runtime().decode(&mut elder, 5).unwrap();
    let lc = control_rt.decode(&mut ctrl_elder, 5).unwrap();
    assert_eq!(bits(&le), bits(&lc), "shared prefix corrupted by preemption");

    // release the hog: the evicted sharer resumes over the elder's
    // still-resident prefix and completes bit-identically
    eng.runtime().end_session(&mut hog);
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    let c = ha.wait().expect("the preempted sharer must still complete");
    assert_eq!(c.n_generated, 8);
    assert_eq!(c.text, control_text, "resumed sharer must match the control run");
}

/// Chunked prefill: a long prompt is warmed into the prefix cache one
/// chunk per admission slot instead of paying a monolithic prefill, and
/// the final admission adopts the warmed blocks — same trajectory as an
/// unchunked run, bounded prefill work per round.
#[test]
fn chunked_prefill_warms_across_rounds_and_matches_unchunked() {
    let cfg = ReferenceConfig {
        kv_block_tokens: 8,
        kv_pool_blocks: 32,
        ..ReferenceConfig::default()
    };
    let prompt = format!("{:<40}", "long document"); // 40 byte-tokens
    let mut control = Engine::new(LlmRuntime::reference(cfg.clone()), EngineConfig::default());
    control.submit(&prompt, 8, Sampling::Greedy);
    let control_text = control.run_all().unwrap()[0].text.clone();

    let mut eng = Engine::new(
        LlmRuntime::reference(cfg),
        EngineConfig {
            prefill_chunk_tokens: 8,
            ..EngineConfig::default()
        },
    );
    let h = eng.submit(&prompt, 8, Sampling::Greedy);
    // the first round only warms (prefills_per_round chunks): the
    // request stays queued and nothing is live — the bounded-work
    // property that keeps one huge prompt from stalling live decodes
    eng.step_round().unwrap();
    assert_eq!(eng.active_sessions(), 0, "warming rounds admit nothing");
    assert_eq!(eng.pending(), 1);
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].n_generated, 8);
    assert_eq!(done[0].text, control_text, "chunking must not change the trajectory");
    assert!(h.wait().is_ok());
    let mem = eng.runtime().memory().unwrap();
    assert!(
        mem.prefix_hits > 0,
        "the final prefill must adopt warmed blocks, not recompute: {mem:?}"
    );
}

/// The two-class queue: a latency-class arrival jumps waiting batch
/// work, but only until the batch head has aged past the
/// anti-starvation bound — then it holds its turn.
#[test]
fn latency_class_jumps_batch_queue_with_bounded_starvation() {
    // returns completion order (ids) for (blocker, batch, vip)
    let order = |aging_rounds: u64| -> (u64, u64, Vec<u64>) {
        let mut eng = Engine::new(
            LlmRuntime::reference(ReferenceConfig::default()),
            EngineConfig {
                max_active: 1,
                batch_aging_rounds: aging_rounds,
                ..EngineConfig::default()
            },
        );
        // a 6-round blocker so the queue actually waits
        eng.submit("running", 6, Sampling::Greedy);
        eng.step_round().unwrap();
        let batch = eng.submit("batch work", 2, Sampling::Greedy).id();
        let vip = eng
            .submit_with_priority("interactive", 2, Sampling::Greedy, Priority::Latency)
            .id();
        let ids = eng.run_all().unwrap().iter().map(|c| c.id).collect();
        (batch, vip, ids)
    };

    // generous bound: the blocker's 6 rounds never age the batch head,
    // so the latency request is admitted (and so retires) first
    let (batch, vip, ids) = order(32);
    let pos = |id: u64, ids: &[u64]| ids.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(vip, &ids) < pos(batch, &ids),
        "latency class must jump waiting batch work: {ids:?}"
    );

    // tight bound: by the time a slot frees, the batch head has waited
    // out the aging rounds and can no longer be jumped
    let (batch, vip, ids) = order(2);
    assert!(
        pos(batch, &ids) < pos(vip, &ids),
        "an aged batch head must hold its turn: {ids:?}"
    );
}

fn send_request(addr: std::net::SocketAddr, body: String) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{body}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

/// Eight simultaneous TCP clients share one scheduler; everyone gets
/// their own completion.
#[test]
fn tcp_server_serves_concurrent_clients() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let eng = engine_with(4);
    thread::spawn(move || {
        let _ = server::serve_on(eng, listener);
    });

    let handles: Vec<_> = (0..8)
        .map(|i| {
            thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "client {i} says hi", "max_new_tokens": {}}}"#,
                    4 + i
                );
                send_request(addr, body)
            })
        })
        .collect();

    let mut counts = Vec::new();
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.get("error").is_none(), "{reply}");
        counts.push(reply.get("n_generated").unwrap().as_usize().unwrap());
    }
    counts.sort_unstable();
    assert_eq!(counts, vec![4, 5, 6, 7, 8, 9, 10, 11]);

    // server-side stats: every request went through the one scheduler
    // (pool overlap itself is asserted deterministically in
    // concurrent_requests_complete_with_correct_token_counts — here the
    // degree of overlap depends on client thread timing)
    let stats = send_request(addr, r#"{"stats": true}"#.to_string());
    assert_eq!(stats.get("completed").unwrap().as_usize(), Some(8));
    assert_eq!(stats.get("decode_tokens").unwrap().as_usize(), Some(60));

    // protocol errors come back as structured replies over TCP too
    let err = send_request(addr, r#"{"max_new_tokens": 4}"#.to_string());
    assert!(err.get("error").is_some());
}
