//! Serving-path integration tests: engine queue → decode loop → protocol.
//!
//! Runs against the pure-Rust reference backend, so the whole path is
//! exercised on any machine — no AOT artifacts needed.

use edgellm::coordinator::engine::{Engine, EngineConfig};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server::process_line;
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::ReferenceConfig;

fn engine() -> Engine {
    Engine::new(
        LlmRuntime::reference(ReferenceConfig::default()),
        EngineConfig::default(),
    )
}

/// max_tokens=32 with prefill buckets [8, 16, 32].
fn small_engine() -> Engine {
    Engine::new(
        LlmRuntime::reference(ReferenceConfig {
            max_tokens: 32,
            ..ReferenceConfig::default()
        }),
        EngineConfig::default(),
    )
}

#[test]
fn engine_serves_fifo_requests() {
    let mut eng = engine();
    eng.submit("Hello", 4, Sampling::Greedy);
    eng.submit("World", 6, Sampling::Greedy);
    assert_eq!(eng.pending(), 2);
    let all = eng.run_all().unwrap();
    assert_eq!(all.len(), 2);
    assert_eq!(all[0].id, 1);
    assert_eq!(all[1].id, 2);
    assert_eq!(all[0].n_generated, 4);
    assert_eq!(all[1].n_generated, 6);
    assert!(all[0].tokens_per_s > 0.0);
    assert!(all[0].sim_tokens_per_s > 0.0);
}

#[test]
fn greedy_generation_is_deterministic() {
    let mut eng = engine();
    eng.submit("abc", 8, Sampling::Greedy);
    eng.submit("abc", 8, Sampling::Greedy);
    let all = eng.run_all().unwrap();
    assert_eq!(all[0].text, all[1].text);
}

#[test]
fn generation_respects_kv_budget() {
    let mut eng = small_engine();
    let long_prompt = "x".repeat(100);
    eng.submit(&long_prompt, 1000, Sampling::Greedy);
    let c = eng.step().unwrap().unwrap();
    // prompt clamped to the largest prefill bucket, generation clamped
    // to the remaining cache budget
    assert!(c.n_prompt <= 32, "{}", c.n_prompt);
    assert!(c.n_prompt + c.n_generated <= 32);
}

#[test]
fn protocol_request_response() {
    let mut eng = engine();
    let reply = process_line(
        &mut eng,
        r#"{"prompt": "Hi", "max_new_tokens": 3, "temperature": 0}"#,
    );
    assert!(reply.get("error").is_none(), "{reply}");
    assert_eq!(reply.get("n_generated").unwrap().as_usize(), Some(3));
    assert!(reply.get("text").is_some());
    assert!(reply.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn protocol_rejects_bad_input_with_structured_errors() {
    let mut eng = engine();
    // malformed JSON
    let r = process_line(&mut eng, "not json");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("json"));
    // missing prompt
    let r = process_line(&mut eng, r#"{"no_prompt": 1}"#);
    assert!(r.get("error").unwrap().as_str().unwrap().contains("prompt"));
    // out-of-range max_new_tokens: zero, negative, huge, non-numeric
    for bad in [
        r#"{"prompt":"x","max_new_tokens":0}"#,
        r#"{"prompt":"x","max_new_tokens":-5}"#,
        r#"{"prompt":"x","max_new_tokens":1000000}"#,
        r#"{"prompt":"x","max_new_tokens":"ten"}"#,
    ] {
        let r = process_line(&mut eng, bad);
        let msg = r.get("error").expect("error reply").as_str().unwrap();
        assert!(msg.contains("max_new_tokens"), "{bad} -> {msg}");
    }
    // the engine survived all of it
    let ok = process_line(&mut eng, r#"{"prompt":"Hi","max_new_tokens":2}"#);
    assert_eq!(ok.get("n_generated").unwrap().as_usize(), Some(2));
}

#[test]
fn protocol_stats_reply() {
    let mut eng = engine();
    process_line(&mut eng, r#"{"prompt":"warm up","max_new_tokens":4}"#);
    let stats = process_line(&mut eng, r#"{"stats": true}"#);
    assert_eq!(stats.get("completed").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("decode_tokens").unwrap().as_usize(), Some(4));
    assert_eq!(stats.get("cancelled").unwrap().as_usize(), Some(0));
    assert!(stats.get("sim_tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
}

/// v2 requests on the synchronous path: `stream` is accepted (answered
/// with the whole v1 reply — line streaming lives in the threaded
/// server), `cancel` answers found:false with nothing in flight, and a
/// malformed cancel id is a structured error.
#[test]
fn protocol_v2_on_sync_path() {
    let mut eng = engine();
    let reply = process_line(
        &mut eng,
        r#"{"prompt": "Hi", "max_new_tokens": 3, "stream": true}"#,
    );
    assert!(reply.get("error").is_none(), "{reply}");
    assert_eq!(reply.get("n_generated").unwrap().as_usize(), Some(3));

    let reply = process_line(&mut eng, r#"{"prompt": "Hi", "stream": "yes"}"#);
    assert!(reply.get("error").unwrap().as_str().unwrap().contains("stream"));

    let reply = process_line(&mut eng, r#"{"cancel": 999}"#);
    assert_eq!(reply.get("cancelled").unwrap().as_usize(), Some(999));
    assert_eq!(reply.get("found").unwrap().as_bool(), Some(false));

    let reply = process_line(&mut eng, r#"{"cancel": "one"}"#);
    assert!(reply.get("error").unwrap().as_str().unwrap().contains("cancel"));
}

#[test]
fn tokenizer_sampler_roundtrip_is_deterministic() {
    use edgellm::coordinator::sampler::{sample, Sampling as S};
    use edgellm::coordinator::tokenizer::{decode, encode};
    use edgellm::util::rng::Rng;

    // tokenizer: byte round-trip is lossless and stable across calls
    let text = "EdgeLLM round-trip ✓ — bytes 0..255 stay bytes";
    let toks = encode(text);
    assert_eq!(encode(text), toks);
    assert_eq!(decode(&toks), text);
    assert!(toks.iter().all(|&t| (0..256).contains(&t)));

    // sampler: identical logits + identically-seeded RNGs draw the same
    // token sequence for every policy (the serving determinism contract)
    let logits: Vec<f32> = (0..256).map(|i| ((i * 37) % 101) as f32 / 25.0).collect();
    for policy in [
        S::Greedy,
        S::Temperature(0.8),
        S::TopP { p: 0.9, temperature: 1.2 },
    ] {
        let mut r1 = Rng::new(1234);
        let mut r2 = Rng::new(1234);
        for _ in 0..64 {
            assert_eq!(
                sample(&logits, policy, &mut r1),
                sample(&logits, policy, &mut r2)
            );
        }
    }
}

#[test]
fn temperature_sampling_changes_output() {
    let mut eng = engine();
    eng.submit("seed text", 12, Sampling::Temperature(5.0));
    eng.submit("seed text", 12, Sampling::Temperature(5.0));
    let all = eng.run_all().unwrap();
    // hot sampling with different RNG positions: overwhelmingly different
    assert_ne!(all[0].text, all[1].text);
}
