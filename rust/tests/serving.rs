//! Serving-path integration tests: engine queue → decode loop → protocol.
//! Requires `make artifacts` (uses the fast `test` model).

use edgellm::coordinator::engine::{Engine, EngineConfig};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server::process_line;
use edgellm::runtime::model::LlmRuntime;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Engine> {
    if !artifacts_dir().join("test.manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let rt = LlmRuntime::load(artifacts_dir(), "test").unwrap();
    Some(Engine::new(rt, EngineConfig::default()))
}

#[test]
fn engine_serves_fifo_requests() {
    let Some(mut eng) = engine() else { return };
    eng.submit("Hello", 4, Sampling::Greedy);
    eng.submit("World", 6, Sampling::Greedy);
    assert_eq!(eng.pending(), 2);
    let all = eng.run_all().unwrap();
    assert_eq!(all.len(), 2);
    assert_eq!(all[0].id, 1);
    assert_eq!(all[1].id, 2);
    assert_eq!(all[0].n_generated, 4);
    assert_eq!(all[1].n_generated, 6);
    assert!(all[0].tokens_per_s > 0.0);
    assert!(all[0].sim_tokens_per_s > 0.0);
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(mut eng) = engine() else { return };
    eng.submit("abc", 8, Sampling::Greedy);
    eng.submit("abc", 8, Sampling::Greedy);
    let all = eng.run_all().unwrap();
    assert_eq!(all[0].text, all[1].text);
}

#[test]
fn generation_respects_kv_budget() {
    let Some(mut eng) = engine() else { return };
    // test model: max_tokens=32, largest prefill bucket=16.
    let long_prompt = "x".repeat(100);
    eng.submit(&long_prompt, 1000, Sampling::Greedy);
    let c = eng.step().unwrap().unwrap();
    // prompt clamped to bucket, generation clamped to cache budget
    assert!(c.n_prompt <= 16, "{}", c.n_prompt);
    assert!(c.n_prompt + c.n_generated <= 32);
}

#[test]
fn protocol_request_response() {
    let Some(mut eng) = engine() else { return };
    let reply = process_line(
        &mut eng,
        r#"{"prompt": "Hi", "max_new_tokens": 3, "temperature": 0}"#,
    )
    .unwrap();
    assert_eq!(reply.get("n_generated").unwrap().as_usize(), Some(3));
    assert!(reply.get("text").is_some());
    assert!(reply.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn protocol_rejects_bad_json() {
    let Some(mut eng) = engine() else { return };
    assert!(process_line(&mut eng, "not json").is_err());
    assert!(process_line(&mut eng, r#"{"no_prompt": 1}"#).is_err());
}

#[test]
fn temperature_sampling_changes_output() {
    let Some(mut eng) = engine() else { return };
    eng.submit("seed text", 12, Sampling::Temperature(5.0));
    eng.submit("seed text", 12, Sampling::Temperature(5.0));
    let all = eng.run_all().unwrap();
    // hot sampling with different RNG positions: overwhelmingly different
    assert_ne!(all[0].text, all[1].text);
}
