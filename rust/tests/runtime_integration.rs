//! End-to-end integration: AOT HLO artifacts executed from rust must
//! reproduce the python reference loop bit-for-bit (within f32 tolerance)
//! — plus always-run coverage of the `Backend` trait surface every
//! runtime constructor now funnels through.
//!
//! The artifact tests require `make artifacts` to have produced
//! artifacts/test.*.

use edgellm::models::{DENSE, GLM_6B, TINY};
use edgellm::runtime::backend::{Backend, ReferenceBackend, SimBackend};
use edgellm::runtime::model::{argmax, LlmRuntime};
use edgellm::runtime::reference::ReferenceConfig;
use edgellm::sim::Memory;
use edgellm::util::json::Json;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("test.manifest.json").exists()
}

#[test]
fn golden_generation_matches_python() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let golden: Json = Json::parse(
        &std::fs::read_to_string(dir.join("test.golden.json")).unwrap(),
    )
    .unwrap();
    let prompt: Vec<i32> = golden
        .get("prompt")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let expect_tokens: Vec<i32> = golden
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let expect_prefill_head: Vec<f32> = golden
        .get("prefill_logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();

    let rt = LlmRuntime::load(&dir, "test").expect("load test model");
    assert_eq!(rt.info.vocab, 256);

    let (logits, mut session) = rt.prefill(&prompt).expect("prefill");
    for (i, (&got, &want)) in
        logits.iter().zip(&expect_prefill_head).enumerate()
    {
        assert!(
            (got - want).abs() < 1e-4,
            "prefill logit {i}: {got} vs {want}"
        );
    }

    let mut cur = argmax(&logits);
    let mut generated = Vec::new();
    let mut last_logits = Vec::new();
    for _ in 0..expect_tokens.len() {
        generated.push(cur);
        last_logits = rt.decode(&mut session, cur).expect("decode");
        cur = argmax(&last_logits);
    }
    assert_eq!(generated, expect_tokens, "greedy token trajectory");

    let expect_decode_head: Vec<f32> = golden
        .get("last_decode_logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    for (i, (&got, &want)) in
        last_logits.iter().zip(&expect_decode_head).enumerate()
    {
        assert!(
            (got - want).abs() < 1e-4,
            "decode logit {i}: {got} vs {want}"
        );
    }
}

#[test]
fn session_respects_max_tokens() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = LlmRuntime::load(artifacts_dir(), "test").unwrap();
    let max = rt.info.max_tokens;
    let (_logits, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
    let mut steps = 0;
    while s.pos < max {
        rt.decode(&mut s, 7).unwrap();
        steps += 1;
    }
    assert_eq!(steps, max - 3);
    assert!(rt.decode(&mut s, 7).is_err(), "cache-full must error");
}

#[test]
fn prefill_rejects_oversized_prompt() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = LlmRuntime::load(artifacts_dir(), "test").unwrap();
    let too_long = vec![1i32; rt.info.max_tokens + 1];
    assert!(rt.prefill(&too_long).is_err());
    assert!(rt.prefill(&[]).is_err());
}

// ------------------------------------------------- trait surface (always)

/// The wrapper over a hand-boxed `ReferenceBackend` behaves exactly like
/// `LlmRuntime::reference` — the constructor is sugar, the trait is the
/// interface.
#[test]
fn boxed_reference_backend_is_the_reference_runtime() {
    let a = LlmRuntime::reference(ReferenceConfig::default());
    let b = LlmRuntime::from_backend(Box::new(ReferenceBackend::new(
        ReferenceConfig::default(),
    )));
    let prompt = [72, 101, 108, 108, 111];
    let (la, mut sa) = a.prefill(&prompt).unwrap();
    let (lb, mut sb) = b.prefill(&prompt).unwrap();
    assert_eq!(la, lb);
    assert_eq!(a.decode(&mut sa, 33).unwrap(), b.decode(&mut sb, 33).unwrap());
    assert!(a.supports_batched_decode() && b.supports_batched_decode());
    assert_eq!(a.ffn_weight_bytes(), b.ffn_weight_bytes());
    assert!(a.ffn_weight_bytes().unwrap() > 0);
}

/// The sim backend serves the same runtime contract: buckets, KV-budget
/// enforcement via the wrapper, deterministic greedy trajectories.
#[test]
fn sim_backend_honors_the_runtime_contract() {
    let rt = LlmRuntime::simulator(&TINY, &DENSE, Memory::Hbm, 32, 7);
    assert_eq!(rt.prefill_buckets(), &[8, 16, 32]);
    assert_eq!(rt.bucket_for(9), Some(16));
    // honest capability flags: no weight stream to share, no FFN
    assert!(!rt.supports_batched_decode());
    assert!(rt.ffn_weight_bytes().is_none());

    let (_l, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
    let mut tok = 5i32;
    while s.pos < rt.info.max_tokens {
        tok = argmax(&rt.decode(&mut s, tok).unwrap());
    }
    assert!(rt.decode(&mut s, tok).is_err(), "cache-full must error");

    // same seed → same greedy trajectory (the determinism the serving
    // tests lean on, backend-independent)
    let rt2 = LlmRuntime::simulator(&TINY, &DENSE, Memory::Hbm, 32, 7);
    let (l1, _) = rt.prefill(&[9, 9]).unwrap();
    let (l2, _) = rt2.prefill(&[9, 9]).unwrap();
    assert_eq!(l1, l2);
}

/// GLM-6B-shaped serving metadata without a single real weight: the
/// latency-model backend scales to paper-sized architectures.
#[test]
fn sim_backend_reports_paper_scale_architecture() {
    let rt = LlmRuntime::simulator(&GLM_6B, &DENSE, Memory::Hbm, 256, 0);
    assert_eq!(rt.info.d_model, 4096);
    assert_eq!(rt.info.n_layers, 28);
    assert!(rt.info.n_params > 5_000_000_000);
    let (l, s) = rt.prefill(&[40; 100]).unwrap();
    assert_eq!(l.len(), rt.info.vocab);
    assert_eq!(s.pos, 100);
}

/// `dyn Backend` round-trips through the trait object the scheduler
/// actually uses (no concrete types on the hot path).
#[test]
fn dyn_backend_dispatch_matches_concrete_calls() {
    let concrete = ReferenceBackend::new(ReferenceConfig::default());
    let (lc, _) = concrete.prefill(&[42, 43]).unwrap();
    let boxed: Box<dyn Backend> = Box::new(ReferenceBackend::new(ReferenceConfig::default()));
    let (ld, _) = boxed.prefill(&[42, 43]).unwrap();
    assert_eq!(lc, ld);
    assert_eq!(boxed.info().vocab, 256);

    let sim: Box<dyn Backend> = Box::new(SimBackend::new(&TINY, &DENSE, Memory::Hbm, 16, 1));
    assert!(!sim.supports_batched_decode(), "latency model steps, honestly");
}
