//! End-to-end integration: AOT HLO artifacts executed from rust must
//! reproduce the python reference loop bit-for-bit (within f32 tolerance).
//!
//! Requires `make artifacts` to have produced artifacts/test.*.

use edgellm::runtime::model::{argmax, LlmRuntime};
use edgellm::util::json::Json;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("test.manifest.json").exists()
}

#[test]
fn golden_generation_matches_python() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let golden: Json = Json::parse(
        &std::fs::read_to_string(dir.join("test.golden.json")).unwrap(),
    )
    .unwrap();
    let prompt: Vec<i32> = golden
        .get("prompt")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let expect_tokens: Vec<i32> = golden
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let expect_prefill_head: Vec<f32> = golden
        .get("prefill_logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();

    let rt = LlmRuntime::load(&dir, "test").expect("load test model");
    assert_eq!(rt.info.vocab, 256);

    let (logits, mut session) = rt.prefill(&prompt).expect("prefill");
    for (i, (&got, &want)) in
        logits.iter().zip(&expect_prefill_head).enumerate()
    {
        assert!(
            (got - want).abs() < 1e-4,
            "prefill logit {i}: {got} vs {want}"
        );
    }

    let mut cur = argmax(&logits);
    let mut generated = Vec::new();
    let mut last_logits = Vec::new();
    for _ in 0..expect_tokens.len() {
        generated.push(cur);
        last_logits = rt.decode(&mut session, cur).expect("decode");
        cur = argmax(&last_logits);
    }
    assert_eq!(generated, expect_tokens, "greedy token trajectory");

    let expect_decode_head: Vec<f32> = golden
        .get("last_decode_logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    for (i, (&got, &want)) in
        last_logits.iter().zip(&expect_decode_head).enumerate()
    {
        assert!(
            (got - want).abs() < 1e-4,
            "decode logit {i}: {got} vs {want}"
        );
    }
}

#[test]
fn session_respects_max_tokens() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = LlmRuntime::load(artifacts_dir(), "test").unwrap();
    let max = rt.info.max_tokens;
    let (_logits, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
    let mut steps = 0;
    while s.pos < max {
        rt.decode(&mut s, 7).unwrap();
        steps += 1;
    }
    assert_eq!(steps, max - 3);
    assert!(rt.decode(&mut s, 7).is_err(), "cache-full must error");
}

#[test]
fn prefill_rejects_oversized_prompt() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = LlmRuntime::load(artifacts_dir(), "test").unwrap();
    let too_long = vec![1i32; rt.info.max_tokens + 1];
    assert!(rt.prefill(&too_long).is_err());
    assert!(rt.prefill(&[]).is_err());
}
