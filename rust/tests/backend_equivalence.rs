//! Equivalence tests for the batched quantized backend (PR 2 acceptance):
//!
//! * batched `decode_batch` logits must match the sequential scalar path
//!   within 1e-4 for every session of a mixed-length batch (the backend
//!   actually guarantees bit-identity; the tolerance is the contract);
//! * the FP16×INT4 FFN fast path (dense nibble-packed and log-scale
//!   structured-sparse) must match its f32 dequantized reference;
//! * sequence-level GEMM prefill must equal token-by-token stepping.

use edgellm::quant::Sparsity;
use edgellm::runtime::model::{LlmRuntime, Session};
use edgellm::runtime::reference::{KernelTier, RefLlm, ReferenceConfig};
use edgellm::util::rng::Rng;

const TOL: f32 = 1e-4;

fn cfg(sparsity: Sparsity) -> ReferenceConfig {
    ReferenceConfig {
        max_tokens: 64,
        ffn_sparsity: sparsity,
        ..ReferenceConfig::default()
    }
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < TOL,
            "{what}: logit {i} diverged: {x} vs {y}"
        );
    }
}

/// Prefill the same mixed-length prompts twice: one set decoded
/// sequentially (scalar path), one set through `decode_batch`.
fn mixed_batch(rt: &LlmRuntime) -> (Vec<Session>, Vec<Session>) {
    let prompts: [&[i32]; 4] = [&[7], &[1, 2, 3], &[100, 90, 80, 70, 60, 50, 40], &[
        42, 42, 42, 42, 42, 42, 42, 42, 42, 42, 42, 42,
    ]];
    let mut seq = Vec::new();
    let mut bat = Vec::new();
    for p in prompts {
        let (la, sa) = rt.prefill(p).unwrap();
        let (lb, sb) = rt.prefill(p).unwrap();
        assert_close(&la, &lb, "prefill determinism");
        seq.push(sa);
        bat.push(sb);
    }
    (seq, bat)
}

#[test]
fn mixed_length_batched_decode_matches_sequential() {
    let rt = LlmRuntime::reference(cfg(Sparsity::Dense));
    let (mut seq, mut bat) = mixed_batch(&rt);
    // three consecutive rounds so later rounds see KV state produced by
    // earlier *batched* rounds
    let token_rounds = [[5i32, 6, 7, 8], [200, 201, 202, 203], [9, 9, 9, 9]];
    for (round, tokens) in token_rounds.iter().enumerate() {
        let scalar: Vec<Vec<f32>> = seq
            .iter_mut()
            .zip(tokens)
            .map(|(s, &t)| rt.decode(s, t).unwrap())
            .collect();
        let mut refs: Vec<&mut Session> = bat.iter_mut().collect();
        let batched = rt.decode_batch(&mut refs, tokens).unwrap();
        for (i, (a, b)) in scalar.iter().zip(&batched).enumerate() {
            assert_close(a, b, &format!("round {round} session {i}"));
        }
    }
    for (a, b) in seq.iter().zip(&bat) {
        assert_eq!(a.pos, b.pos, "positions must advance identically");
    }
}

#[test]
fn mixed_length_batched_decode_matches_sequential_sparse_ffn() {
    let rt = LlmRuntime::reference(cfg(Sparsity::Quarter));
    let (mut seq, mut bat) = mixed_batch(&rt);
    let tokens = [11i32, 12, 13, 14];
    let scalar: Vec<Vec<f32>> = seq
        .iter_mut()
        .zip(&tokens)
        .map(|(s, &t)| rt.decode(s, t).unwrap())
        .collect();
    let mut refs: Vec<&mut Session> = bat.iter_mut().collect();
    let batched = rt.decode_batch(&mut refs, &tokens).unwrap();
    for (i, (a, b)) in scalar.iter().zip(&batched).enumerate() {
        assert_close(a, b, &format!("sparse session {i}"));
    }
}

#[test]
fn batch_order_does_not_change_a_session() {
    // the same session decoded inside two differently-composed batches
    // must produce the same logits
    let rt = LlmRuntime::reference(cfg(Sparsity::Dense));
    let (_, mut a1) = rt.prefill(&[1, 2, 3]).unwrap();
    let (_, mut a2) = rt.prefill(&[1, 2, 3]).unwrap();
    let (_, mut x) = rt.prefill(&[50, 60]).unwrap();
    let (_, mut y) = rt.prefill(&[70, 80, 90, 100]).unwrap();

    let mut b1: Vec<&mut Session> = vec![&mut a1, &mut x];
    let l1 = rt.decode_batch(&mut b1, &[33, 44]).unwrap();
    let mut b2: Vec<&mut Session> = vec![&mut y, &mut a2];
    let l2 = rt.decode_batch(&mut b2, &[55, 33]).unwrap();
    assert_close(&l1[0], &l2[1], "session across batch compositions");
}

#[test]
fn quantized_ffn_matches_f32_dequant_reference() {
    for sparsity in [
        Sparsity::Dense,
        Sparsity::Half,
        Sparsity::Quarter,
        Sparsity::Eighth,
    ] {
        let m = RefLlm::new(cfg(sparsity));
        let d = m.info().d_model;
        let mut rng = Rng::new(2024);
        for li in 0..m.info().n_layers {
            for trial in 0..4 {
                let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let fast = m.ffn_fast(li, &x);
                let reference = m.ffn_reference(li, &x);
                for (i, (f, r)) in fast.iter().zip(&reference).enumerate() {
                    assert!(
                        (f - r).abs() < TOL,
                        "{sparsity:?} layer {li} trial {trial} out {i}: \
                         fast {f} vs reference {r}"
                    );
                }
            }
        }
    }
}

#[test]
fn gemm_prefill_matches_token_stepping() {
    for sparsity in [Sparsity::Dense, Sparsity::Half] {
        let rt = LlmRuntime::reference(cfg(sparsity));
        let prompt: Vec<i32> = (0..17).map(|i| (i * 13 + 5) % 256).collect();
        let (single, s_single) = rt.prefill(&prompt).unwrap();
        let (_, mut s_step) = rt.prefill(&prompt[..1]).unwrap();
        let mut stepped = Vec::new();
        for &t in &prompt[1..] {
            stepped = rt.decode(&mut s_step, t).unwrap();
        }
        assert_eq!(s_single.pos, s_step.pos);
        assert_close(&single, &stepped, "prefill vs stepping");
    }
}

#[test]
fn greedy_trajectories_identical_at_any_batch_size() {
    // full generation loop: 4 sessions advanced 12 rounds by greedy
    // argmax, scalar vs batched — trajectories must be identical
    let rt1 = LlmRuntime::reference(cfg(Sparsity::Dense));
    let prompts: [&[i32]; 4] = [&[10, 20], &[30], &[40, 50, 60, 70], &[80, 90, 100]];

    let mut scalar_traj: Vec<Vec<i32>> = Vec::new();
    for p in prompts {
        let (mut logits, mut s) = rt1.prefill(p).unwrap();
        let mut traj = Vec::new();
        for _ in 0..12 {
            let t = edgellm::runtime::model::argmax(&logits);
            traj.push(t);
            logits = rt1.decode(&mut s, t).unwrap();
        }
        scalar_traj.push(traj);
    }

    let mut sessions = Vec::new();
    let mut next = Vec::new();
    for p in prompts {
        let (logits, s) = rt1.prefill(p).unwrap();
        sessions.push(s);
        next.push(edgellm::runtime::model::argmax(&logits));
    }
    let mut batched_traj: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    for _ in 0..12 {
        for (traj, &t) in batched_traj.iter_mut().zip(&next) {
            traj.push(t);
        }
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let logits = rt1.decode_batch(&mut refs, &next).unwrap();
        for (n, l) in next.iter_mut().zip(&logits) {
            *n = edgellm::runtime::model::argmax(l);
        }
    }
    assert_eq!(scalar_traj, batched_traj);
}

/// Acceptance (paged KV): decoding through a block-granular arena is
/// *bit-identical* to the contiguous cache layout for mixed-length
/// batches — `kv_block_tokens = max_tokens` is the degenerate
/// one-block-per-session (contiguous) layout, 8-token blocks page every
/// session across a table, and the logits bits must agree at every
/// round. (The bridged-backend variant of this assertion lives in
/// rust/tests/bridge.rs::paged_device_blocks_are_bitwise_invisible_end_to_end.)
#[test]
fn paged_kv_decode_is_bit_identical_to_contiguous_for_mixed_batches() {
    let contiguous = LlmRuntime::reference(ReferenceConfig {
        kv_block_tokens: 64,
        ..cfg(Sparsity::Dense)
    });
    let paged = LlmRuntime::reference(ReferenceConfig {
        kv_block_tokens: 8,
        ..cfg(Sparsity::Dense)
    });
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    let prompts: [&[i32]; 4] = [&[7], &[1, 2, 3], &[100, 90, 80, 70, 60, 50, 40], &[
        42, 42, 42, 42, 42, 42, 42, 42, 42, 42, 42, 42,
    ]];
    let mut sc = Vec::new();
    let mut sp = Vec::new();
    for p in prompts {
        let (lc, s1) = contiguous.prefill(p).unwrap();
        let (lp, s2) = paged.prefill(p).unwrap();
        assert_eq!(bits(&lc), bits(&lp), "prefill bits diverged");
        sc.push(s1);
        sp.push(s2);
    }
    // enough rounds that every session crosses at least one 8-token
    // block boundary; later rounds read KV produced by earlier ones
    for round in 0..10 {
        let tokens: [i32; 4] = [round, round + 50, round + 100, round + 150];
        let mut rc: Vec<&mut Session> = sc.iter_mut().collect();
        let lc = contiguous.decode_batch(&mut rc, &tokens).unwrap();
        let mut rp: Vec<&mut Session> = sp.iter_mut().collect();
        let lp = paged.decode_batch(&mut rp, &tokens).unwrap();
        for (i, (a, b)) in lc.iter().zip(&lp).enumerate() {
            assert_eq!(bits(a), bits(b), "round {round} session {i} bits diverged");
        }
    }
    for (a, b) in sc.iter().zip(&sp) {
        assert_eq!(a.pos, b.pos);
    }
}

/// Acceptance (prefix sharing): K sessions prefilled with one identical
/// prompt hold exactly **one** physical copy of the full-block prefix —
/// each extra session pins only its private copy-on-write boundary
/// block — and every shared session's decode logits are *byte*-identical
/// to a private session on a runtime that never shares anything.
#[test]
fn shared_prefix_decode_is_bit_identical_to_private() {
    let paged = ReferenceConfig {
        kv_block_tokens: 8,
        ..cfg(Sparsity::Dense)
    };
    let sharing = LlmRuntime::reference(paged.clone());
    // control: same weights/config, but each prompt is prefilled once,
    // so nothing is ever adopted from the prefix index
    let private = LlmRuntime::reference(paged);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    // two full 8-token blocks plus a partially-filled boundary block
    let prompt: Vec<i32> = (0..19).map(|i| (i * 7 + 3) % 256).collect();
    let (lp, mut control) = private.prefill(&prompt).unwrap();

    const K: usize = 4;
    let mut sessions = Vec::new();
    let mut pinned_after_first = 0;
    for k in 0..K {
        let hint = sharing.shared_prefix_len(&prompt);
        if k == 0 {
            assert_eq!(hint, 0, "cold index must report no shared prefix");
        } else {
            // whole-prompt hit: everything but the final token is resident
            assert_eq!(hint, prompt.len() - 1);
        }
        let (l, s) = sharing.prefill_from(&prompt, hint).unwrap();
        assert_eq!(bits(&l), bits(&lp), "prefill bits diverged at session {k}");
        sessions.push(s);

        let m = sharing.memory().unwrap();
        let pinned = m.blocks_total - m.blocks_free;
        if k == 0 {
            pinned_after_first = pinned;
            assert_eq!(pinned, 3, "19 tokens at bt=8 span 3 blocks");
        } else {
            // one physical copy of the 2 full prefix blocks; each extra
            // session owns only its CoW'd boundary block
            assert_eq!(
                pinned,
                pinned_after_first + k as u64,
                "session {k} pinned more than its boundary block"
            );
        }
    }
    assert_eq!(
        sharing.memory().unwrap().prefix_hits,
        (K - 1) as u64,
        "every warm prefill must adopt from the index"
    );

    // enough rounds that every session fills its boundary block and
    // grows a fresh one (pos 19 -> 27 crosses the 24-token boundary)
    for round in 0..8i32 {
        let t = (round * 31 + 11) % 256;
        let want = bits(&private.decode(&mut control, t).unwrap());
        for (k, s) in sessions.iter_mut().enumerate() {
            let got = bits(&sharing.decode(s, t).unwrap());
            assert_eq!(got, want, "round {round} session {k} bits diverged");
        }
    }
    for s in &sessions {
        assert_eq!(s.pos, control.pos);
    }
}

fn logit_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Prefill a mixed-length batch and run several batched decode rounds,
/// returning every logits vector as raw bits — the whole observable
/// compute trajectory of the runtime.
fn bit_trajectory(rt: &LlmRuntime) -> Vec<Vec<u32>> {
    let prompts: [&[i32]; 3] = [&[3, 1, 4, 1, 5], &[9], &[2, 7, 1, 8, 2, 8, 1, 8, 2, 8]];
    let mut out = Vec::new();
    let mut sessions = Vec::new();
    for p in prompts {
        let (l, s) = rt.prefill(p).unwrap();
        out.push(logit_bits(&l));
        sessions.push(s);
    }
    for round in 0..6i32 {
        let tokens: Vec<i32> = (0..3).map(|i| (round * 3 + i) * 17 % 256).collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        for l in rt.decode_batch(&mut refs, &tokens).unwrap() {
            out.push(logit_bits(&l));
        }
    }
    out
}

/// Acceptance (kernel tiers, PR 10): the `Simd` and `SimdParallel`
/// tiers run the scalar oracle's per-element operation sequence
/// unchanged (mul+add, never FMA; vectorization only across independent
/// accumulators), so every logits vector — prefill and decode, at every
/// round — is **bit**-identical to the `Scalar` tier, at any thread
/// count. Shapes are chosen hostile: `d_model = 20` gives `d_ffn = 80`
/// and a 256-wide logits head with partial tail lanes, the batch (3) is
/// smaller than the largest pool (8), and one prompt is a single token.
#[test]
fn kernel_tiers_are_bit_identical_across_thread_counts() {
    let mk = |tier, threads| {
        LlmRuntime::reference(ReferenceConfig {
            d_model: 20, // not a vector-lane multiple: exercises tails
            kernel_tier: tier,
            threads,
            ..cfg(Sparsity::Dense)
        })
    };
    let want = bit_trajectory(&mk(KernelTier::Scalar, 1));
    let simd = bit_trajectory(&mk(KernelTier::Simd, 1));
    assert_eq!(want, simd, "simd tier diverged from the scalar oracle");
    for threads in [1usize, 2, 8] {
        let got = bit_trajectory(&mk(KernelTier::SimdParallel, threads));
        assert_eq!(want, got, "simd-parallel({threads}) diverged from the scalar oracle");
    }
}

/// Same tier matrix over the structured-sparse FFN path (the gather
/// kernel) and a paged arena small enough that sessions cross block
/// boundaries mid-trajectory.
#[test]
fn kernel_tiers_are_bit_identical_on_sparse_paged_path() {
    let mk = |tier, threads| {
        LlmRuntime::reference(ReferenceConfig {
            kernel_tier: tier,
            threads,
            kv_block_tokens: 8,
            ..cfg(Sparsity::Quarter)
        })
    };
    let want = bit_trajectory(&mk(KernelTier::Scalar, 1));
    for threads in [2usize, 8] {
        let got = bit_trajectory(&mk(KernelTier::SimdParallel, threads));
        assert_eq!(want, got, "sparse simd-parallel({threads}) diverged");
    }
}

#[test]
fn decode_batch_rejects_full_session_without_corrupting_others() {
    let rt = LlmRuntime::reference(ReferenceConfig {
        max_tokens: 8,
        ..ReferenceConfig::default()
    });
    let (_, mut full) = rt.prefill(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
    let (_, mut ok) = rt.prefill(&[1]).unwrap();
    let pos_before = ok.pos;
    let mut refs: Vec<&mut Session> = vec![&mut ok, &mut full];
    assert!(rt.decode_batch(&mut refs, &[1, 2]).is_err());
    // the full-cache error happens before any session advances
    assert_eq!(ok.pos, pos_before);
}
