//! Backend-trait surface tests: a mock backend proves the trait is
//! object-safe and makes the scheduler testable without any model, and
//! a deliberately *slow* mock makes the v2 streaming/cancellation
//! protocol deterministic over real TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use edgellm::coordinator::engine::{Engine, EngineConfig, Event};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server;
use edgellm::runtime::backend::Backend;
use edgellm::runtime::model::{LlmRuntime, ModelInfo, Session};
use edgellm::util::json::Json;

/// A model-free backend: greedy decoding walks the byte ring
/// `t → (t+1) mod 256`. No weights, no KV tensors, no RNG — pure
/// scheduler fuel. `decode_delay` throttles each decode call so tests
/// can observe (and interrupt) generation mid-flight.
struct MockBackend {
    info: ModelInfo,
    buckets: Vec<usize>,
    decode_delay: Duration,
    decodes: Arc<AtomicUsize>,
}

impl MockBackend {
    fn new(max_tokens: usize, decode_delay: Duration) -> Self {
        let info = ModelInfo {
            name: "mock".to_string(),
            vocab: 256,
            d_model: 1,
            n_layers: 1,
            n_heads: 1,
            n_kv_heads: 1,
            d_ffn: 1,
            max_tokens,
            head_dim: 1,
            n_params: 0,
            cache_shape: [0, 0, 0, 0],
        };
        MockBackend {
            info,
            buckets: vec![max_tokens],
            decode_delay,
            decodes: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Logits whose argmax is `(token + 1) mod 256`.
    fn ring_logits(token: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; 256];
        l[(token.rem_euclid(256) as usize + 1) % 256] = 1.0;
        l
    }
}

impl Backend for MockBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let mut s = Session::new([0, 0, 0, 0]);
        s.pos = prompt.len();
        Ok((Self::ring_logits(*prompt.last().expect("validated")), s))
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        self.decodes.fetch_add(1, Ordering::Relaxed);
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        session.pos += 1;
        Ok(Self::ring_logits(token))
    }
}

fn mock_engine(max_active: usize, delay: Duration) -> (Engine, Arc<AtomicUsize>) {
    let mock = MockBackend::new(4096, delay);
    let decodes = Arc::clone(&mock.decodes);
    let eng = Engine::new(
        LlmRuntime::from_backend(Box::new(mock)),
        EngineConfig {
            max_active,
            ..EngineConfig::default()
        },
    );
    (eng, decodes)
}

#[test]
fn trait_is_object_safe_and_wrapper_validates() {
    // Box<dyn Backend> through the LlmRuntime wrapper: the mock never
    // sees invalid input because the wrapper validates
    let boxed: Box<dyn Backend> = Box::new(MockBackend::new(8, Duration::ZERO));
    let rt = LlmRuntime::from_backend(boxed);
    assert!(!rt.supports_batched_decode(), "mock keeps the default flag");
    assert!(rt.ffn_weight_bytes().is_none());
    assert!(rt.prefill(&[]).is_err(), "wrapper rejects empty prompts");
    assert!(rt.prefill(&[0; 9]).is_err(), "wrapper rejects oversized prompts");

    let (logits, mut s) = rt.prefill(&[65]).unwrap();
    assert_eq!(logits.len(), 256);
    assert_eq!(s.pos, 1);
    // default decode_batch steps sessions one by one
    let (_l, mut s2) = rt.prefill(&[70]).unwrap();
    let mut batch = vec![&mut s, &mut s2];
    let out = rt.decode_batch(&mut batch, &[65, 70]).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0][66], 1.0, "ring argmax moved one byte forward");
    assert_eq!(out[1][71], 1.0);

    // wrapper enforces the KV budget for the whole batch
    s.pos = 8;
    let mut full = vec![&mut s];
    assert!(rt.decode_batch(&mut full, &[1]).is_err());
}

#[test]
fn scheduler_runs_on_a_mock_backend() {
    // the whole continuous-batching scheduler, zero model involved
    let (mut eng, decodes) = mock_engine(4, Duration::ZERO);
    let mut want = Vec::new();
    for i in 0..6 {
        let max_new = 3 + i;
        let h = eng.submit(&format!("req {i}"), max_new, Sampling::Greedy);
        want.push((h.id(), max_new));
    }
    let done = eng.run_all().unwrap();
    let mut got: Vec<(u64, usize)> = done.iter().map(|c| (c.id, c.n_generated)).collect();
    got.sort_unstable();
    assert_eq!(got, want);
    // greedy on the byte ring: consecutive bytes after the prompt's last
    let c0 = done.iter().find(|c| c.id == want[0].0).unwrap();
    let last = *c0.prompt.as_bytes().last().unwrap() as i32;
    let expect: Vec<u8> = (1..=c0.n_generated as i32)
        .map(|k| ((last + k).rem_euclid(256)) as u8)
        .collect();
    assert_eq!(c0.text.as_bytes(), expect.as_slice());
    assert!(decodes.load(Ordering::Relaxed) > 0);
    assert_eq!(eng.metrics().completed, 6);
}

#[test]
fn cancellation_mid_decode_frees_slot_and_is_counted() {
    let (mut eng, _) = mock_engine(1, Duration::ZERO);
    let ha = eng.submit("aaaa", 50, Sampling::Greedy);
    let hb = eng.submit("bbbb", 5, Sampling::Greedy);
    // with max_active=1, B waits in the queue behind A
    for _ in 0..3 {
        assert!(eng.step_round().unwrap().is_empty());
    }
    assert_eq!(eng.active_sessions(), 1);
    assert_eq!(eng.pending(), 1);

    ha.cancel();
    // next round: A is reaped before admission, B takes the slot
    eng.step_round().unwrap();
    assert_eq!(eng.metrics().cancelled, 1);
    assert_eq!(eng.pending(), 0);
    assert_eq!(eng.active_sessions(), 1, "slot reused by B in the same round");

    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, hb.id());
    assert_eq!(done[0].n_generated, 5);
    assert_eq!(eng.metrics().completed, 1);

    // A's stream: some tokens, then the terminal cancellation error
    let mut a_tokens = 0;
    let mut a_terminal = None;
    while let Some(ev) = ha.try_recv() {
        match ev {
            Event::Token(_) => a_tokens += 1,
            other => a_terminal = Some(other),
        }
    }
    assert!(a_tokens >= 2, "A decoded before cancellation ({a_tokens})");
    assert!(
        matches!(a_terminal, Some(Event::Error(ref m)) if m == "cancelled"),
        "{a_terminal:?}"
    );
}

#[test]
fn queued_request_cancelled_by_id_never_prefills() {
    let (mut eng, decodes) = mock_engine(1, Duration::ZERO);
    let _ha = eng.submit("live", 4, Sampling::Greedy);
    let hb = eng.submit("never admitted", 4, Sampling::Greedy);
    assert!(eng.cancel(hb.id()), "queued request found by id");
    let done = eng.run_all().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(eng.metrics().cancelled, 1);
    assert!(matches!(hb.wait(), Err(ref m) if m == "cancelled"));
    // only the live request's tokens were ever decoded
    assert_eq!(decodes.load(Ordering::Relaxed), 4);
}

// ---------------------------------------------------------------- TCP v2

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "connection closed early");
    Json::parse(line.trim()).unwrap()
}

/// Acceptance: a TCP client receives ≥2 token events before the final
/// line, and the final line is the v1 completion plus done:true.
#[test]
fn tcp_streaming_yields_token_events_then_final_line() {
    let (eng, _) = mock_engine(4, Duration::ZERO);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = server::spawn_on(eng, listener).unwrap();

    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    writeln!(
        stream,
        r#"{{"prompt": "stream me", "max_new_tokens": 6, "stream": true}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream);

    let ack = read_json_line(&mut reader);
    assert_eq!(ack.get("stream").and_then(|v| v.as_bool()), Some(true));
    let id = ack.get("id").unwrap().as_usize().unwrap();

    let mut tokens = Vec::new();
    let final_line = loop {
        let line = read_json_line(&mut reader);
        if line.get("done").is_some() {
            break line;
        }
        assert_eq!(line.get("id").unwrap().as_usize(), Some(id));
        assert_eq!(line.get("index").unwrap().as_usize(), Some(tokens.len()));
        tokens.push(line.get("token").unwrap().as_usize().unwrap());
    };
    assert!(tokens.len() >= 2, "want ≥2 token events, got {}", tokens.len());
    assert_eq!(tokens.len(), 6);
    assert_eq!(final_line.get("n_generated").unwrap().as_usize(), Some(6));
    assert!(final_line.get("error").is_none(), "{final_line}");
    // token ids reconstruct the final text (byte vocab)
    let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
    assert_eq!(
        final_line.get("text").unwrap().as_str().unwrap(),
        String::from_utf8_lossy(&bytes)
    );

    handle.shutdown();
}

/// Acceptance: `{"cancel": id}` from a second connection terminates an
/// in-flight stream early, and the freed slot serves a later request.
#[test]
fn tcp_cancel_terminates_stream_and_slot_is_reused() {
    // 10 ms per decode: ~3 s uncancelled, so an early terminal line can
    // only come from the cancel path
    let (eng, _) = mock_engine(1, Duration::from_millis(10));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = server::spawn_on(eng, listener).unwrap();

    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    writeln!(
        stream,
        r#"{{"prompt": "long one", "max_new_tokens": 300, "stream": true}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let ack = read_json_line(&mut reader);
    let id = ack.get("id").unwrap().as_usize().unwrap();

    // let at least two tokens stream before cancelling
    let mut seen = 0;
    while seen < 2 {
        let line = read_json_line(&mut reader);
        assert!(line.get("done").is_none(), "finished before cancel: {line}");
        seen += 1;
    }

    // cancel from a *different* connection
    let mut side = TcpStream::connect(handle.addr()).unwrap();
    writeln!(side, r#"{{"cancel": {id}}}"#).unwrap();
    let mut side_reader = BufReader::new(side);
    let reply = read_json_line(&mut side_reader);
    assert_eq!(reply.get("cancelled").unwrap().as_usize(), Some(id));
    assert_eq!(reply.get("found").unwrap().as_bool(), Some(true));

    // the stream terminates early with the cancellation error
    let terminal = loop {
        let line = read_json_line(&mut reader);
        if line.get("done").is_some() {
            break line;
        }
        seen += 1;
    };
    assert_eq!(terminal.get("error").and_then(|v| v.as_str()), Some("cancelled"));
    assert!(seen < 300, "cancel must cut generation short ({seen} tokens)");

    // the freed slot (max_active = 1) serves a fresh request to completion
    let side2 = TcpStream::connect(handle.addr()).unwrap();
    let mut w = side2.try_clone().unwrap();
    writeln!(w, r#"{{"prompt": "after cancel", "max_new_tokens": 3}}"#).unwrap();
    let mut r2 = BufReader::new(side2);
    let done = read_json_line(&mut r2);
    assert!(done.get("error").is_none(), "{done}");
    assert_eq!(done.get("n_generated").unwrap().as_usize(), Some(3));

    // server-side counters saw the cancellation
    let mut stats_conn = TcpStream::connect(handle.addr()).unwrap();
    writeln!(stats_conn, r#"{{"stats": true}}"#).unwrap();
    let mut rs = BufReader::new(stats_conn);
    let stats = read_json_line(&mut rs);
    assert_eq!(stats.get("cancelled").unwrap().as_usize(), Some(1));

    handle.shutdown();
}

/// The shutdown signal reaps the scheduler and accept threads — no test
/// relies on process exit.
#[test]
fn server_shutdown_reaps_threads_and_fails_inflight_requests() {
    let (eng, _) = mock_engine(1, Duration::from_millis(10));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = server::spawn_on(eng, listener).unwrap();
    let addr = handle.addr();

    // park a slow streaming request in flight
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(
        stream,
        r#"{{"prompt": "doomed", "max_new_tokens": 300, "stream": true}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let _ack = read_json_line(&mut reader);
    let _first_token = read_json_line(&mut reader);

    // shutdown() joins the scheduler + acceptor; returning at all is the
    // reaping guarantee
    handle.shutdown();

    // the in-flight stream was failed, not wedged: a terminal line with
    // done:true arrives (either the abort error or a just-finished round)
    let terminal = loop {
        let line = read_json_line(&mut reader);
        if line.get("done").is_some() {
            break line;
        }
    };
    assert!(terminal.get("error").is_some(), "{terminal}");
}
