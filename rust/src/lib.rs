//! EdgeLLM reproduction: rust coordinator + simulator over AOT JAX/Pallas compute.
pub mod baselines;
pub mod bridge;
pub mod compiler;
pub mod coordinator;
pub mod fp;
pub mod models;
pub mod obs;
pub mod pack;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
