//! Persistent worker pool for the parallel kernel tier — std threads
//! and channels only, no external dependencies.
//!
//! The pool exists to split *disjoint-output* work (GEMM column
//! stripes, per-session attention) across cores. Determinism is a
//! design invariant, not an aspiration: callers hand each task its own
//! output region and its own scratch, every floating-point operation
//! happens inside exactly one task, and no task reads another task's
//! output. The result is therefore bitwise independent of how many
//! workers exist or how the OS schedules them — the equivalence suite
//! asserts this across `threads ∈ {1, 2, 8}`.
//!
//! Panic discipline (this file is covered by the in-repo analyzer's
//! panic-path lint): the worker loop never unwraps, never indexes, and
//! never panics on its own. A panicking *task* is caught with
//! `catch_unwind`, reported through the completion channel, and
//! re-raised on the submitting thread with `resume_unwind` — after
//! every other in-flight task has been drained, so a panic can neither
//! deadlock the pool nor leave a worker running against freed borrows.

#![deny(missing_docs)]

use std::any::Any;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// A unit of work: runs once, writes only its own output region.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

/// Completion signal a worker sends after running one task.
enum Done {
    /// Task ran to completion.
    Ok,
    /// Task unwound; the payload is re-raised on the submitting thread.
    Panicked(Box<dyn Any + Send>),
}

/// One queued task plus the channel to acknowledge it on.
struct Job {
    task: Task<'static>,
    done: Sender<Done>,
}

/// A fixed set of persistent worker threads fed over per-worker
/// channels (round-robin). Workers park on `recv` between batches;
/// dropping the pool closes the channels and the threads exit.
///
/// `WorkerPool::new(1)` spawns no threads at all — `run` executes
/// inline on the caller, which is the degenerate (and still
/// bit-identical) single-core configuration.
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
}

fn worker_loop(rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let outcome = match catch_unwind(AssertUnwindSafe(job.task)) {
            Ok(()) => Done::Ok,
            Err(payload) => Done::Panicked(payload),
        };
        // the submitter may itself be unwinding and have dropped the
        // receiving end; a failed ack must not take the worker down
        let _ = job.done.send(outcome);
    }
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1; one
    /// thread means "inline", so `threads - 1` OS threads exist at
    /// most). Spawn failures degrade capacity instead of erroring: a
    /// pool that ends up with zero workers still runs everything
    /// inline, bit-identically.
    pub fn new(threads: usize) -> Self {
        let workers = threads.max(1) - 1;
        let mut senders = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            let spawned = thread::Builder::new()
                .name(format!("edgellm-pool-{i}"))
                .spawn(move || worker_loop(rx));
            if spawned.is_ok() {
                senders.push(tx);
            }
        }
        WorkerPool { senders }
    }

    /// Degree of parallelism `run` can deliver: workers plus the
    /// submitting thread. Partition work into this many pieces.
    pub fn threads(&self) -> usize {
        self.senders.len() + 1
    }

    /// Run every task to completion before returning. Tasks must write
    /// disjoint outputs; beyond that, no ordering is observable —
    /// results are bitwise identical for any worker count because each
    /// output element is produced by exactly one task.
    ///
    /// The last task runs inline on the submitting thread (it would
    /// otherwise just block), as does everything when no workers exist.
    /// If a task panics, the first payload is re-raised here — after
    /// *all* dispatched tasks have been drained, so no task can still
    /// be touching the `'scope` borrows when this frame unwinds.
    pub fn run<'scope>(&self, tasks: Vec<Task<'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if self.senders.is_empty() || tasks.len() == 1 {
            let mut first_panic: Option<Box<dyn Any + Send>> = None;
            for task in tasks {
                if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
            if let Some(p) = first_panic {
                resume_unwind(p);
            }
            return;
        }
        let (done_tx, done_rx) = channel::<Done>();
        let mut dispatched = 0usize;
        let mut inline: Vec<Task<'static>> = Vec::new();
        let keep_here = tasks.len().div_ceil(self.threads());
        let mut iter = tasks.into_iter();
        // the submitter's own share runs inline; everything else is
        // dealt round-robin to the workers
        for _ in 0..keep_here {
            if let Some(task) = iter.next() {
                // SAFETY: see the transmute justification below — inline
                // tasks trivially finish before `run` returns.
                inline.push(unsafe { erase_lifetime(task) });
            }
        }
        for (task, tx) in iter.zip(self.senders.iter().cycle()) {
            // SAFETY: the borrows captured in `task` live for `'scope`,
            // which outlives this call. `run` does not return (normally
            // or by unwind) until every dispatched job has acknowledged
            // completion on `done_rx`, and a worker acknowledges only
            // after the task has finished running — so no job ever
            // outlives `'scope` despite the erased lifetime.
            let task = unsafe { erase_lifetime(task) };
            match tx.send(Job { task, done: done_tx.clone() }) {
                Ok(()) => dispatched += 1,
                // worker gone (spawn raced a shutdown): reclaim the task
                // and run it inline rather than losing the work
                Err(returned) => inline.push(returned.0.task),
            }
        }
        drop(done_tx);
        let mut first_panic: Option<Box<dyn Any + Send>> = None;
        for task in inline {
            if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                if first_panic.is_none() {
                    first_panic = Some(p);
                }
            }
        }
        // drain every acknowledgement before returning or unwinding —
        // this blocking loop is what makes the lifetime erasure sound
        for _ in 0..dispatched {
            match done_rx.recv() {
                Ok(Done::Ok) => {}
                Ok(Done::Panicked(p)) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
                // all senders dropped: every outstanding job has already
                // acknowledged (workers send before dropping)
                Err(_) => break,
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
    }
}

/// Erase a task's borrow lifetime so it can cross the channel. Sound
/// only because [`WorkerPool::run`] blocks until the task has finished
/// (see the safety comments at the call sites).
unsafe fn erase_lifetime(task: Task<'_>) -> Task<'static> {
    std::mem::transmute::<Task<'_>, Task<'static>>(task)
}

/// Split `0..n` into at most `parts` contiguous, non-empty,
/// near-equal ranges covering every index exactly once.
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    partition_aligned(n, parts, 1)
}

/// [`partition`] with every boundary (except the final `n`) a multiple
/// of `align` — the q4 kernels need even column starts so a stripe
/// never splits a nibble-packed byte.
pub fn partition_aligned(n: usize, parts: usize, align: usize) -> Vec<Range<usize>> {
    let align = align.max(1);
    let parts = parts.max(1);
    let units = n.div_ceil(align);
    let step = units.div_ceil(parts) * align;
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < n {
        let end = (start + step).min(n);
        out.push(start..end);
        start = end;
    }
    out
}

/// Resolve the default worker count: `EDGELLM_THREADS` when set to a
/// positive integer, else the machine's available parallelism.
pub fn default_threads() -> usize {
    let from_env = std::env::var("EDGELLM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 0);
    match from_env {
        Some(t) => t,
        None => thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// A `*mut f32` that asserts cross-thread sendability. Used to hand
/// workers the *base* of a shared output buffer; each worker derives
/// `&mut` slices only for its own disjoint region, so no two threads
/// ever hold overlapping mutable views.
#[derive(Clone, Copy)]
pub struct SendPtr {
    ptr: *mut f32,
}

impl SendPtr {
    /// Wrap a base pointer (typically `slice.as_mut_ptr()`).
    pub fn new(ptr: *mut f32) -> Self {
        SendPtr { ptr }
    }

    /// The wrapped pointer.
    pub fn get(self) -> *mut f32 {
        self.ptr
    }
}

// SAFETY: SendPtr is a plain address. The disjointness contract that
// makes concurrent use sound is enforced by the kernel drivers (each
// task touches only its own column stripe) and documented at every
// construction site.
unsafe impl Send for SendPtr {}
// SAFETY: as above — shared access never materializes overlapping
// mutable views.
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_everything_once() {
        for n in [0usize, 1, 7, 8, 64, 257] {
            for parts in [1usize, 2, 3, 8, 300] {
                let ranges = partition(n, parts);
                assert!(ranges.len() <= parts.max(1));
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "gap at {n}/{parts}");
                    assert!(r.end > r.start, "empty range at {n}/{parts}");
                    next = r.end;
                }
                assert_eq!(next, n, "missing tail at {n}/{parts}");
            }
        }
    }

    #[test]
    fn partition_aligned_keeps_boundaries_aligned() {
        for n in [2usize, 10, 16, 30, 128, 130] {
            for parts in [1usize, 2, 3, 7] {
                let ranges = partition_aligned(n, parts, 2);
                for r in &ranges {
                    assert_eq!(r.start % 2, 0, "odd start at {n}/{parts}");
                }
                assert_eq!(ranges.last().unwrap().end, n);
            }
        }
    }

    #[test]
    fn pool_runs_disjoint_tasks_to_completion() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u32; 100];
        let ranges = partition(data.len(), pool.threads());
        {
            let mut rest = data.as_mut_slice();
            let mut tasks: Vec<Task> = Vec::new();
            for r in ranges {
                let (mine, tail) = rest.split_at_mut(r.len());
                rest = tail;
                let start = r.start as u32;
                tasks.push(Box::new(move || {
                    for (i, v) in mine.iter_mut().enumerate() {
                        *v = start + i as u32;
                    }
                }));
            }
            pool.run(tasks);
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v as usize, i);
        }
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = WorkerPool::new(3);
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task> = (0..6)
                .map(|i| {
                    Box::new(move || {
                        if i == 3 {
                            panic!("task {i} exploded");
                        }
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }));
        assert!(boom.is_err(), "panic must propagate to the submitter");
        // the pool is still serviceable afterwards
        let mut hits = vec![false; 8];
        let mut rest = hits.as_mut_slice();
        let mut tasks: Vec<Task> = Vec::new();
        for r in partition(8, pool.threads()) {
            let (mine, tail) = rest.split_at_mut(r.len());
            rest = tail;
            tasks.push(Box::new(move || {
                for v in mine.iter_mut() {
                    *v = true;
                }
            }));
        }
        pool.run(tasks);
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut x = 0u64;
        let tasks: Vec<Task> = vec![Box::new(|| x += 41), Box::new(|| ())];
        pool.run(tasks);
        x += 1;
        assert_eq!(x, 42);
    }

    #[test]
    fn more_tasks_than_workers() {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u8; 37];
        let mut rest = data.as_mut_slice();
        let mut tasks: Vec<Task> = Vec::new();
        while !rest.is_empty() {
            let take = rest.len().min(3);
            let (mine, tail) = rest.split_at_mut(take);
            rest = tail;
            tasks.push(Box::new(move || {
                for v in mine.iter_mut() {
                    *v = 1;
                }
            }));
        }
        pool.run(tasks);
        assert!(data.iter().all(|&v| v == 1));
    }
}
