//! Model runtime: execute the LLM forward pass for the serving engine.
//!
//! The serving scheduler drives one object-safe [`backend::Backend`]
//! trait; [`model::LlmRuntime`] is the thin validating wrapper around a
//! `Box<dyn Backend>`. Backends in-tree:
//!
//! * **Reference** (always built): a small pure-Rust transformer with
//!   real KV-cache semantics ([`reference`]), used by the serving /
//!   continuous-batching tests and the offline examples so the decode
//!   loop is exercised without artifacts.
//! * **Sim** ([`backend::SimBackend`], always built): the VCU128 latency
//!   model served as a functional backend — deterministic pseudo-tokens,
//!   no compute, any architecture size.
//! * **PJRT** (feature `pjrt`): load AOT-compiled HLO artifacts and run
//!   them through the `xla` crate — `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!   The python side (`python/compile/aot.py`) lowers the JAX/Pallas
//!   model to HLO *text* (see `/opt/xla-example/README.md` for why text,
//!   not proto). Needs a vendored `xla` crate + libxla, hence the gate.

#[cfg(feature = "pjrt")]
use anyhow::Result;
#[cfg(feature = "pjrt")]
use std::path::Path;

/// A compiled HLO executable bound to a PJRT client.
#[cfg(feature = "pjrt")]
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// Shared PJRT client wrapper. One per process.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<HloExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse hlo text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(HloExecutable {
            exe,
            name: path.display().to_string(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl HloExecutable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs; returns the elements of the result tuple.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single output
    /// buffer is a tuple literal; we decompose it for the caller.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {}: {e:?}", self.name))?;
        lit.decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose tuple {}: {e:?}", self.name))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape literal to {dims:?}: {e:?}"))
}

/// Extract an f32 vec from a literal.
#[cfg(feature = "pjrt")]
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec f32: {e:?}"))
}

pub mod backend;
pub mod kernels;
pub mod kv;
pub mod model;
pub mod pool;
pub mod reference;
pub mod weights;
