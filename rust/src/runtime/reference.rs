//! Pure-Rust reference LLM backend.
//!
//! A deliberately small autoregressive transformer (byte vocabulary,
//! seeded random weights) with the *same* session semantics as the AOT
//! artifact path: per-layer K/V caches indexed by position, prefill that
//! returns the last token's logits plus a fresh [`Session`], and one
//! decode step per generated token. It exists so the serving engine, the
//! continuous-batching scheduler, and the TCP protocol are exercised
//! end-to-end on any machine — no artifacts, no PJRT, no Python.
//!
//! Numbers produced here are functional, not paper numbers; the VCU128
//! performance model lives in `sim::engine` and is charged by the
//! serving engine independently of which functional backend runs.

use anyhow::{bail, Result};

use super::model::{ModelInfo, Session};
use crate::util::rng::Rng;

/// Byte-level vocabulary, matching `coordinator::tokenizer`.
pub const REF_VOCAB: usize = 256;

/// Dimensions of the reference model.
#[derive(Debug, Clone)]
pub struct ReferenceConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_tokens: usize,
    pub seed: u64,
}

impl Default for ReferenceConfig {
    fn default() -> Self {
        ReferenceConfig {
            name: "ref-tiny".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            max_tokens: 64,
            seed: 0x5EED,
        }
    }
}

/// Per-layer projection weights, row-major `d × d`.
struct Layer {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
}

pub struct RefLlm {
    info: ModelInfo,
    /// token embeddings, `REF_VOCAB × d`
    emb: Vec<f32>,
    layers: Vec<Layer>,
    /// output head, `REF_VOCAB × d`
    w_out: Vec<f32>,
    buckets: Vec<usize>,
}

fn init(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// `y = W x` for row-major `rows × d` W.
fn matvec(w: &[f32], x: &[f32], rows: usize) -> Vec<f32> {
    let d = x.len();
    let mut y = vec![0.0f32; rows];
    for (r, yr) in y.iter_mut().enumerate() {
        let row = &w[r * d..(r + 1) * d];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(x.iter()) {
            acc += a * b;
        }
        *yr = acc;
    }
    y
}

impl RefLlm {
    pub fn new(cfg: ReferenceConfig) -> Self {
        let d = cfg.d_model;
        let mut rng = Rng::new(cfg.seed);
        // 1/sqrt(d) keeps activations and logits O(1) through the depth
        let s = 1.0 / (d as f32).sqrt();
        let emb = init(&mut rng, REF_VOCAB * d, 1.0);
        let layers: Vec<Layer> = (0..cfg.n_layers)
            .map(|_| Layer {
                wq: init(&mut rng, d * d, s),
                wk: init(&mut rng, d * d, s),
                wv: init(&mut rng, d * d, s),
                wo: init(&mut rng, d * d, s),
            })
            .collect();
        let w_out = init(&mut rng, REF_VOCAB * d, s);
        // power-of-two prefill buckets up to max_tokens, mirroring the
        // AOT artifact layout (one compiled graph per bucket)
        let mut buckets = Vec::new();
        let mut b = 8usize;
        while b < cfg.max_tokens {
            buckets.push(b);
            b *= 2;
        }
        buckets.push(cfg.max_tokens);
        let n_params = emb.len() + layers.len() * 4 * d * d + w_out.len();
        let info = ModelInfo {
            name: cfg.name,
            vocab: REF_VOCAB,
            d_model: d,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_heads,
            d_ffn: 4 * d,
            max_tokens: cfg.max_tokens,
            head_dim: d / cfg.n_heads.max(1),
            n_params,
            cache_shape: [cfg.n_layers, cfg.max_tokens, 1, d],
        };
        RefLlm {
            info,
            emb,
            layers,
            w_out,
            buckets,
        }
    }

    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    pub fn prefill_buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn fresh_session(&self) -> Session {
        let [l, t, h, d] = self.info.cache_shape;
        Session {
            pos: 0,
            k_cache: vec![0.0; l * t * h * d],
            v_cache: vec![0.0; l * t * h * d],
            cache_dims: self.info.cache_shape.to_vec(),
        }
    }

    /// One forward step at `session.pos`: writes K/V rows, attends over
    /// the cache, advances the position, returns next-token logits.
    fn step(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        let d = self.info.d_model;
        let max_t = self.info.max_tokens;
        let pos = session.pos;
        if pos >= max_t {
            bail!("KV cache full (max_tokens={max_t})");
        }
        let tok = token.rem_euclid(REF_VOCAB as i32) as usize;
        let mut h: Vec<f32> = self.emb[tok * d..(tok + 1) * d].to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let q = matvec(&layer.wq, &h, d);
            let k = matvec(&layer.wk, &h, d);
            let v = matvec(&layer.wv, &h, d);
            let base = li * max_t * d;
            session.k_cache[base + pos * d..base + (pos + 1) * d].copy_from_slice(&k);
            session.v_cache[base + pos * d..base + (pos + 1) * d].copy_from_slice(&v);
            // causal attention over cached positions 0..=pos
            let inv_sqrt_d = 1.0 / (d as f32).sqrt();
            let mut scores = Vec::with_capacity(pos + 1);
            for i in 0..=pos {
                let ki = &session.k_cache[base + i * d..base + (i + 1) * d];
                let mut s = 0.0f32;
                for (a, b) in ki.iter().zip(q.iter()) {
                    s += a * b;
                }
                scores.push(s * inv_sqrt_d);
            }
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut wsum = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                wsum += *s;
            }
            let mut ctx = vec![0.0f32; d];
            for (i, s) in scores.iter().enumerate() {
                let a = s / wsum;
                let vi = &session.v_cache[base + i * d..base + (i + 1) * d];
                for (c, x) in ctx.iter_mut().zip(vi.iter()) {
                    *c += a * x;
                }
            }
            let o = matvec(&layer.wo, &ctx, d);
            for (hx, ox) in h.iter_mut().zip(o.iter()) {
                *hx = (*hx + ox).tanh();
            }
        }
        session.pos += 1;
        Ok(matvec(&self.w_out, &h, REF_VOCAB))
    }

    /// Prefill: run the prompt token by token against a fresh session,
    /// return the last token's logits plus the session.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let mut session = self.fresh_session();
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(&mut session, t)?;
        }
        Ok((logits, session))
    }

    /// One decode step.
    pub fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        self.step(session, token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_a_seed() {
        let a = RefLlm::new(ReferenceConfig::default());
        let b = RefLlm::new(ReferenceConfig::default());
        let (la, _) = a.prefill(&[72, 105]).unwrap();
        let (lb, _) = b.prefill(&[72, 105]).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn different_seeds_differ() {
        let a = RefLlm::new(ReferenceConfig::default());
        let b = RefLlm::new(ReferenceConfig {
            seed: 99,
            ..ReferenceConfig::default()
        });
        let (la, _) = a.prefill(&[72]).unwrap();
        let (lb, _) = b.prefill(&[72]).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn logits_depend_on_history() {
        // the same token decoded after different prefixes must see
        // different attention contexts
        let m = RefLlm::new(ReferenceConfig::default());
        let (_, mut s1) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut s2) = m.prefill(&[9, 8, 7]).unwrap();
        let l1 = m.decode(&mut s1, 42).unwrap();
        let l2 = m.decode(&mut s2, 42).unwrap();
        assert_ne!(l1, l2);
    }

    #[test]
    fn cache_full_errors() {
        let m = RefLlm::new(ReferenceConfig {
            max_tokens: 8,
            ..ReferenceConfig::default()
        });
        let (_, mut s) = m.prefill(&[1, 2, 3]).unwrap();
        for _ in 0..5 {
            m.decode(&mut s, 7).unwrap();
        }
        assert_eq!(s.pos, 8);
        assert!(m.decode(&mut s, 7).is_err());
    }

    #[test]
    fn logits_are_finite_and_vocab_sized() {
        let m = RefLlm::new(ReferenceConfig::default());
        let (l, _) = m.prefill(&[0, 255, 128]).unwrap();
        assert_eq!(l.len(), REF_VOCAB);
        assert!(l.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn buckets_cover_max_tokens() {
        let m = RefLlm::new(ReferenceConfig {
            max_tokens: 48,
            ..ReferenceConfig::default()
        });
        let b = m.prefill_buckets();
        assert_eq!(*b.last().unwrap(), 48);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
    }
}
