//! Pure-Rust reference LLM backend: a batched, blocked, quantized
//! compute engine.
//!
//! A deliberately small autoregressive transformer (byte vocabulary,
//! seeded random weights) with the *same* session semantics as the AOT
//! artifact path — per-layer K/V caches indexed by position, prefill
//! that returns the last token's logits plus a fresh [`Session`], one
//! decode step per generated token — but with the serving hot path built
//! the way the paper's datapath works:
//!
//! * **MHA in FP16-class float** (f32 here): the attention projections
//!   run through dense GEMMs whose outer loop streams each weight row
//!   exactly once per batched round ([`kernels::gemm_into`]).
//! * **FFN in FP16×INT4**: the up/down projections are group-quantized
//!   to INT4 with FP16 block scales (`quant::quantize`), stored in the
//!   nibble-packed row-major layout (`pack::layout::PackedQ4`), and
//!   executed by a dequant-on-the-fly GEMM. An optional log-scale
//!   structured-sparsity fast path walks the fixed-slot packed layout
//!   instead (`quant::sparse`).
//! * **Sequence-level prefill**: the whole prompt is processed as
//!   `T`-row GEMMs (one weight pass for all prompt tokens) instead of
//!   `T` scalar steps, and only the last position's logits touch the
//!   output head.
//! * **True batched decode**: [`RefLlm::decode_batch`] advances every
//!   live session in one pass per weight matrix, mirroring the
//!   weight-stream-once accounting of `sim::engine::decode_round`. For
//!   any fixed session the operation order is identical at every batch
//!   size, so batched and scalar decode are bit-identical.
//! * **Steady-state zero allocation**: all intermediates live in a
//!   per-engine scratch arena ([`Scratch`]) that grows once and is
//!   reused; the only per-call allocations are the returned logits.
//!
//! Numbers produced here are functional, not paper numbers; the VCU128
//! performance model lives in `sim::engine` and is charged by the
//! serving engine independently of which functional backend runs.

use std::cell::RefCell;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::kernels::par::{self, AttnJob};
use super::kernels::simd;
use super::kernels::{
    attend_paged_into, gelu, gemm_into, matvec_into, q4_gemm_into, q4_sparse_gemm_into,
};
use super::kv::{KvArena, MemoryStats, DEFAULT_BLOCK_TOKENS};
use super::model::{ModelInfo, Session};
use super::pool::{self, WorkerPool};
use crate::pack::layout::PackedQ4;
use crate::quant::sparse::{pack_sparse, SparseMatrix};
use crate::quant::{self, prune_log_scale, Sparsity, SGROUP};
use crate::util::rng::Rng;

/// Byte-level vocabulary, matching `coordinator::tokenizer`.
pub const REF_VOCAB: usize = 256;

/// Which kernel implementation executes the hot path. Every tier is
/// **bit-identical** to [`Scalar`](KernelTier::Scalar) — the scalar
/// kernels are the oracle, the other tiers are how fast the same bits
/// are produced (see `runtime::kernels::simd` for why that holds).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelTier {
    /// Resolve at engine construction: `SimdParallel` when more than
    /// one thread is available, else `Simd` when AVX2 is detected, else
    /// `Scalar`. The `EDGELLM_KERNEL_TIER` environment variable, when
    /// set to a parseable tier, overrides an `Auto` config (the CI
    /// lever — tests build default configs).
    #[default]
    Auto,
    /// The single-threaded scalar oracle kernels — the reference
    /// everything else is compared against. Pin with
    /// `--kernel-tier scalar` when bisecting a numeric question.
    Scalar,
    /// Single-threaded with runtime-dispatched AVX2 bodies
    /// (`runtime::kernels::simd`); falls back to scalar-order bodies
    /// per call on machines without AVX2.
    Simd,
    /// SIMD kernels driven by the persistent worker pool
    /// (`runtime::kernels::par`), splitting GEMM output columns and
    /// per-session attention across cores with deterministic disjoint
    /// partitioning.
    SimdParallel,
}

impl KernelTier {
    /// Parse a CLI/env spelling (`auto`, `scalar`, `simd`,
    /// `simd-parallel`). Returns `None` for anything else.
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelTier::Auto),
            "scalar" => Some(KernelTier::Scalar),
            "simd" => Some(KernelTier::Simd),
            "simd-parallel" | "simdparallel" | "parallel" => Some(KernelTier::SimdParallel),
            _ => None,
        }
    }
}

/// Dimensions of the reference model.
#[derive(Debug, Clone)]
pub struct ReferenceConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_tokens: usize,
    pub seed: u64,
    /// Log-scale structured sparsity applied to the FFN weights before
    /// quantization; `Sparsity::Dense` uses the dense nibble-packed path.
    pub ffn_sparsity: Sparsity,
    /// Tokens per KV-arena block (CLI `--kv-block-tokens`). Smaller
    /// blocks track actual context lengths more tightly at the cost of
    /// a longer block table; `block_tokens >= max_tokens` degenerates
    /// to one contiguous block per session.
    pub kv_block_tokens: usize,
    /// KV pool capacity in blocks (CLI `--kv-pool-blocks`). `0` = auto:
    /// 64 full-length sessions' worth — storage materializes lazily, so
    /// the generous default costs nothing until blocks are touched.
    pub kv_pool_blocks: usize,
    /// Kernel execution tier (CLI `--kernel-tier`). All tiers produce
    /// bit-identical results; `Auto` picks the fastest available.
    pub kernel_tier: KernelTier,
    /// Worker count for the `SimdParallel` tier (CLI `--threads`).
    /// `0` = auto: `EDGELLM_THREADS` when set, else the machine's
    /// available parallelism. Ignored by the single-threaded tiers.
    pub threads: usize,
}

impl Default for ReferenceConfig {
    fn default() -> Self {
        ReferenceConfig {
            name: "ref-tiny".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            max_tokens: 64,
            seed: 0x5EED,
            ffn_sparsity: Sparsity::Dense,
            kv_block_tokens: DEFAULT_BLOCK_TOKENS,
            kv_pool_blocks: 0,
            kernel_tier: KernelTier::Auto,
            threads: 0,
        }
    }
}

/// A group-quantized INT4 linear layer, logical `d_in → n`. Input
/// channels are zero-padded to a QBLOCK multiple; the matching
/// activation padding lives in the scratch arena and is skipped by the
/// kernels at zero cost.
struct QLinear {
    d_in: usize,
    k_pad: usize,
    n: usize,
    body: QBody,
}

enum QBody {
    /// nibble-packed dense layout
    Dense(PackedQ4),
    /// fixed-slot structured-sparse layout + pre-decoded per-slot scales
    Sparse { m: SparseMatrix, slot_scale: Vec<f32> },
}

impl QLinear {
    /// Quantize a row-major `d_in × n` (input-major) f32 matrix.
    fn build(w: &[f32], d_in: usize, n: usize, sparsity: Sparsity) -> QLinear {
        assert_eq!(w.len(), d_in * n);
        let k_pad = quant::pad_to_qblock(d_in);
        let keep = sparsity.keep_of_8();
        let qm = if keep < SGROUP {
            // pruning must see the padded matrix (group-of-8 structure)
            let mut padded = quant::pad_rows(w, d_in, n);
            prune_log_scale(&mut padded, k_pad, n, keep);
            quant::quantize(&padded, k_pad, n)
        } else {
            quant::quantize_padded(w, d_in, n)
        };
        let body = if keep < SGROUP {
            let m = pack_sparse(&qm, keep);
            let slot_scale = m.slot_scales();
            QBody::Sparse { m, slot_scale }
        } else {
            QBody::Dense(PackedQ4::from_quant(&qm))
        };
        QLinear { d_in, k_pad, n, body }
    }

    /// Dequantized weight at (input row, output col) — reference path.
    fn dequant(&self, r: usize, c: usize) -> f32 {
        match &self.body {
            QBody::Dense(p) => p.dequant(r, c),
            QBody::Sparse { m, slot_scale } => {
                let keep = m.keep_of_8;
                let g = r / SGROUP;
                for s in 0..keep {
                    let slot = (g * keep + s) * m.n + c;
                    if m.idx[slot] as usize == r && m.val[slot] != 0 {
                        return m.val[slot] as f32 * slot_scale[slot];
                    }
                }
                0.0
            }
        }
    }
}

/// Per-layer weights: dense f32 attention projections + quantized FFN.
/// Every matrix is stored **input-major** (`k × n`, input channels are
/// rows) — the same streaming layout the quantizer and the HBM packager
/// use, and the order the axpy kernels walk.
struct Layer {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    /// `d → d_ffn`, INT4
    w_up: QLinear,
    /// `d_ffn → d`, INT4
    w_down: QLinear,
}

/// Scratch arena for the batched forward pass. Buffers grow to the
/// high-water mark (`max(batch, prompt_len)` rows) on first use and are
/// reused forever after — the decode hot path performs no allocation.
///
/// Invariant: the padding tail of each `ffn_in` / `ffn_mid` row
/// (`[d_in, k_pad)`) is zero. It is initialized to zero, never written,
/// and the quantized kernels only read it.
#[derive(Default)]
struct Scratch {
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    o: Vec<f32>,
    scores: Vec<f32>,
    ffn_in: Vec<f32>,
    ffn_up: Vec<f32>,
    ffn_mid: Vec<f32>,
    ffn_out: Vec<f32>,
    partial: Vec<f32>,
    xcol: Vec<f32>,
    /// one dequantized INT4 weight row, expanded once per round
    qrow: Vec<f32>,
    logits: Vec<f32>,
}

fn ensure(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

/// The resolved execution engine behind [`KernelTier`]: which kernel
/// family every GEMM/attention dispatch goes through. Resolved once at
/// construction — the hot path matches on a three-way enum, never
/// re-detects features.
enum Exec {
    /// scalar oracle kernels, single-threaded
    Scalar,
    /// `kernels::simd` bodies, single-threaded
    Simd,
    /// `kernels::par` drivers over this persistent pool
    Parallel(WorkerPool),
}

pub struct RefLlm {
    info: ModelInfo,
    /// token embeddings, `REF_VOCAB × d` (row lookup, not a GEMM)
    emb: Vec<f32>,
    layers: Vec<Layer>,
    /// output head, input-major `d × REF_VOCAB`
    w_out: Vec<f32>,
    buckets: Vec<usize>,
    /// resolved kernel tier (see [`KernelTier`]); every dispatch helper
    /// below matches on this
    exec: Exec,
    /// human-readable tier name for `info`/stats/benches
    tier_label: String,
    scratch: RefCell<Scratch>,
    /// all session KV storage, block-granular; sessions carry only a
    /// block table (RefCell: `Backend` methods take `&self`, and the
    /// engine serializes calls externally)
    arena: RefCell<KvArena>,
}

fn init(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

impl RefLlm {
    pub fn new(cfg: ReferenceConfig) -> Self {
        let d = cfg.d_model;
        assert!(d % 2 == 0, "d_model={d} must be even (nibble-packed FFN)");
        let d_ffn = 4 * d;
        let mut rng = Rng::new(cfg.seed);
        // 1/sqrt(fan-in) keeps activations and logits O(1) through depth
        let s = 1.0 / (d as f32).sqrt();
        let s_ffn = 1.0 / (d_ffn as f32).sqrt();
        let emb = init(&mut rng, REF_VOCAB * d, 1.0);
        let layers: Vec<Layer> = (0..cfg.n_layers)
            .map(|_| {
                // all matrices are input-major (k × n) — the streaming /
                // quantization layout the axpy kernels walk
                let wq = init(&mut rng, d * d, s);
                let wk = init(&mut rng, d * d, s);
                let wv = init(&mut rng, d * d, s);
                let wo = init(&mut rng, d * d, s);
                let up = init(&mut rng, d * d_ffn, s);
                let down = init(&mut rng, d_ffn * d, s_ffn);
                Layer {
                    wq,
                    wk,
                    wv,
                    wo,
                    w_up: QLinear::build(&up, d, d_ffn, cfg.ffn_sparsity),
                    w_down: QLinear::build(&down, d_ffn, d, cfg.ffn_sparsity),
                }
            })
            .collect();
        let w_out = init(&mut rng, REF_VOCAB * d, s);
        // power-of-two prefill buckets up to max_tokens, mirroring the
        // AOT artifact layout (one compiled graph per bucket)
        let mut buckets = Vec::new();
        let mut b = 8usize;
        while b < cfg.max_tokens {
            buckets.push(b);
            b *= 2;
        }
        buckets.push(cfg.max_tokens);
        let n_params =
            emb.len() + cfg.n_layers * (4 * d * d + 2 * d * d_ffn) + w_out.len();
        let info = ModelInfo {
            name: cfg.name,
            vocab: REF_VOCAB,
            d_model: d,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_heads,
            d_ffn,
            max_tokens: cfg.max_tokens,
            head_dim: d / cfg.n_heads.max(1),
            n_params,
            cache_shape: [cfg.n_layers, cfg.max_tokens, 1, d],
        };
        // the KV arena owns all session memory as token blocks: row
        // width is the per-layer cache row (kv_heads * head_dim = d
        // here), pool defaults to 64 full-length sessions' worth
        // (lazily materialized)
        let bt = cfg.kv_block_tokens.max(1);
        let blocks_per_session = cfg.max_tokens.max(1).div_ceil(bt);
        let max_blocks = if cfg.kv_pool_blocks > 0 {
            cfg.kv_pool_blocks
        } else {
            blocks_per_session * 64
        };
        // resolve the kernel tier once: explicit config wins, the
        // EDGELLM_KERNEL_TIER env var overrides an Auto config (the CI
        // lever — integration tests build default configs), and Auto
        // itself prefers all cores, then AVX2, then the oracle
        let mut tier = cfg.kernel_tier;
        if tier == KernelTier::Auto {
            if let Some(t) = std::env::var("EDGELLM_KERNEL_TIER")
                .ok()
                .and_then(|s| KernelTier::parse(&s))
            {
                tier = t;
            }
        }
        let threads = if cfg.threads > 0 {
            cfg.threads
        } else {
            pool::default_threads()
        };
        let exec = match tier {
            KernelTier::Scalar => Exec::Scalar,
            KernelTier::Simd => Exec::Simd,
            KernelTier::SimdParallel => Exec::Parallel(WorkerPool::new(threads)),
            KernelTier::Auto => {
                if threads > 1 {
                    Exec::Parallel(WorkerPool::new(threads))
                } else if simd::available() {
                    Exec::Simd
                } else {
                    Exec::Scalar
                }
            }
        };
        let tier_label = match &exec {
            Exec::Scalar => "scalar".to_string(),
            Exec::Simd => "simd".to_string(),
            Exec::Parallel(p) => format!("simd-parallel({})", p.threads()),
        };
        RefLlm {
            info,
            emb,
            layers,
            w_out,
            buckets,
            exec,
            tier_label,
            scratch: RefCell::new(Scratch::default()),
            arena: RefCell::new(KvArena::new(cfg.n_layers, d, bt, max_blocks)),
        }
    }

    /// The resolved kernel tier's human-readable name (`"scalar"`,
    /// `"simd"`, `"simd-parallel(8)"`).
    pub fn kernel_tier_label(&self) -> &str {
        &self.tier_label
    }

    /// Worker slots the scratch arena must provision for (1 on the
    /// single-threaded tiers).
    fn pool_threads(&self) -> usize {
        match &self.exec {
            Exec::Parallel(p) => p.threads(),
            _ => 1,
        }
    }

    /// Tier-dispatched dense GEMM — every tier produces bit-identical
    /// output (see `kernels::simd`), so callers never care which ran.
    fn gemm(&self, x: &[f32], b: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
        match &self.exec {
            Exec::Scalar => gemm_into(x, b, k, w, n, out),
            Exec::Simd => simd::gemm_into(x, b, k, w, n, out),
            Exec::Parallel(p) => par::gemm_into(p, x, b, k, w, n, out),
        }
    }

    /// Tier-dispatched matvec (the prefill logits head).
    fn matvec(&self, w: &[f32], x: &[f32], out: &mut [f32]) {
        match &self.exec {
            Exec::Scalar => matvec_into(w, x, out),
            Exec::Simd => simd::matvec_into(w, x, out),
            Exec::Parallel(p) => par::matvec_into(p, w, x, out),
        }
    }

    /// Tier-dispatched quantized forward over `b` zero-padded
    /// activation rows (`b × k_pad`) of a [`QLinear`].
    #[allow(clippy::too_many_arguments)]
    fn q_forward(
        &self,
        q: &QLinear,
        x: &[f32],
        b: usize,
        partial: &mut [f32],
        xcol: &mut [f32],
        qrow: &mut [f32],
        out: &mut [f32],
    ) {
        match (&self.exec, &q.body) {
            (Exec::Scalar, QBody::Dense(p)) => q4_gemm_into(x, b, p, partial, xcol, qrow, out),
            (Exec::Simd, QBody::Dense(p)) => simd::q4_gemm_into(x, b, p, partial, xcol, qrow, out),
            (Exec::Parallel(pl), QBody::Dense(p)) => {
                par::q4_gemm_into(pl, x, b, p, partial, xcol, qrow, out)
            }
            (Exec::Scalar, QBody::Sparse { m, slot_scale }) => {
                q4_sparse_gemm_into(x, b, m, slot_scale, out)
            }
            (Exec::Simd, QBody::Sparse { m, slot_scale }) => {
                simd::q4_sparse_gemm_into(x, b, m, slot_scale, out)
            }
            (Exec::Parallel(pl), QBody::Sparse { m, slot_scale }) => {
                par::q4_sparse_gemm_into(pl, x, b, m, slot_scale, out)
            }
        }
    }

    /// Tier-dispatched attention over a batch of independent jobs.
    /// `scores` is softmax scratch (`pool_threads() × max_tokens` wide,
    /// see [`RefLlm::reserve`]); its contents never escape, so tiers
    /// that stripe it differently still produce identical `ctx` rows.
    fn attend_all(&self, jobs: Vec<AttnJob<'_>>, scores: &mut [f32], max_len: usize) {
        match &self.exec {
            Exec::Scalar => {
                for j in jobs {
                    attend_paged_into(j.q, &j.keys, &j.vals, &mut scores[..j.len], j.ctx);
                }
            }
            Exec::Simd => {
                for j in jobs {
                    simd::attend_paged_into(j.q, &j.keys, &j.vals, &mut scores[..j.len], j.ctx);
                }
            }
            Exec::Parallel(p) => par::attend_jobs(p, jobs, scores, max_len),
        }
    }

    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    pub fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Grow the scratch arena to hold `rows` activation rows.
    fn reserve(&self, sc: &mut Scratch, rows: usize) {
        let d = self.info.d_model;
        let d_ffn = self.info.d_ffn;
        let (kup, kdown) = match self.layers.first() {
            Some(l) => (l.w_up.k_pad, l.w_down.k_pad),
            None => (0, 0),
        };
        ensure(&mut sc.h, rows * d);
        ensure(&mut sc.q, rows * d);
        ensure(&mut sc.k, rows * d);
        ensure(&mut sc.v, rows * d);
        ensure(&mut sc.ctx, rows * d);
        ensure(&mut sc.o, rows * d);
        // scores: one max_tokens-wide softmax stripe per worker;
        // xcol: one batch-wide activation gather per worker stripe
        let t = self.pool_threads();
        ensure(&mut sc.scores, t * self.info.max_tokens);
        ensure(&mut sc.ffn_in, rows * kup);
        ensure(&mut sc.ffn_up, rows * d_ffn);
        ensure(&mut sc.ffn_mid, rows * kdown);
        ensure(&mut sc.ffn_out, rows * d);
        ensure(&mut sc.partial, rows * d_ffn.max(d));
        ensure(&mut sc.xcol, t * rows);
        ensure(&mut sc.qrow, d_ffn.max(d));
        ensure(&mut sc.logits, rows * REF_VOCAB);
    }

    /// FFN for `b` rows of `sc.h`, result in `sc.ffn_out` (no residual).
    fn ffn_batch(&self, layer: &Layer, b: usize, sc: &mut Scratch) {
        let d = layer.w_up.d_in;
        let d_ffn = layer.w_up.n;
        let (kup, kdown) = (layer.w_up.k_pad, layer.w_down.k_pad);
        for s in 0..b {
            let src = &sc.h[s * d..(s + 1) * d];
            sc.ffn_in[s * kup..s * kup + d].copy_from_slice(src);
        }
        self.q_forward(
            &layer.w_up,
            &sc.ffn_in,
            b,
            &mut sc.partial,
            &mut sc.xcol,
            &mut sc.qrow,
            &mut sc.ffn_up,
        );
        for s in 0..b {
            for i in 0..d_ffn {
                sc.ffn_mid[s * kdown + i] = gelu(sc.ffn_up[s * d_ffn + i]);
            }
        }
        self.q_forward(
            &layer.w_down,
            &sc.ffn_mid,
            b,
            &mut sc.partial,
            &mut sc.xcol,
            &mut sc.qrow,
            &mut sc.ffn_out,
        );
    }

    /// The Q/K/V projections for `b` rows of `sc.h` — three GEMMs, each
    /// streaming its weight matrix once for the whole batch.
    fn qkv(&self, layer: &Layer, b: usize, sc: &mut Scratch) {
        let d = self.info.d_model;
        self.gemm(&sc.h, b, d, &layer.wq, d, &mut sc.q);
        self.gemm(&sc.h, b, d, &layer.wk, d, &mut sc.k);
        self.gemm(&sc.h, b, d, &layer.wv, d, &mut sc.v);
    }

    /// Output projection + residual mix + quantized FFN + residual mix,
    /// applied to `b` rows of `sc.ctx`/`sc.h` in place.
    fn mix_and_ffn(&self, layer: &Layer, b: usize, sc: &mut Scratch) {
        let d = self.info.d_model;
        self.gemm(&sc.ctx, b, d, &layer.wo, d, &mut sc.o);
        for i in 0..b * d {
            sc.h[i] = (sc.h[i] + sc.o[i]).tanh();
        }
        self.ffn_batch(layer, b, sc);
        for i in 0..b * d {
            sc.h[i] = (sc.h[i] + sc.ffn_out[i]).tanh();
        }
    }

    /// Sequence-level prefill: the whole prompt advances through each
    /// weight matrix in one GEMM; only the last position's logits are
    /// computed. Returns those logits plus the primed session, whose KV
    /// rows live in arena blocks reserved here (recycled from retired
    /// sessions when the free list has any).
    ///
    /// Prefix caching is always on: when the arena's prefix index holds
    /// KV state for a prefix of `prompt` (a previous session with the
    /// same system prompt), the shared blocks are adopted by refcount
    /// and only the suffix from the divergence point is computed. The
    /// result is bit-identical to a cold prefill — each output row's
    /// accumulation order in the kernels is independent of the row
    /// count, and adopted blocks hold exactly the bytes a cold prefill
    /// would have written. On return the prompt is registered in the
    /// index so later sessions can share it.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let t = prompt.len();
        if t == 0 {
            bail!("empty prompt");
        }
        let max_t = self.info.max_tokens;
        if t > max_t {
            bail!("prompt of {t} exceeds max_tokens {max_t}");
        }
        let d = self.info.d_model;
        // adopt the longest resident prefix (refcounts bumped), then
        // grow to the full prompt and make every block we are about to
        // write private (CoW on the shared boundary block; a no-op on
        // fresh blocks) — all-or-nothing, so a failure leaks nothing
        let (mut kv, start) = {
            let mut arena = self.arena.borrow_mut();
            let (mut kv, start) = arena
                .adopt_prefix(prompt)
                .unwrap_or((Default::default(), 0));
            let bt = arena.block_tokens();
            let grown = arena.ensure(&mut kv, t).and_then(|()| {
                for bi in (start / bt)..=((t - 1) / bt) {
                    arena.ensure_writable(&mut kv, bi * bt)?;
                }
                Ok(())
            });
            if let Err(e) = grown {
                arena.release(&mut kv);
                return Err(anyhow::Error::new(e));
            }
            (kv, start)
        };
        let mut session = Session::with_kv(kv);
        let n = t - start; // suffix rows actually computed
        let mut sc = self.scratch.borrow_mut();
        let sc = &mut *sc;
        self.reserve(sc, n);
        for (i, &tok) in prompt[start..].iter().enumerate() {
            let v = tok.rem_euclid(REF_VOCAB as i32) as usize;
            sc.h[i * d..(i + 1) * d].copy_from_slice(&self.emb[v * d..(v + 1) * d]);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            self.qkv(layer, n, sc);
            {
                // scatter the fresh suffix K/V rows into the block
                // table, then attend over the *full* history (adopted
                // prefix rows + fresh rows) through the gather view —
                // bit-identical to the cold-prefill writes
                let mut arena = self.arena.borrow_mut();
                for i in 0..n {
                    let pos = start + i;
                    arena
                        .k_row_mut(&session.kv, li, pos)
                        .copy_from_slice(&sc.k[i * d..(i + 1) * d]);
                    arena
                        .v_row_mut(&session.kv, li, pos)
                        .copy_from_slice(&sc.v[i * d..(i + 1) * d]);
                }
                let arena = &*arena;
                let kr = arena.k_rows(&session.kv, li);
                let vr = arena.v_rows(&session.kv, li);
                // one independent causal-attention job per suffix
                // position, all sharing the same gather view —
                // the parallel tier spreads them across workers
                let jobs: Vec<AttnJob> = sc.q[..n * d]
                    .chunks(d)
                    .zip(sc.ctx[..n * d].chunks_mut(d))
                    .enumerate()
                    .map(|(i, (qrow, ctxrow))| AttnJob {
                        q: qrow,
                        keys: kr,
                        vals: vr,
                        len: start + i + 1,
                        ctx: ctxrow,
                    })
                    .collect();
                self.attend_all(jobs, &mut sc.scores, t);
            }
            self.mix_and_ffn(layer, n, sc);
        }
        session.pos = t;
        let mut logits = vec![0f32; REF_VOCAB];
        self.matvec(&self.w_out, &sc.h[(n - 1) * d..n * d], &mut logits);
        // make this prompt's blocks adoptable by later sessions (the
        // index takes its own refcounts, so they survive end_session)
        self.arena.borrow_mut().register_prefix(prompt, &session.kv);
        Ok((logits, session))
    }

    /// One batched decode round: feed `tokens[s]` to `sessions[s]`,
    /// walking each weight matrix once for the whole batch. Returns each
    /// session's next-token logits. Bit-identical to calling
    /// [`RefLlm::decode`] per session in any order.
    pub fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        if sessions.len() != tokens.len() {
            bail!(
                "decode_batch: {} sessions vs {} tokens",
                sessions.len(),
                tokens.len()
            );
        }
        let b = sessions.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let max_t = self.info.max_tokens;
        for sess in sessions.iter() {
            if sess.pos >= max_t {
                bail!("KV cache full (max_tokens={max_t})");
            }
        }
        // lazy growth, all-or-nothing *before* any compute or scatter: a
        // session crossing a block boundary takes one block from the
        // pool here, and a session about to write into a block the
        // prefix index (or another sharer) still references gets a
        // private copy first (CoW) — no decode ever writes through a
        // shared block. On exhaustion the round fails with the typed
        // KvExhausted error while every session is still unadvanced, so
        // the scheduler can preempt and retry the round bit-identically
        {
            let mut arena = self.arena.borrow_mut();
            for sess in sessions.iter_mut() {
                arena
                    .ensure(&mut sess.kv, sess.pos + 1)
                    .and_then(|()| arena.ensure_writable(&mut sess.kv, sess.pos))
                    .map_err(anyhow::Error::new)?;
            }
        }
        let d = self.info.d_model;
        let mut sc = self.scratch.borrow_mut();
        let sc = &mut *sc;
        self.reserve(sc, b);
        for (s, &tok) in tokens.iter().enumerate() {
            let v = tok.rem_euclid(REF_VOCAB as i32) as usize;
            sc.h[s * d..(s + 1) * d].copy_from_slice(&self.emb[v * d..(v + 1) * d]);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            self.qkv(layer, b, sc);
            {
                // scatter every session's fresh K/V row first, then
                // attend all sessions — same order per session as the
                // interleaved form (a session's attend never read
                // another session's rows), but now the attends are a
                // batch of independent jobs the parallel tier can
                // spread across workers
                let mut arena = self.arena.borrow_mut();
                for (s, sess) in sessions.iter_mut().enumerate() {
                    let pos = sess.pos;
                    arena
                        .k_row_mut(&sess.kv, li, pos)
                        .copy_from_slice(&sc.k[s * d..(s + 1) * d]);
                    arena
                        .v_row_mut(&sess.kv, li, pos)
                        .copy_from_slice(&sc.v[s * d..(s + 1) * d]);
                }
                let arena = &*arena;
                let mut max_len = 0usize;
                let jobs: Vec<AttnJob> = sessions
                    .iter()
                    .zip(sc.q[..b * d].chunks(d))
                    .zip(sc.ctx[..b * d].chunks_mut(d))
                    .map(|((sess, qrow), ctxrow)| {
                        let len = sess.pos + 1;
                        max_len = max_len.max(len);
                        AttnJob {
                            q: qrow,
                            keys: arena.k_rows(&sess.kv, li),
                            vals: arena.v_rows(&sess.kv, li),
                            len,
                            ctx: ctxrow,
                        }
                    })
                    .collect();
                self.attend_all(jobs, &mut sc.scores, max_len);
            }
            self.mix_and_ffn(layer, b, sc);
        }
        self.gemm(&sc.h, b, d, &self.w_out, REF_VOCAB, &mut sc.logits);
        for sess in sessions.iter_mut() {
            sess.pos += 1;
        }
        Ok((0..b)
            .map(|s| sc.logits[s * REF_VOCAB..(s + 1) * REF_VOCAB].to_vec())
            .collect())
    }

    /// One decode step (batch-1 specialization of [`RefLlm::decode_batch`]).
    pub fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        let mut one = [session];
        let mut out = self.decode_batch(&mut one, &[token])?;
        Ok(out.pop().expect("batch of one"))
    }

    /// Validation hook: layer `li`'s quantized FFN fast path on one
    /// activation row (no residual). Used by the equivalence tests.
    pub fn ffn_fast(&self, li: usize, x: &[f32]) -> Vec<f32> {
        let d = self.info.d_model;
        assert_eq!(x.len(), d);
        let mut sc = self.scratch.borrow_mut();
        let sc = &mut *sc;
        self.reserve(sc, 1);
        sc.h[..d].copy_from_slice(x);
        self.ffn_batch(&self.layers[li], 1, sc);
        sc.ffn_out[..d].to_vec()
    }

    /// Validation hook: the same FFN computed against the *dequantized*
    /// f32 weights with f64 accumulation — the reference the fast path
    /// must match within tolerance.
    pub fn ffn_reference(&self, li: usize, x: &[f32]) -> Vec<f32> {
        let d = self.info.d_model;
        let d_ffn = self.info.d_ffn;
        assert_eq!(x.len(), d);
        let layer = &self.layers[li];
        let mut up = vec![0f64; d_ffn];
        for (c, u) in up.iter_mut().enumerate() {
            for (r, &xv) in x.iter().enumerate() {
                *u += xv as f64 * layer.w_up.dequant(r, c) as f64;
            }
        }
        let mid: Vec<f32> = up.iter().map(|&u| gelu(u as f32)).collect();
        let mut out = vec![0f64; d];
        for (c, o) in out.iter_mut().enumerate() {
            for (r, &mv) in mid.iter().enumerate() {
                *o += mv as f64 * layer.w_down.dequant(r, c) as f64;
            }
        }
        out.into_iter().map(|v| v as f32).collect()
    }

    /// Current KV-arena accounting (also surfaced through
    /// `Backend::memory` / `LlmRuntime::memory`).
    pub fn memory_stats(&self) -> MemoryStats {
        self.arena.borrow().stats()
    }

    /// Resident weight bytes of the quantized FFN stack (values +
    /// scales) — surfaced through `LlmRuntime::ffn_weight_bytes` into
    /// the throughput bench's JSON record.
    pub fn ffn_weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                [&l.w_up, &l.w_down]
                    .iter()
                    .map(|q| match &q.body {
                        QBody::Dense(p) => p.bytes(),
                        QBody::Sparse { m, slot_scale } => {
                            m.idx.len() * 4 + m.val.len() + slot_scale.len() * 4
                        }
                    })
                    .sum::<usize>()
            })
            .sum()
    }
}

/// The reference engine is the always-built [`Backend`]: batched rounds
/// are genuinely shared (weights streamed once per round), so
/// `supports_batched_decode` is true and the quantized FFN footprint is
/// exposed for the throughput benches.
impl Backend for RefLlm {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        RefLlm::prefill(self, prompt)
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        RefLlm::decode(self, session, token)
    }

    fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        RefLlm::decode_batch(self, sessions, tokens)
    }

    fn supports_batched_decode(&self) -> bool {
        true
    }

    fn ffn_weight_bytes(&self) -> Option<usize> {
        Some(RefLlm::ffn_weight_bytes(self))
    }

    /// The tier resolved at construction (`--kernel-tier` /
    /// `EDGELLM_KERNEL_TIER` / auto-detect) — every tier is
    /// bit-identical, so this is provenance for benches and the stats
    /// line, not a semantic switch.
    fn kernel_tier(&self) -> Option<String> {
        Some(self.tier_label.clone())
    }

    /// Retirement returns the session's blocks to the free list, where
    /// the next admission recycles them without re-zeroing — the whole
    /// point of the arena. Draining the handle makes a repeated call a
    /// no-op.
    fn end_session(&self, session: &mut Session) {
        self.arena.borrow_mut().release(&mut session.kv);
    }

    fn memory(&self) -> Option<MemoryStats> {
        Some(self.memory_stats())
    }

    /// Arena pressure counters for the obs layer: allocation stalls and
    /// copy-on-write copies since construction.
    fn kv_pressure(&self) -> Option<crate::obs::KvPressure> {
        let a = self.arena.borrow();
        Some(crate::obs::KvPressure {
            alloc_stalls: a.alloc_stalls(),
            cow_copies: a.cow_copies(),
        })
    }

    /// The admission gate's query: longest resident prefix of `prompt`
    /// per the arena's index, without adopting it.
    fn shared_prefix_len(&self, prompt: &[i32]) -> usize {
        self.arena.borrow().shared_prefix_len(prompt)
    }

    /// The hint is advisory (the index may have moved since the caller
    /// sampled it); prefix caching is always on in this engine, so this
    /// is exactly [`RefLlm::prefill`] — which re-derives sharing from
    /// the live index and is bit-identical either way.
    fn prefill_from(&self, prompt: &[i32], _shared_len: usize) -> Result<(Vec<f32>, Session)> {
        RefLlm::prefill(self, prompt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_a_seed() {
        let a = RefLlm::new(ReferenceConfig::default());
        let b = RefLlm::new(ReferenceConfig::default());
        let (la, _) = a.prefill(&[72, 105]).unwrap();
        let (lb, _) = b.prefill(&[72, 105]).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn different_seeds_differ() {
        let a = RefLlm::new(ReferenceConfig::default());
        let b = RefLlm::new(ReferenceConfig {
            seed: 99,
            ..ReferenceConfig::default()
        });
        let (la, _) = a.prefill(&[72]).unwrap();
        let (lb, _) = b.prefill(&[72]).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn logits_depend_on_history() {
        // the same token decoded after different prefixes must see
        // different attention contexts
        let m = RefLlm::new(ReferenceConfig::default());
        let (_, mut s1) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut s2) = m.prefill(&[9, 8, 7]).unwrap();
        let l1 = m.decode(&mut s1, 42).unwrap();
        let l2 = m.decode(&mut s2, 42).unwrap();
        assert_ne!(l1, l2);
    }

    #[test]
    fn cache_full_errors() {
        let m = RefLlm::new(ReferenceConfig {
            max_tokens: 8,
            ..ReferenceConfig::default()
        });
        let (_, mut s) = m.prefill(&[1, 2, 3]).unwrap();
        for _ in 0..5 {
            m.decode(&mut s, 7).unwrap();
        }
        assert_eq!(s.pos, 8);
        assert!(m.decode(&mut s, 7).is_err());
    }

    #[test]
    fn logits_are_finite_and_vocab_sized() {
        let m = RefLlm::new(ReferenceConfig::default());
        let (l, _) = m.prefill(&[0, 255, 128]).unwrap();
        assert_eq!(l.len(), REF_VOCAB);
        assert!(l.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn buckets_cover_max_tokens() {
        let m = RefLlm::new(ReferenceConfig {
            max_tokens: 48,
            ..ReferenceConfig::default()
        });
        let b = m.prefill_buckets();
        assert_eq!(*b.last().unwrap(), 48);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn single_pass_prefill_equals_stepping() {
        // prefill(prompt) must produce the same logits and KV state as
        // prefill(first token) followed by decoding the rest one by one
        let m = RefLlm::new(ReferenceConfig::default());
        let prompt = [10i32, 200, 42, 7, 99];
        let (single, s_single) = m.prefill(&prompt).unwrap();
        let (_, mut s_step) = m.prefill(&prompt[..1]).unwrap();
        let mut stepped = Vec::new();
        for &t in &prompt[1..] {
            stepped = m.decode(&mut s_step, t).unwrap();
        }
        assert_eq!(s_single.pos, s_step.pos);
        for (i, (a, b)) in single.iter().zip(&stepped).enumerate() {
            assert!((a - b).abs() < 1e-4, "logit {i}: {a} vs {b}");
        }
    }

    #[test]
    fn batched_decode_is_bitwise_scalar_decode() {
        let m = RefLlm::new(ReferenceConfig::default());
        let (_, mut a1) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut b1) = m.prefill(&[5]).unwrap();
        let (_, mut a2) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut b2) = m.prefill(&[5]).unwrap();
        let la = m.decode(&mut a1, 11).unwrap();
        let lb = m.decode(&mut b1, 12).unwrap();
        let mut batch = [&mut a2, &mut b2];
        let batched = m.decode_batch(&mut batch, &[11, 12]).unwrap();
        assert_eq!(batched[0], la);
        assert_eq!(batched[1], lb);
    }

    #[test]
    fn ffn_fast_matches_dequant_reference() {
        for sparsity in [Sparsity::Dense, Sparsity::Half, Sparsity::Quarter] {
            let m = RefLlm::new(ReferenceConfig {
                ffn_sparsity: sparsity,
                ..ReferenceConfig::default()
            });
            let d = m.info().d_model;
            let mut rng = Rng::new(77);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for li in 0..m.info().n_layers {
                let fast = m.ffn_fast(li, &x);
                let reference = m.ffn_reference(li, &x);
                for (i, (f, r)) in fast.iter().zip(&reference).enumerate() {
                    assert!(
                        (f - r).abs() < 1e-4,
                        "{sparsity:?} layer {li} out {i}: fast {f} vs ref {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_ffn_differs_from_dense_but_serves() {
        let dense = RefLlm::new(ReferenceConfig::default());
        let sparse = RefLlm::new(ReferenceConfig {
            ffn_sparsity: Sparsity::Half,
            ..ReferenceConfig::default()
        });
        let (ld, _) = dense.prefill(&[1, 2, 3]).unwrap();
        let (ls, _) = sparse.prefill(&[1, 2, 3]).unwrap();
        assert_ne!(ld, ls, "pruning must change the function");
        assert!(ls.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn paged_blocks_match_contiguous_sized_blocks_bitwise() {
        // block_tokens = max_tokens is the degenerate one-block-per-session
        // (contiguous) layout; a 4-token block layout pages every session
        // across many blocks. Same seed => outputs must be bit-identical.
        let contiguous = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 64,
            ..ReferenceConfig::default()
        });
        let paged = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 4,
            ..ReferenceConfig::default()
        });
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let (lc, mut sc) = contiguous.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let (lp, mut sp) = paged.prefill(&[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(bits(&lc), bits(&lp), "prefill diverged");
        for t in 0..10 {
            let dc = contiguous.decode(&mut sc, t).unwrap();
            let dp = paged.decode(&mut sp, t).unwrap();
            assert_eq!(bits(&dc), bits(&dp), "decode diverged at token {t}");
        }
        assert!(sp.kv.blocks().len() > sc.kv.blocks().len(), "paged run spans blocks");
    }

    #[test]
    fn end_session_recycles_blocks_without_rezeroing() {
        let m = RefLlm::new(ReferenceConfig {
            kv_pool_blocks: 2,
            kv_block_tokens: 64,
            ..ReferenceConfig::default()
        });
        let (_, mut a) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut b) = m.prefill(&[4, 5]).unwrap();
        // pool of 2 is now exhausted
        let err = m.prefill(&[6]).unwrap_err();
        assert!(format!("{err:#}").contains("kv arena exhausted"), "{err:#}");
        // retiring a session leaves its block cached (the prefix index
        // still holds it); a *different* prompt evicts the cache entry,
        // recycles the block without re-zeroing, and must still compute
        // correctly on the stale bytes
        Backend::end_session(&m, &mut a);
        assert!(a.kv.is_empty());
        assert_eq!(Backend::memory(&m).unwrap().prefix_cached_blocks, 1);
        let (l1, mut c) = m.prefill(&[9, 9, 8]).unwrap();
        let stats = Backend::memory(&m).unwrap();
        assert_eq!(stats.reuse_hits, 1, "{stats:?}");
        assert_eq!(stats.blocks_free, 0);
        // the recycled block serves bit-identical logits to a fresh model
        let fresh = RefLlm::new(ReferenceConfig {
            kv_pool_blocks: 2,
            kv_block_tokens: 64,
            ..ReferenceConfig::default()
        });
        let (l2, _) = fresh.prefill(&[9, 9, 8]).unwrap();
        assert_eq!(l1, l2, "stale block bytes leaked into the computation");
        Backend::end_session(&m, &mut b);
        Backend::end_session(&m, &mut c);
        let stats = m.memory_stats();
        assert_eq!(stats.blocks_free, stats.blocks_total, "blocks leaked");
    }

    #[test]
    fn repeated_prompt_adopts_shared_prefix_bit_identically() {
        // K sessions with an identical prompt: one physical copy of the
        // prefix, bit-identical logits, and the prefix meter counts the
        // adoptions
        let m = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 4,
            ..ReferenceConfig::default()
        });
        let prompt = [1i32, 2, 3, 4, 5, 6, 7, 8, 9, 10]; // 2 full + 1 boundary block
        let (l0, s0) = m.prefill(&prompt).unwrap();
        let pinned_after_one =
            m.memory_stats().blocks_total - m.memory_stats().blocks_free;
        let mut sessions = vec![s0];
        for _ in 0..3 {
            let (l, s) = m.prefill(&prompt).unwrap();
            assert_eq!(l0, l, "adopted prefill must be bit-identical");
            // the full 4-token blocks are physically shared
            assert_eq!(s.kv.blocks()[..2], sessions[0].kv.blocks()[..2]);
            sessions.push(s);
        }
        let stats = m.memory_stats();
        assert_eq!(stats.prefix_hits, 3, "{stats:?}");
        // 4 sessions over a 3-block prompt: 2 shared + 4 private
        // boundary copies = 6 blocks, not 12
        assert_eq!(
            stats.blocks_total - stats.blocks_free,
            pinned_after_one + 3,
            "each extra session must pin only its private boundary block"
        );
        // shared history decodes bit-identically to the private owner
        let mut logits = Vec::new();
        for s in sessions.iter_mut() {
            logits.push(m.decode(s, 42).unwrap());
        }
        for l in &logits[1..] {
            assert_eq!(&logits[0], l, "shared-block decode diverged");
        }
        for s in sessions.iter_mut() {
            Backend::end_session(&m, s);
        }
        let stats = m.memory_stats();
        assert_eq!(stats.blocks_free, stats.blocks_total, "blocks leaked");
    }

    #[test]
    fn shared_prefix_len_reports_resident_prefixes() {
        let m = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 4,
            ..ReferenceConfig::default()
        });
        let prompt = [1i32, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        assert_eq!(Backend::shared_prefix_len(&m, &prompt), 0, "cold index");
        let (_, _s) = m.prefill(&prompt).unwrap();
        // identical prompt: everything but the last token is resident
        assert_eq!(Backend::shared_prefix_len(&m, &prompt), 9);
        // same first 2 blocks, different tail: the full blocks are
        let mut div = prompt;
        div[9] = 99;
        assert_eq!(Backend::shared_prefix_len(&m, &div), 8);
        // unrelated prompt: nothing
        assert_eq!(Backend::shared_prefix_len(&m, &[50, 60, 70]), 0);
        // prefill_from with any advisory hint matches plain prefill
        let (a, _) = Backend::prefill_from(&m, &div, 8).unwrap();
        let fresh = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 4,
            ..ReferenceConfig::default()
        });
        let (b, _) = fresh.prefill(&div).unwrap();
        assert_eq!(a, b, "partial prefill diverged from cold prefill");
    }

    #[test]
    fn decode_growth_exhaustion_is_typed_and_leaves_sessions_unadvanced() {
        use crate::runtime::kv::KvExhausted;
        // one 4-token block per session, 2-block pool: two sessions fit
        // until either needs a second block
        let m = RefLlm::new(ReferenceConfig {
            kv_block_tokens: 4,
            kv_pool_blocks: 2,
            ..ReferenceConfig::default()
        });
        let (_, mut a) = m.prefill(&[1, 2, 3]).unwrap();
        let (_, mut b) = m.prefill(&[4, 5, 6]).unwrap();
        m.decode(&mut a, 7).unwrap(); // pos 4, block full
        let pos_a = a.pos;
        let pos_b = b.pos;
        let mut batch = [&mut a, &mut b];
        let err = m.decode_batch(&mut batch, &[8, 9]).unwrap_err();
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "{err:#}");
        assert_eq!(a.pos, pos_a, "failed growth must not advance sessions");
        assert_eq!(b.pos, pos_b);
        // releasing b unblocks a's growth
        Backend::end_session(&m, &mut b);
        m.decode(&mut a, 8).unwrap();
        assert_eq!(a.pos, pos_a + 1);
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // interleaving unrelated prefills/decodes through the shared
        // scratch arena must not leak state between calls
        let m = RefLlm::new(ReferenceConfig::default());
        let (l1, _) = m.prefill(&[42, 43]).unwrap();
        let _ = m.prefill(&[200, 201, 202, 203, 204]).unwrap();
        let (_, mut s) = m.prefill(&[9]).unwrap();
        let _ = m.decode(&mut s, 10).unwrap();
        let (l2, _) = m.prefill(&[42, 43]).unwrap();
        assert_eq!(l1, l2);
    }
}
