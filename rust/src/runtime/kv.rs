//! Paged KV storage: a refcounted, block-granular arena shared by every
//! session of a backend, with copy-on-write prefix sharing.
//!
//! EdgeLLM's premise is that KV/weight memory traffic — not FLOPs —
//! bounds edge serving. The old session model worked against that:
//! every admitted request zero-allocated a full `max_tokens` K/V cache
//! and dropped it at retirement, so a short request paid for the
//! longest possible one and a retired session's memory was never
//! reused. The arena fixes both:
//!
//! * **Block-granular ownership.** All KV storage lives in one pool of
//!   fixed-size *token blocks* (default [`DEFAULT_BLOCK_TOKENS`] = 64
//!   tokens; each block holds, per layer, `block_tokens` rows of
//!   `[kv_heads, head_dim]`). A session holds a [`KvHandle`] — a block
//!   table plus nothing else — and grows one block at a time as it
//!   decodes, so resident bytes track *actual* context lengths.
//! * **Refcounted sharing.** Every block carries a reference count:
//!   handles and the prefix index hold references, [`KvArena::release`]
//!   decrements, and a block returns to the free list only at zero. K
//!   sessions with the same prompt prefix hold *one* physical copy of
//!   its full blocks; [`KvArena::ensure_writable`] copies a block on
//!   write (CoW) when anyone else still references it, so no session
//!   ever writes through a shared block.
//! * **Prefix index.** Completed prefills register their prompt under
//!   two kinds of key: a hash of the token ids covering each *full*
//!   block (tier 1 — any later prompt sharing that block-aligned prefix
//!   adopts the blocks), and a hash of the *whole* prompt (tier 2 — an
//!   identical prompt re-prefills only its final token, after CoW of
//!   the partially-filled boundary block). Index-held blocks that no
//!   handle pins count as *reclaimable*: they stay cached while memory
//!   is idle and are evicted LRU-entry-at-a-time the moment an
//!   allocation needs them.
//! * **Free-list recycling without re-zeroing.** Released blocks go on
//!   a free list and are handed out again as-is; every position a
//!   reader can reach (`< pos`) is written by prefill/decode before it
//!   is read, so stale bytes are unobservable and the recycle path
//!   costs no memset. [`MemoryStats::reuse_hits`] counts each recycled
//!   block — the figure the serving stats line surfaces as
//!   `kv_reuse_hits`.
//! * **Memory-aware admission.** [`MemoryStats`] (total/free/reserved
//!   bytes plus block-granular counters) is what
//!   [`Backend::memory`](super::backend::Backend::memory) reports and
//!   what the scheduler's admission gate consumes: a request is
//!   admitted while the arena can still cover its *worst-case* block
//!   count (prompt + `max_new_tokens`), so `max_active` becomes a cap,
//!   not the allocator. `blocks_free` counts cache-only blocks as free
//!   (they are reclaimable on demand), and a CoW copy is *neutral* for
//!   `blocks_free` — the copy consumes one block while the original it
//!   un-pins becomes cache-only — so prefix sharing never invalidates
//!   the gate's arithmetic.
//! * **Structured exhaustion.** Growth past the pool fails with the
//!   typed [`KvExhausted`] error; the scheduler turns that into a
//!   preemption (`Event::Error("preempted: …")`) of the youngest
//!   session instead of failing the whole round.
//!
//! Layout of one block (`block_stride` f32 elements, identical for K
//! and V):
//!
//! ```text
//! block b:  [layer 0: block_tokens rows of `row` floats]
//!           [layer 1: block_tokens rows]
//!           ...
//!           [layer L-1: block_tokens rows]
//! position p of a session lives in  block_table[p / block_tokens]
//! at row offset                     (p % block_tokens) * row
//! ```
//!
//! The gather path ([`PagedRows`] + `kernels::attend_paged_into`) walks
//! positions in the same order and with the same per-row arithmetic as
//! the contiguous kernels, so paged attention is **bit-identical** to
//! the contiguous path — asserted in `rust/tests/backend_equivalence.rs`
//! and the kernel unit tests. Shared blocks hold bytes written by a
//! deterministic prefill, and CoW copies them verbatim, so sharing
//! preserves that bit-identity.
//!
//! # Example: reserve, share, release
//!
//! ```
//! use edgellm::runtime::kv::KvArena;
//!
//! // 2 layers, 4-float rows, 8-token blocks, 16-block pool
//! let mut arena = KvArena::new(2, 4, 8, 16);
//! let prompt: Vec<i32> = (0..16).collect();
//!
//! // first session: private blocks, then registered in the prefix index
//! let mut a = arena.reserve(prompt.len()).unwrap();
//! arena.k_row_mut(&a, 0, 0).fill(1.0);
//! arena.register_prefix(&prompt, &a);
//!
//! // second session with the same prompt adopts the shared blocks:
//! // both full blocks are physically shared, only the last token is
//! // left for the caller to recompute
//! let (mut b, shared_len) = arena.adopt_prefix(&prompt).unwrap();
//! assert_eq!(shared_len, prompt.len() - 1);
//! assert_eq!(a.blocks(), b.blocks());
//!
//! // writing into a shared block first makes it private (CoW)
//! arena.ensure_writable(&mut b, 15).unwrap();
//! assert_ne!(a.blocks()[1], b.blocks()[1], "boundary block was copied");
//! assert_eq!(a.blocks()[0], b.blocks()[0], "full prefix block stays shared");
//!
//! // release decrements refcounts; the shared block is freed only when
//! // the last holder (here: the prefix index itself) lets go
//! arena.release(&mut a);
//! arena.release(&mut b);
//! assert_eq!(arena.stats().blocks_free, 16, "cached blocks count as free");
//! ```

#![deny(missing_docs)]

use std::collections::HashMap;
use std::fmt;

/// Default tokens per block. 64 keeps the block table tiny while
/// bounding per-request overallocation to < 64 tokens of KV rows.
pub const DEFAULT_BLOCK_TOKENS: usize = 64;

/// Arena accounting reported by [`Backend::memory`] and surfaced on the
/// serving stats line (`kv_blocks_total`, `kv_blocks_free`,
/// `kv_reuse_hits`). Byte figures count K **and** V storage.
///
/// [`Backend::memory`]: super::backend::Backend::memory
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// pool capacity in bytes (`blocks_total * block bytes`)
    pub total_bytes: u64,
    /// bytes not pinned by any live handle (`total_bytes -
    /// reserved_bytes`; includes cache-only blocks, which are
    /// reclaimable on demand)
    pub free_bytes: u64,
    /// bytes pinned by live handles (`total_bytes - free_bytes`)
    pub reserved_bytes: u64,
    /// tokens per block — what converts a token budget into blocks
    pub block_tokens: u64,
    /// pool capacity in blocks
    pub blocks_total: u64,
    /// blocks an allocation could obtain right now: truly free blocks
    /// plus cache-only blocks (held only by the prefix index, evictable
    /// on demand)
    pub blocks_free: u64,
    /// blocks handed out from the free list (recycled without zeroing)
    pub reuse_hits: u64,
    /// high-water mark of `reserved_bytes` over the arena's lifetime —
    /// the true peak KV residency, including blocks that were released
    /// again before any caller could sample `reserved_bytes`
    pub peak_reserved_bytes: u64,
    /// blocks currently held *only* by the prefix index (no live
    /// handle): resident prompt cache, all of it reclaimable
    pub prefix_cached_blocks: u64,
    /// cumulative prefix-index hits: prefills that adopted a resident
    /// prefix instead of recomputing it
    pub prefix_hits: u64,
}

/// The stable marker every rendering of [`KvExhausted`] starts with —
/// what the scheduler matches when the error crossed the bridge as a
/// `Frame::Error` string and the typed downcast is unavailable. One
/// constant shared by the `Display` impl and the matcher, so the two
/// cannot drift apart (a reworded message would otherwise silently turn
/// bridged preemptions into whole-round failures).
pub const KV_EXHAUSTED_MARKER: &str = "kv arena exhausted";

/// Typed "the pool is out of blocks" error. The scheduler downcasts it
/// (or matches [`KV_EXHAUSTED_MARKER`] when it crossed the bridge as a
/// string) to drive the preemption path instead of failing the whole
/// round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvExhausted {
    /// blocks the failed allocation still needed
    pub needed_blocks: usize,
    /// blocks obtainable at the time of failure (free list + evictable
    /// cache — 0 by construction when growth fails)
    pub blocks_free: usize,
}

impl fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{KV_EXHAUSTED_MARKER}: need {} block(s), {} free",
            self.needed_blocks, self.blocks_free
        )
    }
}

impl std::error::Error for KvExhausted {}

/// A session's share of the arena: the ordered block table. Positions
/// `[0, blocks.len() * block_tokens)` are addressable; `Session::pos`
/// tracks how many are live. Deliberately not `Clone` — two handles
/// naming the same blocks without the arena knowing would alias KV
/// state and double-free on release; sharing is explicit and
/// refcounted, via [`KvArena::adopt_prefix`].
#[derive(Debug, Default)]
pub struct KvHandle {
    blocks: Vec<u32>,
}

impl KvHandle {
    /// The block table (ids into the owning arena), in position order.
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// True for sessions that hold no arena storage (stateless/remote
    /// backends, or already released).
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Token positions this handle can address.
    pub fn capacity_tokens(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens
    }
}

/// Read-only view of one layer's K (or V) rows through a block table —
/// the gather side of the paged path, consumed by
/// `kernels::attend_paged_into`. Constructed by [`KvArena::k_rows`] /
/// [`KvArena::v_rows`] (or [`PagedRows::new`] for custom storage).
/// `Copy` because it is a pair of shared views plus addressing
/// constants — the parallel attention driver hands each worker its own.
#[derive(Clone, Copy)]
pub struct PagedRows<'a> {
    data: &'a [f32],
    blocks: &'a [u32],
    block_tokens: usize,
    block_stride: usize,
    layer_off: usize,
    row: usize,
}

impl<'a> PagedRows<'a> {
    /// View `row`-float rows of one layer (at `layer_off` floats into
    /// each block) through `blocks` over the backing `data`.
    pub fn new(
        data: &'a [f32],
        blocks: &'a [u32],
        block_tokens: usize,
        block_stride: usize,
        layer_off: usize,
        row: usize,
    ) -> Self {
        PagedRows { data, blocks, block_tokens, block_stride, layer_off, row }
    }

    /// The `row`-float K/V row of position `pos`. One block-table
    /// lookup plus an offset — the paged analogue of `&cache[pos*d..]`.
    #[inline(always)]
    pub fn row(&self, pos: usize) -> &'a [f32] {
        let off = row_offset(
            self.blocks,
            self.block_tokens,
            self.block_stride,
            self.layer_off,
            self.row,
            pos,
        );
        &self.data[off..off + self.row]
    }
}

/// The one block/layer/row addressing formula, shared by the gather
/// view and the arena's mutable accessors so the two can never diverge.
#[inline(always)]
fn row_offset(
    blocks: &[u32],
    block_tokens: usize,
    block_stride: usize,
    layer_off: usize,
    row: usize,
    pos: usize,
) -> usize {
    let b = blocks[pos / block_tokens] as usize;
    b * block_stride + layer_off + (pos % block_tokens) * row
}

/// FNV-1a over the token id bytes — the prefix-index key. Collisions
/// are tolerated (entries also store the exact tokens and verify on
/// lookup); the hash only has to spread well.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// One resident prefix: the exact tokens it covers (collision
/// verification), the blocks holding their KV rows (one index
/// reference each), and an LRU stamp.
struct IndexEntry {
    tokens: Vec<i32>,
    blocks: Vec<u32>,
    last_used: u64,
}

/// The two-tier prefix index. Tier 1 (`full`) keys block-aligned
/// prefixes — `tokens[..k*block_tokens]` for every full block `k` of a
/// registered prompt — so any later prompt extending that prefix
/// adopts the blocks. Tier 2 (`whole`) keys entire prompts, partial
/// boundary block included, so an *identical* prompt recomputes only
/// its final token (after CoW of the boundary block).
#[derive(Default)]
struct PrefixIndex {
    full: HashMap<u64, IndexEntry>,
    whole: HashMap<u64, IndexEntry>,
}

/// The pool. Owns all K/V storage of one backend as `max_blocks`
/// fixed-size blocks; storage is materialized lazily (first use of a
/// fresh block grows the backing `Vec` by one `block_stride`), so a
/// generous cap costs nothing until blocks are actually touched.
pub struct KvArena {
    block_tokens: usize,
    max_blocks: usize,
    /// f32 elements per block (per K and per V): `layers * block_tokens * row`
    block_stride: usize,
    row: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// released blocks, handed out again without re-zeroing
    free: Vec<u32>,
    /// blocks whose storage exists (`k.len() == materialized * stride`)
    materialized: usize,
    /// per-materialized-block total reference count: live handles plus
    /// prefix-index entries. A block is freed only at zero.
    refs: Vec<u32>,
    /// per-materialized-block references held by the prefix index alone
    /// (always `<= refs`); `refs == idx_refs > 0` means cache-only
    idx_refs: Vec<u32>,
    /// physical blocks with `refs > 0`
    in_use: usize,
    /// live blocks held *only* by the prefix index — reclaimable, so
    /// they count as free for admission
    cached_only: usize,
    /// high-water mark of handle-pinned blocks (`in_use - cached_only`)
    peak_pinned: usize,
    reuse_hits: u64,
    prefix_hits: u64,
    /// allocation attempts (reserve / ensure / ensure_writable) refused
    /// for want of free blocks — the obs layer's pressure signal
    alloc_stalls: u64,
    /// copy-on-write block copies performed
    cow_copies: u64,
    index: PrefixIndex,
    /// monotone LRU clock, bumped on every index lookup/registration
    lru_clock: u64,
}

impl KvArena {
    /// `row` is the per-token, per-layer KV row width in f32 elements
    /// (`kv_heads * head_dim`).
    pub fn new(n_layers: usize, row: usize, block_tokens: usize, max_blocks: usize) -> Self {
        let block_tokens = block_tokens.max(1);
        KvArena {
            block_tokens,
            max_blocks,
            block_stride: n_layers * block_tokens * row,
            row,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            materialized: 0,
            refs: Vec::new(),
            idx_refs: Vec::new(),
            in_use: 0,
            cached_only: 0,
            peak_pinned: 0,
            reuse_hits: 0,
            prefix_hits: 0,
            alloc_stalls: 0,
            cow_copies: 0,
            index: PrefixIndex::default(),
            lru_clock: 0,
        }
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Pool capacity in blocks.
    pub fn blocks_total(&self) -> usize {
        self.max_blocks
    }

    /// Blocks an allocation could obtain right now: truly free blocks
    /// plus cache-only blocks (the prefix index yields them on demand).
    pub fn blocks_free(&self) -> usize {
        self.max_blocks - self.in_use + self.cached_only
    }

    /// Blocks needed to address `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.block_tokens)
    }

    /// Total references (handles + index entries) on `block` — test and
    /// diagnostics hook for the sharing invariants.
    pub fn block_refs(&self, block: u32) -> u32 {
        self.refs[block as usize]
    }

    /// A handle (not the index) now references `b`.
    fn add_handle_ref(&mut self, b: u32) {
        let i = b as usize;
        if self.refs[i] == 0 {
            self.in_use += 1;
        } else if self.refs[i] == self.idx_refs[i] {
            // was cache-only; a handle now pins it
            self.cached_only -= 1;
        }
        self.refs[i] += 1;
        self.peak_pinned = self.peak_pinned.max(self.in_use - self.cached_only);
    }

    /// A handle reference on `b` goes away; free at zero.
    fn drop_handle_ref(&mut self, b: u32) {
        let i = b as usize;
        debug_assert!(self.refs[i] > self.idx_refs[i], "handle ref under-count");
        self.refs[i] -= 1;
        if self.refs[i] == 0 {
            self.in_use -= 1;
            self.free.push(b);
        } else if self.refs[i] == self.idx_refs[i] {
            self.cached_only += 1;
        }
    }

    /// The prefix index takes a reference on `b`. Only called while a
    /// handle holds the block (registration happens at prefill end), so
    /// it can never create a cache-only block.
    fn add_index_ref(&mut self, b: u32) {
        let i = b as usize;
        debug_assert!(self.refs[i] > self.idx_refs[i], "index ref without a handle");
        self.refs[i] += 1;
        self.idx_refs[i] += 1;
    }

    /// An index reference on `b` goes away (entry eviction); free at
    /// zero.
    fn drop_index_ref(&mut self, b: u32) {
        let i = b as usize;
        debug_assert!(self.idx_refs[i] > 0, "index ref under-count");
        let was_cached = self.refs[i] == self.idx_refs[i];
        self.refs[i] -= 1;
        self.idx_refs[i] -= 1;
        if self.refs[i] == 0 {
            self.in_use -= 1;
            if was_cached {
                self.cached_only -= 1;
            }
            self.free.push(b);
        }
        // still referenced: if it was cache-only it stays cache-only
        // (both counts fell together), and a handle-pinned block cannot
        // become cache-only by losing an *index* ref — no counter moves
    }

    /// Obtain one block with zero references: pop the free list,
    /// materialize fresh storage, or evict LRU prefix-index entries
    /// until one of those succeeds. `None` means truly exhausted —
    /// every block is pinned by a live handle.
    fn take_block(&mut self) -> Option<u32> {
        loop {
            if let Some(b) = self.free.pop() {
                // recycled as-is: every reachable position is written
                // before it is read, so stale bytes are unobservable
                self.reuse_hits += 1;
                return Some(b);
            }
            if self.materialized < self.max_blocks {
                let b = self.materialized as u32;
                self.materialized += 1;
                self.k.resize(self.materialized * self.block_stride, 0.0);
                self.v.resize(self.materialized * self.block_stride, 0.0);
                self.refs.push(0);
                self.idx_refs.push(0);
                return Some(b);
            }
            if !self.evict_lru_entry() {
                return None;
            }
            // the eviction may have freed blocks (loop pops them) or
            // only dropped refs on blocks handles still pin (loop
            // evicts further entries until none remain)
        }
    }

    /// Drop the least-recently-used prefix-index entry (either tier),
    /// releasing its block references. Returns false when the index is
    /// empty.
    fn evict_lru_entry(&mut self) -> bool {
        let mut best: Option<(bool, u64, u64)> = None; // (whole?, key, last_used)
        for (&key, e) in &self.index.full {
            if best.map_or(true, |(_, _, lu)| e.last_used < lu) {
                best = Some((false, key, e.last_used));
            }
        }
        for (&key, e) in &self.index.whole {
            if best.map_or(true, |(_, _, lu)| e.last_used < lu) {
                best = Some((true, key, e.last_used));
            }
        }
        let Some((whole, key, _)) = best else { return false };
        let e = if whole {
            self.index.whole.remove(&key)
        } else {
            self.index.full.remove(&key)
        }
        .expect("picked from a live entry");
        for b in e.blocks {
            self.drop_index_ref(b);
        }
        true
    }

    /// Allocate a handle covering `tokens` positions, or fail whole —
    /// a partial reservation is never handed out.
    pub fn reserve(&mut self, tokens: usize) -> Result<KvHandle, KvExhausted> {
        let need = self.blocks_for(tokens);
        if need > self.blocks_free() {
            self.alloc_stalls += 1;
            return Err(KvExhausted { needed_blocks: need, blocks_free: self.blocks_free() });
        }
        let mut h = KvHandle::default();
        for _ in 0..need {
            // cannot fail: each taken block lowers blocks_free() by
            // exactly one (eviction is neutral), and need was checked
            let b = self.take_block().expect("blocks_free() covered the need");
            self.add_handle_ref(b);
            h.blocks.push(b);
        }
        Ok(h)
    }

    /// Grow `h` until it addresses `tokens` positions (lazy decode-time
    /// growth: one extra block per `block_tokens` generated tokens).
    pub fn ensure(&mut self, h: &mut KvHandle, tokens: usize) -> Result<(), KvExhausted> {
        let need_total = self.blocks_for(tokens);
        while h.blocks.len() < need_total {
            let Some(b) = self.take_block() else {
                self.alloc_stalls += 1;
                return Err(KvExhausted {
                    needed_blocks: need_total - h.blocks.len(),
                    blocks_free: 0,
                });
            };
            self.add_handle_ref(b);
            h.blocks.push(b);
        }
        Ok(())
    }

    /// Make the block holding `pos` safe for `h` to write: if anyone
    /// else (another handle or the prefix index) still references it,
    /// copy it — K and V contents verbatim — into a private block and
    /// swap that into `h`'s table (copy-on-write). No-op when `h` is
    /// the sole owner. Callers must invoke this before any scatter into
    /// a possibly-shared block; the paged writers do.
    ///
    /// A CoW is *neutral* for [`KvArena::blocks_free`]: the copy
    /// consumes one block while the original it un-pins becomes
    /// cache-only (or stays pinned by its other holder). Eviction
    /// inside the allocation can also simply un-share the block (the
    /// index drops its reference), in which case no copy happens.
    pub fn ensure_writable(&mut self, h: &mut KvHandle, pos: usize) -> Result<(), KvExhausted> {
        let bi = pos / self.block_tokens;
        loop {
            let b = h.blocks[bi];
            if self.refs[b as usize] <= 1 {
                return Ok(()); // sole owner — writable as-is
            }
            // shared: try to obtain a private block without the
            // take_block() eviction loop, because evicting may instead
            // drop the *sharer's* reference and make b private — the
            // re-check at the top of the loop catches that
            if let Some(nb) = self.free.pop() {
                self.reuse_hits += 1;
                self.cow_into(h, bi, nb);
                return Ok(());
            }
            if self.materialized < self.max_blocks {
                let nb = self.materialized as u32;
                self.materialized += 1;
                self.k.resize(self.materialized * self.block_stride, 0.0);
                self.v.resize(self.materialized * self.block_stride, 0.0);
                self.refs.push(0);
                self.idx_refs.push(0);
                self.cow_into(h, bi, nb);
                return Ok(());
            }
            if !self.evict_lru_entry() {
                self.alloc_stalls += 1;
                return Err(KvExhausted { needed_blocks: 1, blocks_free: 0 });
            }
        }
    }

    /// The copy half of CoW: clone block `h.blocks[bi]`'s K and V
    /// contents into fresh block `nb` and repoint the handle.
    fn cow_into(&mut self, h: &mut KvHandle, bi: usize, nb: u32) {
        let b = h.blocks[bi];
        debug_assert_ne!(b, nb, "a pinned block cannot come off the free list");
        let src = b as usize * self.block_stride;
        let dst = nb as usize * self.block_stride;
        self.k.copy_within(src..src + self.block_stride, dst);
        self.v.copy_within(src..src + self.block_stride, dst);
        self.add_handle_ref(nb);
        self.drop_handle_ref(b);
        h.blocks[bi] = nb;
        self.cow_copies += 1;
    }

    /// Drop every block reference `h` holds. Shared blocks only lose
    /// one reference (the other holders keep their bytes); blocks whose
    /// count reaches zero return to the free list. Draining the handle
    /// makes a second release (or a release after `end_session` already
    /// ran) a structural no-op — no double-free is representable.
    pub fn release(&mut self, h: &mut KvHandle) {
        for b in h.blocks.drain(..) {
            self.drop_handle_ref(b);
        }
    }

    /// Longest resident prefix of `tokens`, in tokens, without adopting
    /// it — the admission gate's read-only query. Capped at
    /// `tokens.len() - 1` so at least one token is always recomputed
    /// (logits must come from real compute).
    pub fn shared_prefix_len(&self, tokens: &[i32]) -> usize {
        let t = tokens.len();
        if t >= 2 {
            if let Some(e) = self.index.whole.get(&hash_tokens(tokens)) {
                if e.tokens == tokens {
                    return t - 1;
                }
            }
        }
        if t == 0 {
            return 0;
        }
        let bt = self.block_tokens;
        let mut k = (t - 1) / bt;
        while k >= 1 {
            if let Some(e) = self.index.full.get(&hash_tokens(&tokens[..k * bt])) {
                if e.tokens == tokens[..k * bt] {
                    return k * bt;
                }
            }
            k -= 1;
        }
        0
    }

    /// Adopt the longest resident prefix of `tokens`: returns a handle
    /// referencing the shared blocks (refcounts bumped) plus the number
    /// of positions they already hold. A tier-2 (whole-prompt) hit
    /// shares everything but the final token — the caller must
    /// [`KvArena::ensure_writable`] the boundary block before writing
    /// it. A tier-1 hit shares only full blocks, so the caller's writes
    /// land in fresh private blocks. `None` when nothing is resident.
    pub fn adopt_prefix(&mut self, tokens: &[i32]) -> Option<(KvHandle, usize)> {
        let t = tokens.len();
        self.lru_clock += 1;
        let clock = self.lru_clock;
        if t >= 2 {
            let key = hash_tokens(tokens);
            let blocks = self.index.whole.get_mut(&key).and_then(|e| {
                if e.tokens == tokens {
                    e.last_used = clock;
                    Some(e.blocks.clone())
                } else {
                    None
                }
            });
            if let Some(blocks) = blocks {
                return Some((self.adopt_blocks(&blocks), t - 1));
            }
        }
        if t == 0 {
            return None;
        }
        let bt = self.block_tokens;
        let mut k = (t - 1) / bt;
        while k >= 1 {
            let key = hash_tokens(&tokens[..k * bt]);
            let blocks = self.index.full.get_mut(&key).and_then(|e| {
                if e.tokens == tokens[..k * bt] {
                    e.last_used = clock;
                    Some(e.blocks.clone())
                } else {
                    None
                }
            });
            if let Some(blocks) = blocks {
                return Some((self.adopt_blocks(&blocks), k * bt));
            }
            k -= 1;
        }
        None
    }

    /// Bump handle refs on every adopted block and count the hit.
    fn adopt_blocks(&mut self, blocks: &[u32]) -> KvHandle {
        let mut h = KvHandle::default();
        for &b in blocks {
            self.add_handle_ref(b);
            h.blocks.push(b);
        }
        self.prefix_hits += 1;
        h
    }

    /// Register a completed prefill's prompt in the index: one tier-1
    /// entry per full block of the prompt, plus a tier-2 whole-prompt
    /// entry (prompts of at least 2 tokens — a 1-token prompt has
    /// nothing shareable). Existing entries are refreshed, hash
    /// collisions keep the incumbent, and only *prompt* tokens are ever
    /// registered — decode-generated positions are private by
    /// construction. Each entry holds one index reference per block, so
    /// the cached rows survive the session's release.
    pub fn register_prefix(&mut self, tokens: &[i32], h: &KvHandle) {
        let t = tokens.len();
        let bt = self.block_tokens;
        if t == 0 || h.blocks.len() * bt < t {
            return; // handle does not cover the prompt — nothing safe to share
        }
        self.lru_clock += 1;
        let clock = self.lru_clock;
        for k in 1..=(t / bt) {
            let covered = &tokens[..k * bt];
            let key = hash_tokens(covered);
            if let Some(e) = self.index.full.get_mut(&key) {
                if e.tokens == covered {
                    e.last_used = clock;
                }
                continue;
            }
            let blocks: Vec<u32> = h.blocks[..k].to_vec();
            for &b in &blocks {
                self.add_index_ref(b);
            }
            self.index.full.insert(
                key,
                IndexEntry { tokens: covered.to_vec(), blocks, last_used: clock },
            );
        }
        if t >= 2 {
            let key = hash_tokens(tokens);
            if let Some(e) = self.index.whole.get_mut(&key) {
                if e.tokens == tokens {
                    e.last_used = clock;
                }
                return;
            }
            let blocks: Vec<u32> = h.blocks[..t.div_ceil(bt)].to_vec();
            for &b in &blocks {
                self.add_index_ref(b);
            }
            self.index.whole.insert(
                key,
                IndexEntry { tokens: tokens.to_vec(), blocks, last_used: clock },
            );
        }
    }

    fn offset(&self, h: &KvHandle, layer: usize, pos: usize) -> usize {
        row_offset(
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
            pos,
        )
    }

    /// Mutable K row of `pos` — the scatter side of the paged path.
    /// The caller must have [`KvArena::ensure_writable`]'d the block
    /// (prefill/decode do, before any scatter).
    pub fn k_row_mut(&mut self, h: &KvHandle, layer: usize, pos: usize) -> &mut [f32] {
        let o = self.offset(h, layer, pos);
        &mut self.k[o..o + self.row]
    }

    /// Mutable V row of `pos`.
    pub fn v_row_mut(&mut self, h: &KvHandle, layer: usize, pos: usize) -> &mut [f32] {
        let o = self.offset(h, layer, pos);
        &mut self.v[o..o + self.row]
    }

    /// Gather view over `h`'s K rows of one layer.
    pub fn k_rows<'a>(&'a self, h: &'a KvHandle, layer: usize) -> PagedRows<'a> {
        PagedRows::new(
            &self.k,
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
        )
    }

    /// Gather view over `h`'s V rows of one layer.
    pub fn v_rows<'a>(&'a self, h: &'a KvHandle, layer: usize) -> PagedRows<'a> {
        PagedRows::new(
            &self.v,
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
        )
    }

    /// Current arena accounting. `free_bytes + reserved_bytes ==
    /// total_bytes` always; cache-only blocks count as free (they are
    /// reclaimable on demand), and `prefix_cached_blocks` says how many
    /// of the free blocks are that cache.
    pub fn stats(&self) -> MemoryStats {
        let block_bytes = (self.block_stride * 2 * std::mem::size_of::<f32>()) as u64;
        let pinned = (self.in_use - self.cached_only) as u64;
        MemoryStats {
            total_bytes: self.max_blocks as u64 * block_bytes,
            free_bytes: (self.max_blocks as u64 - pinned) * block_bytes,
            reserved_bytes: pinned * block_bytes,
            block_tokens: self.block_tokens as u64,
            blocks_total: self.max_blocks as u64,
            blocks_free: self.blocks_free() as u64,
            reuse_hits: self.reuse_hits,
            peak_reserved_bytes: self.peak_pinned as u64 * block_bytes,
            prefix_cached_blocks: self.cached_only as u64,
            prefix_hits: self.prefix_hits,
        }
    }

    /// Allocation attempts refused for want of free blocks. Not part of
    /// the wire-anchored [`MemoryStats`]; surfaced through the obs
    /// layer (`Backend::kv_pressure`).
    pub fn alloc_stalls(&self) -> u64 {
        self.alloc_stalls
    }

    /// Copy-on-write block copies performed (same caveat as
    /// [`KvArena::alloc_stalls`]).
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KvArena {
        // 2 layers, 4-float rows, 8-token blocks, 4-block pool
        KvArena::new(2, 4, 8, 4)
    }

    #[test]
    fn reserve_rounds_up_to_blocks() {
        let mut a = tiny();
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(8), 1);
        assert_eq!(a.blocks_for(9), 2);
        let h = a.reserve(9).unwrap();
        assert_eq!(h.blocks().len(), 2);
        assert_eq!(h.capacity_tokens(a.block_tokens()), 16);
        assert_eq!(a.blocks_free(), 2);
    }

    #[test]
    fn reserve_fails_whole_when_short() {
        let mut a = tiny();
        let _h = a.reserve(32).unwrap(); // all 4 blocks
        let err = a.reserve(1).unwrap_err();
        assert_eq!(err.blocks_free, 0);
        assert_eq!(a.blocks_free(), 0, "failed reserve must not hold blocks");
        assert!(format!("{err}").contains("kv arena exhausted"));
    }

    #[test]
    fn ensure_grows_one_block_at_a_time() {
        let mut a = tiny();
        let mut h = a.reserve(3).unwrap();
        assert_eq!(h.blocks().len(), 1);
        a.ensure(&mut h, 8).unwrap();
        assert_eq!(h.blocks().len(), 1, "still inside the first block");
        a.ensure(&mut h, 9).unwrap();
        assert_eq!(h.blocks().len(), 2);
        a.ensure(&mut h, 32).unwrap();
        assert_eq!(h.blocks().len(), 4);
        assert!(a.ensure(&mut h, 33).is_err(), "pool holds only 4 blocks");
    }

    #[test]
    fn release_recycles_without_rezeroing_and_counts_reuse() {
        let mut a = tiny();
        let mut h = a.reserve(8).unwrap();
        a.k_row_mut(&h, 0, 3).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let block = h.blocks()[0];
        a.release(&mut h);
        assert!(h.is_empty());
        assert_eq!(a.blocks_free(), 4);
        assert_eq!(a.stats().reuse_hits, 0);

        let h2 = a.reserve(8).unwrap();
        assert_eq!(h2.blocks()[0], block, "free list hands the block back");
        assert_eq!(a.stats().reuse_hits, 1);
        // recycled as-is: the stale row is still there (and would be
        // overwritten before any reader could reach it)
        assert_eq!(a.k_rows(&h2, 0).row(3), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn double_release_is_a_noop() {
        let mut a = tiny();
        let mut h = a.reserve(8).unwrap();
        a.release(&mut h);
        a.release(&mut h);
        assert_eq!(a.blocks_free(), 4);
        assert_eq!(a.stats().blocks_free, a.stats().blocks_total);
    }

    #[test]
    fn paged_rows_address_across_shuffled_blocks() {
        let mut a = tiny();
        // force a non-identity block table: reserve, release the first
        // handle, reserve again so the free list reverses the order
        let mut h0 = a.reserve(16).unwrap();
        let mut h1 = a.reserve(16).unwrap();
        a.release(&mut h0);
        a.release(&mut h1);
        let h = a.reserve(32).unwrap();
        // write a recognizable value at every position/layer, then read
        // it back through the gather view
        for layer in 0..2 {
            for pos in 0..32 {
                let val = (layer * 100 + pos) as f32;
                a.k_row_mut(&h, layer, pos).fill(val);
                a.v_row_mut(&h, layer, pos).fill(-val);
            }
        }
        for layer in 0..2 {
            let kr = a.k_rows(&h, layer);
            let vr = a.v_rows(&h, layer);
            for pos in 0..32 {
                let val = (layer * 100 + pos) as f32;
                assert!(kr.row(pos).iter().all(|&x| x == val), "k layer {layer} pos {pos}");
                assert!(vr.row(pos).iter().all(|&x| x == -val), "v layer {layer} pos {pos}");
            }
        }
    }

    #[test]
    fn stats_are_internally_consistent() {
        let mut a = tiny();
        let s0 = a.stats();
        assert_eq!(s0.total_bytes, s0.free_bytes);
        assert_eq!(s0.reserved_bytes, 0);
        assert_eq!(s0.block_tokens, 8);
        let mut h = a.reserve(20).unwrap(); // 3 blocks
        let s1 = a.stats();
        assert_eq!(s1.blocks_total, 4);
        assert_eq!(s1.blocks_free, 1);
        assert_eq!(s1.free_bytes + s1.reserved_bytes, s1.total_bytes);
        // one block = 2 layers * 8 tokens * 4 floats * 4 bytes * (K+V)
        assert_eq!(s1.total_bytes, 4 * (2 * 8 * 4 * 4 * 2) as u64);
        // the watermark survives a release that a later sample would miss
        assert_eq!(s1.peak_reserved_bytes, s1.reserved_bytes);
        assert_eq!(s1.prefix_cached_blocks, 0);
        assert_eq!(s1.prefix_hits, 0);
        a.release(&mut h);
        let s2 = a.stats();
        assert_eq!(s2.reserved_bytes, 0);
        assert_eq!(s2.peak_reserved_bytes, s1.reserved_bytes, "peak must not reset");
    }

    #[test]
    fn storage_materializes_lazily() {
        let mut a = KvArena::new(1, 4, 8, 1024);
        assert_eq!(a.k.len(), 0, "no storage before first use");
        let mut h = a.reserve(8).unwrap();
        assert_eq!(a.k.len(), a.block_stride, "one block materialized");
        a.release(&mut h);
        let _h2 = a.reserve(8).unwrap();
        assert_eq!(a.k.len(), a.block_stride, "recycling allocates nothing");
    }

    // ---- prefix sharing ----

    /// 3-block prompt in an 8-token-block arena: 2 full blocks + 1
    /// partial boundary block.
    fn prompt20() -> Vec<i32> {
        (0..20).collect()
    }

    #[test]
    fn whole_prompt_hit_shares_every_block() {
        let mut a = KvArena::new(2, 4, 8, 16);
        let p = prompt20();
        let h1 = a.reserve(p.len()).unwrap();
        a.register_prefix(&p, &h1);
        assert_eq!(a.shared_prefix_len(&p), 19, "whole-prompt hit: all but last");
        let (h2, shared) = a.adopt_prefix(&p).unwrap();
        assert_eq!(shared, 19);
        assert_eq!(h1.blocks(), h2.blocks(), "one physical copy");
        for &b in h1.blocks() {
            assert!(a.block_refs(b) >= 2, "block {b} must be shared");
        }
        assert_eq!(a.stats().prefix_hits, 1);
        // the two handles pin 3 physical blocks total, not 6
        assert_eq!(a.stats().blocks_total - a.stats().blocks_free, 3);
    }

    #[test]
    fn full_block_prefix_hit_shares_only_full_blocks() {
        let mut a = KvArena::new(2, 4, 8, 16);
        let p = prompt20();
        let h1 = a.reserve(p.len()).unwrap();
        a.register_prefix(&p, &h1);
        // same 16-token (2-block) prefix, different tail
        let mut q = prompt20();
        q[18] = 99;
        assert_eq!(a.shared_prefix_len(&q), 16, "full blocks only");
        let (h2, shared) = a.adopt_prefix(&q).unwrap();
        assert_eq!(shared, 16);
        assert_eq!(h2.blocks(), &h1.blocks()[..2]);
        // a 5-token prompt matches nothing block-aligned
        assert_eq!(a.shared_prefix_len(&q[..5]), 0);
        assert!(a.adopt_prefix(&q[..5]).is_none());
    }

    #[test]
    fn cow_copies_shared_block_and_preserves_bytes() {
        let mut a = KvArena::new(1, 4, 8, 16);
        let p: Vec<i32> = (0..12).collect(); // 1 full + 1 boundary block
        let mut h1 = a.reserve(p.len()).unwrap();
        for pos in 0..12 {
            a.k_row_mut(&h1, 0, pos).fill(pos as f32);
            a.v_row_mut(&h1, 0, pos).fill(-(pos as f32));
        }
        a.register_prefix(&p, &h1);
        let (mut h2, shared) = a.adopt_prefix(&p).unwrap();
        assert_eq!(shared, 11);
        let boundary = h2.blocks()[1];
        // writing position 11 lands in the shared boundary block: CoW
        a.ensure_writable(&mut h2, 11).unwrap();
        assert_ne!(h2.blocks()[1], boundary, "boundary block must be copied");
        assert_eq!(h2.blocks()[0], h1.blocks()[0], "full block stays shared");
        // the copy carried the original bytes verbatim
        for pos in 8..12 {
            assert_eq!(a.k_rows(&h2, 0).row(pos), &[pos as f32; 4][..]);
            assert_eq!(a.v_rows(&h2, 0).row(pos), &[-(pos as f32); 4][..]);
        }
        // writing through h2 leaves h1 (and the cache) untouched
        a.ensure_writable(&mut h2, 11).unwrap(); // now a no-op
        a.k_row_mut(&h2, 0, 11).fill(777.0);
        assert_eq!(a.k_rows(&h1, 0).row(11), &[11.0; 4][..]);
        a.release(&mut h1);
        a.release(&mut h2);
    }

    #[test]
    fn pressure_counters_track_stalls_and_cow() {
        let mut a = KvArena::new(1, 4, 8, 2);
        assert_eq!((a.alloc_stalls(), a.cow_copies()), (0, 0));
        let mut h = a.reserve(16).unwrap(); // whole pool
        assert!(a.reserve(8).is_err());
        assert_eq!(a.alloc_stalls(), 1, "refused reserve counts");
        assert!(a.ensure(&mut h, 24).is_err());
        assert_eq!(a.alloc_stalls(), 2, "refused growth counts");
        a.release(&mut h);
        // a CoW on a boundary block shared with a *live* handle bumps
        // cow_copies (an index-only sharer would be evicted instead)
        let mut a = KvArena::new(1, 4, 8, 4);
        let p: Vec<i32> = (0..12).collect();
        let mut h1 = a.reserve(p.len()).unwrap();
        a.register_prefix(&p, &h1);
        let (mut h2, _) = a.adopt_prefix(&p).unwrap();
        a.ensure_writable(&mut h2, 11).unwrap();
        assert_eq!(a.cow_copies(), 1);
        assert_eq!(a.alloc_stalls(), 0, "fresh arena, no stalls");
        a.release(&mut h1);
        a.release(&mut h2);
    }

    #[test]
    fn cached_blocks_count_as_free_and_survive_release() {
        let mut a = KvArena::new(1, 4, 8, 4);
        let p: Vec<i32> = (0..16).collect(); // 2 full blocks
        let mut h = a.reserve(p.len()).unwrap();
        a.register_prefix(&p, &h);
        let s = a.stats();
        assert_eq!(s.blocks_free, 2, "handle pins 2 of 4");
        assert_eq!(s.prefix_cached_blocks, 0, "handle still pins the cache");
        a.release(&mut h);
        let s = a.stats();
        assert_eq!(s.prefix_cached_blocks, 2, "cache-only now");
        assert_eq!(s.blocks_free, 4, "cache-only blocks are reclaimable");
        assert_eq!(s.reserved_bytes, 0, "nothing pinned by handles");
        // and the cached rows are still adoptable
        let (h2, shared) = a.adopt_prefix(&p).unwrap();
        assert_eq!(shared, 15);
        assert_eq!(h2.blocks().len(), 2);
        assert_eq!(a.stats().prefix_cached_blocks, 0, "adopted = pinned again");
    }

    #[test]
    fn allocation_evicts_lru_entries_under_pressure() {
        let mut a = KvArena::new(1, 4, 8, 2);
        let p1: Vec<i32> = (0..8).collect();
        let p2: Vec<i32> = (100..108).collect();
        let mut h1 = a.reserve(8).unwrap();
        a.register_prefix(&p1, &h1);
        let mut h2 = a.reserve(8).unwrap();
        a.register_prefix(&p2, &h2);
        a.release(&mut h1);
        a.release(&mut h2);
        // both blocks are cache-only; a fresh 2-block reservation must
        // evict both entries and succeed
        assert_eq!(a.stats().prefix_cached_blocks, 2);
        let h3 = a.reserve(16).unwrap();
        assert_eq!(h3.blocks().len(), 2);
        assert_eq!(a.stats().prefix_cached_blocks, 0);
        assert!(a.adopt_prefix(&p1).is_none(), "evicted entries are gone");
        assert!(a.adopt_prefix(&p2).is_none());
    }

    #[test]
    fn eviction_prefers_least_recently_used() {
        let mut a = KvArena::new(1, 4, 8, 2);
        let p1: Vec<i32> = (0..8).collect();
        let p2: Vec<i32> = (100..108).collect();
        let mut h1 = a.reserve(8).unwrap();
        a.register_prefix(&p1, &h1);
        let mut h2 = a.reserve(8).unwrap();
        a.register_prefix(&p2, &h2);
        a.release(&mut h1);
        a.release(&mut h2);
        // touch p1 so p2 becomes the LRU entry
        let (mut t, _) = a.adopt_prefix(&p1).unwrap();
        a.release(&mut t);
        // one block of demand: p2's entry must be the one evicted
        let h3 = a.reserve(8).unwrap();
        assert!(a.adopt_prefix(&p1).is_some(), "recently-used entry survives");
        assert!(a.adopt_prefix(&p2).is_none(), "LRU entry was evicted");
        drop(h3);
    }

    #[test]
    fn ensure_writable_unshares_without_copy_when_eviction_frees_the_ref() {
        // 1-block pool: the only sharer of the block is the index
        // entry itself, so CoW pressure must un-share (evict the
        // entry) rather than copy — there is nowhere to copy to
        let mut a = KvArena::new(1, 4, 8, 1);
        let p: Vec<i32> = (0..8).collect();
        let mut h = a.reserve(8).unwrap();
        a.k_row_mut(&h, 0, 0).fill(5.0);
        a.register_prefix(&p, &h);
        // a block-aligned prompt registers in both tiers (a tier-1
        // full-block entry for longer prompts extending it, a tier-2
        // whole-prompt entry for identical prompts), so the only block
        // carries two index refs on top of the handle's
        assert_eq!(a.block_refs(h.blocks()[0]), 3);
        let b = h.blocks()[0];
        a.ensure_writable(&mut h, 0).unwrap();
        assert_eq!(h.blocks()[0], b, "no copy — the index refs were dropped");
        assert_eq!(a.block_refs(b), 1);
        assert_eq!(a.k_rows(&h, 0).row(0), &[5.0; 4][..]);
    }

    #[test]
    fn release_of_one_sharer_keeps_blocks_for_the_rest() {
        let mut a = KvArena::new(1, 4, 8, 16);
        let p: Vec<i32> = (0..16).collect();
        let mut h1 = a.reserve(16).unwrap();
        for pos in 0..16 {
            a.k_row_mut(&h1, 0, pos).fill(pos as f32);
        }
        a.register_prefix(&p, &h1);
        let (h2, _) = a.adopt_prefix(&p).unwrap();
        a.release(&mut h1);
        assert!(h1.is_empty());
        // h2 still reads the shared rows — nothing was freed
        for pos in 0..16 {
            assert_eq!(a.k_rows(&h2, 0).row(pos), &[pos as f32; 4][..]);
        }
        let s = a.stats();
        assert_eq!(s.blocks_total - s.blocks_free, 2, "h2 pins both blocks");
    }

    #[test]
    fn one_token_prompts_are_never_indexed() {
        let mut a = KvArena::new(1, 4, 8, 4);
        let h = a.reserve(1).unwrap();
        a.register_prefix(&[42], &h);
        assert_eq!(a.shared_prefix_len(&[42]), 0);
        assert!(a.adopt_prefix(&[42]).is_none());
    }
}
