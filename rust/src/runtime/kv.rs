//! Paged KV storage: a block-granular arena shared by every session of
//! a backend, replacing per-request contiguous `Vec` caches.
//!
//! EdgeLLM's premise is that KV/weight memory traffic — not FLOPs —
//! bounds edge serving. The old session model worked against that:
//! every admitted request zero-allocated a full `max_tokens` K/V cache
//! and dropped it at retirement, so a short request paid for the
//! longest possible one and a retired session's memory was never
//! reused. The arena fixes both:
//!
//! * **Block-granular ownership.** All KV storage lives in one pool of
//!   fixed-size *token blocks* (default [`DEFAULT_BLOCK_TOKENS`] = 64
//!   tokens; each block holds, per layer, `block_tokens` rows of
//!   `[kv_heads, head_dim]`). A session holds a [`KvHandle`] — a block
//!   table plus nothing else — and grows one block at a time as it
//!   decodes, so resident bytes track *actual* context lengths.
//! * **Free-list recycling without re-zeroing.** Released blocks go on
//!   a free list and are handed out again as-is; every position a
//!   reader can reach (`< pos`) is written by prefill/decode before it
//!   is read, so stale bytes are unobservable and the recycle path
//!   costs no memset. [`MemoryStats::reuse_hits`] counts each recycled
//!   block — the figure the serving stats line surfaces as
//!   `kv_reuse_hits`.
//! * **Memory-aware admission.** [`MemoryStats`] (total/free/reserved
//!   bytes plus block-granular counters) is what
//!   [`Backend::memory`](super::backend::Backend::memory) reports and
//!   what the scheduler's admission gate consumes: a request is
//!   admitted while the arena can still cover its *worst-case* block
//!   count (prompt + `max_new_tokens`), so `max_active` becomes a cap,
//!   not the allocator.
//! * **Structured exhaustion.** Growth past the pool fails with the
//!   typed [`KvExhausted`] error; the scheduler turns that into a
//!   preemption (`Event::Error("preempted: …")`) of the youngest
//!   session instead of failing the whole round.
//!
//! Layout of one block (`block_stride` f32 elements, identical for K
//! and V):
//!
//! ```text
//! block b:  [layer 0: block_tokens rows of `row` floats]
//!           [layer 1: block_tokens rows]
//!           ...
//!           [layer L-1: block_tokens rows]
//! position p of a session lives in  block_table[p / block_tokens]
//! at row offset                     (p % block_tokens) * row
//! ```
//!
//! The gather path ([`PagedRows`] + `kernels::attend_paged_into`) walks
//! positions in the same order and with the same per-row arithmetic as
//! the contiguous kernels, so paged attention is **bit-identical** to
//! the contiguous path — asserted in `rust/tests/backend_equivalence.rs`
//! and the kernel unit tests.

use std::fmt;

/// Default tokens per block. 64 keeps the block table tiny while
/// bounding per-request overallocation to < 64 tokens of KV rows.
pub const DEFAULT_BLOCK_TOKENS: usize = 64;

/// Arena accounting reported by [`Backend::memory`] and surfaced on the
/// serving stats line (`kv_blocks_total`, `kv_blocks_free`,
/// `kv_reuse_hits`). Byte figures count K **and** V storage.
///
/// [`Backend::memory`]: super::backend::Backend::memory
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// pool capacity in bytes (`blocks_total * block bytes`)
    pub total_bytes: u64,
    /// bytes not held by any live handle
    pub free_bytes: u64,
    /// bytes held by live handles (`total_bytes - free_bytes`)
    pub reserved_bytes: u64,
    /// tokens per block — what converts a token budget into blocks
    pub block_tokens: u64,
    pub blocks_total: u64,
    pub blocks_free: u64,
    /// blocks handed out from the free list (recycled without zeroing)
    pub reuse_hits: u64,
    /// high-water mark of `reserved_bytes` over the arena's lifetime —
    /// the true peak KV residency, including blocks that were released
    /// again before any caller could sample `reserved_bytes`
    pub peak_reserved_bytes: u64,
}

/// The stable marker every rendering of [`KvExhausted`] starts with —
/// what the scheduler matches when the error crossed the bridge as a
/// `Frame::Error` string and the typed downcast is unavailable. One
/// constant shared by the `Display` impl and the matcher, so the two
/// cannot drift apart (a reworded message would otherwise silently turn
/// bridged preemptions into whole-round failures).
pub const KV_EXHAUSTED_MARKER: &str = "kv arena exhausted";

/// Typed "the pool is out of blocks" error. The scheduler downcasts it
/// (or matches [`KV_EXHAUSTED_MARKER`] when it crossed the bridge as a
/// string) to drive the preemption path instead of failing the whole
/// round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvExhausted {
    pub needed_blocks: usize,
    pub blocks_free: usize,
}

impl fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{KV_EXHAUSTED_MARKER}: need {} block(s), {} free",
            self.needed_blocks, self.blocks_free
        )
    }
}

impl std::error::Error for KvExhausted {}

/// A session's share of the arena: the ordered block table. Positions
/// `[0, blocks.len() * block_tokens)` are addressable; `Session::pos`
/// tracks how many are live. Deliberately not `Clone` — two handles
/// naming the same blocks would alias KV state and double-free on
/// release.
#[derive(Debug, Default)]
pub struct KvHandle {
    blocks: Vec<u32>,
}

impl KvHandle {
    /// The block table (ids into the owning arena), in position order.
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// True for sessions that hold no arena storage (stateless/remote
    /// backends, or already released).
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Token positions this handle can address.
    pub fn capacity_tokens(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens
    }
}

/// Read-only view of one layer's K (or V) rows through a block table —
/// the gather side of the paged path, consumed by
/// `kernels::attend_paged_into`. Constructed by [`KvArena::k_rows`] /
/// [`KvArena::v_rows`] (or [`PagedRows::new`] for custom storage).
pub struct PagedRows<'a> {
    data: &'a [f32],
    blocks: &'a [u32],
    block_tokens: usize,
    block_stride: usize,
    layer_off: usize,
    row: usize,
}

impl<'a> PagedRows<'a> {
    pub fn new(
        data: &'a [f32],
        blocks: &'a [u32],
        block_tokens: usize,
        block_stride: usize,
        layer_off: usize,
        row: usize,
    ) -> Self {
        PagedRows { data, blocks, block_tokens, block_stride, layer_off, row }
    }

    /// The `row`-float K/V row of position `pos`. One block-table
    /// lookup plus an offset — the paged analogue of `&cache[pos*d..]`.
    #[inline(always)]
    pub fn row(&self, pos: usize) -> &'a [f32] {
        let off = row_offset(
            self.blocks,
            self.block_tokens,
            self.block_stride,
            self.layer_off,
            self.row,
            pos,
        );
        &self.data[off..off + self.row]
    }
}

/// The one block/layer/row addressing formula, shared by the gather
/// view and the arena's mutable accessors so the two can never diverge.
#[inline(always)]
fn row_offset(
    blocks: &[u32],
    block_tokens: usize,
    block_stride: usize,
    layer_off: usize,
    row: usize,
    pos: usize,
) -> usize {
    let b = blocks[pos / block_tokens] as usize;
    b * block_stride + layer_off + (pos % block_tokens) * row
}

/// The pool. Owns all K/V storage of one backend as `max_blocks`
/// fixed-size blocks; storage is materialized lazily (first use of a
/// fresh block grows the backing `Vec` by one `block_stride`), so a
/// generous cap costs nothing until blocks are actually touched.
pub struct KvArena {
    block_tokens: usize,
    max_blocks: usize,
    /// f32 elements per block (per K and per V): `layers * block_tokens * row`
    block_stride: usize,
    row: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// released blocks, handed out again without re-zeroing
    free: Vec<u32>,
    /// blocks whose storage exists (`k.len() == materialized * stride`)
    materialized: usize,
    /// blocks currently held by live handles
    in_use: usize,
    /// high-water mark of `in_use`
    peak_in_use: usize,
    reuse_hits: u64,
}

impl KvArena {
    /// `row` is the per-token, per-layer KV row width in f32 elements
    /// (`kv_heads * head_dim`).
    pub fn new(n_layers: usize, row: usize, block_tokens: usize, max_blocks: usize) -> Self {
        let block_tokens = block_tokens.max(1);
        KvArena {
            block_tokens,
            max_blocks,
            block_stride: n_layers * block_tokens * row,
            row,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            materialized: 0,
            in_use: 0,
            peak_in_use: 0,
            reuse_hits: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn blocks_total(&self) -> usize {
        self.max_blocks
    }

    pub fn blocks_free(&self) -> usize {
        self.max_blocks - self.in_use
    }

    /// Blocks needed to address `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.block_tokens)
    }

    fn take_block(&mut self) -> u32 {
        if let Some(b) = self.free.pop() {
            // recycled as-is: every reachable position is written before
            // it is read, so stale bytes are unobservable
            self.reuse_hits += 1;
            return b;
        }
        let b = self.materialized as u32;
        self.materialized += 1;
        self.k.resize(self.materialized * self.block_stride, 0.0);
        self.v.resize(self.materialized * self.block_stride, 0.0);
        b
    }

    /// Allocate a handle covering `tokens` positions, or fail whole —
    /// a partial reservation is never handed out.
    pub fn reserve(&mut self, tokens: usize) -> Result<KvHandle, KvExhausted> {
        let need = self.blocks_for(tokens);
        if need > self.blocks_free() {
            return Err(KvExhausted { needed_blocks: need, blocks_free: self.blocks_free() });
        }
        let mut h = KvHandle::default();
        for _ in 0..need {
            let b = self.take_block();
            self.in_use += 1;
            h.blocks.push(b);
        }
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Ok(h)
    }

    /// Grow `h` until it addresses `tokens` positions (lazy decode-time
    /// growth: one extra block per `block_tokens` generated tokens).
    pub fn ensure(&mut self, h: &mut KvHandle, tokens: usize) -> Result<(), KvExhausted> {
        let need_total = self.blocks_for(tokens);
        while h.blocks.len() < need_total {
            if self.blocks_free() == 0 {
                return Err(KvExhausted {
                    needed_blocks: need_total - h.blocks.len(),
                    blocks_free: 0,
                });
            }
            let b = self.take_block();
            self.in_use += 1;
            h.blocks.push(b);
        }
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Ok(())
    }

    /// Return every block of `h` to the free list. Draining the handle
    /// makes a second release (or a release after `end_session` already
    /// ran) a structural no-op — no double-free is representable.
    pub fn release(&mut self, h: &mut KvHandle) {
        self.in_use -= h.blocks.len();
        self.free.append(&mut h.blocks);
    }

    fn offset(&self, h: &KvHandle, layer: usize, pos: usize) -> usize {
        row_offset(
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
            pos,
        )
    }

    /// Mutable K row of `pos` — the scatter side of the paged path.
    pub fn k_row_mut(&mut self, h: &KvHandle, layer: usize, pos: usize) -> &mut [f32] {
        let o = self.offset(h, layer, pos);
        &mut self.k[o..o + self.row]
    }

    /// Mutable V row of `pos`.
    pub fn v_row_mut(&mut self, h: &KvHandle, layer: usize, pos: usize) -> &mut [f32] {
        let o = self.offset(h, layer, pos);
        &mut self.v[o..o + self.row]
    }

    /// Gather view over `h`'s K rows of one layer.
    pub fn k_rows<'a>(&'a self, h: &'a KvHandle, layer: usize) -> PagedRows<'a> {
        PagedRows::new(
            &self.k,
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
        )
    }

    /// Gather view over `h`'s V rows of one layer.
    pub fn v_rows<'a>(&'a self, h: &'a KvHandle, layer: usize) -> PagedRows<'a> {
        PagedRows::new(
            &self.v,
            &h.blocks,
            self.block_tokens,
            self.block_stride,
            layer * self.block_tokens * self.row,
            self.row,
        )
    }

    pub fn stats(&self) -> MemoryStats {
        let block_bytes = (self.block_stride * 2 * std::mem::size_of::<f32>()) as u64;
        MemoryStats {
            total_bytes: self.max_blocks as u64 * block_bytes,
            free_bytes: self.blocks_free() as u64 * block_bytes,
            reserved_bytes: self.in_use as u64 * block_bytes,
            block_tokens: self.block_tokens as u64,
            blocks_total: self.max_blocks as u64,
            blocks_free: self.blocks_free() as u64,
            reuse_hits: self.reuse_hits,
            peak_reserved_bytes: self.peak_in_use as u64 * block_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KvArena {
        // 2 layers, 4-float rows, 8-token blocks, 4-block pool
        KvArena::new(2, 4, 8, 4)
    }

    #[test]
    fn reserve_rounds_up_to_blocks() {
        let mut a = tiny();
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(8), 1);
        assert_eq!(a.blocks_for(9), 2);
        let h = a.reserve(9).unwrap();
        assert_eq!(h.blocks().len(), 2);
        assert_eq!(h.capacity_tokens(a.block_tokens()), 16);
        assert_eq!(a.blocks_free(), 2);
    }

    #[test]
    fn reserve_fails_whole_when_short() {
        let mut a = tiny();
        let _h = a.reserve(32).unwrap(); // all 4 blocks
        let err = a.reserve(1).unwrap_err();
        assert_eq!(err.blocks_free, 0);
        assert_eq!(a.blocks_free(), 0, "failed reserve must not hold blocks");
        assert!(format!("{err}").contains("kv arena exhausted"));
    }

    #[test]
    fn ensure_grows_one_block_at_a_time() {
        let mut a = tiny();
        let mut h = a.reserve(3).unwrap();
        assert_eq!(h.blocks().len(), 1);
        a.ensure(&mut h, 8).unwrap();
        assert_eq!(h.blocks().len(), 1, "still inside the first block");
        a.ensure(&mut h, 9).unwrap();
        assert_eq!(h.blocks().len(), 2);
        a.ensure(&mut h, 32).unwrap();
        assert_eq!(h.blocks().len(), 4);
        assert!(a.ensure(&mut h, 33).is_err(), "pool holds only 4 blocks");
    }

    #[test]
    fn release_recycles_without_rezeroing_and_counts_reuse() {
        let mut a = tiny();
        let mut h = a.reserve(8).unwrap();
        a.k_row_mut(&h, 0, 3).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let block = h.blocks()[0];
        a.release(&mut h);
        assert!(h.is_empty());
        assert_eq!(a.blocks_free(), 4);
        assert_eq!(a.stats().reuse_hits, 0);

        let h2 = a.reserve(8).unwrap();
        assert_eq!(h2.blocks()[0], block, "free list hands the block back");
        assert_eq!(a.stats().reuse_hits, 1);
        // recycled as-is: the stale row is still there (and would be
        // overwritten before any reader could reach it)
        assert_eq!(a.k_rows(&h2, 0).row(3), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn double_release_is_a_noop() {
        let mut a = tiny();
        let mut h = a.reserve(8).unwrap();
        a.release(&mut h);
        a.release(&mut h);
        assert_eq!(a.blocks_free(), 4);
        assert_eq!(a.stats().blocks_free, a.stats().blocks_total);
    }

    #[test]
    fn paged_rows_address_across_shuffled_blocks() {
        let mut a = tiny();
        // force a non-identity block table: reserve, release the first
        // handle, reserve again so the free list reverses the order
        let mut h0 = a.reserve(16).unwrap();
        let mut h1 = a.reserve(16).unwrap();
        a.release(&mut h0);
        a.release(&mut h1);
        let h = a.reserve(32).unwrap();
        // write a recognizable value at every position/layer, then read
        // it back through the gather view
        for layer in 0..2 {
            for pos in 0..32 {
                let val = (layer * 100 + pos) as f32;
                a.k_row_mut(&h, layer, pos).fill(val);
                a.v_row_mut(&h, layer, pos).fill(-val);
            }
        }
        for layer in 0..2 {
            let kr = a.k_rows(&h, layer);
            let vr = a.v_rows(&h, layer);
            for pos in 0..32 {
                let val = (layer * 100 + pos) as f32;
                assert!(kr.row(pos).iter().all(|&x| x == val), "k layer {layer} pos {pos}");
                assert!(vr.row(pos).iter().all(|&x| x == -val), "v layer {layer} pos {pos}");
            }
        }
    }

    #[test]
    fn stats_are_internally_consistent() {
        let mut a = tiny();
        let s0 = a.stats();
        assert_eq!(s0.total_bytes, s0.free_bytes);
        assert_eq!(s0.reserved_bytes, 0);
        assert_eq!(s0.block_tokens, 8);
        let mut h = a.reserve(20).unwrap(); // 3 blocks
        let s1 = a.stats();
        assert_eq!(s1.blocks_total, 4);
        assert_eq!(s1.blocks_free, 1);
        assert_eq!(s1.free_bytes + s1.reserved_bytes, s1.total_bytes);
        // one block = 2 layers * 8 tokens * 4 floats * 4 bytes * (K+V)
        assert_eq!(s1.total_bytes, 4 * (2 * 8 * 4 * 4 * 2) as u64);
        // the watermark survives a release that a later sample would miss
        assert_eq!(s1.peak_reserved_bytes, s1.reserved_bytes);
        a.release(&mut h);
        let s2 = a.stats();
        assert_eq!(s2.reserved_bytes, 0);
        assert_eq!(s2.peak_reserved_bytes, s1.reserved_bytes, "peak must not reset");
    }

    #[test]
    fn storage_materializes_lazily() {
        let mut a = KvArena::new(1, 4, 8, 1024);
        assert_eq!(a.k.len(), 0, "no storage before first use");
        let mut h = a.reserve(8).unwrap();
        assert_eq!(a.k.len(), a.block_stride, "one block materialized");
        a.release(&mut h);
        let _h2 = a.reserve(8).unwrap();
        assert_eq!(a.k.len(), a.block_stride, "recycling allocates nothing");
    }
}
