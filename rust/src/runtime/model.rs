//! LLM artifact runtime: manifest + weights + compiled HLO executables.
//!
//! Weights are uploaded to the PJRT device **once** at load time
//! (`execute_b` with persistent `PjRtBuffer`s); the per-step inputs
//! (token id, position, KV cache) are tiny. Python never runs here.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use super::weights::{self, DType, Tensor};
use crate::util::json::Json;

/// Model architecture constants mirrored from the python ModelConfig.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ffn: usize,
    pub max_tokens: usize,
    pub head_dim: usize,
    pub n_params: usize,
    pub cache_shape: [usize; 4], // [L, max_tokens, kvh, head_dim]
}

/// A loaded, compiled, weight-resident model ready to serve.
pub struct LlmRuntime {
    pub info: ModelInfo,
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    /// (bucket_len, executable) sorted ascending by bucket.
    prefill_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

/// Mutable per-request state: the KV cache (host copy) and position.
pub struct Session {
    pub pos: usize,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    cache_dims: Vec<usize>,
}

fn parse_manifest(dir: &Path, name: &str) -> Result<(Json, ModelInfo)> {
    let mpath = dir.join(format!("{name}.manifest.json"));
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("read manifest {}", mpath.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest json: {e}"))?;
    let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
    let get = |k: &str| -> Result<usize> {
        cfg.get(k)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest config missing {k}"))
    };
    let cache: Vec<usize> = j
        .get("cache_shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("manifest missing cache_shape"))?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect();
    let info = ModelInfo {
        name: name.to_string(),
        vocab: get("vocab")?,
        d_model: get("d_model")?,
        n_layers: get("n_layers")?,
        n_heads: get("n_heads")?,
        n_kv_heads: get("n_kv_heads")?,
        d_ffn: get("d_ffn")?,
        max_tokens: get("max_tokens")?,
        head_dim: get("head_dim")?,
        n_params: get("n_params")?,
        cache_shape: [cache[0], cache[1], cache[2], cache[3]],
    };
    Ok((j, info))
}

impl LlmRuntime {
    /// Load `<dir>/<name>.*` artifacts, compile, and upload weights.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = dir.as_ref();
        let (manifest, info) = parse_manifest(dir, name)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let p: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&p)
                .map_err(|e| anyhow!("parse hlo {}: {e:?}", p.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", p.display()))
        };

        let decode_file = manifest
            .get("decode")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing decode"))?;
        let decode_exe = compile(decode_file)?;

        let mut prefill_exes = Vec::new();
        if let Some(Json::Obj(m)) = manifest.get("prefill") {
            for (bucket, file) in m {
                let t: usize = bucket.parse().context("prefill bucket key")?;
                let f = file
                    .as_str()
                    .ok_or_else(|| anyhow!("prefill file not a string"))?;
                prefill_exes.push((t, compile(f)?));
            }
        }
        prefill_exes.sort_by_key(|(t, _)| *t);
        if prefill_exes.is_empty() {
            bail!("manifest has no prefill buckets");
        }

        let wfile = manifest
            .get("weights")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing weights"))?;
        let tensors = weights::load(dir.join(wfile))?;
        let expected: Vec<String> = manifest
            .get("weight_names")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing weight_names"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        if expected.len() != tensors.len() {
            bail!(
                "weights container has {} tensors, manifest expects {}",
                tensors.len(),
                expected.len()
            );
        }
        let mut weight_bufs = Vec::with_capacity(tensors.len());
        for (t, name) in tensors.iter().zip(&expected) {
            if &t.name != name {
                bail!("weight order mismatch: {} vs {}", t.name, name);
            }
            weight_bufs.push(upload(&client, t)?);
        }
        Ok(LlmRuntime { info, client, decode_exe, prefill_exes, weight_bufs })
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_exes
            .iter()
            .map(|(t, _)| *t)
            .find(|t| *t >= len)
    }

    pub fn prefill_buckets(&self) -> Vec<usize> {
        self.prefill_exes.iter().map(|(t, _)| *t).collect()
    }

    /// Run prefill over `prompt` (padded to a bucket); returns the logits
    /// of the last real token plus a fresh session.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > self.info.max_tokens {
            bail!(
                "prompt of {} exceeds max_tokens {}",
                prompt.len(),
                self.info.max_tokens
            );
        }
        let (bucket, exe) = self
            .prefill_exes
            .iter()
            .find(|(t, _)| *t >= prompt.len())
            .ok_or_else(|| {
                anyhow!(
                    "prompt of {} exceeds largest prefill bucket {:?}",
                    prompt.len(),
                    self.prefill_exes.last().map(|(t, _)| *t)
                )
            })?;
        let mut padded = prompt.to_vec();
        padded.resize(*bucket, 0);
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&padded, &[*bucket], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_bufs.iter());
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill fetch: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("prefill detuple: {e:?}"))?;
        let [logits, kc, vc]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("prefill returned wrong arity"))?;
        let all_logits = logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let v = self.info.vocab;
        let last = prompt.len() - 1;
        let last_logits = all_logits[last * v..(last + 1) * v].to_vec();
        let session = Session {
            pos: prompt.len(),
            k_cache: kc.to_vec::<f32>().map_err(|e| anyhow!("kc to_vec: {e:?}"))?,
            v_cache: vc.to_vec::<f32>().map_err(|e| anyhow!("vc to_vec: {e:?}"))?,
            cache_dims: self.info.cache_shape.to_vec(),
        };
        Ok((last_logits, session))
    }

    /// One decode step: feed `token`, advance the session, return logits.
    pub fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        if session.pos >= self.info.max_tokens {
            bail!("KV cache full (max_tokens={})", self.info.max_tokens);
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[token], &[1], None)
            .map_err(|e| anyhow!("upload token: {e:?}"))?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[session.pos as i32], &[1], None)
            .map_err(|e| anyhow!("upload pos: {e:?}"))?;
        let kc_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&session.k_cache, &session.cache_dims, None)
            .map_err(|e| anyhow!("upload k cache: {e:?}"))?;
        let vc_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&session.v_cache, &session.cache_dims, None)
            .map_err(|e| anyhow!("upload v cache: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &kc_buf, &vc_buf];
        args.extend(self.weight_bufs.iter());
        let outs = self
            .decode_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode fetch: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decode detuple: {e:?}"))?;
        let [logits, kc, vc]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("decode returned wrong arity"))?;
        session.k_cache = kc.to_vec::<f32>().map_err(|e| anyhow!("kc to_vec: {e:?}"))?;
        session.v_cache = vc.to_vec::<f32>().map_err(|e| anyhow!("vc to_vec: {e:?}"))?;
        session.pos += 1;
        logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }
}

// NOTE: `buffer_from_host_raw_bytes` in xla 0.1.6 is buggy — it passes the
// `ElementType` discriminant (F32=10) where XLA expects a `PrimitiveType`
// (F32=11), silently creating F16 buffers. Always go through the typed
// `buffer_from_host_buffer`, which maps the type correctly.

fn upload(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    match t.dtype {
        DType::F32 => upload_f32_bytes(client, &t.data, &t.dims),
        DType::I32 => {
            let v: Vec<i32> = t
                .data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            client
                .buffer_from_host_buffer::<i32>(&v, &t.dims, None)
                .map_err(|e| anyhow!("upload tensor {}: {e:?}", t.name))
        }
        DType::I8 => {
            // &[u8] -> &[i8] is a bit-identical reinterpretation
            let v: &[i8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const i8, t.data.len())
            };
            client
                .buffer_from_host_buffer::<i8>(v, &t.dims, None)
                .map_err(|e| anyhow!("upload tensor {}: {e:?}", t.name))
        }
    }
    .map_err(|e| anyhow!("tensor {}: {e}", t.name))
}

fn upload_f32_bytes(
    client: &xla::PjRtClient,
    data: &[u8],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    let v: Vec<f32> = data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    client
        .buffer_from_host_buffer::<f32>(&v, dims, None)
        .map_err(|e| anyhow!("upload f32 buffer: {e:?}"))
}

/// Greedy argmax sampling.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}
