//! `LlmRuntime`: a thin, validating wrapper around one `Box<dyn Backend>`.
//!
//! Backend selection happens **only** in the constructors —
//!
//! * [`LlmRuntime::reference`] — the pure-Rust batched quantized engine
//!   ([`super::reference`]), always built; tests/CI/examples use this.
//! * [`LlmRuntime::simulator`] — the VCU128 latency model served as a
//!   functional backend ([`super::backend::SimBackend`]).
//! * [`LlmRuntime::load`] — AOT HLO artifacts through PJRT (feature
//!   `pjrt`): manifest + weights + compiled executables, weights
//!   uploaded to the device once at load time.
//! * [`LlmRuntime::from_backend`] — any other [`Backend`] impl (mocks,
//!   future FPGA bridge, sharded backends).
//!
//! — after construction the scheduler path is `cfg`-free: every call
//! dispatches through the object-safe [`Backend`] trait, and the
//! wrapper owns the generic entry-point validation (prompt bounds,
//! batch arity, KV budget) so every backend inherits it.

use anyhow::{anyhow, bail, Result};
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use super::weights::{self, DType, Tensor};
use super::backend::{Backend, TransferMeter};
use super::kv::{KvHandle, MemoryStats};
use super::reference::{RefLlm, ReferenceConfig};
use crate::models::{LlmArch, SparseStrategy};
use crate::sim::Memory;
use crate::util::json::Json;

/// Model architecture constants mirrored from the python ModelConfig.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ffn: usize,
    pub max_tokens: usize,
    pub head_dim: usize,
    pub n_params: usize,
    pub cache_shape: [usize; 4], // [L, max_tokens, kvh, head_dim]
}

/// A loaded, weight-resident model ready to serve: `ModelInfo` + bucket
/// table cached on the wrapper (so the scheduler reads slices, not
/// virtual calls) over the trait object that executes.
pub struct LlmRuntime {
    pub info: ModelInfo,
    /// prefill bucket lengths, ascending — cached here so the scheduler
    /// reads a slice instead of cloning a Vec every admission
    buckets: Vec<usize>,
    backend: Box<dyn Backend>,
}

/// Mutable per-request state: position plus a handle to whatever KV
/// storage the owning backend keeps for it.
///
/// One `Session` per live request; the continuous-batching scheduler
/// keeps up to `max_active` of these in flight at once. Since the paged
/// refactor a session no longer *owns* cache tensors: the reference
/// backend's KV lives in its shared [`KvArena`](super::kv::KvArena) and
/// the session carries only the block table ([`KvHandle`]). Backends
/// that keep no host KV at all (latency models, mocks, the bridge)
/// mint sessions with `Session::new([0, 0, 0, 0])` and only advance
/// `pos`. Deliberately not `Clone`: two sessions naming the same arena
/// blocks would alias KV state and double-free on release — reset a
/// workload with a fresh `prefill` instead (the benches do).
///
/// A session that leaves the scheduler is handed back to its backend
/// via [`Backend::end_session`] so arena blocks (or device-side state)
/// are recycled, not leaked until process exit.
pub struct Session {
    pub pos: usize,
    /// Backend-private correlation tag, carried opaquely by the
    /// scheduler. Remote backends store their device-side session id
    /// here (the bridge reserves 0 for "no remote session"); in-process
    /// backends leave it at 0.
    pub tag: u64,
    /// Block table into the owning backend's paged KV arena; empty for
    /// stateless and remote backends.
    pub(crate) kv: KvHandle,
    // Legacy contiguous host KV copy — only the PJRT artifact path uses
    // these (it re-uploads the whole cache every step), so they exist
    // only under that feature. The default build's Session is a block
    // table plus two integers: paged arena storage replaced the
    // contiguous copy everywhere else, and carrying always-empty Vecs
    // behind allow(dead_code) hid that from both the reader and the
    // dead-code lint.
    #[cfg(feature = "pjrt")]
    pub(crate) k_cache: Vec<f32>,
    #[cfg(feature = "pjrt")]
    pub(crate) v_cache: Vec<f32>,
    /// only the PJRT backend re-uploads the cache and needs its dims
    #[cfg(feature = "pjrt")]
    pub(crate) cache_dims: Vec<usize>,
}

impl Session {
    /// Fresh zeroed session for a model whose per-layer KV cache has the
    /// given shape `[layers, max_tokens, kv_heads, head_dim]`. Public so
    /// out-of-crate [`Backend`] implementations can mint sessions; a
    /// stateless backend passes `[0, 0, 0, 0]`. (Backends that page
    /// their KV through a [`KvArena`](super::kv::KvArena) use
    /// [`Session::with_kv`] instead — this constructor allocates the
    /// legacy contiguous host copy.)
    pub fn new(cache_shape: [usize; 4]) -> Self {
        #[cfg(not(feature = "pjrt"))]
        let _ = cache_shape; // shape only materializes host tensors for PJRT
        Session {
            pos: 0,
            tag: 0,
            kv: KvHandle::default(),
            #[cfg(feature = "pjrt")]
            k_cache: vec![0.0; cache_shape.iter().product()],
            #[cfg(feature = "pjrt")]
            v_cache: vec![0.0; cache_shape.iter().product()],
            #[cfg(feature = "pjrt")]
            cache_dims: cache_shape.to_vec(),
        }
    }

    /// Session whose KV state is the given arena block table (no host
    /// tensors). The backend that reserved the handle owns the arena
    /// and must release the handle in its `end_session`.
    pub fn with_kv(kv: KvHandle) -> Self {
        Session {
            pos: 0,
            tag: 0,
            kv,
            #[cfg(feature = "pjrt")]
            k_cache: Vec::new(),
            #[cfg(feature = "pjrt")]
            v_cache: Vec::new(),
            #[cfg(feature = "pjrt")]
            cache_dims: Vec::new(),
        }
    }
}

fn parse_manifest(dir: &Path, name: &str) -> Result<(Json, ModelInfo)> {
    let mpath = dir.join(format!("{name}.manifest.json"));
    let text = std::fs::read_to_string(&mpath)
        .map_err(|e| anyhow!("read manifest {}: {e}", mpath.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest json: {e}"))?;
    let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
    let get = |k: &str| -> Result<usize> {
        cfg.get(k)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest config missing {k}"))
    };
    let cache: Vec<usize> = j
        .get("cache_shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("manifest missing cache_shape"))?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect();
    if cache.len() != 4 {
        bail!("manifest cache_shape must have 4 dims, got {}", cache.len());
    }
    let info = ModelInfo {
        name: name.to_string(),
        vocab: get("vocab")?,
        d_model: get("d_model")?,
        n_layers: get("n_layers")?,
        n_heads: get("n_heads")?,
        n_kv_heads: get("n_kv_heads")?,
        d_ffn: get("d_ffn")?,
        max_tokens: get("max_tokens")?,
        head_dim: get("head_dim")?,
        n_params: get("n_params")?,
        cache_shape: [cache[0], cache[1], cache[2], cache[3]],
    };
    Ok((j, info))
}

impl LlmRuntime {
    /// Wrap any backend. The single construction path every other
    /// constructor funnels through — and the extension point for
    /// backends defined outside this crate (mocks, bridges).
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        let info = backend.info().clone();
        let buckets = backend.prefill_buckets().to_vec();
        LlmRuntime { info, buckets, backend }
    }

    /// Build the pure-Rust reference model (no artifacts required).
    pub fn reference(cfg: ReferenceConfig) -> Self {
        Self::from_backend(Box::new(RefLlm::new(cfg)))
    }

    /// Reference model with default (tiny) dimensions.
    pub fn reference_tiny() -> Self {
        Self::reference(ReferenceConfig::default())
    }

    /// Serve from the VCU128 latency model: deterministic pseudo-tokens,
    /// no functional compute, any architecture size. See
    /// [`super::backend::SimBackend`].
    pub fn simulator(
        arch: &LlmArch,
        strat: &SparseStrategy,
        mem: Memory,
        max_tokens: usize,
        seed: u64,
    ) -> Self {
        Self::from_backend(Box::new(super::backend::SimBackend::new(
            arch, strat, mem, max_tokens, seed,
        )))
    }

    /// Try the AOT artifacts at `<dir>/<name>.*`; fall back to the
    /// reference model (`ref_cfg`) when they are absent or this build
    /// has no PJRT backend. The single backend-selection policy used by
    /// the CLI and the examples.
    pub fn load_or_reference(
        dir: impl AsRef<Path>,
        name: &str,
        ref_cfg: ReferenceConfig,
    ) -> Self {
        match Self::load(dir, name) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("artifacts unavailable ({e:#}); using the reference backend");
                Self::reference(ref_cfg)
            }
        }
    }

    /// Load `<dir>/<name>.*` artifacts, compile, and upload weights.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        Ok(Self::from_backend(Box::new(PjrtBackend::load(
            dir.as_ref(),
            name,
        )?)))
    }

    /// Without the `pjrt` feature, artifacts cannot be executed; the
    /// manifest is still validated so errors stay informative.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = dir.as_ref();
        let (_manifest, info) = parse_manifest(dir, name)?;
        bail!(
            "artifacts for '{}' found but this build has no PJRT backend \
             (rebuild with --features pjrt, or use LlmRuntime::reference())",
            info.name
        )
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|t| *t >= len)
    }

    /// Prefill bucket lengths, ascending (no allocation).
    pub fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Capability flag: does the backend execute `decode_batch` as a
    /// genuinely shared round (weights streamed once per round)?
    pub fn supports_batched_decode(&self) -> bool {
        self.backend.supports_batched_decode()
    }

    /// Resident quantized-FFN weight bytes, when the backend exposes
    /// them — the stream the batched decode round amortizes.
    pub fn ffn_weight_bytes(&self) -> Option<usize> {
        self.backend.ffn_weight_bytes()
    }

    /// Resolved kernel execution tier (`"scalar"`, `"simd"`,
    /// `"simd-parallel(N)"`), when the backend runs the tiered CPU
    /// kernels — provenance for `info`, the stats line, and benches.
    pub fn kernel_tier(&self) -> Option<String> {
        self.backend.kernel_tier()
    }

    /// Notify the backend that `session` is leaving the scheduler
    /// (retired, cancelled, or aborted). No-op for in-process backends;
    /// remote backends release device-side state. Best-effort — never
    /// fails the caller.
    pub fn end_session(&self, session: &mut Session) {
        self.backend.end_session(session);
    }

    /// True when backend calls cross a transport to a device daemon.
    pub fn is_remote(&self) -> bool {
        self.backend.is_remote()
    }

    /// KV-arena accounting, when the backend pages its session memory
    /// (`None` for stateless backends and mocks). The scheduler's
    /// memory-aware admission gate and the serving stats line
    /// (`kv_blocks_total/free`, `kv_reuse_hits`) read this; for the
    /// bridge it is one metered round trip to the device.
    pub fn memory(&self) -> Option<MemoryStats> {
        self.backend.memory()
    }

    /// Cumulative host↔device transport counters (remote backends).
    pub fn transfer_meter(&self) -> Option<TransferMeter> {
        self.backend.transfer_meter()
    }

    /// Hand the backend the serving side's observability registry (the
    /// bridge client records frame RTTs and reconnect spans into it).
    /// No-op for backends that don't instrument themselves.
    pub fn attach_obs(&self, obs: &std::sync::Arc<crate::obs::Obs>) {
        self.backend.attach_obs(obs);
    }

    /// KV-arena pressure counters (allocation stalls, CoW copies) for
    /// the stats line; `None` for backends without a paged arena.
    pub fn kv_pressure(&self) -> Option<crate::obs::KvPressure> {
        self.backend.kv_pressure()
    }

    /// The remote device's observability summary (one wire round trip
    /// for the bridge; `None` for in-process backends).
    pub fn device_obs(&self) -> Option<crate::obs::ObsStats> {
        self.backend.device_obs()
    }

    /// Run prefill over `prompt` (padded to a bucket); returns the logits
    /// of the last real token plus a fresh session.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > self.info.max_tokens {
            bail!(
                "prompt of {} exceeds max_tokens {}",
                prompt.len(),
                self.info.max_tokens
            );
        }
        self.backend.prefill(prompt)
    }

    /// Length of the longest prefix of `prompt` the backend already
    /// holds KV state for (0 for backends without a prefix cache). The
    /// scheduler's admission gate uses this to account shared blocks
    /// once instead of per-session; advisory by contract — see
    /// [`Backend::shared_prefix_len`].
    pub fn shared_prefix_len(&self, prompt: &[i32]) -> usize {
        self.backend.shared_prefix_len(prompt)
    }

    /// Prefill with an advisory shared-prefix hint (see
    /// [`Backend::prefill_from`]): a prefix-caching backend adopts the
    /// resident blocks and computes only the suffix, bit-identically to
    /// a full [`LlmRuntime::prefill`]. Same validation as `prefill`.
    pub fn prefill_from(
        &self,
        prompt: &[i32],
        shared_len: usize,
    ) -> Result<(Vec<f32>, Session)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > self.info.max_tokens {
            bail!(
                "prompt of {} exceeds max_tokens {}",
                prompt.len(),
                self.info.max_tokens
            );
        }
        self.backend.prefill_from(prompt, shared_len)
    }

    /// One decode step: feed `token`, advance the session, return logits.
    pub fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        if session.pos >= self.info.max_tokens {
            bail!("KV cache full (max_tokens={})", self.info.max_tokens);
        }
        self.backend.decode(session, token)
    }

    /// One batched decode round: feed `tokens[i]` to `sessions[i]` for
    /// every live session and return each session's next-token logits.
    ///
    /// This is the scheduler's single entry point per round. The KV
    /// budget is validated for the *whole* batch up front, so a full
    /// cache never aborts a round mid-batch regardless of whether the
    /// backend executes a shared round or steps session by session.
    pub fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        if sessions.len() != tokens.len() {
            bail!(
                "decode_batch: {} sessions vs {} tokens",
                sessions.len(),
                tokens.len()
            );
        }
        for s in sessions.iter() {
            if s.pos >= self.info.max_tokens {
                bail!("KV cache full (max_tokens={})", self.info.max_tokens);
            }
        }
        self.backend.decode_batch(sessions, tokens)
    }
}

/// The PJRT/XLA artifact backend: compiled batch-1 HLO executables with
/// device-resident weights. `decode_batch` keeps the trait's default
/// stepping implementation (the artifacts are compiled at batch 1).
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    info: ModelInfo,
    buckets: Vec<usize>,
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    /// (bucket_len, executable) sorted ascending by bucket.
    prefill_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    fn load(dir: &Path, name: &str) -> Result<Self> {
        let (manifest, info) = parse_manifest(dir, name)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let p: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&p)
                .map_err(|e| anyhow!("parse hlo {}: {e:?}", p.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", p.display()))
        };

        let decode_file = manifest
            .get("decode")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing decode"))?;
        let decode_exe = compile(decode_file)?;

        let mut prefill_exes = Vec::new();
        if let Some(Json::Obj(m)) = manifest.get("prefill") {
            for (bucket, file) in m {
                let t: usize = bucket.parse().context("prefill bucket key")?;
                let f = file
                    .as_str()
                    .ok_or_else(|| anyhow!("prefill file not a string"))?;
                prefill_exes.push((t, compile(f)?));
            }
        }
        prefill_exes.sort_by_key(|(t, _)| *t);
        if prefill_exes.is_empty() {
            bail!("manifest has no prefill buckets");
        }

        let wfile = manifest
            .get("weights")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing weights"))?;
        let tensors = weights::load(dir.join(wfile))?;
        let expected: Vec<String> = manifest
            .get("weight_names")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing weight_names"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        if expected.len() != tensors.len() {
            bail!(
                "weights container has {} tensors, manifest expects {}",
                tensors.len(),
                expected.len()
            );
        }
        let mut weight_bufs = Vec::with_capacity(tensors.len());
        for (t, name) in tensors.iter().zip(&expected) {
            if &t.name != name {
                bail!("weight order mismatch: {} vs {}", t.name, name);
            }
            weight_bufs.push(upload(&client, t)?);
        }
        let buckets = prefill_exes.iter().map(|(t, _)| *t).collect();
        Ok(PjrtBackend {
            info,
            buckets,
            client,
            decode_exe,
            prefill_exes,
            weight_bufs,
        })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let (bucket, exe) = self
            .prefill_exes
            .iter()
            .find(|(t, _)| *t >= prompt.len())
            .ok_or_else(|| {
                anyhow!(
                    "prompt of {} exceeds largest prefill bucket {:?}",
                    prompt.len(),
                    self.prefill_exes.last().map(|(t, _)| *t)
                )
            })?;
        let mut padded = prompt.to_vec();
        padded.resize(*bucket, 0);
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&padded, &[*bucket], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_bufs.iter());
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill fetch: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("prefill detuple: {e:?}"))?;
        let [logits, kc, vc]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("prefill returned wrong arity"))?;
        let all_logits = logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let v = self.info.vocab;
        let last = prompt.len() - 1;
        let last_logits = all_logits[last * v..(last + 1) * v].to_vec();
        let session = Session {
            pos: prompt.len(),
            tag: 0,
            kv: KvHandle::default(),
            k_cache: kc.to_vec::<f32>().map_err(|e| anyhow!("kc to_vec: {e:?}"))?,
            v_cache: vc.to_vec::<f32>().map_err(|e| anyhow!("vc to_vec: {e:?}"))?,
            cache_dims: self.info.cache_shape.to_vec(),
        };
        Ok((last_logits, session))
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[token], &[1], None)
            .map_err(|e| anyhow!("upload token: {e:?}"))?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[session.pos as i32], &[1], None)
            .map_err(|e| anyhow!("upload pos: {e:?}"))?;
        let kc_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&session.k_cache, &session.cache_dims, None)
            .map_err(|e| anyhow!("upload k cache: {e:?}"))?;
        let vc_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&session.v_cache, &session.cache_dims, None)
            .map_err(|e| anyhow!("upload v cache: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &kc_buf, &vc_buf];
        args.extend(self.weight_bufs.iter());
        let outs = self
            .decode_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode fetch: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decode detuple: {e:?}"))?;
        let [logits, kc, vc]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("decode returned wrong arity"))?;
        session.k_cache = kc.to_vec::<f32>().map_err(|e| anyhow!("kc to_vec: {e:?}"))?;
        session.v_cache = vc.to_vec::<f32>().map_err(|e| anyhow!("vc to_vec: {e:?}"))?;
        session.pos += 1;
        logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }
}

// NOTE: `buffer_from_host_raw_bytes` in xla 0.1.6 is buggy — it passes the
// `ElementType` discriminant (F32=10) where XLA expects a `PrimitiveType`
// (F32=11), silently creating F16 buffers. Always go through the typed
// `buffer_from_host_buffer`, which maps the type correctly.

#[cfg(feature = "pjrt")]
fn upload(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    match t.dtype {
        DType::F32 => upload_f32_bytes(client, &t.data, &t.dims),
        DType::I32 => {
            let v: Vec<i32> = t
                .data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            client
                .buffer_from_host_buffer::<i32>(&v, &t.dims, None)
                .map_err(|e| anyhow!("upload tensor {}: {e:?}", t.name))
        }
        DType::I8 => {
            // &[u8] -> &[i8] is a bit-identical reinterpretation
            let v: &[i8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const i8, t.data.len())
            };
            client
                .buffer_from_host_buffer::<i8>(v, &t.dims, None)
                .map_err(|e| anyhow!("upload tensor {}: {e:?}", t.name))
        }
    }
    .map_err(|e| anyhow!("tensor {}: {e}", t.name))
}

#[cfg(feature = "pjrt")]
fn upload_f32_bytes(
    client: &xla::PjRtClient,
    data: &[u8],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    let v: Vec<f32> = data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    client
        .buffer_from_host_buffer::<f32>(&v, dims, None)
        .map_err(|e| anyhow!("upload f32 buffer: {e:?}"))
}

/// Greedy argmax sampling.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_without_artifacts_is_informative() {
        let err = LlmRuntime::load("definitely-missing-dir", "nope").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[test]
    fn decode_batch_checks_arity() {
        let rt = LlmRuntime::reference_tiny();
        let (_l, mut s) = rt.prefill(&[1, 2, 3]).unwrap();
        let mut sessions = vec![&mut s];
        assert!(rt.decode_batch(&mut sessions, &[1, 2]).is_err());
    }

    #[test]
    fn decode_batch_matches_sequential_decode() {
        let rt = LlmRuntime::reference_tiny();
        let (_l, mut a) = rt.prefill(&[10, 20]).unwrap();
        let (_l, mut b) = rt.prefill(&[30]).unwrap();
        let (_l, mut a2) = rt.prefill(&[10, 20]).unwrap();
        let (_l, mut b2) = rt.prefill(&[30]).unwrap();

        let la = rt.decode(&mut a, 5).unwrap();
        let lb = rt.decode(&mut b, 6).unwrap();

        let mut sessions = vec![&mut a2, &mut b2];
        let batched = rt.decode_batch(&mut sessions, &[5, 6]).unwrap();
        assert_eq!(batched[0], la);
        assert_eq!(batched[1], lb);
        assert_eq!(a.pos, a2.pos);
    }

    #[test]
    fn wrapper_equals_direct_backend_construction() {
        let cfg = ReferenceConfig::default();
        let direct = LlmRuntime::from_backend(Box::new(RefLlm::new(cfg.clone())));
        let wrapped = LlmRuntime::reference(cfg);
        let (ld, _) = direct.prefill(&[7, 8, 9]).unwrap();
        let (lw, _) = wrapped.prefill(&[7, 8, 9]).unwrap();
        assert_eq!(ld, lw);
        assert!(direct.supports_batched_decode());
        assert!(direct.ffn_weight_bytes().unwrap() > 0);
        assert_eq!(direct.prefill_buckets(), wrapped.prefill_buckets());
    }

    #[test]
    fn session_new_has_requested_shape() {
        let s = Session::new([2, 8, 1, 4]);
        assert_eq!(s.pos, 0);
        assert_eq!(s.tag, 0);
        // the contiguous host cache exists only for the PJRT path
        #[cfg(feature = "pjrt")]
        {
            assert_eq!(s.k_cache.len(), 2 * 8 * 4);
            let empty = Session::new([0, 0, 0, 0]);
            assert!(empty.k_cache.is_empty());
        }
    }
}
