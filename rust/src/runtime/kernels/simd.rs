//! Explicitly vectorized kernels — the AVX2 execution tier under the
//! scalar oracle in [`kernels`](super).
//!
//! Every function here computes the *same floating-point operation
//! sequence per output element* as its scalar twin, so the results are
//! **bit-identical**, not merely close — the equivalence suite compares
//! `to_bits()`. Three rules make that possible:
//!
//! 1. **Multiply + add, never FMA.** `_mm256_fmadd_ps` rounds once
//!    where the scalar `*o += x * w` rounds twice; a fused tier could
//!    only promise a ULP bound. We deliberately use
//!    `_mm256_add_ps(_mm256_mul_ps(..))` — same speedup class (the
//!    axpy loops are load/store-bound), strictly stronger contract.
//!    Dispatch therefore keys on `avx2` alone and never requires `fma`.
//! 2. **Vectorize across independent accumulators only.** The axpy
//!    loops step 8 *output channels* at once; each channel's
//!    multiply/add sequence over input rows is unchanged at any vector
//!    width. The one true reduction ([`dot4`](super::dot4)) already
//!    fixes a 4-lane summation order, and the SSE version reproduces
//!    exactly those 4 lanes and the scalar combine.
//! 3. **Exact integer expansion.** Nibble unpack, sign extension, and
//!    `i32 → f32` conversion are exact in both scalar and vector form;
//!    softmax keeps the scalar libm `exp` (vector polynomial exp would
//!    change results).
//!
//! Runtime dispatch: every public kernel checks
//! `is_x86_feature_detected!("avx2")` (cached by std) and falls back to
//! an in-module scalar body with the identical operation order — on
//! non-x86-64 targets that fallback is the whole implementation. The
//! `*_cols_raw` variants compute a **column stripe** `[c0, c2)` of the
//! same output and exist for the worker pool (`runtime::pool`): paged
//! addressing and partitioning stay outside the vector bodies, so
//! paged == contiguous and striped == full-width identities hold by
//! construction.

use std::ops::Range;

use super::super::kv::PagedRows;
use super::super::pool::SendPtr;
use crate::pack::layout::{nibble_i8, PackedQ4};
use crate::quant::sparse::SparseMatrix;
use crate::quant::QBLOCK;

/// Whether the vector path is live on this machine (AVX2 detected at
/// runtime). When false every kernel in this module still works — it
/// runs the identical-order scalar body.
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// Whether the vector path is live on this machine (never, off x86-64).
#[cfg(not(target_arch = "x86_64"))]
#[inline]
pub fn available() -> bool {
    false
}

/// Worker-local scratch for the striped q4 kernel
/// ([`q4_gemm_cols_raw`]). Each stripe needs its own copy — the
/// parallel driver slices one contiguous scratch buffer per worker.
pub struct ColScratch<'a> {
    /// activation gather across the batch, `>= b`
    pub xcol: &'a mut [f32],
    /// one expanded nibble stripe, `>= cols.len()`
    pub qrow: &'a mut [f32],
    /// per-QBLOCK partial accumulators, `>= b * cols.len()`
    pub partial: &'a mut [f32],
}

// ---------------------------------------------------------------------
// dense GEMM
// ---------------------------------------------------------------------

/// Vector-tier [`gemm_into`](super::gemm_into): identical contract and
/// bit-identical output.
pub fn gemm_into(x: &[f32], b: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    assert!(x.len() >= b * k && w.len() >= k * n && out.len() >= b * n);
    // SAFETY: `out` covers the full `b × n` output and the stripe is
    // the whole width; no other view of `out` exists during the call.
    unsafe { gemm_cols_raw(x, b, k, w, n, 0..n, SendPtr::new(out.as_mut_ptr())) }
}

/// Vector-tier [`matvec_into`](super::matvec_into).
pub fn matvec_into(w: &[f32], x: &[f32], out: &mut [f32]) {
    let (k, n) = (x.len(), out.len());
    gemm_into(x, 1, k, w, n, out);
}

/// Column stripe `cols` of [`gemm_into`]: fills rows `s*n + cols` of
/// the output at `out` for every session `s`. The stripe owns those
/// elements exclusively, so disjoint stripes may run concurrently.
///
/// # Safety
///
/// `out` must point to a live `f32` buffer of at least `b * n`
/// elements that outlives the call, `cols` must lie within `0..=n`,
/// and no other thread may touch `out`'s elements `s*n + cols` for any
/// `s < b` while this runs. `x`/`w` must not overlap `out`.
pub unsafe fn gemm_cols_raw(
    x: &[f32],
    b: usize,
    k: usize,
    w: &[f32],
    n: usize,
    cols: Range<usize>,
    out: SendPtr,
) {
    debug_assert!(cols.end <= n && cols.start <= cols.end);
    debug_assert!(x.len() >= b * k && w.len() >= k * n);
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            gemm_cols_avx2(x, b, k, w, n, cols, out);
            return;
        }
    }
    gemm_cols_scalar(x, b, k, w, n, cols, out)
}

/// Scalar fallback with the oracle's exact loop body, restricted to a
/// column stripe. Per output element the (multiply, add) sequence over
/// input channels is unchanged, so stripe results equal the full-width
/// kernel's bitwise.
unsafe fn gemm_cols_scalar(
    x: &[f32],
    b: usize,
    k: usize,
    w: &[f32],
    n: usize,
    cols: Range<usize>,
    out: SendPtr,
) {
    let (c0, cw) = (cols.start, cols.len());
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for i in 0..k {
        let wrow = &w[i * n + c0..i * n + c0 + cw];
        for s in 0..b {
            let xv = x[s * k + i];
            if xv == 0.0 {
                continue;
            }
            let orow = stripe_mut(out, s * n + c0, cw);
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_cols_avx2(
    x: &[f32],
    b: usize,
    k: usize,
    w: &[f32],
    n: usize,
    cols: Range<usize>,
    out: SendPtr,
) {
    let (c0, cw) = (cols.start, cols.len());
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for i in 0..k {
        let wrow = &w[i * n + c0..i * n + c0 + cw];
        for s in 0..b {
            let xv = x[s * k + i];
            if xv == 0.0 {
                continue;
            }
            axpy_avx2(xv, wrow, stripe_mut(out, s * n + c0, cw));
        }
    }
}

/// Materialize the caller-promised disjoint output stripe. Each call
/// creates a fresh `&mut` that dies with the expression, and no two
/// concurrent stripes overlap (pool drivers partition the columns), so
/// no aliasing `&mut` ever coexists.
#[inline(always)]
unsafe fn stripe_mut<'a>(base: SendPtr, off: usize, len: usize) -> &'a mut [f32] {
    std::slice::from_raw_parts_mut(base.get().add(off), len)
}

/// `dst[j] += a * src[j]` — the vector form of the axpy inner loop.
/// Mul then add per element, matching the scalar rounding exactly.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(a: f32, src: &[f32], dst: &mut [f32]) {
    use std::arch::x86_64::*;
    let len = src.len().min(dst.len());
    let av = _mm256_set1_ps(a);
    let mut j = 0usize;
    while j + 8 <= len {
        let sv = _mm256_loadu_ps(src.as_ptr().add(j));
        let dv = _mm256_loadu_ps(dst.as_ptr().add(j));
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(dv, _mm256_mul_ps(av, sv)));
        j += 8;
    }
    while j < len {
        *dst.get_unchecked_mut(j) += a * *src.get_unchecked(j);
        j += 1;
    }
}

// ---------------------------------------------------------------------
// dense q4 GEMM
// ---------------------------------------------------------------------

/// Vector-tier [`q4_gemm_into`](super::q4_gemm_into): identical
/// contract (same scratch shapes) and bit-identical output.
pub fn q4_gemm_into(
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    partial: &mut [f32],
    xcol: &mut [f32],
    qrow: &mut [f32],
    out: &mut [f32],
) {
    let n = w.n;
    assert!(x.len() >= b * w.k && out.len() >= b * n);
    assert!(partial.len() >= b * n && xcol.len() >= b && qrow.len() >= n);
    let sc = ColScratch { xcol, qrow, partial };
    // SAFETY: full-width stripe of an exclusively borrowed `out`.
    unsafe { q4_gemm_cols_raw(x, b, w, 0..n, sc, SendPtr::new(out.as_mut_ptr())) }
}

/// Column stripe `cols` of the q4 GEMM. `cols.start` and `cols.end`
/// must be even (a stripe never splits a nibble-packed byte; the
/// aligned partitioner guarantees this). Scratch is worker-local; the
/// `partial` accumulators are indexed stripe-locally (`s * cols.len()`
/// rows), so a stripe touches no scratch outside its own.
///
/// # Safety
///
/// As [`gemm_cols_raw`]: `out` live for `b * w.n` elements, `cols`
/// within `0..=w.n` with even bounds, stripe elements untouched by
/// any other thread, no overlap with `x` or the scratch.
pub unsafe fn q4_gemm_cols_raw(
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    cols: Range<usize>,
    sc: ColScratch<'_>,
    out: SendPtr,
) {
    debug_assert!(cols.start % 2 == 0 && cols.end % 2 == 0 && cols.end <= w.n);
    debug_assert!(sc.xcol.len() >= b);
    debug_assert!(sc.qrow.len() >= cols.len());
    debug_assert!(sc.partial.len() >= b * cols.len());
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            q4_cols_avx2(x, b, w, cols, sc, out);
            return;
        }
    }
    q4_cols_scalar(x, b, w, cols, sc, out)
}

unsafe fn q4_cols_scalar(
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    cols: Range<usize>,
    sc: ColScratch<'_>,
    out: SendPtr,
) {
    let (k, n) = (w.k, w.n);
    let (c0, cw) = (cols.start, cols.len());
    let half = n / 2;
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for blk in 0..k / QBLOCK {
        sc.partial[..b * cw].fill(0.0);
        for i in blk * QBLOCK..(blk + 1) * QBLOCK {
            let mut any = false;
            for s in 0..b {
                let xv = x[s * k + i];
                sc.xcol[s] = xv;
                any |= xv != 0.0;
            }
            if !any {
                continue;
            }
            let row = &w.data[i * half + c0 / 2..i * half + (c0 + cw) / 2];
            for (j, &byte) in row.iter().enumerate() {
                sc.qrow[2 * j] = nibble_i8(byte & 0xF) as f32;
                sc.qrow[2 * j + 1] = nibble_i8(byte >> 4) as f32;
            }
            for (s, &xv) in sc.xcol[..b].iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let prow = &mut sc.partial[s * cw..(s + 1) * cw];
                for (p, &qv) in prow.iter_mut().zip(&sc.qrow[..cw]) {
                    *p += xv * qv;
                }
            }
        }
        let srow = &w.scales[blk * n + c0..blk * n + c0 + cw];
        for s in 0..b {
            let orow = stripe_mut(out, s * n + c0, cw);
            let prow = &sc.partial[s * cw..(s + 1) * cw];
            for ((o, &p), &scale) in orow.iter_mut().zip(prow).zip(srow) {
                *o += p * scale;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn q4_cols_avx2(
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    cols: Range<usize>,
    sc: ColScratch<'_>,
    out: SendPtr,
) {
    let (k, n) = (w.k, w.n);
    let (c0, cw) = (cols.start, cols.len());
    let half = n / 2;
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for blk in 0..k / QBLOCK {
        sc.partial[..b * cw].fill(0.0);
        for i in blk * QBLOCK..(blk + 1) * QBLOCK {
            let mut any = false;
            for s in 0..b {
                let xv = x[s * k + i];
                sc.xcol[s] = xv;
                any |= xv != 0.0;
            }
            if !any {
                continue;
            }
            let row = &w.data[i * half + c0 / 2..i * half + (c0 + cw) / 2];
            expand_nibbles_avx2(row, &mut sc.qrow[..cw]);
            for (s, &xv) in sc.xcol[..b].iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                axpy_avx2(xv, &sc.qrow[..cw], &mut sc.partial[s * cw..(s + 1) * cw]);
            }
        }
        let srow = &w.scales[blk * n + c0..blk * n + c0 + cw];
        for s in 0..b {
            let orow = stripe_mut(out, s * n + c0, cw);
            scale_add_avx2(&sc.partial[s * cw..(s + 1) * cw], srow, orow);
        }
    }
}

/// Expand `bytes.len()` nibble-packed bytes into `2 * bytes.len()`
/// dequantized-integer f32 lanes, column order `(lo, hi)` per byte —
/// the vector twin of the `nibble_i8` loop. Unpack, mask, compare-based
/// sign extension, and widening conversion are all exact.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn expand_nibbles_avx2(bytes: &[u8], dst: &mut [f32]) {
    use std::arch::x86_64::*;
    debug_assert!(dst.len() >= bytes.len() * 2);
    let lo_mask = _mm_set1_epi8(0x0F);
    let seven = _mm_set1_epi8(7);
    let sixteen = _mm_set1_epi8(16);
    let mut j = 0usize;
    while j + 16 <= bytes.len() {
        let raw = _mm_loadu_si128(bytes.as_ptr().add(j) as *const __m128i);
        let lo = _mm_and_si128(raw, lo_mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), lo_mask);
        // interleave restores storage column order: byte t holds
        // columns (2t, 2t+1) as (low, high) nibble
        let il0 = _mm_unpacklo_epi8(lo, hi); // columns 0..16 of this chunk
        let il1 = _mm_unpackhi_epi8(lo, hi); // columns 16..32
        // two's-complement sign extension of a 4-bit value: v - 16 iff v > 7
        let s0 = _mm_sub_epi8(il0, _mm_and_si128(_mm_cmpgt_epi8(il0, seven), sixteen));
        let s1 = _mm_sub_epi8(il1, _mm_and_si128(_mm_cmpgt_epi8(il1, seven), sixteen));
        store8_i8_as_f32(dst.as_mut_ptr().add(2 * j), s0);
        store8_i8_as_f32(dst.as_mut_ptr().add(2 * j + 8), _mm_srli_si128::<8>(s0));
        store8_i8_as_f32(dst.as_mut_ptr().add(2 * j + 16), s1);
        store8_i8_as_f32(dst.as_mut_ptr().add(2 * j + 24), _mm_srli_si128::<8>(s1));
        j += 16;
    }
    while j < bytes.len() {
        let byte = *bytes.get_unchecked(j);
        *dst.get_unchecked_mut(2 * j) = nibble_i8(byte & 0xF) as f32;
        *dst.get_unchecked_mut(2 * j + 1) = nibble_i8(byte >> 4) as f32;
        j += 1;
    }
}

/// Sign-extend the low 8 `i8` lanes to `i32` and store as 8 exact f32.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn store8_i8_as_f32(dst: *mut f32, v: std::arch::x86_64::__m128i) {
    use std::arch::x86_64::*;
    _mm256_storeu_ps(dst, _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(v)));
}

/// `out[j] += partial[j] * scales[j]` — the block-scale application,
/// mul then add per element like the scalar loop.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_add_avx2(partial: &[f32], scales: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let len = out.len().min(partial.len()).min(scales.len());
    let mut j = 0usize;
    while j + 8 <= len {
        let p = _mm256_loadu_ps(partial.as_ptr().add(j));
        let s = _mm256_loadu_ps(scales.as_ptr().add(j));
        let o = _mm256_loadu_ps(out.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(o, _mm256_mul_ps(p, s)));
        j += 8;
    }
    while j < len {
        *out.get_unchecked_mut(j) += *partial.get_unchecked(j) * *scales.get_unchecked(j);
        j += 1;
    }
}

// ---------------------------------------------------------------------
// sparse q4 GEMM
// ---------------------------------------------------------------------

/// Vector-tier [`q4_sparse_gemm_into`](super::q4_sparse_gemm_into):
/// identical contract and bit-identical output.
pub fn q4_sparse_gemm_into(
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    out: &mut [f32],
) {
    let n = m.n;
    assert!(x.len() >= b * m.k && slot_scale.len() >= m.kk() * n && out.len() >= b * n);
    // SAFETY: full-width stripe of an exclusively borrowed `out`.
    unsafe { q4_sparse_cols_raw(x, b, m, slot_scale, 0..n, SendPtr::new(out.as_mut_ptr())) }
}

/// Column stripe `cols` of the sparse q4 GEMM (`idx`-gather per slot
/// row). Any column split is valid — slots are per-column.
///
/// # Safety
///
/// As [`gemm_cols_raw`], with `cols` within `0..=m.n` and every
/// `m.idx` entry `< m.k` (the packer's invariant — the gather indexes
/// `x` with them).
pub unsafe fn q4_sparse_cols_raw(
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    cols: Range<usize>,
    out: SendPtr,
) {
    debug_assert!(cols.end <= m.n);
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            sparse_cols_avx2(x, b, m, slot_scale, cols, out);
            return;
        }
    }
    sparse_cols_scalar(x, b, m, slot_scale, cols, out)
}

unsafe fn sparse_cols_scalar(
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    cols: Range<usize>,
    out: SendPtr,
) {
    let (k, n, kk) = (m.k, m.n, m.kk());
    let (c0, cw) = (cols.start, cols.len());
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for r in 0..kk {
        let idxrow = &m.idx[r * n + c0..r * n + c0 + cw];
        let valrow = &m.val[r * n + c0..r * n + c0 + cw];
        let srow = &slot_scale[r * n + c0..r * n + c0 + cw];
        for s in 0..b {
            let xs = &x[s * k..(s + 1) * k];
            let orow = stripe_mut(out, s * n + c0, cw);
            for c in 0..cw {
                orow[c] += xs[idxrow[c] as usize] * valrow[c] as f32 * srow[c];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sparse_cols_avx2(
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    cols: Range<usize>,
    out: SendPtr,
) {
    use std::arch::x86_64::*;
    let (k, n, kk) = (m.k, m.n, m.kk());
    let (c0, cw) = (cols.start, cols.len());
    for s in 0..b {
        stripe_mut(out, s * n + c0, cw).fill(0.0);
    }
    for r in 0..kk {
        let idxrow = &m.idx[r * n + c0..r * n + c0 + cw];
        let valrow = &m.val[r * n + c0..r * n + c0 + cw];
        let srow = &slot_scale[r * n + c0..r * n + c0 + cw];
        for s in 0..b {
            let xs = &x[s * k..(s + 1) * k];
            let orow = stripe_mut(out, s * n + c0, cw);
            let mut j = 0usize;
            while j + 8 <= cw {
                // gather activations by slot index, widen INT4 values,
                // then ((x * v) * scale) + acc — the scalar grouping
                let iv = _mm256_loadu_si256(idxrow.as_ptr().add(j) as *const __m256i);
                let g = _mm256_i32gather_ps::<4>(xs.as_ptr(), iv);
                let v8 = _mm_loadl_epi64(valrow.as_ptr().add(j) as *const __m128i);
                let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(v8));
                let sv = _mm256_loadu_ps(srow.as_ptr().add(j));
                let ov = _mm256_loadu_ps(orow.as_ptr().add(j));
                let acc = _mm256_add_ps(ov, _mm256_mul_ps(_mm256_mul_ps(g, vf), sv));
                _mm256_storeu_ps(orow.as_mut_ptr().add(j), acc);
                j += 8;
            }
            while j < cw {
                *orow.get_unchecked_mut(j) += *xs.get_unchecked(*idxrow.get_unchecked(j) as usize)
                    * *valrow.get_unchecked(j) as f32
                    * *srow.get_unchecked(j);
                j += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------
// attention
// ---------------------------------------------------------------------

/// Vector-tier [`attend_into`](super::attend_into) — same degenerate
/// block-table delegation as the oracle.
pub fn attend_into(q: &[f32], keys: &[f32], vals: &[f32], scores: &mut [f32], ctx: &mut [f32]) {
    let d = q.len();
    let len = scores.len();
    debug_assert!(keys.len() >= len * d && vals.len() >= len * d);
    let blocks = [0u32];
    let kr = PagedRows::new(keys, &blocks, len.max(1), 0, 0, d);
    let vr = PagedRows::new(vals, &blocks, len.max(1), 0, 0, d);
    attend_paged_into(q, &kr, &vr, scores, ctx);
}

/// Vector-tier [`attend_paged_into`](super::attend_paged_into):
/// SSE 4-lane score dots (the exact [`dot4`](super::dot4) lanes),
/// scalar softmax (libm `exp` is the contract), vector accumulate.
/// Paged addressing stays outside the vector body.
pub fn attend_paged_into(
    q: &[f32],
    keys: &PagedRows,
    vals: &PagedRows,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            // SAFETY: runtime-detected avx2.
            unsafe { attend_paged_avx2(q, keys, vals, scores, ctx) };
            return;
        }
    }
    super::attend_paged_into(q, keys, vals, scores, ctx)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn attend_paged_avx2(
    q: &[f32],
    keys: &PagedRows,
    vals: &PagedRows,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    let d = q.len();
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for (i, s) in scores.iter_mut().enumerate() {
        *s = dot4_sse(keys.row(i), q) * inv_sqrt_d;
    }
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut wsum = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        wsum += *s;
    }
    ctx.fill(0.0);
    for (i, s) in scores.iter().enumerate() {
        let a = s / wsum;
        axpy_avx2(a, vals.row(i), ctx);
    }
}

/// SSE twin of [`dot4`](super::dot4): lane `l` of the 128-bit
/// accumulator receives exactly the scalar `acc[l]` sequence, and the
/// final combine is the scalar `(acc0 + acc1) + (acc2 + acc3) + tail`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_sse(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let len = a.len().min(b.len());
    let body = len - len % 4;
    let mut acc = _mm_setzero_ps();
    let mut i = 0usize;
    while i < body {
        let av = _mm_loadu_ps(a.as_ptr().add(i));
        let bv = _mm_loadu_ps(b.as_ptr().add(i));
        acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
        i += 4;
    }
    let mut lanes = [0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f32;
    while i < len {
        tail += *a.get_unchecked(i) * *b.get_unchecked(i);
        i += 1;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
}

#[cfg(test)]
mod tests {
    use super::super::{self as kernels};
    use super::*;
    use crate::quant::sparse::pack_sparse;
    use crate::quant::{prune_log_scale, quantize};
    use crate::util::rng::Rng;

    fn random(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_bit_identical_to_scalar_oracle() {
        // odd n (tail lanes), n < 8, n exactly 8, large n
        for (k, n, b) in [(24usize, 18usize, 3usize), (16, 5, 1), (8, 8, 2), (32, 67, 4)] {
            let w = random(k * n, 3);
            let x = random(b * k, 4);
            let mut want = vec![0f32; b * n];
            kernels::gemm_into(&x, b, k, &w, n, &mut want);
            let mut got = vec![0f32; b * n];
            gemm_into(&x, b, k, &w, n, &mut got);
            assert_eq!(bits(&want), bits(&got), "k={k} n={n} b={b}");
        }
    }

    #[test]
    fn gemm_stripes_compose_to_full_width() {
        let (k, n, b) = (16usize, 30usize, 3usize);
        let w = random(k * n, 5);
        let x = random(b * k, 6);
        let mut want = vec![0f32; b * n];
        kernels::gemm_into(&x, b, k, &w, n, &mut want);
        let mut got = vec![0f32; b * n];
        let base = SendPtr::new(got.as_mut_ptr());
        for cols in [0..7, 7..8, 8..30] {
            // SAFETY: sequential disjoint stripes of `got`.
            unsafe { gemm_cols_raw(&x, b, k, &w, n, cols, base) };
        }
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn q4_gemm_bit_identical_to_scalar_oracle() {
        use crate::quant::QBLOCK;
        let (k, n, b) = (QBLOCK * 2, 20usize, 3usize);
        let w = random(k * n, 9);
        let q = quantize(&w, k, n);
        let p = PackedQ4::from_quant(&q);
        let x = random(b * k, 10);
        let mut partial = vec![0f32; b * n];
        let mut xcol = vec![0f32; b];
        let mut qrow = vec![0f32; n];
        let mut want = vec![0f32; b * n];
        kernels::q4_gemm_into(&x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut want);
        let mut got = vec![0f32; b * n];
        q4_gemm_into(&x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut got);
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn q4_stripes_compose_to_full_width() {
        use crate::quant::QBLOCK;
        let (k, n, b) = (QBLOCK, 24usize, 2usize);
        let w = random(k * n, 11);
        let q = quantize(&w, k, n);
        let p = PackedQ4::from_quant(&q);
        let x = random(b * k, 12);
        let mut partial = vec![0f32; b * n];
        let mut xcol = vec![0f32; b];
        let mut qrow = vec![0f32; n];
        let mut want = vec![0f32; b * n];
        kernels::q4_gemm_into(&x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut want);
        let mut got = vec![0f32; b * n];
        let base = SendPtr::new(got.as_mut_ptr());
        for cols in [0..10usize, 10..16, 16..24] {
            let cw = cols.len();
            let sc = ColScratch {
                xcol: &mut xcol,
                qrow: &mut qrow[..cw],
                partial: &mut partial[..b * cw],
            };
            // SAFETY: sequential disjoint even-aligned stripes.
            unsafe { q4_gemm_cols_raw(&x, b, &p, cols, sc, base) };
        }
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn sparse_bit_identical_to_scalar_oracle() {
        use crate::quant::QBLOCK;
        let (k, n, b) = (QBLOCK, 19usize, 3usize);
        for keep in [1usize, 2, 4] {
            let mut w = random(k * n, 20 + keep as u64);
            prune_log_scale(&mut w, k, n, keep);
            let q = quantize(&w, k, n);
            let sm = pack_sparse(&q, keep);
            let ss = sm.slot_scales();
            let x = random(b * k, 21);
            let mut want = vec![0f32; b * n];
            kernels::q4_sparse_gemm_into(&x, b, &sm, &ss, &mut want);
            let mut got = vec![0f32; b * n];
            q4_sparse_gemm_into(&x, b, &sm, &ss, &mut got);
            assert_eq!(bits(&want), bits(&got), "keep {keep}");
        }
    }

    #[test]
    fn attend_bit_identical_to_scalar_oracle() {
        for (d, len) in [(8usize, 13usize), (6, 1), (16, 5), (20, 33)] {
            let q = random(d, 30);
            let keys = random(len * d, 31);
            let vals = random(len * d, 32);
            let mut s1 = vec![0f32; len];
            let mut c1 = vec![0f32; d];
            kernels::attend_into(&q, &keys, &vals, &mut s1, &mut c1);
            let mut s2 = vec![0f32; len];
            let mut c2 = vec![0f32; d];
            attend_into(&q, &keys, &vals, &mut s2, &mut c2);
            assert_eq!(bits(&c1), bits(&c2), "ctx d={d} len={len}");
            assert_eq!(bits(&s1), bits(&s2), "scores d={d} len={len}");
        }
    }

    #[test]
    fn nibble_expansion_is_exact_for_every_byte() {
        // all 256 byte values through the (possibly vector) q4 path via
        // a 1-row matvec against a delta activation
        use crate::quant::QBLOCK;
        let (k, n) = (QBLOCK, 32usize);
        let w = random(k * n, 40);
        let q = quantize(&w, k, n);
        let p = PackedQ4::from_quant(&q);
        let mut x = vec![0f32; k];
        x[17] = 1.0;
        let mut partial = vec![0f32; n];
        let mut xcol = vec![0f32; 1];
        let mut qrow = vec![0f32; n];
        let mut want = vec![0f32; n];
        kernels::q4_gemm_into(&x, 1, &p, &mut partial, &mut xcol, &mut qrow, &mut want);
        let mut got = vec![0f32; n];
        q4_gemm_into(&x, 1, &p, &mut partial, &mut xcol, &mut qrow, &mut got);
        assert_eq!(bits(&want), bits(&got));
    }
}
