//! Multicore drivers over the striped SIMD kernels — the
//! `simd-parallel` execution tier.
//!
//! Each driver splits the *output* of one kernel call into disjoint
//! column stripes (or whole attention jobs) and runs the stripes on the
//! persistent [`WorkerPool`](super::super::pool::WorkerPool). The
//! determinism contract is structural, not numeric:
//!
//! - Stripes never share an output element or a scratch element, so
//!   there is no combining step — nothing is reduced across workers.
//! - Within a stripe the [`simd`](super::simd) kernel runs the scalar
//!   oracle's per-element operation sequence unchanged; the stripe
//!   boundary only decides *which* elements a worker computes, never
//!   the order of operations *per* element.
//!
//! Results are therefore bitwise identical for any thread count
//! (asserted by the equivalence suite across `threads ∈ {1, 2, 8}`),
//! and a q4 stripe boundary is kept even so it never splits a
//! nibble-packed byte.
//!
//! Every driver falls back to the single-threaded SIMD kernel when the
//! split would be degenerate (one stripe, a pool without workers, or
//! scratch sized for fewer stripes than requested) — callers never need
//! a size check before dispatching here.

use std::ops::Range;

use super::super::kv::PagedRows;
use super::super::pool::{partition, partition_aligned, SendPtr, Task, WorkerPool};
use super::simd::{self, ColScratch};
use crate::pack::layout::PackedQ4;
use crate::quant::sparse::SparseMatrix;

/// Column stripes for an `n`-wide output on this pool, `align`-aligned.
fn stripes(pool: &WorkerPool, n: usize, align: usize) -> Vec<Range<usize>> {
    partition_aligned(n, pool.threads(), align)
}

/// Parallel [`gemm_into`](super::gemm_into): output columns are split
/// 8-aligned (full vector lanes per stripe where possible) across the
/// pool. Bit-identical to the scalar oracle at any thread count.
pub fn gemm_into(
    pool: &WorkerPool,
    x: &[f32],
    b: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert!(x.len() >= b * k && w.len() >= k * n && out.len() >= b * n);
    let ranges = stripes(pool, n, 8);
    if ranges.len() <= 1 {
        simd::gemm_into(x, b, k, w, n, out);
        return;
    }
    let base = SendPtr::new(out.as_mut_ptr());
    let tasks: Vec<Task> = ranges
        .into_iter()
        .map(|cols| {
            // SAFETY: stripes are disjoint column ranges of `out`, each
            // worker writes only `s*n + cols`; the pool joins every
            // task before `run` returns, within `out`'s borrow.
            Box::new(move || unsafe { simd::gemm_cols_raw(x, b, k, w, n, cols, base) }) as Task
        })
        .collect();
    pool.run(tasks);
}

/// Parallel [`matvec_into`](super::matvec_into) (the logits head).
pub fn matvec_into(pool: &WorkerPool, w: &[f32], x: &[f32], out: &mut [f32]) {
    let (k, n) = (x.len(), out.len());
    gemm_into(pool, x, 1, k, w, n, out);
}

/// Parallel [`q4_gemm_into`](super::q4_gemm_into): even-aligned column
/// stripes (a stripe never splits a nibble-packed byte), with the
/// caller's scratch carved into per-worker [`ColScratch`] regions —
/// `b` activation lanes, `cols.len()` expanded nibbles and
/// `b * cols.len()` partials each, all disjoint. Falls back to the
/// single-threaded kernel when `xcol` was sized for fewer stripes.
pub fn q4_gemm_into(
    pool: &WorkerPool,
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    partial: &mut [f32],
    xcol: &mut [f32],
    qrow: &mut [f32],
    out: &mut [f32],
) {
    let n = w.n;
    assert!(x.len() >= b * w.k && out.len() >= b * n);
    assert!(partial.len() >= b * n && qrow.len() >= n);
    let ranges = stripes(pool, n, 2);
    if ranges.len() <= 1 || xcol.len() < ranges.len() * b {
        simd::q4_gemm_into(x, b, w, partial, xcol, qrow, out);
        return;
    }
    let base = SendPtr::new(out.as_mut_ptr());
    let mut xcol_rest = xcol;
    let mut qrow_rest = qrow;
    let mut partial_rest = partial;
    let tasks: Vec<Task> = ranges
        .into_iter()
        .map(|cols| {
            let cw = cols.len();
            let (xc, rest) = std::mem::take(&mut xcol_rest).split_at_mut(b);
            xcol_rest = rest;
            let (qr, rest) = std::mem::take(&mut qrow_rest).split_at_mut(cw);
            qrow_rest = rest;
            let (pp, rest) = std::mem::take(&mut partial_rest).split_at_mut(b * cw);
            partial_rest = rest;
            // SAFETY: disjoint even-aligned column stripes of `out`,
            // each with its own scratch region; the pool joins every
            // task before `run` returns, within `out`'s borrow.
            Box::new(move || {
                let sc = ColScratch { xcol: xc, qrow: qr, partial: pp };
                unsafe { simd::q4_gemm_cols_raw(x, b, w, cols, sc, base) }
            }) as Task
        })
        .collect();
    pool.run(tasks);
}

/// Parallel [`q4_sparse_gemm_into`](super::q4_sparse_gemm_into): slots
/// are per-column, so any column split is valid and no scratch is
/// needed.
pub fn q4_sparse_gemm_into(
    pool: &WorkerPool,
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    out: &mut [f32],
) {
    let n = m.n;
    assert!(x.len() >= b * m.k && slot_scale.len() >= m.kk() * n && out.len() >= b * n);
    let ranges = stripes(pool, n, 8);
    if ranges.len() <= 1 {
        simd::q4_sparse_gemm_into(x, b, m, slot_scale, out);
        return;
    }
    let base = SendPtr::new(out.as_mut_ptr());
    let tasks: Vec<Task> = ranges
        .into_iter()
        .map(|cols| {
            // SAFETY: disjoint column stripes of `out`; the pool joins
            // every task before `run` returns, within `out`'s borrow.
            Box::new(move || unsafe { simd::q4_sparse_cols_raw(x, b, m, slot_scale, cols, base) })
                as Task
        })
        .collect();
    pool.run(tasks);
}

/// One session-position attention problem: `q` against `len` cached
/// rows, context written to this job's own `ctx` row. Jobs are
/// independent by construction (each session attends over its own
/// cache), which is what makes attention the *job*-parallel axis while
/// the GEMMs are *column*-parallel.
pub struct AttnJob<'a> {
    /// query row, `d` wide
    pub q: &'a [f32],
    /// paged key rows for this session
    pub keys: PagedRows<'a>,
    /// paged value rows for this session
    pub vals: PagedRows<'a>,
    /// cached positions to attend over
    pub len: usize,
    /// output context row, `d` wide — exclusive to this job
    pub ctx: &'a mut [f32],
}

/// Run a batch of attention jobs across the pool. `scores` is scratch
/// for softmax logits: every worker group gets its own `max_len`-wide
/// stripe, so the same buffer serves any thread count. Scores never
/// escape (only `ctx` does), so tiers that stripe the buffer
/// differently still produce identical outputs.
pub fn attend_jobs(pool: &WorkerPool, jobs: Vec<AttnJob<'_>>, scores: &mut [f32], max_len: usize) {
    debug_assert!(jobs.iter().all(|j| j.len <= max_len));
    let groups = partition(jobs.len(), pool.threads());
    if groups.len() <= 1 || scores.len() < groups.len() * max_len {
        for j in jobs {
            simd::attend_paged_into(j.q, &j.keys, &j.vals, &mut scores[..j.len], j.ctx);
        }
        return;
    }
    let mut remaining = jobs;
    let mut scores_rest = scores;
    let tasks: Vec<Task> = groups
        .into_iter()
        .map(|g| {
            let rest = remaining.split_off(g.len());
            let group = std::mem::replace(&mut remaining, rest);
            let (stripe, rest) = std::mem::take(&mut scores_rest).split_at_mut(max_len);
            scores_rest = rest;
            Box::new(move || {
                for j in group {
                    simd::attend_paged_into(j.q, &j.keys, &j.vals, &mut stripe[..j.len], j.ctx);
                }
            }) as Task
        })
        .collect();
    pool.run(tasks);
}

#[cfg(test)]
mod tests {
    use super::super::{self as kernels};
    use super::*;
    use crate::pack::layout::PackedQ4;
    use crate::quant::sparse::pack_sparse;
    use crate::quant::{prune_log_scale, quantize, QBLOCK};
    use crate::util::rng::Rng;

    fn random(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_invariant_across_thread_counts() {
        let (k, n, b) = (24usize, 37usize, 3usize); // odd width, tail lanes
        let w = random(k * n, 1);
        let x = random(b * k, 2);
        let mut want = vec![0f32; b * n];
        kernels::gemm_into(&x, b, k, &w, n, &mut want);
        for threads in [1usize, 2, 8, 16] {
            let pool = WorkerPool::new(threads);
            let mut got = vec![0f32; b * n];
            gemm_into(&pool, &x, b, k, &w, n, &mut got);
            assert_eq!(bits(&want), bits(&got), "threads {threads}");
        }
    }

    #[test]
    fn q4_gemm_invariant_across_thread_counts() {
        let (k, n, b) = (QBLOCK, 26usize, 3usize);
        let w = random(k * n, 3);
        let p = PackedQ4::from_quant(&quantize(&w, k, n));
        let x = random(b * k, 4);
        let mut partial = vec![0f32; b * n];
        let mut xcol1 = vec![0f32; b];
        let mut qrow = vec![0f32; n];
        let mut want = vec![0f32; b * n];
        kernels::q4_gemm_into(&x, b, &p, &mut partial, &mut xcol1, &mut qrow, &mut want);
        for threads in [1usize, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut xcol = vec![0f32; pool.threads() * b];
            let mut got = vec![0f32; b * n];
            q4_gemm_into(&pool, &x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut got);
            assert_eq!(bits(&want), bits(&got), "threads {threads}");
        }
    }

    #[test]
    fn q4_gemm_falls_back_when_scratch_is_small() {
        let (k, n, b) = (QBLOCK, 16usize, 2usize);
        let w = random(k * n, 5);
        let p = PackedQ4::from_quant(&quantize(&w, k, n));
        let x = random(b * k, 6);
        let mut partial = vec![0f32; b * n];
        let mut xcol = vec![0f32; b]; // sized for one stripe only
        let mut qrow = vec![0f32; n];
        let mut want = vec![0f32; b * n];
        kernels::q4_gemm_into(&x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut want);
        let pool = WorkerPool::new(4);
        let mut got = vec![0f32; b * n];
        q4_gemm_into(&pool, &x, b, &p, &mut partial, &mut xcol, &mut qrow, &mut got);
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn sparse_invariant_across_thread_counts() {
        let (k, n, b) = (QBLOCK, 21usize, 2usize);
        let mut w = random(k * n, 7);
        prune_log_scale(&mut w, k, n, 2);
        let sm = pack_sparse(&quantize(&w, k, n), 2);
        let ss = sm.slot_scales();
        let x = random(b * k, 8);
        let mut want = vec![0f32; b * n];
        kernels::q4_sparse_gemm_into(&x, b, &sm, &ss, &mut want);
        for threads in [1usize, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut got = vec![0f32; b * n];
            q4_sparse_gemm_into(&pool, &x, b, &sm, &ss, &mut got);
            assert_eq!(bits(&want), bits(&got), "threads {threads}");
        }
    }

    #[test]
    fn attention_jobs_invariant_across_thread_counts() {
        let d = 16usize;
        let lens = [5usize, 1, 12, 9, 3]; // fewer jobs than 8 threads
        let q: Vec<Vec<f32>> = (0..lens.len()).map(|i| random(d, 10 + i as u64)).collect();
        let keys: Vec<Vec<f32>> = lens.iter().map(|&l| random(l * d, 20 + l as u64)).collect();
        let vals: Vec<Vec<f32>> = lens.iter().map(|&l| random(l * d, 30 + l as u64)).collect();
        let max_len = 12usize;
        let mut want = vec![0f32; lens.len() * d];
        for (i, &len) in lens.iter().enumerate() {
            let mut sc = vec![0f32; len];
            kernels::attend_into(&q[i], &keys[i], &vals[i], &mut sc, &mut want[i * d..(i + 1) * d]);
        }
        let blocks = [0u32];
        for threads in [1usize, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut scores = vec![0f32; pool.threads() * max_len];
            let mut got = vec![0f32; lens.len() * d];
            let mut rows = got.chunks_mut(d);
            let jobs: Vec<AttnJob> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| AttnJob {
                    q: &q[i],
                    keys: PagedRows::new(&keys[i], &blocks, len.max(1), 0, 0, d),
                    vals: PagedRows::new(&vals[i], &blocks, len.max(1), 0, 0, d),
                    len,
                    ctx: rows.next().unwrap(),
                })
                .collect();
            attend_jobs(&pool, jobs, &mut scores, max_len);
            assert_eq!(bits(&want), bits(&got), "threads {threads}");
        }
    }
}
