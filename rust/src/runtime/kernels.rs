//! Batched, blocked CPU kernels for the functional backend — the
//! software mirror of the paper's dataflow.
//!
//! Every kernel here obeys two contracts:
//!
//! 1. **Weight-stream-once loop order.** The outer loop of every matrix
//!    kernel walks the weight matrix in its storage order (input-major,
//!    the same `k × n` layout the quantizer and the HBM packager use);
//!    the batch dimension is inner. One batched decode round therefore
//!    reads each weight element exactly once from memory (it stays in
//!    L1 across the batch), which is the same accounting
//!    `sim::engine::Simulator::decode_round` charges the accelerator:
//!    the weight stream is shared, only the per-session work multiplies.
//! 2. **Batch-order invariance.** For a fixed session, the sequence of
//!    floating-point operations is identical whether the session runs at
//!    batch 1 or inside any larger batch. Batched decode is therefore
//!    *bit-identical* to scalar decode, not merely close — the
//!    equivalence tests assert both.
//!
//! The matrix kernels accumulate in axpy form (`out_row += x_i · w_row`):
//! the inner loop is contiguous over independent output accumulators, so
//! it vectorizes and is never serialized on floating-point add latency
//! the way a naive dot-product reduction is — that difference is most of
//! the single-stream throughput, and what makes batch-1 decode genuinely
//! weight-stream-bound (and batching therefore genuinely profitable).
//!
//! All kernels write into caller-provided scratch (no allocation on the
//! hot path). The FP16×INT4 kernels consume the nibble-packed
//! [`PackedQ4`] layout (dense) or the fixed-slot [`SparseMatrix`] layout
//! (log-scale structured sparsity) and dequantize on the fly — each
//! packed row is expanded once per round and amortized over the whole
//! batch, with scales factored out per 128-channel block like the
//! mix-precision PE's scale stage. The *bit-exact* PE arithmetic model
//! lives in `fp::mixpe`; these kernels are the fast functional
//! counterpart.

pub mod par;
pub mod simd;

use super::kv::PagedRows;
use crate::pack::layout::{nibble_i8, PackedQ4};
use crate::quant::sparse::SparseMatrix;
use crate::quant::QBLOCK;

/// Four-lane dot product (fixed summation order): breaks the
/// floating-point add latency chain of a naive reduction while staying
/// deterministic. Used for attention scores, where the output is a
/// scalar and axpy form does not apply.
#[inline(always)]
pub fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0f32;
    for (xa, xb) in ca.remainder().iter().zip(cb.remainder()) {
        tail += xa * xb;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `out[s*n + c] = Σ_i x[s*k + i] · w[i*n + c]` for input-major
/// `k × n` weights and `b` activation rows (row-major `b × k`).
/// Overwrites `out[..b*n]`.
///
/// Loop order: weight row outer (streamed once per call), sessions
/// inner, output channels innermost (contiguous axpy). Input channels
/// whose activation is zero contribute nothing and are skipped.
pub fn gemm_into(x: &[f32], b: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    debug_assert!(x.len() >= b * k);
    debug_assert!(w.len() >= k * n);
    debug_assert!(out.len() >= b * n);
    out[..b * n].fill(0.0);
    for i in 0..k {
        let wrow = &w[i * n..(i + 1) * n];
        for s in 0..b {
            let xv = x[s * k + i];
            if xv == 0.0 {
                continue;
            }
            let orow = &mut out[s * n..(s + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// `out[c] = Σ_i x[i] · w[i*n + c]` — batch-1 [`gemm_into`].
pub fn matvec_into(w: &[f32], x: &[f32], out: &mut [f32]) {
    let (k, n) = (x.len(), out.len());
    gemm_into(x, 1, k, w, n, out);
}

/// Dequant-on-the-fly FP16×INT4 batched GEMM over the nibble-packed
/// dense layout: `out[s*n + c] = Σ_i x[s*k + i] · dq(w[i, c])`,
/// overwriting `out[..b*n]`.
///
/// Each packed row is expanded to f32 once per round into `qrow` and
/// amortized over all `b` sessions — at batch 1 the nibble decode is
/// the dominant cost, so this amortization is a large part of the
/// batched speedup. Scales are factored out per QBLOCK: INT4 values
/// accumulate into `partial` and the block's f32 scale is applied once
/// per output — the software shape of the PE's block-scale stage.
/// Rows whose activations are zero across the whole batch (e.g. the
/// zero-padding above the model's true width) are skipped.
///
/// Scratch: `partial` needs `b*n` slots, `xcol` needs `b`, `qrow` `n`.
pub fn q4_gemm_into(
    x: &[f32],
    b: usize,
    w: &PackedQ4,
    partial: &mut [f32],
    xcol: &mut [f32],
    qrow: &mut [f32],
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    debug_assert!(x.len() >= b * k);
    debug_assert!(partial.len() >= b * n);
    debug_assert!(xcol.len() >= b);
    debug_assert!(qrow.len() >= n);
    debug_assert!(out.len() >= b * n);
    out[..b * n].fill(0.0);
    let half = n / 2;
    for blk in 0..k / QBLOCK {
        partial[..b * n].fill(0.0);
        for i in blk * QBLOCK..(blk + 1) * QBLOCK {
            // gather this input channel's activation across the batch
            let mut any = false;
            for s in 0..b {
                let xv = x[s * k + i];
                xcol[s] = xv;
                any |= xv != 0.0;
            }
            if !any {
                continue; // padded / inactive channel
            }
            // expand the nibble row once for the whole batch
            let row = &w.data[i * half..(i + 1) * half];
            for (j, &byte) in row.iter().enumerate() {
                qrow[2 * j] = nibble_i8(byte & 0xF) as f32;
                qrow[2 * j + 1] = nibble_i8(byte >> 4) as f32;
            }
            for (s, &xv) in xcol[..b].iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let prow = &mut partial[s * n..(s + 1) * n];
                for (p, &qv) in prow.iter_mut().zip(&qrow[..n]) {
                    *p += xv * qv;
                }
            }
        }
        let srow = &w.scales[blk * n..(blk + 1) * n];
        for s in 0..b {
            let prow = &partial[s * n..(s + 1) * n];
            let orow = &mut out[s * n..(s + 1) * n];
            for ((o, &p), &sc) in orow.iter_mut().zip(prow).zip(srow) {
                *o += p * sc;
            }
        }
    }
}

/// Structured-sparse FP16×INT4 batched GEMM over the fixed-slot packed
/// layout (log-scale N:M pruning): only the kept slots are walked, with
/// the slot index selecting the matching activation lane — the software
/// model of the sparse DMA's activation select. `slot_scale` holds the
/// pre-decoded f32 scale of each slot (`kk × n`, see
/// `SparseMatrix::idx`). Overwrites `out[..b*n]`.
pub fn q4_sparse_gemm_into(
    x: &[f32],
    b: usize,
    m: &SparseMatrix,
    slot_scale: &[f32],
    out: &mut [f32],
) {
    let (k, n, kk) = (m.k, m.n, m.kk());
    debug_assert!(x.len() >= b * k);
    debug_assert!(slot_scale.len() >= kk * n);
    debug_assert!(out.len() >= b * n);
    out[..b * n].fill(0.0);
    for r in 0..kk {
        let idxrow = &m.idx[r * n..(r + 1) * n];
        let valrow = &m.val[r * n..(r + 1) * n];
        let srow = &slot_scale[r * n..(r + 1) * n];
        for s in 0..b {
            let xs = &x[s * k..(s + 1) * k];
            let orow = &mut out[s * n..(s + 1) * n];
            for c in 0..n {
                orow[c] += xs[idxrow[c] as usize] * valrow[c] as f32 * srow[c];
            }
        }
    }
}

/// Causal attention for one session: `scores.len()` cached positions,
/// `q.len() = d`. Writes softmax(q·Kᵀ/√d)·V into `ctx`; `scores` is
/// scratch. Identical operation order at any batch size (each session
/// attends over its own cache, so there is nothing to share).
///
/// A contiguous cache is the degenerate paged layout (one block holding
/// every position), so this delegates to [`attend_paged_into`] through
/// an identity block table — bit-identity between the two paths holds
/// by construction, not by keeping two loop bodies in sync.
pub fn attend_into(q: &[f32], keys: &[f32], vals: &[f32], scores: &mut [f32], ctx: &mut [f32]) {
    let d = q.len();
    let len = scores.len();
    debug_assert!(keys.len() >= len * d && vals.len() >= len * d);
    let blocks = [0u32];
    let kr = PagedRows::new(keys, &blocks, len.max(1), 0, 0, d);
    let vr = PagedRows::new(vals, &blocks, len.max(1), 0, 0, d);
    attend_paged_into(q, &kr, &vr, scores, ctx);
}

/// Causal attention over a *paged* KV cache: the gather-path twin of
/// [`attend_into`]. `keys`/`vals` are block-table views
/// ([`PagedRows`]); `scores.len()` is the number of cached positions.
///
/// The loop structure, per-row [`dot4`] arithmetic, softmax, and
/// accumulation order are identical to the contiguous kernel — only the
/// row *addressing* goes through the block table — so for the same
/// logical rows the output is **bit-identical** to [`attend_into`]
/// (asserted in the unit tests below and end-to-end in
/// `rust/tests/backend_equivalence.rs`).
pub fn attend_paged_into(
    q: &[f32],
    keys: &PagedRows,
    vals: &PagedRows,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    let d = q.len();
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for (i, s) in scores.iter_mut().enumerate() {
        *s = dot4(keys.row(i), q) * inv_sqrt_d;
    }
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut wsum = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        wsum += *s;
    }
    ctx.fill(0.0);
    for (i, s) in scores.iter().enumerate() {
        let a = s / wsum;
        let vi = vals.row(i);
        for (c, x) in ctx.iter_mut().zip(vi.iter()) {
            *c += a * x;
        }
    }
}

/// GELU (tanh approximation) — the FFN activation.
#[inline(always)]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sparse::pack_sparse;
    use crate::quant::{prune_log_scale, quantize, QuantMatrix};
    use crate::util::rng::Rng;

    fn random(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn quantized(k: usize, n: usize, keep: usize, seed: u64) -> QuantMatrix {
        let mut w = random(k * n, seed);
        if keep < 8 {
            prune_log_scale(&mut w, k, n, keep);
        }
        quantize(&w, k, n)
    }

    #[test]
    fn dot4_matches_f64_reference() {
        for len in [1usize, 3, 4, 7, 8, 33, 64] {
            let a = random(len, 1);
            let b = random(len, 2);
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot4(&a, &b) as f64;
            assert!((got - want).abs() < 1e-4, "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn gemm_matches_naive_reference() {
        let (k, n, bsz) = (24usize, 18, 3);
        let w = random(k * n, 3);
        let x = random(bsz * k, 4);
        let mut out = vec![0f32; bsz * n];
        gemm_into(&x, bsz, k, &w, n, &mut out);
        for s in 0..bsz {
            for c in 0..n {
                let mut want = 0f64;
                for i in 0..k {
                    want += x[s * k + i] as f64 * w[i * n + c] as f64;
                }
                let got = out[s * n + c] as f64;
                assert!((got - want).abs() < 1e-4, "s={s} c={c}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn gemm_batch1_is_matvec() {
        let (k, n) = (16usize, 24);
        let w = random(k * n, 5);
        let x = random(k, 6);
        let mut a = vec![0f32; n];
        let mut b = vec![0f32; n];
        matvec_into(&w, &x, &mut a);
        gemm_into(&x, 1, k, &w, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn gemm_batched_is_bitwise_per_session() {
        let (k, n, bsz) = (16usize, 24, 5);
        let w = random(k * n, 7);
        let x = random(bsz * k, 8);
        let mut batched = vec![0f32; bsz * n];
        gemm_into(&x, bsz, k, &w, n, &mut batched);
        for s in 0..bsz {
            let mut one = vec![0f32; n];
            matvec_into(&w, &x[s * k..(s + 1) * k], &mut one);
            assert_eq!(one, batched[s * n..(s + 1) * n]);
        }
    }

    #[test]
    fn q4_gemm_matches_dequant_reference() {
        let (k, n, bsz) = (QBLOCK * 2, 16, 3);
        let m = quantized(k, n, 8, 9);
        let p = PackedQ4::from_quant(&m);
        let x = random(bsz * k, 10);
        let mut out = vec![0f32; bsz * n];
        let mut partial = vec![0f32; bsz * n];
        let mut xcol = vec![0f32; bsz];
        let mut qrow = vec![0f32; n];
        q4_gemm_into(&x, bsz, &p, &mut partial, &mut xcol, &mut qrow, &mut out);
        for s in 0..bsz {
            for c in 0..n {
                let mut want = 0f64;
                for r in 0..k {
                    want += x[s * k + r] as f64 * m.dequant(r, c);
                }
                let got = out[s * n + c] as f64;
                assert!((got - want).abs() < 1e-3, "s={s} c={c}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn q4_gemm_batched_is_bitwise_per_session() {
        let (k, n, bsz) = (QBLOCK, 8, 4);
        let m = quantized(k, n, 8, 11);
        let p = PackedQ4::from_quant(&m);
        let x = random(bsz * k, 12);
        let mut batched = vec![0f32; bsz * n];
        let mut partial = vec![0f32; bsz * n];
        let mut xcol = vec![0f32; bsz];
        let mut qrow = vec![0f32; n];
        q4_gemm_into(&x, bsz, &p, &mut partial, &mut xcol, &mut qrow, &mut batched);
        for s in 0..bsz {
            let mut one = vec![0f32; n];
            q4_gemm_into(
                &x[s * k..(s + 1) * k],
                1,
                &p,
                &mut partial,
                &mut xcol,
                &mut qrow,
                &mut one,
            );
            assert_eq!(one, batched[s * n..(s + 1) * n], "session {s}");
        }
    }

    #[test]
    fn q4_gemm_zero_padded_rows_are_free() {
        // activations above the true width are zero: identical result to
        // an x that never had the padding
        let (k, n) = (QBLOCK, 8);
        let m = quantized(k, n, 8, 13);
        let p = PackedQ4::from_quant(&m);
        let mut x = random(k, 14);
        for v in x[40..].iter_mut() {
            *v = 0.0;
        }
        let mut out = vec![0f32; n];
        let mut partial = vec![0f32; n];
        let mut xcol = vec![0f32; 1];
        let mut qrow = vec![0f32; n];
        q4_gemm_into(&x, 1, &p, &mut partial, &mut xcol, &mut qrow, &mut out);
        for c in 0..n {
            let mut want = 0f64;
            for r in 0..40 {
                want += x[r] as f64 * m.dequant(r, c);
            }
            assert!((out[c] as f64 - want).abs() < 1e-3);
        }
    }

    #[test]
    fn q4_sparse_matches_dense_on_pruned() {
        let (k, n, bsz) = (QBLOCK, 16, 3);
        for keep in [1usize, 2, 4] {
            let m = quantized(k, n, keep, 15 + keep as u64);
            let p = PackedQ4::from_quant(&m);
            let sm = pack_sparse(&m, keep);
            let ss = sm.slot_scales();
            let x = random(bsz * k, 16);
            let mut dense = vec![0f32; bsz * n];
            let mut partial = vec![0f32; bsz * n];
            let mut xcol = vec![0f32; bsz];
            let mut qrow = vec![0f32; n];
            q4_gemm_into(&x, bsz, &p, &mut partial, &mut xcol, &mut qrow, &mut dense);
            let mut sparse = vec![0f32; bsz * n];
            q4_sparse_gemm_into(&x, bsz, &sm, &ss, &mut sparse);
            for i in 0..bsz * n {
                assert!(
                    (dense[i] - sparse[i]).abs() < 1e-4,
                    "keep {keep} elem {i}: {} vs {}",
                    dense[i],
                    sparse[i]
                );
            }
        }
    }

    #[test]
    fn q4_sparse_batched_is_bitwise_per_session() {
        let (k, n, bsz) = (QBLOCK, 8, 3);
        let m = quantized(k, n, 2, 17);
        let sm = pack_sparse(&m, 2);
        let ss = sm.slot_scales();
        let x = random(bsz * k, 18);
        let mut batched = vec![0f32; bsz * n];
        q4_sparse_gemm_into(&x, bsz, &sm, &ss, &mut batched);
        for s in 0..bsz {
            let mut one = vec![0f32; n];
            q4_sparse_gemm_into(&x[s * k..(s + 1) * k], 1, &sm, &ss, &mut one);
            assert_eq!(one, batched[s * n..(s + 1) * n]);
        }
    }

    #[test]
    fn attend_single_position_returns_value_row() {
        let d = 8;
        let q = random(d, 19);
        let k = random(d, 20);
        let v = random(d, 21);
        let mut scores = vec![0f32; 1];
        let mut ctx = vec![0f32; d];
        attend_into(&q, &k, &v, &mut scores, &mut ctx);
        for i in 0..d {
            assert!((ctx[i] - v[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn attend_weights_are_convex() {
        // context must lie inside the convex hull of the value rows:
        // with all-equal values it reproduces them exactly
        let (d, len) = (4usize, 6);
        let q = random(d, 22);
        let k = random(len * d, 23);
        let v: Vec<f32> = (0..len * d).map(|i| (i % d) as f32).collect();
        let mut scores = vec![0f32; len];
        let mut ctx = vec![0f32; d];
        attend_into(&q, &k, &v, &mut scores, &mut ctx);
        for i in 0..d {
            assert!((ctx[i] - i as f32).abs() < 1e-5);
        }
    }

    #[test]
    fn attend_paged_is_bitwise_identical_to_contiguous() {
        // same logical rows, addressed (a) contiguously and (b) through a
        // deliberately shuffled block table — outputs must match bit for bit
        let (d, len, block_tokens) = (8usize, 13, 4);
        let n_blocks = len.div_ceil(block_tokens);
        let keys = random(len * d, 31);
        let vals = random(len * d, 32);
        let q = random(d, 33);

        // paged storage: one layer, blocks laid out in reverse order so the
        // table is non-trivial
        let block_stride = block_tokens * d;
        let blocks: Vec<u32> = (0..n_blocks as u32).rev().collect();
        let mut kdata = vec![0f32; n_blocks * block_stride];
        let mut vdata = vec![0f32; n_blocks * block_stride];
        for pos in 0..len {
            let b = blocks[pos / block_tokens] as usize;
            let off = b * block_stride + (pos % block_tokens) * d;
            kdata[off..off + d].copy_from_slice(&keys[pos * d..(pos + 1) * d]);
            vdata[off..off + d].copy_from_slice(&vals[pos * d..(pos + 1) * d]);
        }
        let kr = PagedRows::new(&kdata, &blocks, block_tokens, block_stride, 0, d);
        let vr = PagedRows::new(&vdata, &blocks, block_tokens, block_stride, 0, d);

        for cached in [1usize, 4, 5, 13] {
            let mut s1 = vec![0f32; cached];
            let mut c1 = vec![0f32; d];
            attend_into(&q, &keys[..cached * d], &vals[..cached * d], &mut s1, &mut c1);
            let mut s2 = vec![0f32; cached];
            let mut c2 = vec![0f32; d];
            attend_paged_into(&q, &kr, &vr, &mut s2, &mut c2);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c1), bits(&c2), "ctx diverged at {cached} cached positions");
            assert_eq!(bits(&s1), bits(&s2), "scores diverged at {cached} cached positions");
        }
    }

    #[test]
    fn gelu_fixed_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(3.0) - 2.9964).abs() < 1e-3);
        assert!(gelu(-3.0).abs() < 4e-3);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
    }
}
