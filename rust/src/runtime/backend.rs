//! The `Backend` trait: the runtime interface the serving scheduler
//! drives, decoupled from any concrete execution engine.
//!
//! EdgeLLM's deployment story is *heterogeneous*: the same CPU-side
//! coordinator must drive whatever datapath is present — the pure-Rust
//! reference engine, the PJRT/XLA artifact executor, the VCU128 latency
//! model, or (eventually) a real FPGA bridge. The scheduler therefore
//! talks only to this object-safe trait; picking a backend is a
//! *constructor* decision (`LlmRuntime::reference` / `::simulator` /
//! `::load`), never a `cfg`/`match` branch on the serving hot path.
//!
//! Implementations in-tree:
//!
//! * [`ReferenceBackend`] (= `reference::RefLlm`) — the batched,
//!   blocked, FP16×INT4-quantized functional engine; always built.
//! * `PjrtBackend` (feature `pjrt`, in [`super::model`]) — AOT HLO
//!   artifacts through a PJRT client; batch-1 executables, so it keeps
//!   the default stepping `decode_batch`.
//! * [`SimBackend`] — wraps [`sim::engine::Simulator`]: latency-model
//!   serving as a *real* backend. Tokens are deterministic pseudo-logits
//!   (seeded), so the full serving stack — scheduler, sampler, streaming
//!   protocol, cancellation — runs end-to-end with zero functional
//!   compute, at any architecture size (GLM-6B included).
//! * [`BridgeBackend`](crate::bridge::client::BridgeBackend) — the
//!   trait over a wire: every call becomes a command-stream frame to a
//!   device daemon (`edgellm device-serve`) hosting any other backend.
//!   The remote-capability hooks below ([`Backend::end_session`],
//!   [`Backend::is_remote`], [`Backend::transfer_meter`]) exist for it.
//! * Mock backends in `rust/tests/backend_trait.rs` — the trait is the
//!   scheduler's test seam: a backend needs no weights, no model, not
//!   even a KV cache.
//!
//! [`sim::engine::Simulator`]: crate::sim::engine::Simulator
//!
//! # Example: implementing a `Backend`
//!
//! The trait's required surface is small — five methods. A minimal
//! stateless backend (no KV tensors, sessions track position only)
//! looks like this:
//!
//! ```
//! use anyhow::{bail, Result};
//! use edgellm::runtime::backend::Backend;
//! use edgellm::runtime::model::{ModelInfo, Session};
//!
//! struct Echo {
//!     info: ModelInfo,
//!     buckets: Vec<usize>,
//! }
//!
//! impl Backend for Echo {
//!     fn info(&self) -> &ModelInfo { &self.info }
//!     fn prefill_buckets(&self) -> &[usize] { &self.buckets }
//!     fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
//!         let Some(&last) = prompt.last() else { bail!("empty prompt") };
//!         let mut s = Session::new([0, 0, 0, 0]);
//!         s.pos = prompt.len();
//!         Ok((vec![last as f32; self.info.vocab], s))
//!     }
//!     fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
//!         session.pos += 1;
//!         Ok(vec![token as f32; self.info.vocab])
//!     }
//! }
//!
//! let be = Echo {
//!     info: ModelInfo {
//!         name: "echo".into(),
//!         vocab: 4, d_model: 1, n_layers: 1, n_heads: 1, n_kv_heads: 1,
//!         d_ffn: 1, max_tokens: 16, head_dim: 1, n_params: 0,
//!         cache_shape: [1, 16, 0, 0],
//!     },
//!     buckets: vec![16],
//! };
//! let (logits, session) = be.prefill(&[1, 2, 3]).unwrap();
//! assert_eq!(session.pos, 3);
//! assert_eq!(logits, vec![3.0; 4]);
//! // defaults: no batched sharing, no prefix cache, not remote
//! assert!(!be.supports_batched_decode());
//! assert_eq!(be.shared_prefix_len(&[1, 2, 3]), 0);
//! ```

#![deny(missing_docs)]

use std::cell::Cell;

use anyhow::{bail, Result};

use super::model::{ModelInfo, Session};
use crate::models::{LlmArch, SparseStrategy};

/// Re-exported so backend implementations and the serving layer can
/// name the arena accounting type from one place.
pub use super::kv::MemoryStats;
use crate::sim::engine::Simulator;
use crate::sim::Memory;
use crate::util::rng::Rng;

/// The reference backend is `RefLlm` itself; re-exported under the name
/// the serving layer uses for it.
pub use super::reference::RefLlm as ReferenceBackend;

/// Cumulative host↔device transport counters reported by remote
/// backends — the transport analogue of the paper's HBM-bandwidth
/// utilization metric. `tx_bytes` is host→device (commands, tokens),
/// `rx_bytes` device→host (logits rows), `calls` the number of
/// metered backend entry points served (handshake, prefill, decode,
/// batched round, session close).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferMeter {
    /// cumulative host→device bytes (commands, prompt/decode tokens)
    pub tx_bytes: u64,
    /// cumulative device→host bytes (logits rows, stats)
    pub rx_bytes: u64,
    /// metered backend entry points served
    pub calls: u64,
    /// successful reconnect cycles after a broken device connection
    /// (each one re-opened and re-prefilled every live session)
    pub reconnects: u64,
}

/// An LLM execution backend the continuous-batching scheduler can drive.
///
/// Object-safe by construction (`Box<dyn Backend>` is the type
/// [`LlmRuntime`](super::model::LlmRuntime) wraps) and `Send` so an
/// engine owning one can live behind the server's `Mutex`. Sessions are
/// host-side state minted by `prefill`; a backend that keeps no KV state
/// (latency models, mocks) just tracks `Session::pos`.
///
/// The generic entry-point validation (empty/oversized prompts, arity,
/// KV budget) lives in `LlmRuntime`, so implementations may assume:
///
/// * `prefill`: `1 <= prompt.len() <= info().max_tokens`;
/// * `decode` / `decode_batch`: every session has `pos < max_tokens`,
///   and `sessions.len() == tokens.len()`.
pub trait Backend: Send {
    /// Architecture of the loaded model.
    fn info(&self) -> &ModelInfo;

    /// Prefill bucket lengths, ascending; the last bucket bounds the
    /// prompt length the scheduler will admit.
    fn prefill_buckets(&self) -> &[usize];

    /// Run prefill over `prompt`; returns the logits of the last prompt
    /// token plus a fresh session positioned after the prompt.
    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)>;

    /// One decode step: feed `token`, advance the session, return the
    /// next-token logits.
    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>>;

    /// One batched decode round: feed `tokens[i]` to `sessions[i]` and
    /// return each session's next-token logits.
    ///
    /// The default implementation steps the sessions one after another —
    /// correct for any backend, and the right shape for batch-1
    /// executors (PJRT artifacts). Backends that can amortize the weight
    /// stream across the batch (the reference engine) override this and
    /// report it via [`Backend::supports_batched_decode`].
    ///
    /// **Paged-KV contract:** a backend that can fail a round with
    /// [`kv::KvExhausted`](super::kv::KvExhausted) must perform all KV
    /// growth *before* advancing any session (all-or-nothing), so a
    /// failed round leaves every session unadvanced. The scheduler's
    /// preemption path relies on this to retry the identical round
    /// after evicting a victim; a paging backend that kept this default
    /// sequential implementation would advance early sessions before a
    /// later one fails, and the retry would double-feed them. The
    /// reference engine reserves every session's blocks up front for
    /// exactly this reason.
    fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        sessions
            .iter_mut()
            .zip(tokens.iter())
            .map(|(s, &t)| self.decode(s, t))
            .collect()
    }

    /// True when `decode_batch` executes a genuinely shared round
    /// (weights streamed once per round, not once per session).
    fn supports_batched_decode(&self) -> bool {
        false
    }

    /// Resident quantized-FFN weight bytes — the stream a batched round
    /// amortizes — when the backend exposes them (reference engine).
    fn ffn_weight_bytes(&self) -> Option<usize> {
        None
    }

    /// Human-readable kernel execution tier serving the hot path
    /// (`"scalar"`, `"simd"`, `"simd-parallel(8)"`), when the backend
    /// dispatches through the tiered CPU kernels
    /// (`runtime::kernels::{simd, par}`). `None` (the default) for
    /// backends without a CPU compute tier — latency models, remote
    /// bridges, mocks — so the stats line and benches omit the field
    /// rather than report a meaningless one.
    fn kernel_tier(&self) -> Option<String> {
        None
    }

    /// The scheduler is done with `session` (retired, cancelled, or
    /// aborted). In-process backends keep session state on the host and
    /// free it on drop — the default no-op. Remote backends override
    /// this to release device-side state eagerly (the bridge sends
    /// `CloseSession`). Best-effort by contract: it must never fail the
    /// caller, and a backend must tolerate the call being skipped (the
    /// engine being dropped mid-flight) by also reclaiming on
    /// disconnect.
    fn end_session(&self, _session: &mut Session) {}

    /// True when calls cross a transport to a device daemon — lets the
    /// serving layer surface transport stats and pick error wording.
    fn is_remote(&self) -> bool {
        false
    }

    /// Cumulative transport counters, when the backend is remote.
    fn transfer_meter(&self) -> Option<TransferMeter> {
        None
    }

    /// KV-arena accounting (total/free/reserved bytes plus block
    /// counters), when the backend pages its session memory through a
    /// [`KvArena`](super::kv::KvArena). The default `None` keeps
    /// stateless backends (latency models, mocks) and out-of-crate
    /// implementations compiling unchanged — the scheduler then falls
    /// back to slot-counting admission. The reference backend reports
    /// its arena; the bridge forwards the *device's* arena stats (one
    /// round trip per query).
    fn memory(&self) -> Option<MemoryStats> {
        None
    }

    /// Length (in tokens) of the longest prompt prefix the backend
    /// already holds KV state for — the admission gate's query, so the
    /// scheduler can account shared blocks once instead of per-session.
    /// Advisory: the answer may be stale by the time `prefill_from`
    /// runs (the cache entry may have been evicted, or a better one
    /// registered). The default `0` is always safe — it means "no
    /// resident prefix", and the scheduler then budgets the full
    /// prompt. Backends with a prefix-indexed arena (the reference
    /// engine) override it.
    fn shared_prefix_len(&self, _prompt: &[i32]) -> usize {
        0
    }

    /// Prefill knowing that (per [`Backend::shared_prefix_len`]) the
    /// first `shared_len` tokens of `prompt` may already be resident:
    /// an implementation adopts the shared blocks and computes only the
    /// suffix from the divergence point. The hint is *advisory* — the
    /// result must be exactly what [`Backend::prefill`] would return
    /// (the reference engine re-derives sharing from its live index and
    /// guarantees bit-identical logits). The default ignores the hint
    /// and runs a full prefill, which is always correct.
    fn prefill_from(&self, prompt: &[i32], _shared_len: usize) -> Result<(Vec<f32>, Session)> {
        self.prefill(prompt)
    }

    /// Hand the backend the serving side's [`Obs`](crate::obs::Obs)
    /// registry so its internals can record into the shared histograms
    /// and span ring (the bridge client records per-opcode frame RTTs
    /// and reconnect spans there). The default no-op keeps in-process
    /// and out-of-crate backends compiling unchanged; the engine calls
    /// this once at construction, before any request is served.
    fn attach_obs(&self, _obs: &std::sync::Arc<crate::obs::Obs>) {}

    /// KV-arena pressure counters (allocation stalls, copy-on-write
    /// copies) for the stats line — gauges the wire-anchored
    /// [`MemoryStats`] deliberately does not carry. `None` (the
    /// default) for backends without a paged arena.
    fn kv_pressure(&self) -> Option<crate::obs::KvPressure> {
        None
    }

    /// The *device's* observability summary (frame service-time
    /// percentiles plus its arena pressure counters), when the backend
    /// fronts a remote daemon — fetched from the `InfoResp` obs tail,
    /// one metered round trip per call. `None` (the default) for
    /// in-process backends: their figures are readable directly.
    fn device_obs(&self) -> Option<crate::obs::ObsStats> {
        None
    }
}

// The trait must stay object-safe: the scheduler only ever sees it
// through `Box<dyn Backend>`.
const _: fn(&dyn Backend) -> &ModelInfo = |b| b.info();

/// Latency-model-only serving backend: the VCU128 [`Simulator`] as a
/// first-class `Backend`.
///
/// Before the trait existed, "serve from the latency model" meant the
/// side channel threaded through `Engine` (every engine owns a
/// `Simulator` for VCU128 accounting) — there was no way to *run the
/// serving stack itself* on a simulated datapath. `SimBackend` closes
/// that: prefill/decode return deterministic pseudo-logits drawn from a
/// seeded RNG keyed on `(token, position)`, sessions carry no KV tensors
/// (only `pos`), and the wrapped `Simulator` meters every call — each
/// prefill/decode charges its VCU128 cost to [`SimBackend::sim_time_us`],
/// so after serving a workload the backend reports what that exact call
/// sequence costs on the accelerator. That makes scheduler, streaming
/// and protocol behavior testable at GLM-6B scale in microseconds.
///
/// The emitted byte stream is noise by design — this backend models
/// *time*, not language; pair it with an `EngineConfig` whose `sim_arch`
/// matches `arch` so the engine's round-level VCU128 accounting
/// describes the same machine. `supports_batched_decode` stays false:
/// there is no weight stream to share, rounds are stepped.
pub struct SimBackend {
    info: ModelInfo,
    buckets: Vec<usize>,
    sim: Simulator,
    /// accumulated simulated accelerator time of every prefill/decode
    /// served so far, µs (Cell: metering must not require `&mut` on an
    /// object behind `Box<dyn Backend>`)
    sim_us: Cell<f64>,
    seed: u64,
}

impl SimBackend {
    /// Build a latency-model backend for `arch` under sparse strategy
    /// `strat`, with the device memory system `mem` and a KV budget of
    /// `max_tokens` positions per session. `seed` keys the
    /// pseudo-logits stream (two backends with the same seed emit
    /// identical tokens for identical calls).
    pub fn new(
        arch: &LlmArch,
        strat: &SparseStrategy,
        mem: Memory,
        max_tokens: usize,
        seed: u64,
    ) -> Self {
        assert!(max_tokens >= 1, "max_tokens must be at least 1");
        let sim = Simulator::new(arch, strat, mem);
        // power-of-two prefill buckets, mirroring the other backends
        let mut buckets = Vec::new();
        let mut b = 8usize;
        while b < max_tokens {
            buckets.push(b);
            b *= 2;
        }
        buckets.push(max_tokens);
        let info = ModelInfo {
            name: format!("sim-{}", arch.name),
            // byte vocabulary, matching coordinator::tokenizer — the
            // serving stack above is identical for every backend
            vocab: 256,
            d_model: arch.d_model,
            n_layers: arch.n_layers,
            n_heads: arch.n_heads,
            n_kv_heads: arch.n_kv_heads,
            d_ffn: arch.d_ffn,
            max_tokens,
            head_dim: arch.head_dim,
            n_params: arch.n_params(),
            // no functional KV state: sessions track position only
            cache_shape: [arch.n_layers, max_tokens, 0, 0],
        };
        SimBackend {
            info,
            buckets,
            sim,
            sim_us: Cell::new(0.0),
            seed,
        }
    }

    /// The latency model this backend serves from.
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }

    /// Simulated VCU128 µs consumed by every prefill/decode served so
    /// far — the backend-side latency meter.
    pub fn sim_time_us(&self) -> f64 {
        self.sim_us.get()
    }

    /// Deterministic pseudo-logits for (fed token, its position).
    /// History beyond the position is deliberately ignored — this
    /// backend models time, not language.
    fn logits_at(&self, token: i32, pos: usize) -> Vec<f32> {
        let t = token.rem_euclid(self.info.vocab as i32) as u64;
        let mut rng = Rng::new(self.seed ^ (t << 32) ^ pos as u64);
        (0..self.info.vocab).map(|_| rng.normal() as f32).collect()
    }
}

impl Backend for SimBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let Some(&last) = prompt.last() else {
            bail!("empty prompt");
        };
        if prompt.len() > self.info.max_tokens {
            bail!(
                "prompt of {} exceeds max_tokens {}",
                prompt.len(),
                self.info.max_tokens
            );
        }
        let mut session = Session::new([0, 0, 0, 0]);
        session.pos = prompt.len();
        let cost = self.sim.prefill(prompt.len()).breakdown.total_us();
        self.sim_us.set(self.sim_us.get() + cost);
        Ok((self.logits_at(last, prompt.len() - 1), session))
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        if session.pos >= self.info.max_tokens {
            bail!("KV cache full (max_tokens={})", self.info.max_tokens);
        }
        let cost = self.sim.decode_step(session.pos).breakdown.total_us();
        self.sim_us.set(self.sim_us.get() + cost);
        let logits = self.logits_at(token, session.pos);
        session.pos += 1;
        Ok(logits)
    }

    // supports_batched_decode stays at the default `false`: a latency
    // model has no weight stream to share, so a round is honestly a
    // stepped sequence of per-session charges.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DENSE, GLM_6B, TINY};

    fn sim_tiny() -> SimBackend {
        SimBackend::new(&TINY, &DENSE, Memory::Hbm, 64, 0xC0FFEE)
    }

    #[test]
    fn sim_backend_is_deterministic() {
        let a = sim_tiny();
        let b = sim_tiny();
        let (la, mut sa) = a.prefill(&[1, 2, 3]).unwrap();
        let (lb, mut sb) = b.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(la, lb);
        assert_eq!(a.decode(&mut sa, 7).unwrap(), b.decode(&mut sb, 7).unwrap());
        assert_eq!(sa.pos, 4);
    }

    #[test]
    fn sim_backend_meters_simulated_time() {
        let s = sim_tiny();
        assert_eq!(s.sim_time_us(), 0.0);
        let (_l, mut sess) = s.prefill(&[1, 2, 3]).unwrap();
        let after_prefill = s.sim_time_us();
        assert!(after_prefill > 0.0, "prefill must charge simulated time");
        s.decode(&mut sess, 4).unwrap();
        let after_decode = s.sim_time_us();
        assert!(after_decode > after_prefill, "decode must charge on top");
        // the meter matches the wrapped Simulator's own arithmetic
        let expect = s.simulator().prefill(3).breakdown.total_us()
            + s.simulator().decode_step(3).breakdown.total_us();
        assert!((after_decode - expect).abs() < 1e-9, "{after_decode} vs {expect}");
    }

    #[test]
    fn sim_backend_logits_depend_on_position_and_token() {
        let s = sim_tiny();
        assert_ne!(s.logits_at(1, 0), s.logits_at(1, 1));
        assert_ne!(s.logits_at(1, 0), s.logits_at(2, 0));
        assert!(s.logits_at(5, 3).iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sim_backend_respects_kv_budget() {
        let s = SimBackend::new(&TINY, &DENSE, Memory::Hbm, 4, 1);
        let (_l, mut sess) = s.prefill(&[1, 2]).unwrap();
        s.decode(&mut sess, 3).unwrap();
        s.decode(&mut sess, 4).unwrap();
        assert!(s.decode(&mut sess, 5).is_err(), "cache-full must error");
        assert!(s.prefill(&[0; 5]).is_err(), "oversized prompt must error");
    }

    #[test]
    fn sim_backend_scales_to_glm() {
        // the whole point: serving-stack shapes at 6B scale, instantly
        let s = SimBackend::new(&GLM_6B, &DENSE, Memory::Hbm, 2048, 2);
        assert_eq!(s.info().d_model, 4096);
        assert!(s.info().n_params > 5_000_000_000);
        let (l, sess) = s.prefill(&[10; 128]).unwrap();
        assert_eq!(l.len(), 256);
        assert_eq!(sess.pos, 128);
        assert_eq!(*s.prefill_buckets().last().unwrap(), 2048);
    }

    #[test]
    fn default_decode_batch_steps_sessions() {
        let s = sim_tiny();
        let (_l, mut a) = s.prefill(&[1]).unwrap();
        let (_l, mut b) = s.prefill(&[2, 3]).unwrap();
        let (_l, mut a2) = s.prefill(&[1]).unwrap();
        let (_l, mut b2) = s.prefill(&[2, 3]).unwrap();
        let la = s.decode(&mut a, 9).unwrap();
        let lb = s.decode(&mut b, 8).unwrap();
        let mut batch = [&mut a2, &mut b2];
        let out = Backend::decode_batch(&s, &mut batch, &[9, 8]).unwrap();
        assert_eq!(out[0], la);
        assert_eq!(out[1], lb);
    }
}
