//! Loader for the `*.weights.bin` tensor container emitted by
//! `python/compile/aot.py` (format: magic "ELLMWT01", u32 count, then per
//! tensor: u32 name_len, name, u8 dtype, u8 ndim, u32 dims…, u64 nbytes,
//! raw little-endian data).

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"ELLMWT01";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

/// One tensor from the container: raw bytes plus shape/dtype metadata.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn n_elements(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor {} is {:?}, not f32", self.name, self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<&[u8]> {
        if self.dtype != DType::I8 {
            bail!("tensor {} is {:?}, not i8", self.name, self.dtype);
        }
        Ok(&self.data)
    }
}

/// Read all tensors from a weights container file.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open weights {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse(&buf).with_context(|| format!("parse weights {}", path.display()))
}

fn parse(buf: &[u8]) -> Result<Vec<Tensor>> {
    let mut at = 0usize;
    let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
        if *at + n > buf.len() {
            bail!("truncated container at byte {at}");
        }
        let s = &buf[*at..*at + n];
        *at += n;
        Ok(s)
    };
    if take(&mut at, 8)? != MAGIC {
        bail!("bad magic");
    }
    let count = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len =
            u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut at, name_len)?.to_vec())
            .context("tensor name not utf-8")?;
        let dtype = DType::from_code(take(&mut at, 1)?[0])?;
        let ndim = take(&mut at, 1)?[0] as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize);
        }
        let nbytes =
            u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap()) as usize;
        let expect = dims.iter().product::<usize>() * dtype.size();
        if nbytes != expect {
            bail!("tensor {name}: nbytes {nbytes} != shape-implied {expect}");
        }
        let data = take(&mut at, nbytes)?.to_vec();
        out.push(Tensor { name, dtype, dims, data });
    }
    if at != buf.len() {
        bail!("trailing bytes after last tensor");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_container() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": f32[2]
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'a');
        b.push(0); // f32
        b.push(1); // ndim
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&8u64.to_le_bytes());
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.extend_from_slice(&(-2.0f32).to_le_bytes());
        // tensor "q": i8[2,2]
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'q');
        b.push(1); // i8
        b.push(2); // ndim
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&4u64.to_le_bytes());
        b.extend_from_slice(&[1u8, 255, 0, 7]);
        b
    }

    #[test]
    fn parse_sample() {
        let ts = parse(&sample_container()).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].as_f32().unwrap(), vec![1.5, -2.0]);
        assert_eq!(ts[1].dims, vec![2, 2]);
        assert_eq!(ts[1].as_i8().unwrap(), &[1, 255, 0, 7]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_container();
        b[0] = b'X';
        assert!(parse(&b).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let b = sample_container();
        assert!(parse(&b[..b.len() - 1]).is_err());
        assert!(parse(&b[..20]).is_err());
    }

    #[test]
    fn rejects_trailing() {
        let mut b = sample_container();
        b.push(0);
        assert!(parse(&b).is_err());
    }
}
