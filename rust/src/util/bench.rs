//! Tiny benchmark + report-table harness (offline build: no criterion).
//!
//! Every `benches/*.rs` binary regenerates one paper table/figure: it runs
//! the workload, prints the paper's reported rows next to ours, and (for
//! hot-path benches) measures wall time with warmup + repeated samples.

use std::time::Instant;

/// Measure `f`'s median wall time over `samples` runs after `warmup` runs.
/// Returns (median_secs, min_secs, mean_secs).
pub fn time_it<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Timing { median, min, mean }
}

#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median: f64,
    pub min: f64,
    pub mean: f64,
}

impl Timing {
    pub fn fmt_human(&self) -> String {
        fmt_secs(self.median)
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Fixed-width ASCII table printer for paper-vs-measured reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |sep: char| {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&sep.to_string().repeat(wi + 2));
                s.push('+');
            }
            s
        };
        println!("{}", line('-'));
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!(" {:<width$} ", h, width = w[i]))
            .collect();
        println!("|{}|", hdr.join("|"));
        println!("{}", line('='));
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = w[i]))
                .collect();
            println!("|{}|", cells.join("|"));
        }
        println!("{}", line('-'));
    }
}

/// Format a ratio like "1.91x".
pub fn ratio(ours: f64, baseline: f64) -> String {
    format!("{:.2}x", ours / baseline)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.min >= 0.0 && t.median >= t.min);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with(" s"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
