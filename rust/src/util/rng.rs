//! Deterministic PRNG (xoshiro256**) — the offline build has no `rand`.
//!
//! Used by the Table-I error harness, property tests, and workload
//! generators. Seeded runs are fully reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for tests).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection sampling for exactness
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from 0..n (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn choose_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.choose_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }
}
