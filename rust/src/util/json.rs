//! Minimal JSON parser/serializer (offline build: no serde available).
//!
//! Supports the full JSON grammar minus some float edge cases; used for
//! artifact manifests, experiment logs and the serving protocol.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), at: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.at != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.at, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.at < self.b.len() && self.b[self.at].is_ascii_whitespace() {
            self.at += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.at).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.at += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.at..].starts_with(s.as_bytes()) {
            self.at += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.at += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.at += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.at += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.at + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.at..self.at + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.at += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) => {
                    // copy a run of plain bytes (utf-8 passes through)
                    let start = self.at;
                    while self.at < self.b.len()
                        && self.b[self.at] != b'"'
                        && self.b[self.at] != b'\\'
                    {
                        self.at += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.at])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
            })
            .unwrap_or(false)
        {
            self.at += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.at]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b']') => {
                    self.at += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b'}') => {
                    self.at += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"tiny","dims":[1,2,3],"f":1.25,"s":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".into())
        );
    }
}
