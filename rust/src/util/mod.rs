//! Shared utilities implemented in-tree (offline build: the vendored crate
//! set has no rand/serde/criterion/clap).

pub mod bench;
pub mod json;
pub mod rng;

/// Unblock a thread parked in `TcpListener::accept` by making one
/// throwaway connection to its address. An unspecified bind address
/// (0.0.0.0 / ::) is not connectable on every platform, so it is
/// rewritten to the matching loopback first. Returns false when the
/// poke could not connect — the acceptor may still be parked and the
/// caller should not join it unconditionally.
pub fn poke_acceptor(addr: std::net::SocketAddr) -> bool {
    use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, TcpStream};
    let mut target = addr;
    if target.ip().is_unspecified() {
        target.set_ip(match target.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    TcpStream::connect(target).is_ok()
}

/// Acquire a mutex, recovering from poison. A daemon thread that
/// panicked while holding the lock poisons it; every *other* session
/// thread would then panic too on `.lock().unwrap()`, taking the whole
/// device down. The guarded state here (session tables, engine queues)
/// stays structurally valid across a panicking operation, so recovery
/// is sound — the poisoned marker is dropped and the data used as-is.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`lock_unpoisoned`]'s counterpart for condvar waits: re-acquires the
/// lock on wakeup even if a sibling thread poisoned it mid-wait.
pub fn wait_unpoisoned<'a, T>(
    cv: &std::sync::Condvar,
    guard: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Minimal CLI flag parser: `--key value` and `--flag` forms.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    pub fn parse() -> Self {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_positional() {
        let a = Args::from_iter(
            ["serve", "--model", "tiny", "--verbose", "--port", "7070"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_usize("port", 0), 7070);
        assert!(a.has("verbose"));
        assert!(!a.has("missing"));
    }
}
