//! Comparator operating points for Table V: the A100 GPU and FlightLLM.
//!
//! We do not have either platform; per DESIGN.md §3 these are analytic
//! models built from each system's published operating point — exactly
//! the information Table V compares on: bandwidth utilization, decode
//! throughput, power, energy efficiency.

use crate::models::LlmArch;

#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub bandwidth_utilization: f64,
    pub tokens_per_s: f64,
    pub power_w: f64,
}

impl Platform {
    pub fn tokens_per_joule(&self) -> f64 {
        self.tokens_per_s / self.power_w
    }
}

/// NVIDIA A100-SXM4-80G at batch size 1 (edge serving): decode is
/// bandwidth-bound and the GPU sustains ~30% of its 2 TB/s HBM on
/// single-stream GEMV (the paper's own premise for Table V).
pub fn a100_batch1(arch: &LlmArch) -> Platform {
    let hbm_bytes = 2.0e12; // A100-80G HBM2e
    let utilization = 0.30;
    // INT4 weights + FP16 activations: the GPU runs FP16 (no INT4 GEMV
    // path in cuBLAS) — it streams FP16 weights, 2 bytes/param.
    let bytes_per_token = arch.n_params() as f64 * 2.0;
    let tokens_per_s = hbm_bytes * utilization / bytes_per_token;
    Platform {
        name: "A100 GPU",
        bandwidth_utilization: utilization,
        tokens_per_s,
        power_w: 220.0,
    }
}

/// FlightLLM on U280 (published: 65.9% bandwidth utilization, 45 W,
/// ~55 token/s on Llama2-7B).
pub const FLIGHTLLM_U280: Platform = Platform {
    name: "FlightLLM U280",
    bandwidth_utilization: 0.659,
    tokens_per_s: 55.0,
    power_w: 45.0,
};

/// FlightLLM on VHK158 (published: 64.8%, 155 W, 0.6 token/J).
pub const FLIGHTLLM_VHK158: Platform = Platform {
    name: "FlightLLM VHK158",
    bandwidth_utilization: 0.648,
    tokens_per_s: 93.0, // 0.6 token/J × 155 W
    power_w: 155.0,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::GLM_6B;

    #[test]
    fn a100_near_paper_operating_point() {
        // Table V: ~45 token/s, 0.2 token/J on a ~6-7B model.
        let p = a100_batch1(&GLM_6B);
        assert!(p.tokens_per_s > 35.0 && p.tokens_per_s < 60.0, "{}", p.tokens_per_s);
        let tpj = p.tokens_per_joule();
        assert!((tpj - 0.2).abs() < 0.05, "A100 {tpj} token/J");
    }

    #[test]
    fn flightllm_efficiency_matches_published() {
        assert!((FLIGHTLLM_U280.tokens_per_joule() - 1.22).abs() < 0.01);
        assert!((FLIGHTLLM_VHK158.tokens_per_joule() - 0.6).abs() < 0.01);
    }
}
