//! Block-level INT4 quantization and log-scale structured sparsity
//! (paper §III.C) — the weight-compression substrate.
//!
//! * 128 adjacent input channels share one FP16 scale (symmetric INT4).
//! * Log-scale structured sparsity: within every group of 8 adjacent
//!   input channels (per output column), only k ∈ {8, 4, 2, 1} weights
//!   are kept (dense / 50% / 75% / 87.5%) — the kept fraction is a power
//!   of two, which is what lets the time-unrolled PE stay at 100%
//!   utilization for any sparsity level.
//!
//! This module must agree bit-for-bit with `python/compile/model.py`'s
//! `quantize`/`prune_log_scale` (tested via the shared recipe).

pub mod nm;
pub mod sparse;

use crate::fp::minifloat::{f16_decode, f16_encode};

/// Input channels per quantization block (shared scale).
pub const QBLOCK: usize = 128;
/// Structured-sparsity group: the "eight adjacent data" unit.
pub const SGROUP: usize = 8;

/// A column-major quantized matrix: values in [-8, 7], one FP16 scale per
/// (QBLOCK input channels × output channel).
#[derive(Debug, Clone)]
pub struct QuantMatrix {
    /// rows = input channels (k), cols = output channels (n)
    pub k: usize,
    pub n: usize,
    /// row-major `k × n` INT4 values stored as i8
    pub q: Vec<i8>,
    /// row-major `(k/QBLOCK) × n` FP16 scale bit patterns
    pub scales: Vec<u16>,
}

impl QuantMatrix {
    pub fn scale_rows(&self) -> usize {
        self.k / QBLOCK
    }

    /// Dequantized value at (row, col) as f64.
    pub fn dequant(&self, row: usize, col: usize) -> f64 {
        let s = self.scales[(row / QBLOCK) * self.n + col];
        self.q[row * self.n + col] as f64 * f16_decode(s)
    }

    /// Count of non-zero INT4 values.
    pub fn nnz(&self) -> usize {
        self.q.iter().filter(|&&v| v != 0).count()
    }
}

/// Symmetric INT4 block quantization with FP16 scales — same recipe as
/// `python/compile/model.py::quantize` (amax/7, scale rounded through
/// FP16, zero-scale columns forced to 1.0).
pub fn quantize(w: &[f32], k: usize, n: usize) -> QuantMatrix {
    assert_eq!(w.len(), k * n);
    assert!(k % QBLOCK == 0, "k={k} not a multiple of {QBLOCK}");
    let blocks = k / QBLOCK;
    let mut q = vec![0i8; k * n];
    let mut scales = vec![0u16; blocks * n];
    // Row-major sweeps (the matrix is row-major): first pass folds |max|
    // per (block, col) across rows, second pass quantizes — §Perf: ~6×
    // over the column-major formulation (sequential instead of strided).
    let mut colbuf = vec![0f32; n]; // per-column amax, then scale
    for b in 0..blocks {
        colbuf.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..QBLOCK {
            let row = &w[(b * QBLOCK + r) * n..(b * QBLOCK + r + 1) * n];
            for (a, &x) in colbuf.iter_mut().zip(row) {
                *a = a.max(x.abs());
            }
        }
        let srow = &mut scales[b * n..(b + 1) * n];
        for (a, s_out) in colbuf.iter_mut().zip(srow.iter_mut()) {
            let mut s = f16_decode(f16_encode((*a / 7.0) as f64)) as f32;
            if s == 0.0 {
                s = 1.0;
            }
            *s_out = f16_encode(s as f64);
            *a = s; // second pass divides by the FP16-rounded scale
        }
        for r in 0..QBLOCK {
            let row = b * QBLOCK + r;
            let src = &w[row * n..(row + 1) * n];
            let dst = &mut q[row * n..(row + 1) * n];
            for ((d, &x), &s) in dst.iter_mut().zip(src).zip(colbuf.iter()) {
                *d = (x / s).round_ties_even().clamp(-8.0, 7.0) as i8;
            }
        }
    }
    QuantMatrix { k, n, q, scales }
}

/// Smallest multiple of [`QBLOCK`] that holds `k` input channels.
pub fn pad_to_qblock(k: usize) -> usize {
    k.div_ceil(QBLOCK) * QBLOCK
}

/// Zero-pad a row-major `k × n` matrix up to [`pad_to_qblock`]`(k)`
/// input-channel rows — the single padding recipe shared by the dense
/// and sparse quantized paths.
pub fn pad_rows(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let mut padded = vec![0f32; pad_to_qblock(k) * n];
    padded[..k * n].copy_from_slice(w);
    padded
}

/// [`quantize`] for a matrix whose input-channel count is not a QBLOCK
/// multiple: rows are zero-padded up to [`pad_to_qblock`]`(k)` first.
/// Padded rows quantize to 0 and contribute nothing as long as the
/// activation vector is zero-padded the same way (the runtime's scratch
/// buffers guarantee that). The quantization recipe itself is unchanged —
/// zero rows only ever lower a block's amax, never raise it.
pub fn quantize_padded(w: &[f32], k: usize, n: usize) -> QuantMatrix {
    let k_pad = pad_to_qblock(k);
    if k_pad == k {
        assert_eq!(w.len(), k * n);
        return quantize(w, k, n);
    }
    quantize(&pad_rows(w, k, n), k_pad, n)
}

/// Dequantize back to f32 (row-major k × n).
pub fn dequantize(m: &QuantMatrix) -> Vec<f32> {
    let mut out = vec![0f32; m.k * m.n];
    for r in 0..m.k {
        for c in 0..m.n {
            out[r * m.n + c] = m.dequant(r, c) as f32;
        }
    }
    out
}

/// Log-scale structured magnitude pruning: keep the `keep_of_8` largest-
/// magnitude weights in every group of 8 adjacent input channels (per
/// column). keep_of_8 ∈ {8, 4, 2, 1} ⇔ sparsity {0, 50, 75, 87.5}%.
/// Same recipe as `python/compile/model.py::prune_log_scale`.
pub fn prune_log_scale(w: &mut [f32], k: usize, n: usize, keep_of_8: usize) {
    assert_eq!(w.len(), k * n);
    assert!(k % SGROUP == 0);
    assert!(
        matches!(keep_of_8, 1 | 2 | 4 | 8),
        "keep_of_8 must be a power of two ≤ 8 (log-scale), got {keep_of_8}"
    );
    if keep_of_8 >= SGROUP {
        return;
    }
    // Alloc-free selection on stack arrays, sweeping each 8-row band once
    // (§Perf: removes the per-(group,column) Vec + comparator sort).
    for g in 0..k / SGROUP {
        let base = g * SGROUP * n;
        for c in 0..n {
            // gather |magnitudes| of the 8-group for this column
            let mut mag = [0f32; SGROUP];
            for (i, m) in mag.iter_mut().enumerate() {
                *m = w[base + i * n + c].abs();
            }
            // zero the (8 - keep) smallest: repeatedly drop the min
            for _ in 0..SGROUP - keep_of_8 {
                let mut min_i = 0;
                for i in 1..SGROUP {
                    // <= : ties drop the later index, keeping the earlier
                    // one, matching numpy's stable argsort in model.py
                    if mag[i] <= mag[min_i] {
                        min_i = i;
                    }
                }
                mag[min_i] = f32::INFINITY; // consumed
                w[base + min_i * n + c] = 0.0;
            }
        }
    }
}

/// Sparsity level expressed as kept fraction (log-scale: 1, 1/2, 1/4, 1/8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sparsity {
    Dense,
    /// 50%: 4-of-8 kept
    Half,
    /// 75%: 2-of-8 kept
    Quarter,
    /// 87.5%: 1-of-8 kept
    Eighth,
}

impl Sparsity {
    pub fn keep_of_8(&self) -> usize {
        match self {
            Sparsity::Dense => 8,
            Sparsity::Half => 4,
            Sparsity::Quarter => 2,
            Sparsity::Eighth => 1,
        }
    }

    pub fn kept_fraction(&self) -> f64 {
        self.keep_of_8() as f64 / 8.0
    }

    pub fn percent(&self) -> f64 {
        100.0 * (1.0 - self.kept_fraction())
    }

    pub fn all() -> [Sparsity; 4] {
        [Sparsity::Dense, Sparsity::Half, Sparsity::Quarter, Sparsity::Eighth]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_w(k: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..k * n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        // |w - dq(q(w))| ≤ scale/2 per element (symmetric, 4-bit).
        let (k, n) = (QBLOCK * 2, 16);
        let w = random_w(k, n, 1);
        let m = quantize(&w, k, n);
        let dq = dequantize(&m);
        for b in 0..m.scale_rows() {
            for c in 0..n {
                let s = f16_decode(m.scales[b * n + c]) as f32;
                for r in 0..QBLOCK {
                    let i = (b * QBLOCK + r) * n + c;
                    assert!(
                        (w[i] - dq[i]).abs() <= s * 0.5 + 1e-6,
                        "elem {i}: w={} dq={} s={s}",
                        w[i],
                        dq[i]
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_values_in_int4_range() {
        let (k, n) = (QBLOCK, 8);
        let w = random_w(k, n, 2);
        let m = quantize(&w, k, n);
        assert!(m.q.iter().all(|&v| (-8..=7).contains(&v)));
    }

    #[test]
    fn quantize_preserves_block_max_sign() {
        // The max-|magnitude| element in each block quantizes to ±7 or ±8.
        let (k, n) = (QBLOCK, 4);
        let w = random_w(k, n, 3);
        let m = quantize(&w, k, n);
        for c in 0..n {
            let (mut best_r, mut best) = (0, 0.0f32);
            for r in 0..k {
                if w[r * n + c].abs() > best {
                    best = w[r * n + c].abs();
                    best_r = r;
                }
            }
            let q = m.q[best_r * n + c];
            assert!(q.abs() >= 6, "block max quantized to {q}");
            assert_eq!(q.signum() as f32, w[best_r * n + c].signum());
        }
    }

    #[test]
    fn quantize_padded_matches_unpadded_prefix() {
        // k = 32 pads to 128; the 32 real rows must quantize exactly as
        // they would inside a hand-padded matrix, and padded rows are 0.
        let (k, n) = (32usize, 8);
        let w = random_w(k, n, 11);
        let m = quantize_padded(&w, k, n);
        assert_eq!(m.k, QBLOCK);
        let mut hand = vec![0f32; QBLOCK * n];
        hand[..k * n].copy_from_slice(&w);
        let hm = quantize(&hand, QBLOCK, n);
        assert_eq!(m.q, hm.q);
        assert_eq!(m.scales, hm.scales);
        for r in k..QBLOCK {
            for c in 0..n {
                assert_eq!(m.q[r * n + c], 0);
            }
        }
    }

    #[test]
    fn quantize_padded_noop_when_aligned() {
        let (k, n) = (QBLOCK, 4);
        let w = random_w(k, n, 12);
        let a = quantize_padded(&w, k, n);
        let b = quantize(&w, k, n);
        assert_eq!(a.q, b.q);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn prune_keeps_exactly_k_per_group() {
        let (k, n) = (QBLOCK, 8);
        for keep in [1usize, 2, 4] {
            let mut w = random_w(k, n, 4);
            prune_log_scale(&mut w, k, n, keep);
            for g in 0..k / SGROUP {
                for c in 0..n {
                    let nz = (0..SGROUP)
                        .filter(|&i| w[(g * SGROUP + i) * n + c] != 0.0)
                        .count();
                    assert!(nz <= keep, "group {g} col {c}: {nz} > {keep}");
                }
            }
        }
    }

    #[test]
    fn prune_keeps_largest_magnitudes() {
        let n = 1;
        let mut w = vec![0.1f32, -3.0, 0.2, 2.0, -0.05, 0.9, -0.4, 0.3];
        prune_log_scale(&mut w, 8, n, 2);
        assert_eq!(w, vec![0.0, -3.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn prune_dense_is_identity() {
        let mut w = random_w(QBLOCK, 4, 5);
        let orig = w.clone();
        prune_log_scale(&mut w, QBLOCK, 4, 8);
        assert_eq!(w, orig);
    }

    #[test]
    #[should_panic]
    fn prune_rejects_non_log_scale() {
        let mut w = random_w(SGROUP, 1, 6);
        prune_log_scale(&mut w, SGROUP, 1, 3);
    }

    #[test]
    fn sparsity_percentages() {
        assert_eq!(Sparsity::Dense.percent(), 0.0);
        assert_eq!(Sparsity::Half.percent(), 50.0);
        assert_eq!(Sparsity::Quarter.percent(), 75.0);
        assert_eq!(Sparsity::Eighth.percent(), 87.5);
    }

    #[test]
    fn pruned_then_quantized_nnz_matches() {
        let (k, n) = (QBLOCK * 2, 8);
        let mut w = random_w(k, n, 7);
        prune_log_scale(&mut w, k, n, 2);
        let m = quantize(&w, k, n);
        // ≤ 25% kept; some kept weights may quantize to 0
        assert!(m.nnz() <= k * n / 4);
        assert!(m.nnz() > k * n / 8, "unexpectedly sparse: {}", m.nnz());
    }
}
