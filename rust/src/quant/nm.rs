//! Generalized N:M structured sparsity — the ablation behind §III.C's
//! claim that EdgeLLM's *larger* sparse blocks (4:8, 8:16, 32:64) beat
//! the GPU's fixed 2:4 at the same sparsity "at the algorithmic level":
//! a magnitude pruner with a bigger selection window discards less
//! signal for the same kept fraction.

use crate::util::rng::Rng;

/// Keep the `keep` largest-|magnitude| weights in every window of `m`
/// adjacent input channels (per output column). `keep/m` is the kept
/// fraction; (2,4) models the A100's 2:4 sparsity, (4,8)/(8,16)/(32,64)
/// the paper's block sizes.
pub fn prune_n_of_m(w: &mut [f32], k: usize, n: usize, keep: usize, m: usize) {
    assert_eq!(w.len(), k * n);
    assert!(k % m == 0, "k={k} not a multiple of m={m}");
    assert!(keep <= m);
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for g in 0..k / m {
        let base = g * m * n;
        for c in 0..n {
            idx.clear();
            idx.extend(0..m);
            idx.sort_by(|&a, &b| {
                let va = w[base + a * n + c].abs();
                let vb = w[base + b * n + c].abs();
                vb.partial_cmp(&va).unwrap()
            });
            for &i in &idx[keep..] {
                w[base + i * n + c] = 0.0;
            }
        }
    }
}

/// Relative reconstruction error ‖W − prune(W)‖₂ / ‖W‖₂ of N:M pruning
/// on Gaussian weights — the quality proxy for the pattern comparison.
pub fn reconstruction_error(keep: usize, m: usize, k: usize, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut p = w.clone();
    prune_n_of_m(&mut p, k, n, keep, m);
    let num: f64 = w
        .iter()
        .zip(&p)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    let den: f64 = w.iter().map(|&a| (a as f64).powi(2)).sum();
    (num / den).sqrt()
}

/// Mask bits per input channel for an N:M pattern under one-hot coding.
pub fn mask_bits_per_channel_one_hot(_keep: usize, _m: usize) -> f64 {
    1.0
}

/// Mask bits per input channel with per-kept-weight indices
/// (ceil(log2 m) bits each) — the GPU's 2:4 metadata style.
pub fn mask_bits_per_channel_indexed(keep: usize, m: usize) -> f64 {
    let bits = (m as f64).log2().ceil();
    keep as f64 * bits / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_of_m_keeps_exactly_n() {
        let (k, n) = (64, 8);
        let mut rng = Rng::new(1);
        for (keep, m) in [(2usize, 4usize), (4, 8), (8, 16), (32, 64)] {
            let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            prune_n_of_m(&mut w, k, n, keep, m);
            for g in 0..k / m {
                for c in 0..n {
                    let nz = (0..m)
                        .filter(|&i| w[(g * m + i) * n + c] != 0.0)
                        .count();
                    assert_eq!(nz, keep, "{keep}:{m} group {g} col {c}");
                }
            }
        }
    }

    #[test]
    fn larger_windows_prune_better() {
        // §III.C's claim: at 50% sparsity, 32:64 < 8:16 < 4:8 < 2:4 in
        // reconstruction error (more freedom in what to drop).
        let k = 1024;
        let n = 64;
        let e24 = reconstruction_error(2, 4, k, n, 9);
        let e48 = reconstruction_error(4, 8, k, n, 9);
        let e816 = reconstruction_error(8, 16, k, n, 9);
        let e3264 = reconstruction_error(32, 64, k, n, 9);
        assert!(e48 < e24, "4:8 {e48} vs 2:4 {e24}");
        assert!(e816 < e48, "8:16 {e816} vs 4:8 {e48}");
        assert!(e3264 < e816, "32:64 {e3264} vs 8:16 {e816}");
    }

    #[test]
    fn equal_fraction_is_comparable_across_m() {
        // all four patterns leave exactly half the weights
        let (k, n) = (256, 16);
        let mut rng = Rng::new(2);
        for (keep, m) in [(2usize, 4usize), (4, 8), (32, 64)] {
            let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            prune_n_of_m(&mut w, k, n, keep, m);
            let nz = w.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nz, k * n / 2);
        }
    }

    #[test]
    fn consistent_with_log_scale_pruner() {
        // prune_n_of_m(keep, 8) must agree with quant::prune_log_scale
        let (k, n) = (128, 8);
        let mut rng = Rng::new(3);
        let w0: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for keep in [1usize, 2, 4] {
            let mut a = w0.clone();
            let mut b = w0.clone();
            prune_n_of_m(&mut a, k, n, keep, 8);
            crate::quant::prune_log_scale(&mut b, k, n, keep);
            assert_eq!(a, b, "keep={keep}");
        }
    }

    #[test]
    fn metadata_costs() {
        // one-hot is 1 bit/channel regardless; indexed 2:4 costs the same
        // 1 bit/channel, and indexed high-sparsity wins (Fig. 5's hybrid)
        assert_eq!(mask_bits_per_channel_one_hot(4, 8), 1.0);
        assert_eq!(mask_bits_per_channel_indexed(2, 4), 1.0);
        assert!(mask_bits_per_channel_indexed(1, 8) < 1.0);
    }
}
