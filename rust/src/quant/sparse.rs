//! Structured-sparse representation: the compiler-side "mask → activation
//! select" transform that feeds the sparse DMA (paper §III.C).
//!
//! For a log-scale-pruned matrix, every group of 8 input channels holds
//! exactly ≤ keep_of_8 non-zeros per column. The hardware stores only the
//! kept values plus a mask; the sparse DMA uses the mask to pick the
//! matching activation lanes. In software we materialize the same thing
//! as an explicit index tensor `idx[kk, n]` + value tensor `val[kk, n]`
//! (column-padded groups ensure a rectangular shape — the time-unrolled
//! micro-architecture's 100%-utilization property).

use super::{QuantMatrix, QBLOCK, SGROUP};

/// Sparse-packed matrix: exactly `keep_of_8` slots per 8-channel group
/// per column (zero-padded within the group when fewer non-zeros exist).
#[derive(Debug, Clone)]
pub struct SparseMatrix {
    pub k: usize,
    pub n: usize,
    pub keep_of_8: usize,
    /// `kk × n` input-channel index of each kept slot (row-major)
    pub idx: Vec<u32>,
    /// `kk × n` INT4 value of each kept slot
    pub val: Vec<i8>,
    /// `(k/QBLOCK) × n` FP16 scales (shared with the dense layout)
    pub scales: Vec<u16>,
}

impl SparseMatrix {
    /// Rows of the packed representation: k × keep/8.
    pub fn kk(&self) -> usize {
        self.k / SGROUP * self.keep_of_8
    }

    /// Pre-decoded f32 scale of every packed slot (`kk × n`, same layout
    /// as `idx`/`val`): slot (r, c) carries the FP16 block scale of its
    /// source row `idx[r*n + c]`. This is what the runtime's sparse
    /// FP16×INT4 kernel multiplies by, decoded once at load time.
    pub fn slot_scales(&self) -> Vec<f32> {
        let (kk, n) = (self.kk(), self.n);
        let mut out = vec![0f32; kk * n];
        for r in 0..kk {
            for c in 0..n {
                let row = self.idx[r * n + c] as usize;
                out[r * n + c] = crate::fp::minifloat::f16_decode(
                    self.scales[(row / QBLOCK) * n + c],
                ) as f32;
            }
        }
        out
    }
}

/// Pack a (pruned, quantized) matrix into the fixed-slot sparse layout.
/// Panics if any group/column exceeds `keep_of_8` non-zeros — that means
/// the matrix was not pruned with the matching pattern.
pub fn pack_sparse(m: &QuantMatrix, keep_of_8: usize) -> SparseMatrix {
    assert!(m.k % SGROUP == 0);
    let groups = m.k / SGROUP;
    let kk = groups * keep_of_8;
    let mut idx = vec![0u32; kk * m.n];
    let mut val = vec![0i8; kk * m.n];
    for c in 0..m.n {
        for g in 0..groups {
            let mut slot = 0usize;
            for r in 0..SGROUP {
                let row = g * SGROUP + r;
                let v = m.q[row * m.n + c];
                if v != 0 {
                    assert!(
                        slot < keep_of_8,
                        "group {g} col {c} has more than {keep_of_8} non-zeros"
                    );
                    let out = (g * keep_of_8 + slot) * m.n + c;
                    idx[out] = row as u32;
                    val[out] = v;
                    slot += 1;
                }
            }
            // unfilled slots keep val=0; point idx at the group base so
            // gathers stay in-bounds
            for s in slot..keep_of_8 {
                idx[(g * keep_of_8 + s) * m.n + c] = (g * SGROUP) as u32;
            }
        }
    }
    SparseMatrix {
        k: m.k,
        n: m.n,
        keep_of_8,
        idx,
        val,
        scales: m.scales.clone(),
    }
}

/// Reference sparse VMM (f64): y = x · W using only the packed slots.
/// Mirrors `python/compile/kernels/sparse_vmm.py`.
pub fn sparse_vmm_ref(s: &SparseMatrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), s.k);
    let kk = s.kk();
    let mut y = vec![0f64; s.n];
    for c in 0..s.n {
        let mut acc = 0f64;
        for r in 0..kk {
            let i = r * s.n + c;
            let row = s.idx[i] as usize;
            let scale = crate::fp::minifloat::f16_decode(
                s.scales[(row / super::QBLOCK) * s.n + c],
            );
            acc += x[row] * s.val[i] as f64 * scale;
        }
        y[c] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{prune_log_scale, quantize, QBLOCK};
    use crate::util::rng::Rng;

    fn pruned_quant(k: usize, n: usize, keep: usize, seed: u64) -> QuantMatrix {
        let mut rng = Rng::new(seed);
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        prune_log_scale(&mut w, k, n, keep);
        quantize(&w, k, n)
    }

    #[test]
    fn packed_shape_is_rectangular() {
        let m = pruned_quant(QBLOCK, 8, 2, 1);
        let s = pack_sparse(&m, 2);
        assert_eq!(s.kk(), QBLOCK / 8 * 2);
        assert_eq!(s.idx.len(), s.kk() * 8);
    }

    #[test]
    fn sparse_vmm_matches_dense() {
        // The packed representation must compute the same product as the
        // dense (pruned) matrix — the 100%-utilization claim is lossless.
        let (k, n) = (QBLOCK * 2, 16);
        for keep in [1usize, 2, 4] {
            let m = pruned_quant(k, n, keep, keep as u64);
            let s = pack_sparse(&m, keep);
            let mut rng = Rng::new(99);
            let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let y_sparse = sparse_vmm_ref(&s, &x);
            // dense reference
            for c in 0..n {
                let mut acc = 0f64;
                for r in 0..k {
                    acc += x[r] * m.dequant(r, c);
                }
                assert!(
                    (acc - y_sparse[c]).abs() < 1e-9,
                    "col {c}: dense {acc} vs sparse {}",
                    y_sparse[c]
                );
            }
        }
    }

    #[test]
    fn indices_stay_in_group() {
        let m = pruned_quant(QBLOCK, 4, 2, 7);
        let s = pack_sparse(&m, 2);
        for g in 0..m.k / SGROUP {
            for slot in 0..2 {
                for c in 0..4 {
                    let row = s.idx[(g * 2 + slot) * 4 + c] as usize;
                    assert!(row / SGROUP == g, "idx escaped its group");
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn overfull_group_rejected() {
        // A dense matrix cannot be packed at keep_of_8 = 2.
        let mut rng = Rng::new(3);
        let w: Vec<f32> = (0..QBLOCK * 2).map(|_| 1.0 + rng.f32()).collect();
        let m = quantize(&w, QBLOCK, 2);
        pack_sparse(&m, 2);
    }
}
