//! The bridge wire format: a length-prefixed binary command stream.
//!
//! Every frame is `[u32 len][u8 opcode][payload]`, all integers and
//! floats little-endian; `len` counts the opcode byte plus the payload.
//! Payloads are the flat 1×row layout the rest of the system already
//! uses — prompts as `i32` token rows, logits as `f32` vocab rows — so
//! neither end reshapes anything: bytes received from the wire are the
//! bytes handed to the kernels (the paper's unified data-parallel
//! layout, applied to the transport).
//!
//! Request frames (host → device): [`Frame::Info`],
//! [`Frame::OpenSession`], [`Frame::Prefill`], [`Frame::Decode`],
//! [`Frame::DecodeBatch`], [`Frame::CloseSession`]. Response frames
//! (device → host): [`Frame::InfoResp`], [`Frame::SessionOpened`],
//! [`Frame::Logits`], [`Frame::LogitsBatch`], [`Frame::Closed`], and the
//! structured [`Frame::Error`] (an [`ErrCode`] plus a message). The
//! device answers every request frame with exactly one response frame,
//! in order — the client may pipeline requests and read the replies
//! back-to-back.
//!
//! Failure taxonomy ([`FrameError`]):
//!
//! * [`FrameError::Malformed`] — the length prefix was honored but the
//!   payload didn't parse (unknown opcode, truncated fields, trailing
//!   bytes). The stream is **still framed**: the reader consumed exactly
//!   `len` bytes, so the daemon replies with an error frame and the
//!   connection keeps working.
//! * [`FrameError::Desync`] — the length prefix itself is untrustworthy
//!   (zero, or beyond [`MAX_FRAME_BYTES`]). Nothing after it can be
//!   framed; the daemon sends one final error frame and closes.
//! * [`FrameError::Io`] — the transport died (including EOF in the
//!   middle of a frame). Connection over; the daemon frees the
//!   connection's sessions.
//!
//! The format is mirrored (golden bytes included) by
//! `python/tests/validate_bridge_protocol.py`.

#![deny(missing_docs)]

use std::fmt;
use std::io::{Read, Write};

use crate::obs::ObsStats;
use crate::runtime::kv::MemoryStats;
use crate::runtime::model::ModelInfo;

/// Wire protocol version, exchanged in `Info`/`InfoResp`. A device
/// refuses mismatched clients with `ErrCode::Version` rather than
/// guessing at frame shapes.
pub const PROTOCOL_VERSION: u8 = 1;

/// Upper bound on `len` (opcode + payload). Large enough for a
/// 4096-session batch of 256-vocab logits rows with room to spare;
/// small enough that a hostile length prefix cannot balloon the
/// daemon's memory.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

// Opcodes: requests in 0x01.., responses in 0x81.., error at 0xEE.
const OP_INFO: u8 = 0x01;
const OP_OPEN_SESSION: u8 = 0x02;
const OP_PREFILL: u8 = 0x03;
const OP_DECODE: u8 = 0x04;
const OP_DECODE_BATCH: u8 = 0x05;
const OP_CLOSE_SESSION: u8 = 0x06;
const OP_INFO_RESP: u8 = 0x81;
const OP_SESSION_OPENED: u8 = 0x82;
const OP_LOGITS: u8 = 0x83;
const OP_LOGITS_BATCH: u8 = 0x84;
const OP_CLOSED: u8 = 0x85;
const OP_ERROR: u8 = 0xEE;

/// Structured error classes carried by [`Frame::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// malformed, desynced, or out-of-place frame
    Protocol,
    /// unknown, duplicate, or not-yet-prefilled session id
    Session,
    /// the hosted backend failed the call (KV budget, compute error)
    Backend,
    /// the device is at capacity (session table full)
    Busy,
    /// protocol version mismatch between client and device
    Version,
}

impl ErrCode {
    fn to_u8(self) -> u8 {
        match self {
            ErrCode::Protocol => 1,
            ErrCode::Session => 2,
            ErrCode::Backend => 3,
            ErrCode::Busy => 4,
            ErrCode::Version => 5,
        }
    }

    fn from_u8(v: u8) -> Option<ErrCode> {
        Some(match v {
            1 => ErrCode::Protocol,
            2 => ErrCode::Session,
            3 => ErrCode::Backend,
            4 => ErrCode::Busy,
            5 => ErrCode::Version,
            _ => return None,
        })
    }
}

/// One logits row inside a [`Frame::LogitsBatch`]: the session it
/// belongs to, its position *after* the decode step, and the vocab row.
#[derive(Debug, Clone, PartialEq)]
pub struct LogitsRow {
    /// Session id the row belongs to.
    pub session: u32,
    /// Session position *after* the decode step.
    pub pos: u32,
    /// One vocab-sized logits vector.
    pub logits: Vec<f32>,
}

/// Every frame of the bridge protocol, requests and responses alike
/// (both ends share one parser; a daemon receiving a response-shaped
/// frame answers `ErrCode::Protocol`).
#[derive(Debug, Clone)]
pub enum Frame {
    /// handshake: the client announces its protocol version
    Info { version: u8 },
    /// allocate `session` (a client-chosen id) in the connection's table
    OpenSession { session: u32 },
    /// run prefill over `prompt` into an open session
    Prefill { session: u32, prompt: Vec<i32> },
    /// one decode step: feed `token` to a prefilled session
    Decode { session: u32, token: i32 },
    /// one batched decode round: feed `tokens[i]` to `sessions[i]`
    DecodeBatch { sessions: Vec<u32>, tokens: Vec<i32> },
    /// release a session's device-side state
    CloseSession { session: u32 },

    /// handshake reply: model architecture + serving capabilities,
    /// plus (since the paged-KV extension) a point-in-time snapshot of
    /// the device's KV-arena accounting. `Info` doubles as the stats
    /// query: `BridgeBackend::memory()` re-sends it and reads `memory`
    /// out of the fresh reply. The field is a *backward-compatible
    /// tail*: frames from pre-paging devices simply end after
    /// `ffn_weight_bytes` and decode as `memory: None`. Compatibility
    /// is one-directional — a current coordinator reads pre-tail
    /// devices, but a pre-tail coordinator's strict decoder rejects the
    /// extra bytes — so in a rolling upgrade, update **coordinators
    /// before devices** (exact version matching leaves no room to
    /// negotiate the tail per-connection without refusing old peers
    /// outright). The prefix-sharing extension grew the tail from
    /// eight to ten `u64`s (`prefix_cached_blocks`, `prefix_hits`)
    /// under the same rule, and the observability extension appended a
    /// *second* flagged tail after it — `obs`, the device's frame
    /// service-time histogram summary plus KV pressure counters
    /// ([`ObsStats`], seven `u64`s) — so a pre-obs device's frames end
    /// after the memory tail and decode as `obs: None`.
    InfoResp {
        version: u8,
        info: ModelInfo,
        buckets: Vec<usize>,
        supports_batched_decode: bool,
        /// 0 when the backend does not expose the figure
        ffn_weight_bytes: u64,
        /// `None` when the hosted backend has no paged KV arena
        memory: Option<MemoryStats>,
        /// `None` from pre-obs devices (shorter payload) or daemons
        /// that don't meter themselves
        obs: Option<ObsStats>,
    },
    /// `OpenSession` acknowledged
    SessionOpened { session: u32 },
    /// logits row for one `Prefill`/`Decode`; `pos` is the session
    /// position after the call
    Logits { session: u32, pos: u32, logits: Vec<f32> },
    /// one row per batch lane, in request order
    LogitsBatch { rows: Vec<LogitsRow> },
    /// `CloseSession` acknowledged
    Closed { session: u32 },
    /// structured failure reply
    Error { code: ErrCode, message: String },
}

impl Frame {
    /// Short frame-kind name for error messages (never the payload —
    /// logits rows don't belong in error strings).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Info { .. } => "Info",
            Frame::OpenSession { .. } => "OpenSession",
            Frame::Prefill { .. } => "Prefill",
            Frame::Decode { .. } => "Decode",
            Frame::DecodeBatch { .. } => "DecodeBatch",
            Frame::CloseSession { .. } => "CloseSession",
            Frame::InfoResp { .. } => "InfoResp",
            Frame::SessionOpened { .. } => "SessionOpened",
            Frame::Logits { .. } => "Logits",
            Frame::LogitsBatch { .. } => "LogitsBatch",
            Frame::Closed { .. } => "Closed",
            Frame::Error { .. } => "Error",
        }
    }
}

/// Why a frame could not be read. See the module docs for which
/// variants leave the stream usable.
#[derive(Debug)]
pub enum FrameError {
    /// transport failure, including EOF in the middle of a frame
    Io(std::io::Error),
    /// length prefix invalid — stream desynced, connection must close
    Desync(String),
    /// payload failed to parse — the stream itself is still framed
    Malformed(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "transport: {e}"),
            FrameError::Desync(m) => write!(f, "desynced: {m}"),
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

// ---------------------------------------------------------------- encode

struct Enc {
    b: Vec<u8>,
}

impl Enc {
    fn new(op: u8) -> Enc {
        Enc { b: vec![op] }
    }

    fn u8(&mut self, v: u8) {
        self.b.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    /// u16 byte length + UTF-8 bytes; clipped at a char boundary if the
    /// string somehow exceeds 64 KiB (error messages, model names).
    fn str16(&mut self, s: &str) {
        let mut end = s.len().min(u16::MAX as usize);
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        self.u16(end as u16);
        self.b.extend_from_slice(&s.as_bytes()[..end]);
    }

    fn vec_u32(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }

    fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.i32(x);
        }
    }

    fn vec_f32(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
}

fn enc_model_info(e: &mut Enc, i: &ModelInfo) {
    e.str16(&i.name);
    e.u32(i.vocab as u32);
    e.u32(i.d_model as u32);
    e.u32(i.n_layers as u32);
    e.u32(i.n_heads as u32);
    e.u32(i.n_kv_heads as u32);
    e.u32(i.d_ffn as u32);
    e.u32(i.max_tokens as u32);
    e.u32(i.head_dim as u32);
    e.u64(i.n_params as u64);
    for d in i.cache_shape {
        e.u32(d as u32);
    }
}

/// Serialize one frame to its on-wire payload (opcode + body, no length
/// prefix).
fn encode_payload(f: &Frame) -> Vec<u8> {
    let mut e;
    match f {
        Frame::Info { version } => {
            e = Enc::new(OP_INFO);
            e.u8(*version);
        }
        Frame::OpenSession { session } => {
            e = Enc::new(OP_OPEN_SESSION);
            e.u32(*session);
        }
        Frame::Prefill { session, prompt } => {
            e = Enc::new(OP_PREFILL);
            e.u32(*session);
            e.vec_i32(prompt);
        }
        Frame::Decode { session, token } => {
            e = Enc::new(OP_DECODE);
            e.u32(*session);
            e.i32(*token);
        }
        Frame::DecodeBatch { sessions, tokens } => {
            debug_assert_eq!(sessions.len(), tokens.len());
            e = Enc::new(OP_DECODE_BATCH);
            // one shared count keeps the arity equal by construction
            e.u32(sessions.len() as u32);
            for &s in sessions {
                e.u32(s);
            }
            for &t in tokens {
                e.i32(t);
            }
        }
        Frame::CloseSession { session } => {
            e = Enc::new(OP_CLOSE_SESSION);
            e.u32(*session);
        }
        Frame::InfoResp {
            version,
            info,
            buckets,
            supports_batched_decode,
            ffn_weight_bytes,
            memory,
            obs,
        } => {
            e = Enc::new(OP_INFO_RESP);
            e.u8(*version);
            enc_model_info(&mut e, info);
            let b: Vec<u32> = buckets.iter().map(|&x| x as u32).collect();
            e.vec_u32(&b);
            e.u8(u8::from(*supports_batched_decode));
            e.u64(*ffn_weight_bytes);
            // backward-compatible tail: presence flag + arena figures
            match memory {
                None => e.u8(0),
                Some(m) => {
                    e.u8(1);
                    e.u64(m.total_bytes);
                    e.u64(m.free_bytes);
                    e.u64(m.reserved_bytes);
                    e.u64(m.block_tokens);
                    e.u64(m.blocks_total);
                    e.u64(m.blocks_free);
                    e.u64(m.reuse_hits);
                    e.u64(m.peak_reserved_bytes);
                    e.u64(m.prefix_cached_blocks);
                    e.u64(m.prefix_hits);
                }
            }
            // second backward-compatible tail: observability figures
            match obs {
                None => e.u8(0),
                Some(o) => {
                    e.u8(1);
                    e.u64(o.alloc_stalls);
                    e.u64(o.cow_copies);
                    e.u64(o.frames_served);
                    e.u64(o.frame_p50_us);
                    e.u64(o.frame_p90_us);
                    e.u64(o.frame_p99_us);
                    e.u64(o.frame_max_us);
                }
            }
        }
        Frame::SessionOpened { session } => {
            e = Enc::new(OP_SESSION_OPENED);
            e.u32(*session);
        }
        Frame::Logits { session, pos, logits } => {
            e = Enc::new(OP_LOGITS);
            e.u32(*session);
            e.u32(*pos);
            e.vec_f32(logits);
        }
        Frame::LogitsBatch { rows } => {
            e = Enc::new(OP_LOGITS_BATCH);
            e.u32(rows.len() as u32);
            for r in rows {
                e.u32(r.session);
                e.u32(r.pos);
                e.vec_f32(&r.logits);
            }
        }
        Frame::Closed { session } => {
            e = Enc::new(OP_CLOSED);
            e.u32(*session);
        }
        Frame::Error { code, message } => {
            e = Enc::new(OP_ERROR);
            e.u8(code.to_u8());
            e.str16(message);
        }
    }
    e.b
}

/// Write one frame (length prefix + payload). Returns the total bytes
/// put on the wire — the figure the client's `TransferMeter` records.
/// The caller owns flushing.
///
/// A frame exceeding [`MAX_FRAME_BYTES`] (a huge-vocab hosted backend
/// at a large batch) fails with `InvalidData` *before* any byte is
/// written, so the stream is never desynced by an unsendable frame;
/// the daemon turns that into a structured error reply.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<usize> {
    let payload = encode_payload(f);
    if payload.len() > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!(
                "{} frame of {} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})",
                f.name(),
                payload.len()
            ),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    Ok(4 + payload.len())
}

// ---------------------------------------------------------------- decode

struct Dec<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.at + n > self.b.len() {
            return Err(format!(
                "payload truncated: need {n} bytes at offset {}, have {}",
                self.at,
                self.b.len() - self.at
            ));
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        // analyzer: allow(panic-path) — take(1) returned exactly 1 byte
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        let s = self.take(2)?;
        // analyzer: allow(panic-path) — take(2) returned exactly 2 bytes
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        // analyzer: allow(panic-path) — take(4) returned exactly 4 bytes
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn i32(&mut self) -> Result<i32, String> {
        Ok(self.u32()? as i32)
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Element count for a vector of `elem_bytes`-wide items, rejected
    /// *before* allocation when the payload cannot possibly hold it.
    fn count(&mut self, elem_bytes: usize) -> Result<usize, String> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.b.len() - self.at {
            return Err(format!(
                "count {n} exceeds payload ({} bytes left)",
                self.b.len() - self.at
            ));
        }
        Ok(n)
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>, String> {
        let n = self.count(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>, String> {
        let n = self.count(4)?;
        (0..n).map(|_| self.i32()).collect()
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, String> {
        let n = self.count(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    fn str16(&mut self) -> Result<String, String> {
        let n = self.u16()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| "invalid utf-8 in string".to_string())
    }

    /// True when the payload is fully consumed — how optional trailing
    /// extensions (the `InfoResp` memory tail) detect an older peer.
    fn at_end(&self) -> bool {
        self.at == self.b.len()
    }

    fn finish(&self) -> Result<(), String> {
        if self.at != self.b.len() {
            return Err(format!("{} trailing bytes after the payload", self.b.len() - self.at));
        }
        Ok(())
    }
}

fn dec_model_info(d: &mut Dec) -> Result<ModelInfo, String> {
    Ok(ModelInfo {
        name: d.str16()?,
        vocab: d.u32()? as usize,
        d_model: d.u32()? as usize,
        n_layers: d.u32()? as usize,
        n_heads: d.u32()? as usize,
        n_kv_heads: d.u32()? as usize,
        d_ffn: d.u32()? as usize,
        max_tokens: d.u32()? as usize,
        head_dim: d.u32()? as usize,
        n_params: d.u64()? as usize,
        cache_shape: [
            d.u32()? as usize,
            d.u32()? as usize,
            d.u32()? as usize,
            d.u32()? as usize,
        ],
    })
}

/// Parse one payload (opcode + body) into a frame.
fn decode_payload(payload: &[u8]) -> Result<Frame, String> {
    let mut d = Dec { b: payload, at: 0 };
    let op = d.u8()?;
    let frame = match op {
        OP_INFO => Frame::Info { version: d.u8()? },
        OP_OPEN_SESSION => Frame::OpenSession { session: d.u32()? },
        OP_PREFILL => Frame::Prefill {
            session: d.u32()?,
            prompt: d.vec_i32()?,
        },
        OP_DECODE => Frame::Decode {
            session: d.u32()?,
            token: d.i32()?,
        },
        OP_DECODE_BATCH => {
            let n = d.count(8)?;
            let sessions = (0..n).map(|_| d.u32()).collect::<Result<Vec<_>, _>>()?;
            let tokens = (0..n).map(|_| d.i32()).collect::<Result<Vec<_>, _>>()?;
            Frame::DecodeBatch { sessions, tokens }
        }
        OP_CLOSE_SESSION => Frame::CloseSession { session: d.u32()? },
        OP_INFO_RESP => {
            let version = d.u8()?;
            let info = dec_model_info(&mut d)?;
            let buckets = d.vec_u32()?.into_iter().map(|x| x as usize).collect();
            let supports_batched_decode = d.u8()? != 0;
            let ffn_weight_bytes = d.u64()?;
            // pre-paging peers end the payload here; the memory tail is
            // a flagged optional extension
            let memory = if d.at_end() {
                None
            } else if d.u8()? != 0 {
                Some(MemoryStats {
                    total_bytes: d.u64()?,
                    free_bytes: d.u64()?,
                    reserved_bytes: d.u64()?,
                    block_tokens: d.u64()?,
                    blocks_total: d.u64()?,
                    blocks_free: d.u64()?,
                    reuse_hits: d.u64()?,
                    peak_reserved_bytes: d.u64()?,
                    prefix_cached_blocks: d.u64()?,
                    prefix_hits: d.u64()?,
                })
            } else {
                None
            };
            // pre-obs peers end after the memory tail; the obs tail is
            // a second flagged optional extension under the same rule
            let obs = if d.at_end() {
                None
            } else if d.u8()? != 0 {
                Some(ObsStats {
                    alloc_stalls: d.u64()?,
                    cow_copies: d.u64()?,
                    frames_served: d.u64()?,
                    frame_p50_us: d.u64()?,
                    frame_p90_us: d.u64()?,
                    frame_p99_us: d.u64()?,
                    frame_max_us: d.u64()?,
                })
            } else {
                None
            };
            Frame::InfoResp {
                version,
                info,
                buckets,
                supports_batched_decode,
                ffn_weight_bytes,
                memory,
                obs,
            }
        }
        OP_SESSION_OPENED => Frame::SessionOpened { session: d.u32()? },
        OP_LOGITS => Frame::Logits {
            session: d.u32()?,
            pos: d.u32()?,
            logits: d.vec_f32()?,
        },
        OP_LOGITS_BATCH => {
            let n = d.count(12)?;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(LogitsRow {
                    session: d.u32()?,
                    pos: d.u32()?,
                    logits: d.vec_f32()?,
                });
            }
            Frame::LogitsBatch { rows }
        }
        OP_CLOSED => Frame::Closed { session: d.u32()? },
        OP_ERROR => {
            let code = ErrCode::from_u8(d.u8()?).ok_or("unknown error code")?;
            Frame::Error {
                code,
                message: d.str16()?,
            }
        }
        other => return Err(format!("unknown opcode 0x{other:02x}")),
    };
    d.finish()?;
    Ok(frame)
}

/// Read one frame. `Ok(None)` is a clean disconnect (EOF at a frame
/// boundary). On success the second tuple element is the total bytes
/// consumed (length prefix included) — the `TransferMeter` figure.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(Frame, usize)>, FrameError> {
    // the length prefix is read byte-wise so EOF *between* frames (a
    // normal hangup) is distinguishable from EOF *inside* one (an error)
    let mut len4 = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside a frame length prefix",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(FrameError::Desync(format!(
            "frame length {len} outside 1..={MAX_FRAME_BYTES}"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(FrameError::Io)?;
    match decode_payload(&payload) {
        Ok(f) => Ok(Some((f, 4 + len))),
        Err(m) => Err(FrameError::Malformed(m)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sample_info() -> ModelInfo {
        ModelInfo {
            name: "ref-tiny".to_string(),
            vocab: 256,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ffn: 128,
            max_tokens: 64,
            head_dim: 16,
            n_params: 123_456,
            cache_shape: [2, 64, 2, 16],
        }
    }

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, f).unwrap();
        assert_eq!(n, buf.len());
        let mut cur = Cursor::new(buf);
        let (out, consumed) = read_frame(&mut cur).unwrap().expect("frame");
        assert_eq!(consumed, n);
        out
    }

    #[test]
    fn every_frame_roundtrips() {
        let frames = vec![
            Frame::Info { version: PROTOCOL_VERSION },
            Frame::OpenSession { session: 7 },
            Frame::Prefill {
                session: 1,
                prompt: vec![5, -1, 255, 0],
            },
            Frame::Decode { session: 9, token: -3 },
            Frame::DecodeBatch {
                sessions: vec![1, 2, 3],
                tokens: vec![10, 20, 30],
            },
            Frame::CloseSession { session: 4 },
            Frame::InfoResp {
                version: PROTOCOL_VERSION,
                info: sample_info(),
                buckets: vec![8, 16, 32, 64],
                supports_batched_decode: true,
                ffn_weight_bytes: 1 << 20,
                memory: None,
                obs: None,
            },
            Frame::InfoResp {
                version: PROTOCOL_VERSION,
                info: sample_info(),
                buckets: vec![8, 16, 32, 64],
                supports_batched_decode: true,
                ffn_weight_bytes: 1 << 20,
                memory: Some(MemoryStats {
                    total_bytes: 1 << 24,
                    free_bytes: 3 << 20,
                    reserved_bytes: (1 << 24) - (3 << 20),
                    block_tokens: 64,
                    blocks_total: 128,
                    blocks_free: 24,
                    reuse_hits: 7,
                    peak_reserved_bytes: 1 << 23,
                    prefix_cached_blocks: 5,
                    prefix_hits: 9,
                }),
                obs: Some(ObsStats {
                    alloc_stalls: 2,
                    cow_copies: 6,
                    frames_served: 1234,
                    frame_p50_us: 90,
                    frame_p90_us: 400,
                    frame_p99_us: 950,
                    frame_max_us: 4100,
                }),
            },
            // obs without memory: a stateless hosted backend that still
            // meters its frame service times
            Frame::InfoResp {
                version: PROTOCOL_VERSION,
                info: sample_info(),
                buckets: vec![8],
                supports_batched_decode: false,
                ffn_weight_bytes: 0,
                memory: None,
                obs: Some(ObsStats {
                    alloc_stalls: 0,
                    cow_copies: 0,
                    frames_served: 3,
                    frame_p50_us: 10,
                    frame_p90_us: 20,
                    frame_p99_us: 30,
                    frame_max_us: 31,
                }),
            },
            Frame::SessionOpened { session: 2 },
            Frame::Logits {
                session: 3,
                pos: 17,
                logits: vec![0.5, -1.25, f32::MIN_POSITIVE, 3.75e8],
            },
            Frame::LogitsBatch {
                rows: vec![
                    LogitsRow { session: 1, pos: 4, logits: vec![1.0, 2.0] },
                    LogitsRow { session: 2, pos: 9, logits: vec![-0.5] },
                ],
            },
            Frame::Closed { session: 11 },
            Frame::Error {
                code: ErrCode::Session,
                message: "session 7 is not open".to_string(),
            },
        ];
        for f in &frames {
            let out = roundtrip(f);
            // Frame holds ModelInfo (no PartialEq); Debug output is a
            // faithful field-by-field rendering for all these payloads
            assert_eq!(format!("{out:?}"), format!("{f:?}"));
        }
    }

    #[test]
    fn float_bits_survive_the_wire() {
        let weird = vec![f32::NAN, f32::INFINITY, -0.0, 1.0000001];
        let out = roundtrip(&Frame::Logits { session: 0, pos: 1, logits: weird.clone() });
        let Frame::Logits { logits, .. } = out else { panic!("wrong frame") };
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&logits), bits(&weird));
    }

    /// Golden bytes, mirrored by python/tests/validate_bridge_protocol.py
    /// — the wire format is a contract, not an implementation detail.
    #[test]
    fn golden_bytes() {
        let enc = |f: &Frame| {
            let mut b = Vec::new();
            write_frame(&mut b, f).unwrap();
            b
        };
        assert_eq!(enc(&Frame::Info { version: 1 }), [2, 0, 0, 0, 0x01, 1]);
        assert_eq!(
            enc(&Frame::OpenSession { session: 3 }),
            [5, 0, 0, 0, 0x02, 3, 0, 0, 0]
        );
        assert_eq!(
            enc(&Frame::Decode { session: 7, token: 42 }),
            [9, 0, 0, 0, 0x04, 7, 0, 0, 0, 42, 0, 0, 0]
        );
        assert_eq!(
            enc(&Frame::Prefill { session: 1, prompt: vec![5, -1] }),
            [
                17, 0, 0, 0, // len
                0x03, // opcode
                1, 0, 0, 0, // session
                2, 0, 0, 0, // count
                5, 0, 0, 0, // token 5
                0xFF, 0xFF, 0xFF, 0xFF, // token -1
            ]
        );
        assert_eq!(
            enc(&Frame::Error { code: ErrCode::Session, message: "x".into() }),
            [5, 0, 0, 0, 0xEE, 2, 1, 0, 0x78]
        );
        // InfoResp with both flagged tails (paged-KV memory, then obs)
        // — the literal produced and asserted by the Python mirror
        // (fields 1..27 in wire order)
        let golden_info = Frame::InfoResp {
            version: 1,
            info: ModelInfo {
                name: "m".to_string(),
                vocab: 1,
                d_model: 2,
                n_layers: 3,
                n_heads: 4,
                n_kv_heads: 5,
                d_ffn: 6,
                max_tokens: 7,
                head_dim: 8,
                n_params: 9,
                cache_shape: [1, 2, 3, 4],
            },
            buckets: vec![7],
            supports_batched_decode: true,
            ffn_weight_bytes: 10,
            memory: Some(MemoryStats {
                total_bytes: 11,
                free_bytes: 12,
                reserved_bytes: 13,
                block_tokens: 14,
                blocks_total: 15,
                blocks_free: 16,
                reuse_hits: 17,
                peak_reserved_bytes: 18,
                prefix_cached_blocks: 19,
                prefix_hits: 20,
            }),
            obs: Some(ObsStats {
                alloc_stalls: 21,
                cow_copies: 22,
                frames_served: 23,
                frame_p50_us: 24,
                frame_p90_us: 25,
                frame_p99_us: 26,
                frame_max_us: 27,
            }),
        };
        let want: Vec<u8> = vec![
            216, 0, 0, 0, // length prefix
            0x81, // opcode
            1, // version
            1, 0, 109, // name "m"
            1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0, 4, 0, 0, 0, // vocab..n_heads
            5, 0, 0, 0, 6, 0, 0, 0, 7, 0, 0, 0, 8, 0, 0, 0, // n_kv_heads..head_dim
            9, 0, 0, 0, 0, 0, 0, 0, // n_params
            1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0, 4, 0, 0, 0, // cache_shape
            1, 0, 0, 0, 7, 0, 0, 0, // buckets [7]
            1, // supports_batched_decode
            10, 0, 0, 0, 0, 0, 0, 0, // ffn_weight_bytes
            1, // memory present
            11, 0, 0, 0, 0, 0, 0, 0, // total_bytes
            12, 0, 0, 0, 0, 0, 0, 0, // free_bytes
            13, 0, 0, 0, 0, 0, 0, 0, // reserved_bytes
            14, 0, 0, 0, 0, 0, 0, 0, // block_tokens
            15, 0, 0, 0, 0, 0, 0, 0, // blocks_total
            16, 0, 0, 0, 0, 0, 0, 0, // blocks_free
            17, 0, 0, 0, 0, 0, 0, 0, // reuse_hits
            18, 0, 0, 0, 0, 0, 0, 0, // peak_reserved_bytes
            19, 0, 0, 0, 0, 0, 0, 0, // prefix_cached_blocks
            20, 0, 0, 0, 0, 0, 0, 0, // prefix_hits
            1, // obs present
            21, 0, 0, 0, 0, 0, 0, 0, // alloc_stalls
            22, 0, 0, 0, 0, 0, 0, 0, // cow_copies
            23, 0, 0, 0, 0, 0, 0, 0, // frames_served
            24, 0, 0, 0, 0, 0, 0, 0, // frame_p50_us
            25, 0, 0, 0, 0, 0, 0, 0, // frame_p90_us
            26, 0, 0, 0, 0, 0, 0, 0, // frame_p99_us
            27, 0, 0, 0, 0, 0, 0, 0, // frame_max_us
        ];
        assert_eq!(enc(&golden_info), want);
    }

    /// A pre-paging peer's `InfoResp` ends right after
    /// `ffn_weight_bytes`; the decoder must accept it as `memory: None`
    /// (and `obs: None`) instead of rejecting the shorter payload.
    #[test]
    fn info_resp_without_memory_tail_still_decodes() {
        // encode the new frame, then strip the two 1-byte `None` flags
        // (memory, obs) to reconstruct the legacy payload byte-for-byte
        let f = Frame::InfoResp {
            version: PROTOCOL_VERSION,
            info: sample_info(),
            buckets: vec![8, 16],
            supports_batched_decode: false,
            ffn_weight_bytes: 42,
            memory: None,
            obs: None,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let payload_len = buf.len() - 4 - 2; // minus prefix, minus both flags
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&(payload_len as u32).to_le_bytes());
        legacy.extend_from_slice(&buf[4..4 + payload_len]);
        let mut cur = Cursor::new(legacy);
        let (out, _) = read_frame(&mut cur).unwrap().expect("legacy frame");
        match out {
            Frame::InfoResp { ffn_weight_bytes: 42, memory: None, obs: None, .. } => {}
            other => panic!("want legacy InfoResp with memory: None, got {other:?}"),
        }
    }

    /// A paging-era but pre-obs peer's `InfoResp` ends right after the
    /// memory tail; the decoder must keep the memory figures and read
    /// `obs: None` rather than rejecting the payload.
    #[test]
    fn info_resp_without_obs_tail_still_decodes() {
        let f = Frame::InfoResp {
            version: PROTOCOL_VERSION,
            info: sample_info(),
            buckets: vec![8, 16],
            supports_batched_decode: true,
            ffn_weight_bytes: 42,
            memory: Some(MemoryStats {
                total_bytes: 1,
                free_bytes: 2,
                reserved_bytes: 3,
                block_tokens: 4,
                blocks_total: 5,
                blocks_free: 6,
                reuse_hits: 7,
                peak_reserved_bytes: 8,
                prefix_cached_blocks: 9,
                prefix_hits: 10,
            }),
            obs: None,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let payload_len = buf.len() - 4 - 1; // minus prefix, minus obs flag
        let mut pre_obs = Vec::new();
        pre_obs.extend_from_slice(&(payload_len as u32).to_le_bytes());
        pre_obs.extend_from_slice(&buf[4..4 + payload_len]);
        let mut cur = Cursor::new(pre_obs);
        let (out, _) = read_frame(&mut cur).unwrap().expect("pre-obs frame");
        match out {
            Frame::InfoResp { memory: Some(m), obs: None, .. } => {
                assert_eq!(m.prefix_hits, 10);
            }
            other => panic!("want pre-obs InfoResp with obs: None, got {other:?}"),
        }
    }

    #[test]
    fn bad_length_prefixes_are_desync() {
        let mut cur = Cursor::new(vec![0u8, 0, 0, 0]);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Desync(_))));
        let mut cur = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Desync(_))));
    }

    #[test]
    fn eof_inside_a_frame_is_io_clean_eof_is_none() {
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_frame(&mut empty), Ok(None)));
        // length says 10, only 3 payload bytes present
        let mut cut = Cursor::new(vec![10u8, 0, 0, 0, 0x04, 1, 2]);
        assert!(matches!(read_frame(&mut cut), Err(FrameError::Io(_))));
        // eof splitting the length prefix itself
        let mut half = Cursor::new(vec![9u8, 0]);
        assert!(matches!(read_frame(&mut half), Err(FrameError::Io(_))));
    }

    #[test]
    fn malformed_payload_keeps_the_stream_framed() {
        let mut bytes = vec![1u8, 0, 0, 0, 0x7F]; // unknown opcode, valid framing
        write_frame(&mut bytes, &Frame::Info { version: 1 }).unwrap();
        let mut cur = Cursor::new(bytes);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Malformed(_))));
        // the next frame on the same stream still parses
        let (f, _) = read_frame(&mut cur).unwrap().expect("frame after malformed");
        assert!(matches!(f, Frame::Info { version: 1 }));
    }

    #[test]
    fn truncated_fields_and_trailing_bytes_are_malformed() {
        // Decode payload missing its token field
        let mut cur = Cursor::new(vec![5u8, 0, 0, 0, 0x04, 7, 0, 0, 0]);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Malformed(_))));
        // valid Info plus a stray trailing byte inside the frame
        let mut cur = Cursor::new(vec![3u8, 0, 0, 0, 0x01, 1, 9]);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Malformed(_))));
        // vector count pointing past the payload must fail before allocating
        let mut bogus = vec![9u8, 0, 0, 0, 0x03, 1, 0, 0, 0];
        bogus.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut cur = Cursor::new(bogus);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn long_strings_are_clipped_at_char_boundaries() {
        let long = "é".repeat(40_000); // 80 000 bytes of 2-byte chars
        let out = roundtrip(&Frame::Error { code: ErrCode::Protocol, message: long });
        let Frame::Error { message, .. } = out else { panic!("wrong frame") };
        assert!(message.len() <= u16::MAX as usize);
        assert!(!message.is_empty());
    }
}
