//! [`BridgeBackend`]: the [`Backend`] trait implemented over the bridge
//! transport — the CPU-side coordinator's view of a remote device.
//!
//! Construction connects, performs the `Info` handshake, and caches the
//! device's architecture + capability flags, so to the scheduler a
//! remote device is indistinguishable from an in-process backend: same
//! trait, same validation (the local [`LlmRuntime`] wrapper still
//! guards every call), bit-identical logits — f32 rows cross the wire
//! as raw little-endian bits, never reformatted.
//!
//! Call mapping (each is one transport round trip):
//!
//! * `prefill` → `OpenSession` + `Prefill`, pipelined in one flush; the
//!   remote session id rides home in [`Session::tag`];
//! * `decode` → `Decode`; `decode_batch` → a single `DecodeBatch` frame
//!   for the whole round (the weight-stream-once batching argument
//!   applies to the wire, too: one round trip per round, not per
//!   session);
//! * scheduler retirement → `end_session` → a *pipelined*
//!   `CloseSession`: the frame is buffered and flushed with the next
//!   request (in steady state the next round's `DecodeBatch`), and its
//!   reply is drained in front of that request's reply — retirement
//!   costs zero round trips. The device session gauge is
//!   eventually-consistent; any later request/reply exchange (or a
//!   [`Backend::memory`] stats query) proves the closes were applied,
//!   and disconnect still reclaims everything.
//!
//! **Failure taxonomy** ([`BridgeError`]): every wire exchange is typed
//! `Io` (the connection is gone — retryable), `Protocol` (the device
//! answered outside the protocol — not retryable, replaying garbage
//! reproduces garbage), or `Backend` (the device answered with a
//! structured error frame — the connection is healthy and the error
//! *is* the answer). Reconnect logic matches on the kind, never on
//! message substrings.
//!
//! **Resilience**: the backend keeps the full token history (prompt +
//! every successfully fed token) of each live session. When a call
//! fails with `Io`, it redials with capped exponential backoff plus
//! jitter, re-verifies the device identity, re-opens every live session
//! under its original id, re-prefills it from history (adopting
//! whatever the device's prefix cache still holds), bumps
//! [`TransferMeter::reconnects`], and replays the failed call. A
//! `device-serve` restart mid-request therefore costs latency, not a
//! failed completion. History is appended only *after* a successful
//! reply, so a replayed round always re-feeds exactly the tokens the
//! device lost.
//!
//! Every frame is counted by a [`TransferMeter`] (host→device tx,
//! device→host rx, per-call), the transport analogue of the paper's
//! HBM-bandwidth-utilization metric; `benches/bridge_overhead.rs`
//! reports bytes/token from it, and the serving stats line exposes it
//! when the engine's backend is remote.
//!
//! A refused connection maps to a structured error naming the address
//! and the fix (`edgellm device-serve`) — the first thing an operator
//! sees when the daemon is down, so it must not be a bare os error.
//!
//! [`Backend`]: crate::runtime::backend::Backend
//! [`LlmRuntime`]: crate::runtime::model::LlmRuntime
//! [`TransferMeter`]: crate::runtime::backend::TransferMeter

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::fmt;
use std::io::{BufReader, BufWriter, ErrorKind, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::protocol::{self, ErrCode, Frame, FrameError, PROTOCOL_VERSION};
use crate::obs::{KvPressure, Obs, ObsStats, SpanKind};
use crate::runtime::backend::{Backend, TransferMeter};
use crate::runtime::kv::MemoryStats;
use crate::runtime::model::{ModelInfo, Session};
use crate::util::rng::Rng;

/// Typed bridge-client error. The retry layer matches on the *kind*:
/// only `Io` triggers reconnect-and-replay.
#[derive(Debug)]
pub enum BridgeError {
    /// the transport died (refused, reset, EOF mid-frame): the
    /// connection is gone and the call may be replayed on a fresh one
    Io(std::io::Error),
    /// the device answered outside the protocol (desync, wrong frame
    /// kind, bad arity): not retryable — replaying reproduces it
    Protocol(String),
    /// a structured error frame from the device ([`ErrCode`] plus
    /// message): the connection is healthy, the error is the answer
    Backend {
        /// the device's structured error class
        code: ErrCode,
        /// the device's error message (never payload bytes)
        message: String,
    },
}

impl fmt::Display for BridgeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BridgeError::Io(e) => write!(f, "device io error: {e}"),
            BridgeError::Protocol(m) => write!(f, "bridge protocol error: {m}"),
            BridgeError::Backend { code, message } => {
                write!(f, "device error ({code:?}): {message}")
            }
        }
    }
}

impl std::error::Error for BridgeError {}

impl From<std::io::Error> for BridgeError {
    fn from(e: std::io::Error) -> Self {
        // a frame the client built beyond the wire cap is a local bug,
        // not a dead connection — do not redial over it
        if e.kind() == ErrorKind::InvalidData {
            BridgeError::Protocol(e.to_string())
        } else {
            BridgeError::Io(e)
        }
    }
}

/// Reconnect policy: capped exponential backoff with jitter.
const RECONNECT_ATTEMPTS: u32 = 8;
const BACKOFF_BASE_MS: u64 = 10;
const BACKOFF_CAP_MS: u64 = 640;
/// Full reconnect cycles one call may burn before giving up — bounds a
/// flapping device to a finite client-side stall.
const RECONNECT_CYCLES_PER_CALL: u32 = 2;

/// The connection: buffered halves of one TCP stream plus the meter.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    meter: TransferMeter,
    /// `CloseSession` frames written but whose replies have not been
    /// read yet (close pipelining): the frames sit in the write buffer
    /// until the next request flushes them, and their replies — which
    /// the device sends strictly in request order — are drained in
    /// front of that request's reply by [`Conn::recv_reply`].
    pending_closes: usize,
}

impl Conn {
    fn send(&mut self, f: &Frame) -> Result<(), BridgeError> {
        let n = protocol::write_frame(&mut self.writer, f)?;
        self.meter.tx_bytes += n as u64;
        Ok(())
    }

    fn flush(&mut self) -> Result<(), BridgeError> {
        self.writer.flush().map_err(BridgeError::from)
    }

    fn recv(&mut self) -> Result<Frame, BridgeError> {
        match protocol::read_frame(&mut self.reader) {
            Ok(Some((f, n))) => {
                self.meter.rx_bytes += n as u64;
                Ok(f)
            }
            Ok(None) => Err(BridgeError::Io(std::io::Error::new(
                ErrorKind::UnexpectedEof,
                "device closed the connection",
            ))),
            Err(FrameError::Io(e)) => Err(BridgeError::Io(e)),
            Err(e @ (FrameError::Desync(_) | FrameError::Malformed(_))) => {
                Err(BridgeError::Protocol(e.to_string()))
            }
        }
    }

    /// Read the reply to the request just flushed, draining any
    /// pipelined `CloseSession` replies queued in front of it first.
    /// Closes are best-effort by contract, so their replies are only
    /// sanity-checked, never failed on.
    fn recv_reply(&mut self) -> Result<Frame, BridgeError> {
        while self.pending_closes > 0 {
            self.pending_closes -= 1;
            match self.recv()? {
                // Closed, or a structured error (daemon restarted, id
                // unknown): the device holds no state either way
                Frame::Closed { .. } | Frame::Error { .. } => {}
                other => eprintln!(
                    "bridge: unexpected {} reply to a pipelined close",
                    other.name()
                ),
            }
        }
        self.recv()
    }
}

/// Turn an unexpected reply into the error the caller reports: device
/// error frames keep their structured code, anything else names the
/// frame kinds involved (never payloads).
fn unexpected(frame: Frame, want: &str) -> BridgeError {
    match frame {
        Frame::Error { code, message } => BridgeError::Backend { code, message },
        other => BridgeError::Protocol(format!("expected {want}, got {}", other.name())),
    }
}

/// `Backend` over the bridge transport. See the module docs.
pub struct BridgeBackend {
    addr: String,
    info: ModelInfo,
    buckets: Vec<usize>,
    supports_batched: bool,
    ffn_weight_bytes: Option<usize>,
    /// interior mutability: `Backend` methods take `&self`; the engine
    /// serializes calls externally (it lives behind the server's mutex)
    conn: RefCell<Conn>,
    /// next client-chosen remote session id; 0 is reserved as "no
    /// remote session" so `Session::tag` can mark closed sessions
    next_session: Cell<u32>,
    /// full token history (prompt + every successfully fed token) per
    /// live remote session — what reconnection re-prefills from.
    /// Appended only after a successful decode reply, so a replay after
    /// reconnect restores exactly the pre-call state.
    history: RefCell<HashMap<u32, Vec<i32>>>,
    /// backoff jitter source (spreads the redial stampede of many
    /// clients hitting one restarted device)
    jitter: RefCell<Rng>,
    /// the serving side's observability registry, when attached
    /// (`Backend::attach_obs`): per-opcode frame RTT histograms and
    /// reconnect spans are recorded into it
    obs: RefCell<Option<std::sync::Arc<Obs>>>,
}

impl BridgeBackend {
    /// Dial `addr` and run the `Info` handshake on a fresh connection,
    /// carrying `meter` forward so transport counters survive
    /// reconnects.
    fn handshake(
        addr: &str,
        meter: TransferMeter,
    ) -> Result<(Conn, u8, ModelInfo, Vec<usize>, bool, u64), BridgeError> {
        let stream = TcpStream::connect(addr).map_err(BridgeError::Io)?;
        stream.set_nodelay(true).map_err(BridgeError::Io)?;
        let reader = BufReader::new(stream.try_clone().map_err(BridgeError::Io)?);
        let writer = BufWriter::new(stream);
        let mut conn = Conn {
            reader,
            writer,
            meter,
            pending_closes: 0,
        };
        conn.meter.calls += 1;
        conn.send(&Frame::Info { version: PROTOCOL_VERSION })?;
        conn.flush()?;
        match conn.recv()? {
            Frame::InfoResp {
                version,
                info,
                buckets,
                supports_batched_decode,
                ffn_weight_bytes,
                // handshake-time arena and obs stats go stale
                // immediately; `memory()`/`device_obs()` re-query for a
                // fresh snapshot
                memory: _,
                obs: _,
            } => Ok((conn, version, info, buckets, supports_batched_decode, ffn_weight_bytes)),
            other => Err(unexpected(other, "InfoResp")),
        }
    }

    /// Connect to a device daemon at `addr` ("host:port") and perform
    /// the `Info` handshake. Connection refusal and version mismatch
    /// are structured errors, not panics — they are the two failures an
    /// operator hits first.
    pub fn connect(addr: &str) -> Result<Self> {
        let (conn, version, info, buckets, supports_batched_decode, ffn_weight_bytes) =
            Self::handshake(addr, TransferMeter::default()).map_err(|e| match e {
                BridgeError::Io(e) => anyhow!(
                    "device unreachable at {addr}: {e} \
                     (start one with `edgellm device-serve --addr {addr}`)"
                ),
                other => anyhow::Error::new(other),
            })?;
        if version != PROTOCOL_VERSION {
            bail!("device at {addr} speaks protocol v{version}, this client v{PROTOCOL_VERSION}");
        }
        // jitter seeded per-process/per-address so a fleet of clients
        // redialing one restarted device fans out instead of stampeding
        let seed = std::process::id() as u64 ^ addr.bytes().fold(0u64, |h, b| {
            h.wrapping_mul(131).wrapping_add(b as u64)
        });
        Ok(BridgeBackend {
            addr: addr.to_string(),
            info,
            buckets,
            supports_batched: supports_batched_decode,
            ffn_weight_bytes: (ffn_weight_bytes > 0).then_some(ffn_weight_bytes as usize),
            conn: RefCell::new(conn),
            next_session: Cell::new(1),
            history: RefCell::new(HashMap::new()),
            jitter: RefCell::new(Rng::new(seed | 1)),
            obs: RefCell::new(None),
        })
    }

    /// Record one frame round-trip time (µs) for `opcode` into the
    /// attached registry; silently a no-op when none is attached.
    fn record_rtt(&self, opcode: u8, t0: std::time::Instant) {
        if let Some(obs) = self.obs.borrow().as_ref() {
            if let Some(h) = obs.frame_rtt(opcode) {
                h.record(t0.elapsed().as_micros() as u64);
            }
        }
    }

    /// The device address this backend talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Snapshot of the transport counters.
    pub fn meter(&self) -> TransferMeter {
        self.conn.borrow().meter
    }

    fn fresh_session_id(&self) -> u32 {
        let id = self.next_session.get();
        // skip the reserved 0 on wrap-around
        self.next_session.set(id.checked_add(1).unwrap_or(1));
        id
    }

    /// Run one wire exchange, replaying it after a reconnect when the
    /// transport dies mid-call. `Protocol` and `Backend` errors pass
    /// straight through — only `Io` is retryable.
    fn call<T>(&self, mut op: impl FnMut(&mut Conn) -> Result<T, BridgeError>) -> Result<T> {
        let mut cycles = 0;
        loop {
            let result = op(&mut self.conn.borrow_mut());
            match result {
                Ok(v) => return Ok(v),
                Err(BridgeError::Io(e)) if cycles < RECONNECT_CYCLES_PER_CALL => {
                    cycles += 1;
                    self.reconnect(&e)?;
                }
                Err(e) => return Err(anyhow::Error::new(e)),
            }
        }
    }

    /// The connection is gone. Redial with capped exponential backoff
    /// plus jitter, re-verify the device identity, and restore every
    /// live session from its token history — so to the engine a device
    /// restart is one slow call, not a failed completion.
    fn reconnect(&self, cause: &std::io::Error) -> Result<()> {
        // carry the transport counters across; the dead connection's
        // pipelined closes died with it (the device reclaims those
        // sessions on disconnect, and closed ids are out of `history`)
        let meter = self.conn.borrow().meter;
        // span start: the moment the outage was detected
        let span_start = self.obs.borrow().as_ref().map(|o| o.now_ns());
        let mut delay = BACKOFF_BASE_MS;
        let mut last = cause.to_string();
        for attempt in 1..=RECONNECT_ATTEMPTS {
            let jitter = self.jitter.borrow_mut().next_u64() % (delay / 2 + 1);
            thread::sleep(Duration::from_millis(delay + jitter));
            match Self::handshake(&self.addr, meter) {
                Ok((mut conn, version, info, ..)) => {
                    if version != PROTOCOL_VERSION {
                        bail!(
                            "device at {} restarted speaking protocol v{version}, \
                             this client v{PROTOCOL_VERSION}",
                            self.addr
                        );
                    }
                    if info.name != self.info.name
                        || info.vocab != self.info.vocab
                        || info.max_tokens != self.info.max_tokens
                    {
                        bail!(
                            "device at {} restarted with a different model \
                             ({} vs {}); refusing to resume sessions on it",
                            self.addr,
                            info.name,
                            self.info.name
                        );
                    }
                    match self.replay_sessions(&mut conn) {
                        Ok(()) => {
                            conn.meter.reconnects += 1;
                            let cycle = conn.meter.reconnects;
                            *self.conn.borrow_mut() = conn;
                            // the recovery window — outage detected to
                            // sessions replayed — as a trace span
                            if let Some(obs) = self.obs.borrow().as_ref() {
                                let end = obs.now_ns();
                                obs.trace.record(
                                    0,
                                    SpanKind::Reconnect,
                                    span_start.unwrap_or(end),
                                    end,
                                    cycle,
                                );
                            }
                            eprintln!(
                                "bridge: reconnected to {} (attempt {attempt}) after: {cause}",
                                self.addr
                            );
                            return Ok(());
                        }
                        // died again mid-replay: keep dialing
                        Err(BridgeError::Io(e)) => last = e.to_string(),
                        Err(e) => {
                            return Err(anyhow::Error::new(e)
                                .context("restoring sessions after reconnect"))
                        }
                    }
                }
                Err(BridgeError::Io(e)) => last = e.to_string(),
                Err(e) => return Err(anyhow::Error::new(e).context("reconnect handshake")),
            }
            delay = (delay * 2).min(BACKOFF_CAP_MS);
        }
        Err(anyhow!(
            "device at {} unreachable after {RECONNECT_ATTEMPTS} reconnect attempts \
             (last: {last}; original failure: {cause})",
            self.addr
        ))
    }

    /// Re-open and re-prefill every live session on a fresh connection,
    /// under its original client-chosen id. The device restarted (or
    /// reclaimed this client's sessions on disconnect), so every id is
    /// free; re-prefill adopts whatever the device's prefix cache still
    /// holds and must land each session exactly at `history.len()`.
    fn replay_sessions(&self, conn: &mut Conn) -> Result<(), BridgeError> {
        let history = self.history.borrow();
        for (&id, tokens) in history.iter() {
            conn.meter.calls += 1;
            conn.send(&Frame::OpenSession { session: id })?;
            conn.send(&Frame::Prefill { session: id, prompt: tokens.clone() })?;
            conn.flush()?;
            let opened = conn.recv()?;
            let logits = conn.recv()?;
            match opened {
                Frame::SessionOpened { .. } => {}
                other => return Err(unexpected(other, "SessionOpened")),
            }
            match logits {
                Frame::Logits { pos, .. } if pos as usize == tokens.len() => {}
                Frame::Logits { pos, .. } => {
                    return Err(BridgeError::Protocol(format!(
                        "re-prefill restored session {id} to pos {pos}, expected {}",
                        tokens.len()
                    )))
                }
                other => return Err(unexpected(other, "Logits")),
            }
        }
        Ok(())
    }
}

impl Backend for BridgeBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let id = self.fresh_session_id();
        let (pos, logits) = self.call(|conn| {
            conn.meter.calls += 1;
            let t0 = std::time::Instant::now();
            // pipeline OpenSession + Prefill in one flush (one round
            // trip); BOTH replies are drained before either is
            // inspected, so an error on the first never leaves the
            // second unread in the pipe
            conn.send(&Frame::OpenSession { session: id })?;
            conn.send(&Frame::Prefill { session: id, prompt: prompt.to_vec() })?;
            conn.flush()?;
            let opened = conn.recv_reply()?;
            let logits_frame = conn.recv()?;
            let session = match opened {
                Frame::SessionOpened { session } => session,
                other => return Err(unexpected(other, "SessionOpened")),
            };
            let (s2, pos, logits) = match logits_frame {
                Frame::Logits { session, pos, logits } => (session, pos, logits),
                other => {
                    // the slot WAS opened but never prefilled — release
                    // it, or every failed prefill would consume one of
                    // the connection's session-table slots for good
                    let _ = conn.send(&Frame::CloseSession { session: id });
                    let _ = conn.flush();
                    let _ = conn.recv(); // drain the Closed/Error reply
                    return Err(unexpected(other, "Logits"));
                }
            };
            if session != id || s2 != id {
                return Err(BridgeError::Protocol(
                    "session id mismatch in prefill replies".to_string(),
                ));
            }
            if logits.len() != self.info.vocab {
                return Err(BridgeError::Protocol(format!(
                    "logits row of {} for vocab {}",
                    logits.len(),
                    self.info.vocab
                )));
            }
            self.record_rtt(0x03, t0); // Prefill
            Ok((pos, logits))
        })?;
        self.history.borrow_mut().insert(id, prompt.to_vec());
        // the host session carries no KV tensors — the device owns the
        // cache; only position and the remote id live here
        let mut sess = Session::new([0, 0, 0, 0]);
        sess.pos = pos as usize;
        sess.tag = id as u64;
        Ok((logits, sess))
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        let id = session.tag as u32;
        if id == 0 {
            bail!("bridge: session has no remote id (already closed?)");
        }
        let (pos, logits) = self.call(|conn| {
            conn.meter.calls += 1;
            let t0 = std::time::Instant::now();
            conn.send(&Frame::Decode { session: id, token })?;
            conn.flush()?;
            match conn.recv_reply()? {
                Frame::Logits { session: sid, pos, logits } if sid == id => {
                    self.record_rtt(0x04, t0); // Decode
                    Ok((pos, logits))
                }
                Frame::Logits { session: sid, .. } => Err(BridgeError::Protocol(format!(
                    "logits for session {sid}, asked for {id}"
                ))),
                other => Err(unexpected(other, "Logits")),
            }
        })?;
        session.pos = pos as usize;
        if let Some(h) = self.history.borrow_mut().get_mut(&id) {
            h.push(token);
        }
        Ok(logits)
    }

    fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let ids: Vec<u32> = sessions.iter().map(|s| s.tag as u32).collect();
        if ids.iter().any(|&id| id == 0) {
            bail!("bridge: a batched session has no remote id (already closed?)");
        }
        let rows = self.call(|conn| {
            conn.meter.calls += 1;
            let t0 = std::time::Instant::now();
            conn.send(&Frame::DecodeBatch { sessions: ids.clone(), tokens: tokens.to_vec() })?;
            conn.flush()?;
            let rows = match conn.recv_reply()? {
                Frame::LogitsBatch { rows } => rows,
                other => return Err(unexpected(other, "LogitsBatch")),
            };
            if rows.len() != ids.len() {
                return Err(BridgeError::Protocol(format!(
                    "{} logits rows for a batch of {}",
                    rows.len(),
                    ids.len()
                )));
            }
            for (row, &id) in rows.iter().zip(ids.iter()) {
                if row.session != id {
                    return Err(BridgeError::Protocol(format!(
                        "row for session {} in the slot of {}",
                        row.session, id
                    )));
                }
            }
            self.record_rtt(0x05, t0); // DecodeBatch
            Ok(rows)
        })?;
        let mut history = self.history.borrow_mut();
        let mut out = Vec::with_capacity(rows.len());
        for ((row, s), (&id, &token)) in rows
            .into_iter()
            .zip(sessions.iter_mut())
            .zip(ids.iter().zip(tokens.iter()))
        {
            s.pos = row.pos as usize;
            if let Some(h) = history.get_mut(&id) {
                h.push(token);
            }
            out.push(row.logits);
        }
        Ok(out)
    }

    fn supports_batched_decode(&self) -> bool {
        // the *device's* capability: a shared round there is a shared
        // round end-to-end, because the whole batch rides one frame
        self.supports_batched
    }

    fn ffn_weight_bytes(&self) -> Option<usize> {
        self.ffn_weight_bytes
    }

    fn end_session(&self, session: &mut Session) {
        let id = session.tag as u32;
        if id == 0 {
            return; // never opened remotely, or already closed
        }
        session.tag = 0;
        // closed sessions must never be resurrected by a reconnect
        self.history.borrow_mut().remove(&id);
        // Close pipelining (the ROADMAP follow-on to PR 4's synchronous
        // close): the CloseSession frame is *buffered*, not flushed, and
        // its reply is not awaited — retirement costs zero round trips
        // and zero syscalls. The frame rides the next request's flush
        // (in steady state, the next round's DecodeBatch), and its reply
        // is drained by `recv_reply` in front of that request's reply.
        // The device session gauge is therefore eventually-consistent:
        // any subsequent request/reply exchange (a decode round, a
        // `memory()` stats query) proves all prior closes were applied,
        // and a disconnect still reclaims everything server-side.
        // Best effort by contract: a failure must not fail retirement
        // and must not trigger a reconnect (a dead connection's
        // sessions die with it on the device anyway).
        let Ok(mut conn) = self.conn.try_borrow_mut() else {
            return;
        };
        conn.meter.calls += 1;
        match conn.send(&Frame::CloseSession { session: id }) {
            Ok(()) => conn.pending_closes += 1,
            Err(e) => eprintln!("bridge: closing session {id}: {e}"),
        }
    }

    /// The *device's* arena accounting, fetched fresh per call: `Info`
    /// doubles as the stats query and its flush carries any pipelined
    /// closes, so the figures already reflect every prior retirement.
    fn memory(&self) -> Option<MemoryStats> {
        // defensive re-entrancy guard (Backend methods take &self)
        if self.conn.try_borrow_mut().is_err() {
            return None;
        }
        let fetched = self.call(|conn| {
            conn.meter.calls += 1;
            let t0 = std::time::Instant::now();
            conn.send(&Frame::Info { version: PROTOCOL_VERSION })?;
            conn.flush()?;
            match conn.recv_reply()? {
                Frame::InfoResp { memory, .. } => {
                    self.record_rtt(0x01, t0); // Info
                    Ok(memory)
                }
                other => Err(unexpected(other, "InfoResp")),
            }
        });
        match fetched {
            Ok(m) => m,
            Err(e) => {
                eprintln!("bridge: memory stats query failed: {e:#}");
                None
            }
        }
    }

    fn is_remote(&self) -> bool {
        true
    }

    fn transfer_meter(&self) -> Option<TransferMeter> {
        Some(self.conn.borrow().meter)
    }

    /// Adopt the serving side's registry: frame RTTs and reconnect
    /// spans land in the engine's own histograms and trace ring.
    fn attach_obs(&self, obs: &std::sync::Arc<Obs>) {
        *self.obs.borrow_mut() = Some(std::sync::Arc::clone(obs));
    }

    /// The device's arena pressure, read out of the `InfoResp` obs tail
    /// (same round trip as [`BridgeBackend::device_obs`]).
    fn kv_pressure(&self) -> Option<KvPressure> {
        self.device_obs().map(|o| KvPressure {
            alloc_stalls: o.alloc_stalls,
            cow_copies: o.cow_copies,
        })
    }

    /// The device daemon's own observability summary, fetched fresh:
    /// `Info` doubles as the obs query exactly as it does for `memory`.
    fn device_obs(&self) -> Option<ObsStats> {
        // defensive re-entrancy guard (Backend methods take &self)
        if self.conn.try_borrow_mut().is_err() {
            return None;
        }
        let fetched = self.call(|conn| {
            conn.meter.calls += 1;
            let t0 = std::time::Instant::now();
            conn.send(&Frame::Info { version: PROTOCOL_VERSION })?;
            conn.flush()?;
            match conn.recv_reply()? {
                Frame::InfoResp { obs, .. } => {
                    self.record_rtt(0x01, t0); // Info
                    Ok(obs)
                }
                other => Err(unexpected(other, "InfoResp")),
            }
        });
        match fetched {
            Ok(o) => o,
            Err(e) => {
                eprintln!("bridge: device obs query failed: {e:#}");
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_refused_is_a_structured_error() {
        // bind-then-drop yields a local port that refuses connections
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = BridgeBackend::connect(&format!("127.0.0.1:{port}")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("device unreachable at 127.0.0.1:"), "{msg}");
        assert!(msg.contains("device-serve"), "{msg}");
    }

    #[test]
    fn session_id_allocation_skips_zero() {
        // pure arithmetic on the Cell, no connection needed
        let c = Cell::new(u32::MAX);
        let id = c.get();
        c.set(id.checked_add(1).unwrap_or(1));
        assert_eq!(c.get(), 1, "wrap-around skips the reserved 0");
    }

    #[test]
    fn error_frames_map_to_typed_backend_errors() {
        let e = unexpected(
            Frame::Error { code: ErrCode::Backend, message: "kv arena exhausted: x".into() },
            "Logits",
        );
        match &e {
            BridgeError::Backend { code, message } => {
                assert_eq!(*code, ErrCode::Backend);
                assert!(message.contains("kv arena exhausted"));
            }
            other => panic!("expected Backend, got {other:?}"),
        }
        // the rendering keeps the legacy "device error (Code): msg"
        // shape operators and tests already match on
        assert!(e.to_string().starts_with("device error (Backend):"), "{e}");

        let p = unexpected(Frame::Closed { session: 1 }, "Logits");
        assert!(matches!(p, BridgeError::Protocol(_)), "{p:?}");
    }

    #[test]
    fn io_errors_are_the_only_retryable_kind() {
        let io = BridgeError::from(std::io::Error::new(ErrorKind::ConnectionReset, "rst"));
        assert!(matches!(io, BridgeError::Io(_)));
        // InvalidData marks a locally-built oversized frame: a client
        // bug, not a dead connection — it must not trigger redialing
        let local = BridgeError::from(std::io::Error::new(ErrorKind::InvalidData, "too big"));
        assert!(matches!(local, BridgeError::Protocol(_)));
    }
}
