//! [`BridgeBackend`]: the [`Backend`] trait implemented over the bridge
//! transport — the CPU-side coordinator's view of a remote device.
//!
//! Construction connects, performs the `Info` handshake, and caches the
//! device's architecture + capability flags, so to the scheduler a
//! remote device is indistinguishable from an in-process backend: same
//! trait, same validation (the local [`LlmRuntime`] wrapper still
//! guards every call), bit-identical logits — f32 rows cross the wire
//! as raw little-endian bits, never reformatted.
//!
//! Call mapping (each is one transport round trip):
//!
//! * `prefill` → `OpenSession` + `Prefill`, pipelined in one flush; the
//!   remote session id rides home in [`Session::tag`];
//! * `decode` → `Decode`; `decode_batch` → a single `DecodeBatch` frame
//!   for the whole round (the weight-stream-once batching argument
//!   applies to the wire, too: one round trip per round, not per
//!   session);
//! * scheduler retirement → `end_session` → a *pipelined*
//!   `CloseSession`: the frame is buffered and flushed with the next
//!   request (in steady state the next round's `DecodeBatch`), and its
//!   reply is drained in front of that request's reply — retirement
//!   costs zero round trips. The device session gauge is
//!   eventually-consistent; any later request/reply exchange (or a
//!   [`Backend::memory`] stats query) proves the closes were applied,
//!   and disconnect still reclaims everything.
//!
//! Every frame is counted by a [`TransferMeter`] (host→device tx,
//! device→host rx, per-call), the transport analogue of the paper's
//! HBM-bandwidth-utilization metric; `benches/bridge_overhead.rs`
//! reports bytes/token from it, and the serving stats line exposes it
//! when the engine's backend is remote.
//!
//! A refused connection maps to a structured error naming the address
//! and the fix (`edgellm device-serve`) — the first thing an operator
//! sees when the daemon is down, so it must not be a bare os error.
//!
//! [`Backend`]: crate::runtime::backend::Backend
//! [`LlmRuntime`]: crate::runtime::model::LlmRuntime
//! [`TransferMeter`]: crate::runtime::backend::TransferMeter

use std::cell::{Cell, RefCell};
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use super::protocol::{self, Frame, PROTOCOL_VERSION};
use crate::runtime::backend::{Backend, TransferMeter};
use crate::runtime::kv::MemoryStats;
use crate::runtime::model::{ModelInfo, Session};

/// The connection: buffered halves of one TCP stream plus the meter.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    meter: TransferMeter,
    /// `CloseSession` frames written but whose replies have not been
    /// read yet (close pipelining): the frames sit in the write buffer
    /// until the next request flushes them, and their replies — which
    /// the device sends strictly in request order — are drained in
    /// front of that request's reply by [`Conn::recv_reply`].
    pending_closes: usize,
}

impl Conn {
    fn send(&mut self, f: &Frame) -> Result<()> {
        let n = protocol::write_frame(&mut self.writer, f)
            .map_err(|e| anyhow!("device write failed: {e}"))?;
        self.meter.tx_bytes += n as u64;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.writer.flush().map_err(|e| anyhow!("device write failed: {e}"))
    }

    fn recv(&mut self) -> Result<Frame> {
        match protocol::read_frame(&mut self.reader) {
            Ok(Some((f, n))) => {
                self.meter.rx_bytes += n as u64;
                Ok(f)
            }
            Ok(None) => bail!("device closed the connection"),
            Err(e) => bail!("device read failed: {e}"),
        }
    }

    /// Read the reply to the request just flushed, draining any
    /// pipelined `CloseSession` replies queued in front of it first.
    /// Closes are best-effort by contract, so their replies are only
    /// sanity-checked, never failed on.
    fn recv_reply(&mut self) -> Result<Frame> {
        while self.pending_closes > 0 {
            self.pending_closes -= 1;
            match self.recv()? {
                // Closed, or a structured error (daemon restarted, id
                // unknown): the device holds no state either way
                Frame::Closed { .. } | Frame::Error { .. } => {}
                other => eprintln!(
                    "bridge: unexpected {} reply to a pipelined close",
                    other.name()
                ),
            }
        }
        self.recv()
    }
}

/// Turn an unexpected reply into the error the caller reports: device
/// error frames keep their structured code, anything else names the
/// frame kinds involved (never payloads).
fn unexpected(frame: Frame, want: &str) -> anyhow::Error {
    match frame {
        Frame::Error { code, message } => anyhow!("device error ({code:?}): {message}"),
        other => anyhow!("bridge protocol error: expected {want}, got {}", other.name()),
    }
}

/// `Backend` over the bridge transport. See the module docs.
pub struct BridgeBackend {
    addr: String,
    info: ModelInfo,
    buckets: Vec<usize>,
    supports_batched: bool,
    ffn_weight_bytes: Option<usize>,
    /// interior mutability: `Backend` methods take `&self`; the engine
    /// serializes calls externally (it lives behind the server's mutex)
    conn: RefCell<Conn>,
    /// next client-chosen remote session id; 0 is reserved as "no
    /// remote session" so `Session::tag` can mark closed sessions
    next_session: Cell<u32>,
}

impl BridgeBackend {
    /// Connect to a device daemon at `addr` ("host:port") and perform
    /// the `Info` handshake. Connection refusal and version mismatch
    /// are structured errors, not panics — they are the two failures an
    /// operator hits first.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).map_err(|e| {
            anyhow!(
                "device unreachable at {addr}: {e} \
                 (start one with `edgellm device-serve --addr {addr}`)"
            )
        })?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        let mut conn = Conn {
            reader,
            writer,
            meter: TransferMeter::default(),
            pending_closes: 0,
        };
        conn.meter.calls += 1;
        conn.send(&Frame::Info { version: PROTOCOL_VERSION })?;
        conn.flush()?;
        let (version, info, buckets, supports_batched_decode, ffn_weight_bytes) =
            match conn.recv()? {
                Frame::InfoResp {
                    version,
                    info,
                    buckets,
                    supports_batched_decode,
                    ffn_weight_bytes,
                    // handshake-time arena stats go stale immediately;
                    // `memory()` re-queries for a fresh snapshot
                    memory: _,
                } => (version, info, buckets, supports_batched_decode, ffn_weight_bytes),
                other => return Err(unexpected(other, "InfoResp")),
            };
        if version != PROTOCOL_VERSION {
            bail!("device at {addr} speaks protocol v{version}, this client v{PROTOCOL_VERSION}");
        }
        Ok(BridgeBackend {
            addr: addr.to_string(),
            info,
            buckets,
            supports_batched: supports_batched_decode,
            ffn_weight_bytes: (ffn_weight_bytes > 0).then_some(ffn_weight_bytes as usize),
            conn: RefCell::new(conn),
            next_session: Cell::new(1),
        })
    }

    /// The device address this backend talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Snapshot of the transport counters.
    pub fn meter(&self) -> TransferMeter {
        self.conn.borrow().meter
    }

    fn fresh_session_id(&self) -> u32 {
        let id = self.next_session.get();
        // skip the reserved 0 on wrap-around
        self.next_session.set(id.checked_add(1).unwrap_or(1));
        id
    }
}

impl Backend for BridgeBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Session)> {
        let id = self.fresh_session_id();
        let mut conn = self.conn.borrow_mut();
        conn.meter.calls += 1;
        // pipeline OpenSession + Prefill in one flush (one round trip);
        // BOTH replies are drained before either is inspected, so an
        // error on the first never leaves the second unread in the pipe
        conn.send(&Frame::OpenSession { session: id })?;
        conn.send(&Frame::Prefill { session: id, prompt: prompt.to_vec() })?;
        conn.flush()?;
        let opened = conn.recv_reply()?;
        let logits_frame = conn.recv()?;
        let session = match opened {
            Frame::SessionOpened { session } => session,
            other => return Err(unexpected(other, "SessionOpened")),
        };
        let (s2, pos, logits) = match logits_frame {
            Frame::Logits { session, pos, logits } => (session, pos, logits),
            other => {
                // the slot WAS opened but never prefilled — release it,
                // or every failed prefill would consume one of the
                // connection's session-table slots for good
                let _ = conn.send(&Frame::CloseSession { session: id });
                let _ = conn.flush();
                let _ = conn.recv(); // drain the Closed/Error reply
                return Err(unexpected(other, "Logits"));
            }
        };
        if session != id || s2 != id {
            bail!("bridge protocol error: session id mismatch in prefill replies");
        }
        if logits.len() != self.info.vocab {
            bail!(
                "bridge protocol error: logits row of {} for vocab {}",
                logits.len(),
                self.info.vocab
            );
        }
        // the host session carries no KV tensors — the device owns the
        // cache; only position and the remote id live here
        let mut sess = Session::new([0, 0, 0, 0]);
        sess.pos = pos as usize;
        sess.tag = id as u64;
        Ok((logits, sess))
    }

    fn decode(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        let id = session.tag as u32;
        if id == 0 {
            bail!("bridge: session has no remote id (already closed?)");
        }
        let mut conn = self.conn.borrow_mut();
        conn.meter.calls += 1;
        conn.send(&Frame::Decode { session: id, token })?;
        conn.flush()?;
        let (sid, pos, logits) = match conn.recv_reply()? {
            Frame::Logits { session, pos, logits } => (session, pos, logits),
            other => return Err(unexpected(other, "Logits")),
        };
        if sid != id {
            bail!("bridge protocol error: logits for session {sid}, asked for {id}");
        }
        session.pos = pos as usize;
        Ok(logits)
    }

    fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let ids: Vec<u32> = sessions.iter().map(|s| s.tag as u32).collect();
        if ids.iter().any(|&id| id == 0) {
            bail!("bridge: a batched session has no remote id (already closed?)");
        }
        let mut conn = self.conn.borrow_mut();
        conn.meter.calls += 1;
        conn.send(&Frame::DecodeBatch { sessions: ids.clone(), tokens: tokens.to_vec() })?;
        conn.flush()?;
        let rows = match conn.recv_reply()? {
            Frame::LogitsBatch { rows } => rows,
            other => return Err(unexpected(other, "LogitsBatch")),
        };
        if rows.len() != sessions.len() {
            bail!(
                "bridge protocol error: {} logits rows for a batch of {}",
                rows.len(),
                sessions.len()
            );
        }
        let mut out = Vec::with_capacity(rows.len());
        for ((row, s), &id) in rows.into_iter().zip(sessions.iter_mut()).zip(ids.iter()) {
            if row.session != id {
                bail!(
                    "bridge protocol error: row for session {} in the slot of {}",
                    row.session,
                    id
                );
            }
            s.pos = row.pos as usize;
            out.push(row.logits);
        }
        Ok(out)
    }

    fn supports_batched_decode(&self) -> bool {
        // the *device's* capability: a shared round there is a shared
        // round end-to-end, because the whole batch rides one frame
        self.supports_batched
    }

    fn ffn_weight_bytes(&self) -> Option<usize> {
        self.ffn_weight_bytes
    }

    fn end_session(&self, session: &mut Session) {
        let id = session.tag as u32;
        if id == 0 {
            return; // never opened remotely, or already closed
        }
        session.tag = 0;
        // Close pipelining (the ROADMAP follow-on to PR 4's synchronous
        // close): the CloseSession frame is *buffered*, not flushed, and
        // its reply is not awaited — retirement costs zero round trips
        // and zero syscalls. The frame rides the next request's flush
        // (in steady state, the next round's DecodeBatch), and its reply
        // is drained by `recv_reply` in front of that request's reply.
        // The device session gauge is therefore eventually-consistent:
        // any subsequent request/reply exchange (a decode round, a
        // `memory()` stats query) proves all prior closes were applied,
        // and a disconnect still reclaims everything server-side.
        // Best effort by contract: a failure must not fail retirement.
        let Ok(mut conn) = self.conn.try_borrow_mut() else {
            return;
        };
        conn.meter.calls += 1;
        match conn.send(&Frame::CloseSession { session: id }) {
            Ok(()) => conn.pending_closes += 1,
            Err(e) => eprintln!("bridge: closing session {id}: {e:#}"),
        }
    }

    /// The *device's* arena accounting, fetched fresh per call: `Info`
    /// doubles as the stats query and its flush carries any pipelined
    /// closes, so the figures already reflect every prior retirement.
    fn memory(&self) -> Option<MemoryStats> {
        let Ok(mut conn) = self.conn.try_borrow_mut() else {
            return None;
        };
        conn.meter.calls += 1;
        let fetch = |conn: &mut Conn| -> Result<Option<MemoryStats>> {
            conn.send(&Frame::Info { version: PROTOCOL_VERSION })?;
            conn.flush()?;
            match conn.recv_reply()? {
                Frame::InfoResp { memory, .. } => Ok(memory),
                other => Err(unexpected(other, "InfoResp")),
            }
        };
        match fetch(&mut *conn) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("bridge: memory stats query failed: {e:#}");
                None
            }
        }
    }

    fn is_remote(&self) -> bool {
        true
    }

    fn transfer_meter(&self) -> Option<TransferMeter> {
        Some(self.conn.borrow().meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_refused_is_a_structured_error() {
        // bind-then-drop yields a local port that refuses connections
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = BridgeBackend::connect(&format!("127.0.0.1:{port}")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("device unreachable at 127.0.0.1:"), "{msg}");
        assert!(msg.contains("device-serve"), "{msg}");
    }

    #[test]
    fn session_id_allocation_skips_zero() {
        // pure arithmetic on the Cell, no connection needed
        let c = Cell::new(u32::MAX);
        let id = c.get();
        c.set(id.checked_add(1).unwrap_or(1));
        assert_eq!(c.get(), 1, "wrap-around skips the reserved 0");
    }
}
