//! The CPU↔device bridge: a wire between the coordinator and any
//! [`Backend`](crate::runtime::backend::Backend).
//!
//! EdgeLLM is a *heterogeneous* system: the CPU-side coordinator streams
//! a unified command/data layout to the accelerator and reads results
//! back. Everything above the [`Backend`] trait — scheduler, streaming
//! protocol, cancellation — is already transport-agnostic; this module
//! supplies the transport, so "the FPGA is on the other end of a wire"
//! stops being a simulation detail and becomes a deployment shape:
//!
//! * [`protocol`] — the length-prefixed binary command-stream protocol.
//!   Frames carry the same flat rows the paper's universal data-parallel
//!   layout mandates (token ids one `i32` each, logits one `f32` per
//!   vocab entry, little-endian), so no reshaping happens at either end.
//! * [`device`] — the device daemon: a TCP listener hosting any
//!   `Box<dyn Backend>` (`SimBackend` to model the VCU128,
//!   `ReferenceBackend` for real compute) behind per-connection session
//!   tables, with structured error frames and clean shutdown.
//! * [`client`] — [`client::BridgeBackend`]: `Backend` implemented over
//!   the transport, with a [`TransferMeter`] counting host→device /
//!   device→host bytes per call so benches report transport-bandwidth
//!   utilization next to tokens/s, the way the paper reports HBM
//!   utilization.
//!
//! Because both ends speak through `Backend`, the serving stack composes
//! freely: `edgellm device-serve` hosts the device side, `edgellm serve
//! --backend bridge --device host:port` runs the full continuous-batching
//! scheduler against it, and completions are bit-identical to running the
//! same backend in-process (`rust/tests/bridge.rs`).
//!
//! [`Backend`]: crate::runtime::backend::Backend
//! [`TransferMeter`]: crate::runtime::backend::TransferMeter

pub mod client;
pub mod device;
pub mod protocol;
