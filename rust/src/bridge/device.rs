//! The device daemon: any [`Backend`] served over the bridge protocol.
//!
//! `edgellm device-serve` (or [`spawn_on`] from tests/examples) puts a
//! backend behind a TCP listener and speaks the command-stream protocol
//! of [`super::protocol`]. This is the "FPGA side" of the paper's
//! deployment: the coordinator machine runs the scheduler, the device
//! machine runs the datapath — [`SimBackend`] to model the VCU128,
//! [`ReferenceBackend`] for real compute, and eventually a thin daemon
//! in front of real accelerator drivers.
//!
//! Design points:
//!
//! * **Validation is hosted, not duplicated.** The daemon wraps its
//!   backend in [`LlmRuntime`], so every wire call inherits the same
//!   prompt/budget/arity validation in-process callers get; a hostile
//!   frame can produce an error frame, never a panicked daemon.
//! * **Sessions are connection-scoped.** Each connection owns a session
//!   table (client-chosen `u32` ids, bounded by
//!   [`DeviceConfig::max_sessions_per_conn`]); when the connection dies
//!   — cleanly, or mid-frame — every session in it is reclaimed. A
//!   crashing coordinator can therefore never leak device memory.
//! * **Structured failure.** Malformed payloads get an
//!   [`ErrCode::Protocol`] error frame and the connection continues
//!   (the length prefix kept the stream framed); an untrustworthy
//!   length prefix gets one final error frame and a close; backend
//!   errors map to [`ErrCode::Backend`] with the session left intact.
//!
//! [`Backend`]: crate::runtime::backend::Backend
//! [`SimBackend`]: crate::runtime::backend::SimBackend
//! [`ReferenceBackend`]: crate::runtime::backend::ReferenceBackend

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use anyhow::Result;

use super::protocol::{self, ErrCode, Frame, FrameError, LogitsRow, PROTOCOL_VERSION};
use crate::obs::{Hist, Obs};
use crate::runtime::backend::Backend;
use crate::runtime::model::{LlmRuntime, Session};

/// Daemon limits.
pub struct DeviceConfig {
    /// Max sessions one connection may hold open; `OpenSession` beyond
    /// it is answered with `ErrCode::Busy`. One coordinator connection
    /// needs `max_active` + in-flight-admission sessions, so the
    /// default is far above any sane scheduler pool.
    pub max_sessions_per_conn: usize,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig { max_sessions_per_conn: 256 }
    }
}

/// State shared between the acceptor and connection threads.
struct DeviceShared {
    runtime: Mutex<LlmRuntime>,
    cfg: DeviceConfig,
    shutdown: AtomicBool,
    /// open sessions across all live connections (observability + the
    /// no-leak test hook)
    open_sessions: AtomicUsize,
    /// live connection streams (clones keyed by a connection id),
    /// severed on shutdown so a daemon teardown looks exactly like a
    /// device restart to clients: connection reset, all state gone
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
    /// daemon-side observability: the frame service-time histogram that
    /// travels back in the `InfoResp` obs tail
    obs: Obs,
}

/// Running daemon: address, session gauge, and the acceptor to reap.
pub struct DeviceHandle {
    addr: SocketAddr,
    shared: Arc<DeviceShared>,
    acceptor: JoinHandle<()>,
}

impl DeviceHandle {
    /// The bound address (useful with an ephemeral port 0 listener).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Device-side sessions currently open across all connections.
    pub fn active_sessions(&self) -> usize {
        self.shared.open_sessions.load(Ordering::Relaxed)
    }

    /// Stop the daemon: refuse new connections, **sever every live
    /// connection**, and join the acceptor thread. Severed clients see
    /// a transport error and all their device-side sessions are
    /// reclaimed — to a [`BridgeBackend`](super::client::BridgeBackend)
    /// this is indistinguishable from a device power cycle, which its
    /// reconnect-and-replay path recovers from.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // sever live connections so their threads exit promptly instead
        // of lingering until the client hangs up
        for (_, stream) in crate::util::lock_unpoisoned(&self.shared.conns).drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        if crate::util::poke_acceptor(self.addr) {
            let _ = self.acceptor.join();
        } else {
            eprintln!(
                "device shutdown: could not poke {}, leaving acceptor parked",
                self.addr
            );
        }
    }
}

/// Host `backend` on `addr`, blocking the calling thread — the
/// `edgellm device-serve` entry point.
pub fn serve(backend: Box<dyn Backend>, addr: &str, cfg: DeviceConfig) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let handle = spawn_on(backend, listener, cfg)?;
    let _ = handle.acceptor.join();
    Ok(())
}

/// Host `backend` on an already-bound listener in the background and
/// return the daemon's [`DeviceHandle`].
pub fn spawn_on(
    backend: Box<dyn Backend>,
    listener: TcpListener,
    cfg: DeviceConfig,
) -> Result<DeviceHandle> {
    let addr = listener.local_addr()?;
    let name = backend.info().name.clone();
    eprintln!(
        "edgellm device daemon on {addr} (bridge protocol v{PROTOCOL_VERSION}, backend {name})"
    );
    let shared = Arc::new(DeviceShared {
        runtime: Mutex::new(LlmRuntime::from_backend(backend)),
        cfg,
        shutdown: AtomicBool::new(false),
        open_sessions: AtomicUsize::new(0),
        conns: Mutex::new(HashMap::new()),
        next_conn: AtomicU64::new(0),
        obs: Obs::new(),
    });
    let acceptor = {
        let shared = Arc::clone(&shared);
        thread::spawn(move || accept_loop(&shared, listener))
    };
    Ok(DeviceHandle { addr, shared, acceptor })
}

fn accept_loop(shared: &Arc<DeviceShared>, listener: TcpListener) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match stream {
            Ok(stream) => {
                // register a clone so shutdown can sever the connection
                let cid = shared.next_conn.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    crate::util::lock_unpoisoned(&shared.conns).insert(cid, clone);
                }
                let shared = Arc::clone(shared);
                thread::spawn(move || {
                    handle_conn(&shared, stream);
                    crate::util::lock_unpoisoned(&shared.conns).remove(&cid);
                });
            }
            Err(e) => eprintln!("device accept error: {e}"),
        }
    }
}

/// One connection: run the frame loop, then reclaim whatever sessions
/// it still holds — on *every* exit path, including transport errors.
fn handle_conn(shared: &DeviceShared, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let mut sessions: HashMap<u32, Option<Session>> = HashMap::new();
    let conn_hist = Hist::new();
    let result = conn_loop(shared, stream, &mut sessions, &conn_hist);
    shared.open_sessions.fetch_sub(sessions.len(), Ordering::Relaxed);
    if conn_hist.count() > 0 {
        let s = conn_hist.summary();
        eprintln!(
            "device client {peer}: served {} frames, service p50 {:.0}µs p99 {:.0}µs max {}µs",
            s.count, s.p50, s.p99, s.max
        );
    }
    if let Err(e) = result {
        eprintln!("device client {peer}: {e:#}");
    }
}

fn conn_loop(
    shared: &DeviceShared,
    stream: TcpStream,
    sessions: &mut HashMap<u32, Option<Session>>,
    conn_hist: &Hist,
) -> Result<()> {
    // per-call round trips live on the latency of small frames
    stream.set_nodelay(true)?;
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    loop {
        match protocol::read_frame(&mut reader) {
            Ok(None) => return Ok(()), // clean hangup
            Ok(Some((frame, _bytes))) => {
                let t0 = std::time::Instant::now();
                let reply = respond(shared, sessions, frame);
                let us = t0.elapsed().as_micros() as u64;
                shared.obs.frame_service_us.record(us);
                conn_hist.record(us);
                match protocol::write_frame(&mut writer, &reply) {
                    Ok(_) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                        // reply exceeded the frame cap (huge-vocab backend
                        // at a large batch): nothing hit the wire, so the
                        // stream is intact — answer structurally instead
                        let reply = Frame::Error {
                            code: ErrCode::Protocol,
                            message: format!("reply unsendable: {e}"),
                        };
                        protocol::write_frame(&mut writer, &reply)?;
                    }
                    Err(e) => return Err(e.into()),
                }
                writer.flush()?;
            }
            Err(FrameError::Malformed(m)) => {
                // length prefix was honored: the stream is still framed,
                // answer and keep serving this connection
                let reply = Frame::Error { code: ErrCode::Protocol, message: m };
                protocol::write_frame(&mut writer, &reply)?;
                writer.flush()?;
            }
            Err(FrameError::Desync(m)) => {
                // framing is gone; one best-effort error frame, then close
                let reply = Frame::Error { code: ErrCode::Protocol, message: m };
                let _ = protocol::write_frame(&mut writer, &reply);
                let _ = writer.flush();
                return Ok(());
            }
            Err(FrameError::Io(e)) => {
                // client died mid-frame — routine, not an error to log
                return if e.kind() == std::io::ErrorKind::UnexpectedEof
                    || e.kind() == std::io::ErrorKind::ConnectionReset
                {
                    Ok(())
                } else {
                    Err(e.into())
                };
            }
        }
    }
}

fn err(code: ErrCode, message: String) -> Frame {
    Frame::Error { code, message }
}

/// Map one request frame to its response frame. Pure with respect to
/// the transport — every outcome, including failure, is a frame.
fn respond(
    shared: &DeviceShared,
    sessions: &mut HashMap<u32, Option<Session>>,
    frame: Frame,
) -> Frame {
    match frame {
        Frame::Info { version } => {
            if version != PROTOCOL_VERSION {
                return err(
                    ErrCode::Version,
                    format!("client speaks protocol v{version}, device v{PROTOCOL_VERSION}"),
                );
            }
            let rt = crate::util::lock_unpoisoned(&shared.runtime);
            Frame::InfoResp {
                version: PROTOCOL_VERSION,
                info: rt.info.clone(),
                buckets: rt.prefill_buckets().to_vec(),
                supports_batched_decode: rt.supports_batched_decode(),
                ffn_weight_bytes: rt.ffn_weight_bytes().unwrap_or(0) as u64,
                // a point-in-time arena snapshot: `Info` doubles as the
                // client's memory-stats query, so the coordinator's
                // admission gate sees current device-side figures
                memory: rt.memory(),
                // and the obs tail carries the daemon's frame
                // service-time summary plus arena pressure counters
                obs: Some(shared.obs.device_stats(rt.kv_pressure())),
            }
        }
        Frame::OpenSession { session } => {
            if sessions.contains_key(&session) {
                return err(ErrCode::Session, format!("session {session} is already open"));
            }
            if sessions.len() >= shared.cfg.max_sessions_per_conn {
                return err(
                    ErrCode::Busy,
                    format!(
                        "session table full ({} open, max {})",
                        sessions.len(),
                        shared.cfg.max_sessions_per_conn
                    ),
                );
            }
            sessions.insert(session, None);
            shared.open_sessions.fetch_add(1, Ordering::Relaxed);
            Frame::SessionOpened { session }
        }
        Frame::Prefill { session, prompt } => {
            let Some(slot) = sessions.get_mut(&session) else {
                return err(ErrCode::Session, format!("session {session} is not open"));
            };
            match crate::util::lock_unpoisoned(&shared.runtime).prefill(&prompt) {
                Ok((logits, s)) => {
                    let pos = s.pos as u32;
                    // re-prefill resets the slot: device-side slot reuse
                    *slot = Some(s);
                    Frame::Logits { session, pos, logits }
                }
                Err(e) => err(ErrCode::Backend, format!("prefill: {e:#}")),
            }
        }
        Frame::Decode { session, token } => {
            let Some(Some(s)) = sessions.get_mut(&session) else {
                return err(
                    ErrCode::Session,
                    format!("session {session} is not open or not prefilled"),
                );
            };
            match crate::util::lock_unpoisoned(&shared.runtime).decode(s, token) {
                Ok(logits) => Frame::Logits { session, pos: s.pos as u32, logits },
                Err(e) => err(ErrCode::Backend, format!("decode: {e:#}")),
            }
        }
        Frame::DecodeBatch { sessions: ids, tokens } => {
            decode_batch(shared, sessions, &ids, &tokens)
        }
        Frame::CloseSession { session } => {
            if sessions.remove(&session).is_some() {
                shared.open_sessions.fetch_sub(1, Ordering::Relaxed);
                Frame::Closed { session }
            } else {
                err(ErrCode::Session, format!("session {session} is not open"))
            }
        }
        // response-shaped frames have no business arriving here
        other => err(
            ErrCode::Protocol,
            format!("unexpected {} frame on the device side", other.name()),
        ),
    }
}

/// One batched decode round over the connection's session table. The
/// sessions are temporarily taken out of the table so the runtime can
/// hold `&mut` to all of them at once; they are put back whatever the
/// outcome (a backend error must not eat the batch).
fn decode_batch(
    shared: &DeviceShared,
    table: &mut HashMap<u32, Option<Session>>,
    ids: &[u32],
    tokens: &[i32],
) -> Frame {
    let mut taken: Vec<(u32, Session)> = Vec::with_capacity(ids.len());
    for &id in ids {
        match table.get_mut(&id).and_then(|slot| slot.take()) {
            Some(s) => taken.push((id, s)),
            None => {
                for (tid, s) in taken {
                    if let Some(slot) = table.get_mut(&tid) {
                        *slot = Some(s);
                    }
                }
                return err(
                    ErrCode::Session,
                    format!("session {id} is not prefilled (or repeated in the batch)"),
                );
            }
        }
    }
    let result = {
        let mut refs: Vec<&mut Session> = taken.iter_mut().map(|(_, s)| s).collect();
        crate::util::lock_unpoisoned(&shared.runtime).decode_batch(&mut refs, tokens)
    };
    let reply = match result {
        Ok(logits) => Frame::LogitsBatch {
            rows: taken
                .iter()
                .zip(logits)
                .map(|(&(id, ref s), l)| LogitsRow { session: id, pos: s.pos as u32, logits: l })
                .collect(),
        },
        Err(e) => err(ErrCode::Backend, format!("decode_batch: {e:#}")),
    };
    for (id, s) in taken {
        if let Some(slot) = table.get_mut(&id) {
            *slot = Some(s);
        }
    }
    reply
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ReferenceBackend;
    use crate::runtime::reference::ReferenceConfig;

    fn spawn_tiny(cfg: DeviceConfig) -> DeviceHandle {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        spawn_on(
            Box::new(ReferenceBackend::new(ReferenceConfig::default())),
            listener,
            cfg,
        )
        .unwrap()
    }

    fn ask(stream: &mut TcpStream, f: &Frame) -> Frame {
        protocol::write_frame(stream, f).unwrap();
        protocol::read_frame(stream).unwrap().expect("reply").0
    }

    #[test]
    fn info_open_prefill_decode_close_lifecycle() {
        let dev = spawn_tiny(DeviceConfig::default());
        let mut c = TcpStream::connect(dev.addr()).unwrap();

        let (info, supports_batched_decode) =
            match ask(&mut c, &Frame::Info { version: PROTOCOL_VERSION }) {
                Frame::InfoResp { info, supports_batched_decode, .. } => {
                    (info, supports_batched_decode)
                }
                other => panic!("want InfoResp, got {}", other.name()),
            };
        assert_eq!(info.vocab, 256);
        assert!(supports_batched_decode, "reference backend shares rounds");

        assert!(matches!(
            ask(&mut c, &Frame::OpenSession { session: 5 }),
            Frame::SessionOpened { session: 5 }
        ));
        assert_eq!(dev.active_sessions(), 1);

        let pre = ask(&mut c, &Frame::Prefill { session: 5, prompt: vec![1, 2, 3] });
        match &pre {
            Frame::Logits { session: 5, pos: 3, logits } => assert_eq!(logits.len(), 256),
            other => panic!("want Logits(pos 3), got {other:?}"),
        }

        let dec = ask(&mut c, &Frame::Decode { session: 5, token: 9 });
        assert!(matches!(dec, Frame::Logits { session: 5, pos: 4, .. }), "{dec:?}");

        assert!(matches!(
            ask(&mut c, &Frame::CloseSession { session: 5 }),
            Frame::Closed { session: 5 }
        ));
        assert_eq!(dev.active_sessions(), 0);
        dev.shutdown();
    }

    #[test]
    fn info_resp_carries_service_time_obs_tail() {
        let dev = spawn_tiny(DeviceConfig::default());
        let mut c = TcpStream::connect(dev.addr()).unwrap();
        // do some work first so the histogram has samples
        ask(&mut c, &Frame::OpenSession { session: 1 });
        ask(&mut c, &Frame::Prefill { session: 1, prompt: vec![1, 2, 3] });
        ask(&mut c, &Frame::Decode { session: 1, token: 7 });
        let obs = match ask(&mut c, &Frame::Info { version: PROTOCOL_VERSION }) {
            Frame::InfoResp { obs, .. } => obs.expect("device always meters itself"),
            other => panic!("want InfoResp, got {}", other.name()),
        };
        assert!(obs.frames_served >= 3, "{obs:?}");
        assert!(obs.frame_p50_us <= obs.frame_p99_us, "{obs:?}");
        assert!(obs.frame_p99_us <= obs.frame_max_us, "{obs:?}");
        dev.shutdown();
    }

    #[test]
    fn session_errors_are_structured_and_nonfatal() {
        let dev = spawn_tiny(DeviceConfig { max_sessions_per_conn: 2 });
        let mut c = TcpStream::connect(dev.addr()).unwrap();

        // decode before open / before prefill
        let r = ask(&mut c, &Frame::Decode { session: 1, token: 0 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Session, .. }), "{r:?}");
        ask(&mut c, &Frame::OpenSession { session: 1 });
        let r = ask(&mut c, &Frame::Decode { session: 1, token: 0 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Session, .. }), "{r:?}");

        // duplicate open
        let r = ask(&mut c, &Frame::OpenSession { session: 1 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Session, .. }), "{r:?}");

        // table cap → Busy; closing frees capacity
        ask(&mut c, &Frame::OpenSession { session: 2 });
        let r = ask(&mut c, &Frame::OpenSession { session: 3 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Busy, .. }), "{r:?}");
        ask(&mut c, &Frame::CloseSession { session: 2 });
        assert!(matches!(
            ask(&mut c, &Frame::OpenSession { session: 3 }),
            Frame::SessionOpened { session: 3 }
        ));

        // oversized prompt → Backend error, session intact
        ask(&mut c, &Frame::Prefill { session: 1, prompt: vec![0; 4096] });
        let r = ask(&mut c, &Frame::Prefill { session: 1, prompt: vec![0; 4096] });
        assert!(matches!(r, Frame::Error { code: ErrCode::Backend, .. }), "{r:?}");
        let r = ask(&mut c, &Frame::Prefill { session: 1, prompt: vec![1, 2] });
        assert!(matches!(r, Frame::Logits { session: 1, pos: 2, .. }), "{r:?}");

        // version mismatch
        let r = ask(&mut c, &Frame::Info { version: 99 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Version, .. }), "{r:?}");

        // a response-shaped frame from a confused client
        let r = ask(&mut c, &Frame::Closed { session: 1 });
        assert!(matches!(r, Frame::Error { code: ErrCode::Protocol, .. }), "{r:?}");

        dev.shutdown();
    }

    #[test]
    fn disconnect_reclaims_sessions() {
        let dev = spawn_tiny(DeviceConfig::default());
        {
            let mut c = TcpStream::connect(dev.addr()).unwrap();
            ask(&mut c, &Frame::OpenSession { session: 1 });
            ask(&mut c, &Frame::OpenSession { session: 2 });
            ask(&mut c, &Frame::Prefill { session: 1, prompt: vec![1] });
            assert_eq!(dev.active_sessions(), 2);
        } // dropped without CloseSession
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while dev.active_sessions() != 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "disconnect leaked {} sessions",
                dev.active_sessions()
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        dev.shutdown();
    }

    #[test]
    fn batched_round_keeps_sessions_on_error() {
        let dev = spawn_tiny(DeviceConfig::default());
        let mut c = TcpStream::connect(dev.addr()).unwrap();
        for id in [1u32, 2] {
            ask(&mut c, &Frame::OpenSession { session: id });
            ask(&mut c, &Frame::Prefill { session: id, prompt: vec![id as i32 + 1] });
        }
        // a batch naming an unknown session fails whole, harming nobody
        let r = ask(&mut c, &Frame::DecodeBatch { sessions: vec![1, 9], tokens: vec![4, 5] });
        assert!(matches!(r, Frame::Error { code: ErrCode::Session, .. }), "{r:?}");
        // a duplicated session id fails the same way
        let r = ask(&mut c, &Frame::DecodeBatch { sessions: vec![1, 1], tokens: vec![4, 5] });
        assert!(matches!(r, Frame::Error { code: ErrCode::Session, .. }), "{r:?}");
        // both sessions still decode afterwards
        let good = Frame::DecodeBatch { sessions: vec![1, 2], tokens: vec![4, 5] };
        let rows = match ask(&mut c, &good) {
            Frame::LogitsBatch { rows } => rows,
            other => panic!("want LogitsBatch, got {}", other.name()),
        };
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].session, rows[0].pos), (1, 2));
        assert_eq!((rows[1].session, rows[1].pos), (2, 2));
        dev.shutdown();
    }
}
