//! edgellm — CLI for the EdgeLLM reproduction.
//!
//! Subcommands:
//!   serve        --addr HOST:PORT [--backend auto|ref|sim|bridge|artifacts]
//!                [--device HOST:PORT] [--artifacts DIR --model NAME]
//!                [--max-active N] [--max-queued N]
//!                [--prefill-chunk-tokens N] [--batch-aging-rounds N]
//!   device-serve --addr HOST:PORT [--backend ref|sim] [--max-sessions N]
//!                (host a backend behind the bridge command-stream protocol)
//!   generate     --prompt TEXT [--max-new N] [--temperature T] [--stream]
//!                [--backend auto|ref|sim|bridge|artifacts] [--device HOST:PORT]
//!   simulate     --arch glm|qwen|tiny --strategy dense|s1|s2|s3 --mem hbm|ddr
//!                [--ctx N] [--prefill N] [--batch B]
//!   info         [--backend auto|ref|sim|bridge|artifacts] [--device HOST:PORT]
//!   trace-dump   [--addr HOST:PORT] [--last N] [--out FILE]
//!                (pull the serving engine's lifecycle trace as Chrome
//!                trace-format JSON — load into chrome://tracing or Perfetto)

use edgellm::bridge::client::BridgeBackend;
use edgellm::bridge::device::{self, DeviceConfig};
use edgellm::coordinator::engine::{Engine, EngineConfig, Event};
use edgellm::coordinator::sampler::Sampling;
use edgellm::coordinator::server;
use edgellm::models::{self, LlmArch, SparseStrategy};
use edgellm::runtime::backend::{Backend, ReferenceBackend, SimBackend};
use edgellm::runtime::model::LlmRuntime;
use edgellm::runtime::reference::{KernelTier, ReferenceConfig};
use edgellm::sim::engine::Simulator;
use edgellm::sim::Memory;
use edgellm::util::Args;

/// Default port for the device daemon (the serving port + 1).
const DEFAULT_DEVICE_ADDR: &str = "127.0.0.1:7078";

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "device-serve" => cmd_device_serve(&args),
        "generate" => cmd_generate(&args),
        "simulate" => cmd_simulate(&args),
        "info" => cmd_info(&args),
        "trace-dump" => cmd_trace_dump(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "edgellm — CPU-FPGA heterogeneous LLM accelerator (reproduction)\n\n\
         USAGE:\n  edgellm serve    --addr 127.0.0.1:7077 --max-active 8 --max-queued 1024\n                   \
         --prefill-chunk-tokens 0 --batch-aging-rounds 32\n  \
         edgellm device-serve --addr {DEFAULT_DEVICE_ADDR} --backend sim\n  \
         edgellm generate --prompt \"Hello\" --max-new 32\n  \
         edgellm simulate --arch glm --strategy s3 --ctx 128 --batch 8\n  \
         edgellm info\n  \
         edgellm trace-dump --addr 127.0.0.1:7077 --last 4096 --out trace.json\n\n\
         Backends: --backend ref (pure-Rust reference model, default when\n\
         no artifacts are present; paged KV arena via --kv-block-tokens N\n\
         [64] and --kv-pool-blocks N [0 = auto]; kernel tier via\n\
         --kernel-tier auto|scalar|simd|simd-parallel [auto] and\n\
         --threads N [0 = auto] — all tiers are bit-identical,\n\
         scalar is the oracle), --backend sim (VCU128\n\
         latency model serving deterministic pseudo-tokens; --sim-arch\n\
         glm|qwen|tiny, --max-tokens N), --backend bridge (a remote device\n\
         daemon over the command-stream protocol; --device HOST:PORT, start\n\
         one with `edgellm device-serve`), --backend artifacts (AOT PJRT\n\
         artifacts from --artifacts/--model; needs the pjrt feature)."
    );
}

/// Reference-backend config with the KV-arena and kernel-tier flags
/// threaded in: `--kv-block-tokens` (tokens per arena block, default
/// 64), `--kv-pool-blocks` (pool capacity in blocks, 0 = auto),
/// `--kernel-tier auto|scalar|simd|simd-parallel` (default auto;
/// `EDGELLM_KERNEL_TIER` overrides auto) and `--threads N` (worker
/// count for the parallel tier, 0 = auto via `EDGELLM_THREADS` /
/// available parallelism).
fn ref_config(args: &Args) -> ReferenceConfig {
    let tier_arg = args.get_or("kernel-tier", "auto");
    let kernel_tier = match KernelTier::parse(&tier_arg) {
        Some(t) => t,
        None => {
            eprintln!(
                "unknown --kernel-tier {tier_arg:?} \
                 (want auto|scalar|simd|simd-parallel), using auto"
            );
            KernelTier::Auto
        }
    };
    ReferenceConfig {
        kv_block_tokens: args.get_usize("kv-block-tokens", 64),
        kv_pool_blocks: args.get_usize("kv-pool-blocks", 0),
        kernel_tier,
        threads: args.get_usize("threads", 0),
        ..ReferenceConfig::default()
    }
}

/// Load the functional runtime: AOT artifacts when requested/available,
/// otherwise the always-available pure-Rust reference model.
fn load_runtime(args: &Args) -> anyhow::Result<LlmRuntime> {
    let backend = args.get_or("backend", "auto");
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "tiny");
    let runtime = match backend.as_str() {
        "ref" => LlmRuntime::reference(ref_config(args)),
        "sim" => {
            let (arch, strat) = sim_arch_strategy(args);
            LlmRuntime::simulator(
                &arch,
                &strat,
                Memory::Hbm,
                args.get_usize("max-tokens", 512),
                args.get_usize("seed", 0xED6E) as u64,
            )
        }
        "bridge" => {
            let dev = args.get_or("device", DEFAULT_DEVICE_ADDR);
            LlmRuntime::from_backend(Box::new(BridgeBackend::connect(&dev)?))
        }
        "artifacts" | "pjrt" => LlmRuntime::load(&dir, &model)?,
        _ => LlmRuntime::load_or_reference(&dir, &model, ref_config(args)),
    };
    let decode_mode = if runtime.supports_batched_decode() {
        "shared round"
    } else {
        "stepped"
    };
    let remote = if runtime.is_remote() { ", remote device" } else { "" };
    let tier = match runtime.kernel_tier() {
        Some(t) => format!(", kernels: {t}"),
        None => String::new(),
    };
    eprintln!(
        "loaded {} ({:.1}M params, max_tokens={}, batched decode: {decode_mode}{remote}{tier})",
        runtime.info.name,
        runtime.info.n_params as f64 / 1e6,
        runtime.info.max_tokens,
    );
    Ok(runtime)
}

/// Backend hosted by `device-serve` — the device side of the bridge.
/// `ref` serves real compute, `sim` the VCU128 latency model (the
/// shape a thin daemon in front of real FPGA drivers would take).
fn device_backend(args: &Args) -> anyhow::Result<Box<dyn Backend>> {
    match args.get_or("backend", "ref").as_str() {
        "ref" => Ok(Box::new(ReferenceBackend::new(ref_config(args)))),
        "sim" => {
            let (arch, strat) = sim_arch_strategy(args);
            Ok(Box::new(SimBackend::new(
                &arch,
                &strat,
                Memory::Hbm,
                args.get_usize("max-tokens", 512),
                args.get_usize("seed", 0xED6E) as u64,
            )))
        }
        other => anyhow::bail!(
            "device-serve hosts --backend ref|sim (got {other}); \
             artifacts need the pjrt feature and load in-process"
        ),
    }
}

fn cmd_device_serve(args: &Args) -> anyhow::Result<()> {
    let backend = device_backend(args)?;
    let addr = args.get_or("addr", DEFAULT_DEVICE_ADDR);
    let cfg = DeviceConfig {
        max_sessions_per_conn: args.get_usize("max-sessions", 256),
    };
    device::serve(backend, &addr, cfg)
}

/// The architecture/strategy pair behind `--sim-arch` / `--strategy`.
fn sim_arch_strategy(args: &Args) -> (LlmArch, SparseStrategy) {
    let name = args.get_or("sim-arch", "tiny");
    let arch = match name.as_str() {
        "glm" => models::GLM_6B,
        "qwen" => models::QWEN_7B,
        "tiny" => models::TINY,
        other => {
            eprintln!("unknown sim-arch {other}, using tiny");
            models::TINY
        }
    };
    (arch, parse_strategy(&args.get_or("strategy", "dense")))
}

fn engine_config(args: &Args) -> EngineConfig {
    let mut cfg = EngineConfig {
        max_active: args.get_usize("max-active", 8),
        max_queued: args.get_usize("max-queued", 1024),
        prefill_chunk_tokens: args.get_usize("prefill-chunk-tokens", 0),
        batch_aging_rounds: args.get_usize("batch-aging-rounds", 32) as u64,
        ..EngineConfig::default()
    };
    // latency-model serving: the engine's VCU128 accounting must
    // describe the same machine the SimBackend is emulating
    if args.get_or("backend", "auto") == "sim" {
        let (arch, strat) = sim_arch_strategy(args);
        cfg.sim_arch = arch;
        cfg.sim_strategy = strat;
    }
    cfg
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let runtime = load_runtime(args)?;
    let engine = Engine::new(runtime, engine_config(args));
    let addr = args.get_or("addr", "127.0.0.1:7077");
    server::serve(engine, &addr)
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let runtime = load_runtime(args)?;
    let mut engine = Engine::new(runtime, engine_config(args));
    let prompt = args.get_or("prompt", "Hello");
    let max_new = args.get_usize("max-new", 32);
    let temp = args.get_f64("temperature", 0.0) as f32;
    let sampling = if temp <= 0.0 {
        Sampling::Greedy
    } else {
        Sampling::Temperature(temp)
    };
    if args.has("stream") {
        return stream_generate(&mut engine, &prompt, max_new, sampling);
    }
    // keep the handle: a bounded-queue refusal (--max-queued 0) arrives
    // as its terminal error event, not as a queued completion
    let handle = engine.submit(&prompt, max_new, sampling);
    engine.run_all()?;
    let c = handle
        .wait()
        .map_err(|msg| anyhow::anyhow!("generation failed: {msg}"))?;
    println!("prompt       : {:?}", c.prompt);
    println!("generated    : {:?}", c.text);
    println!("tokens       : {} prompt + {} new", c.n_prompt, c.n_generated);
    println!("first token  : {:.1} ms (measured)", c.first_token_s * 1e3);
    println!("decode speed : {:.2} token/s (measured)", c.tokens_per_s);
    println!(
        "sim (VCU128) : first {:.2} ms, {:.1} token/s",
        c.sim_first_token_ms, c.sim_tokens_per_s
    );
    if let Some(m) = engine.runtime().memory() {
        println!(
            "kv arena     : {}/{} blocks free, {} reuse hits",
            m.blocks_free, m.blocks_total, m.reuse_hits
        );
    }
    Ok(())
}

/// Drive the scheduler and print token chunks as the engine streams
/// them — the CLI view of the v2 protocol.
fn stream_generate(
    engine: &mut Engine,
    prompt: &str,
    max_new: usize,
    sampling: Sampling,
) -> anyhow::Result<()> {
    use std::io::Write as _;

    let handle = engine.submit(prompt, max_new, sampling);
    print!("streaming    : ");
    std::io::stdout().flush()?;
    loop {
        engine.step_round()?;
        while let Some(ev) = handle.try_recv() {
            match ev {
                Event::Token(t) => {
                    print!("{}", t.text.escape_debug());
                    std::io::stdout().flush()?;
                }
                Event::Done(c) => {
                    println!();
                    println!(
                        "tokens       : {} prompt + {} new",
                        c.n_prompt, c.n_generated
                    );
                    println!(
                        "decode speed : {:.2} token/s (measured), {:.1} token/s (sim VCU128)",
                        c.tokens_per_s, c.sim_tokens_per_s
                    );
                    return Ok(());
                }
                Event::Error(msg) => anyhow::bail!("generation failed: {msg}"),
            }
        }
        if !engine.has_work() {
            anyhow::bail!("request ended without a terminal event");
        }
    }
}

fn parse_strategy(s: &str) -> SparseStrategy {
    match s {
        "dense" => models::DENSE,
        "s1" | "strategy-1" => models::STRATEGY_1,
        "s2" | "strategy-2" => models::STRATEGY_2,
        "s3" | "strategy-3" => models::STRATEGY_3,
        _ => {
            eprintln!("unknown strategy {s}, using dense");
            models::DENSE
        }
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let arch = match args.get_or("arch", "glm").as_str() {
        "qwen" => models::QWEN_7B,
        "tiny" => models::TINY,
        _ => models::GLM_6B,
    };
    let strat = parse_strategy(&args.get_or("strategy", "dense"));
    let mem = if args.get_or("mem", "hbm") == "ddr" { Memory::Ddr } else { Memory::Hbm };
    let ctx = args.get_usize("ctx", 128);
    let sim = Simulator::new(&arch, &strat, mem);

    println!("== {} / {} / {:?} ==", arch.name, strat.name, mem);
    let rep = sim.decode_step(ctx);
    println!("decode @ctx={ctx}:");
    for (name, us) in &rep.block_steps {
        println!("  {name:<18} {us:>10.2} µs");
    }
    let bd = &rep.breakdown;
    println!(
        "  block total {:.1} µs | model total {:.1} ms | {:.1} token/s",
        rep.block_steps.iter().take(17).map(|(_, u)| u).sum::<f64>(),
        bd.total_us() / 1e3,
        1e6 / bd.total_us()
    );
    println!(
        "  breakdown: MHA {:.1} ms, FFN {:.1} ms, other {:.1} ms",
        bd.mha_us / 1e3,
        bd.ffn_us / 1e3,
        bd.other_us / 1e3
    );
    if let Some(t) = args.get("prefill") {
        let t: usize = t.parse().unwrap_or(128);
        let pre = sim.prefill(t).breakdown;
        println!("prefill @T={t}: {:.1} ms", pre.total_us() / 1e3);
    }
    let batch = args.get_usize("batch", 1);
    if batch > 1 {
        let round = sim.decode_round(&vec![ctx; batch]);
        println!(
            "batched decode @B={batch}: round {:.2} ms | aggregate {:.1} token/s \
             ({:.2}x over batch-1)",
            round.total_us() / 1e3,
            round.tokens_per_s(),
            round.tokens_per_s() / (1e6 / bd.total_us())
        );
    }
    let e = edgellm::sim::power::decode_energy(&sim, ctx);
    println!(
        "power: {:.2} W avg | energy {:.3} J/token | {:.2} token/J",
        e.avg_power_w,
        e.energy_j,
        1.0 / e.energy_j
    );
    Ok(())
}

/// Pull the serving engine's request-lifecycle trace over the line
/// protocol (`{"trace": N}`) and write it out as Chrome trace-format
/// JSON — one self-contained file for chrome://tracing / Perfetto.
fn cmd_trace_dump(args: &Args) -> anyhow::Result<()> {
    use std::io::{BufRead, BufReader, Write as _};

    let addr = args.get_or("addr", "127.0.0.1:7077");
    let last = args.get_usize("last", 4096).max(1);
    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| anyhow::anyhow!("connect to serving endpoint {addr}: {e}"))?;
    let mut writer = stream.try_clone()?;
    writeln!(writer, "{{\"trace\": {last}}}")?;
    writer.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    let line = line.trim();
    if line.is_empty() {
        anyhow::bail!("server at {addr} closed the connection without a trace line");
    }
    // surface a structured server-side refusal instead of writing it
    // into the output file as if it were a trace
    if let Ok(j) = edgellm::util::json::Json::parse(line) {
        if let Some(msg) = j.get("error").and_then(|v| v.as_str()) {
            anyhow::bail!("server refused trace export: {msg}");
        }
    }
    match args.get("out") {
        Some(path) => {
            std::fs::write(&path, format!("{line}\n"))?;
            eprintln!("wrote {} bytes of trace to {path}", line.len() + 1);
        }
        None => println!("{line}"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let rt = load_runtime(args)?;
    let i = &rt.info;
    println!("model       : {}", i.name);
    println!("params      : {:.1} M", i.n_params as f64 / 1e6);
    println!("d_model     : {}", i.d_model);
    println!("layers      : {}", i.n_layers);
    println!("heads       : {} ({} kv)", i.n_heads, i.n_kv_heads);
    println!("d_ffn       : {}", i.d_ffn);
    println!("max_tokens  : {}", i.max_tokens);
    println!("prefill     : buckets {:?}", rt.prefill_buckets());
    if let Some(t) = rt.kernel_tier() {
        println!("kernels     : {t}");
    }
    if let Some(m) = rt.memory() {
        println!(
            "kv arena    : {} blocks x {} tokens ({:.1} MiB pool, {} free, {} reused)",
            m.blocks_total,
            m.block_tokens,
            m.total_bytes as f64 / (1 << 20) as f64,
            m.blocks_free,
            m.reuse_hits
        );
    }
    Ok(())
}
