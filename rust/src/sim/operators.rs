//! Per-operator latency model, calibrated against Table III.
//!
//! Operator classes follow Fig. 6's fused 17-step block graph (plus the
//! two output-layer steps). Latency formulas:
//!
//! * weight VMMs (`VmmBn*`): max(weight-stream time, compute time) /
//!   utilization + output-proportional BN overhead. In decode (1 token)
//!   these are pure weight streaming; in prefill weights are reused
//!   across the token tile, so compute dominates.
//! * KV-cache VMMs (`MhaMatmul`): FP16 stream of ctx×kv_dim from HBM +
//!   MHA-mode compute at 1024 MAC/cycle.
//! * element-wise ops (`LayerNorm`, `Rope`, `Softmax`, `Act`): DMA
//!   overhead + per-element pipeline cost from/to DDR.
//! * cache writes (`Dat2Hbm`): one token's K or V row over the KV DMA.

use super::{HwConfig, Memory};
use crate::models::{LlmArch, SparseStrategy};
use crate::pack;
use crate::quant::Sparsity;

/// Operator classes of the fused block graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// RMSNorm/LayerNorm over d_model
    LayerNorm,
    /// weight MatMUL + BatchNorm/residual epilogue; fields: (k, n, sparsity)
    VmmBn,
    /// rotary embedding over n channels
    Rope,
    /// KV-cache matmul (Q·Kᵀ or SFT·V): per-head ctx × head_dim
    MhaMatmul,
    /// softmax over heads × ctx
    Softmax,
    /// K/V row write to HBM
    Dat2Hbm,
    /// Swiglu / nonlinear activation over n channels
    Act,
}

/// One instruction in the compiled stream.
#[derive(Debug, Clone)]
pub struct OpInstance {
    pub class: OpClass,
    pub name: &'static str,
    /// input channels (VMM) or element count basis
    pub k: usize,
    /// output channels
    pub n: usize,
    pub sparsity: Sparsity,
}

/// Latency of one operator instance in microseconds.
///
/// `tokens`: tokens processed this pass (1 in decode, T in prefill).
/// `ctx`: attention context length (cache entries visible).
pub fn latency_us(
    hw: &HwConfig,
    op: &OpInstance,
    tokens: usize,
    ctx: usize,
    mem: Memory,
) -> f64 {
    let t = tokens as f64;
    match op.class {
        OpClass::VmmBn => {
            // packaged weight bytes (scale+mask+wt) — sparsity pays off here
            let wbytes = pack::matrix_bytes(op.k, op.n, op.sparsity) as f64;
            let (bw, util) = match mem {
                Memory::Hbm => (hw.hbm_bytes_per_s(), hw.hbm_utilization),
                Memory::Ddr => (hw.ddr_bytes_per_s, hw.ddr_utilization),
            };
            let stream_s = wbytes / (bw * util);
            // compute: tokens × k × n MACs on the (sparsity-skipping) array
            let macs = t * op.k as f64 * op.n as f64
                * op.sparsity.kept_fraction();
            let mut compute_s = macs / (hw.ffn_macs_per_cycle * hw.compute_hz);
            if mem == Memory::Ddr && tokens > 1 {
                // prefill on DDR: activation tiles contend with the weight
                // stream on the single DDR channel (Table III: ~1.6×)
                compute_s *= 1.64;
            }
            let overhead_s = op.n as f64 * 2e-9; // BN/residual epilogue
            (stream_s.max(compute_s) + overhead_s) * 1e6
        }
        OpClass::MhaMatmul => {
            // stream ctx rows of FP16 KV (one kv head group) from HBM…
            let kv_bytes = ctx as f64 * op.k as f64 * 2.0;
            let (bw, util) = match mem {
                Memory::Hbm => (hw.hbm_bytes_per_s(), hw.hbm_utilization),
                Memory::Ddr => (hw.ddr_bytes_per_s, hw.ddr_utilization),
            };
            let stream_s = kv_bytes / (bw * util);
            // …against tokens × heads × head_dim × ctx FP16 MACs
            let macs = t * op.n as f64 * ctx as f64;
            let compute_s = macs / (hw.mha_macs_per_cycle * hw.compute_hz);
            let overhead_s = 2.0e-6; // DMA setup on the KV path
            (stream_s.max(compute_s) + overhead_s) * 1e6
        }
        OpClass::LayerNorm => {
            // Table III: decode 9.55 µs, prefill(128) 533 µs → linear in
            // tokens with ~5.4 µs setup and ~4.1 µs/token at d=4096.
            let per_token = op.n as f64 / 4096.0 * 4.12;
            let (oh, derate) = match mem {
                Memory::Hbm => (5.4, 1.0),
                Memory::Ddr => (5.4, 1.30), // Table III: 15.84/694 µs
            };
            oh + t * per_token * derate
        }
        OpClass::Rope => {
            // Table III EMB_Q: decode 7.79 µs, prefill 274 µs (d=4096)
            let per_token = op.n as f64 / 4096.0 * 2.1;
            let (oh, derate) = match mem {
                Memory::Hbm => (5.6, 1.0),
                Memory::Ddr => (5.6, 1.75),
            };
            oh + t * per_token * derate
        }
        OpClass::Softmax => {
            // elems = heads × ctx per query token; Table III: decode@128
            // 43.4 µs, prefill 873 µs → ~1.6 ns/elem + large fixed cost
            // (cache-read DMA program).
            let elems = t * op.n as f64 * ctx as f64;
            let (oh, per_elem_ns) = match mem {
                Memory::Hbm => (36.9, 1.594),
                Memory::Ddr => (41.5, 1.92),
            };
            oh + elems * per_elem_ns * 1e-3
        }
        OpClass::Dat2Hbm => {
            // one token's KV row (k bytes FP16) over the write-DMA path
            let bytes = t * op.k as f64 * 2.0;
            let (bw, oh) = match mem {
                Memory::Hbm => (hw.hbm_bytes_per_s() / 32.0, 0.2), // one port
                Memory::Ddr => (hw.ddr_bytes_per_s / 8.0, 1.5),
            };
            oh + bytes / bw * 1e6
        }
        OpClass::Act => {
            // Table III ACT (Swiglu, d_ffn=13696): decode 15.36 µs,
            // prefill 890 µs → ~6.9 µs/token at 13696 ch + 8.5 µs setup
            let per_token = op.n as f64 / 13696.0 * 6.9;
            let (oh, derate) = match mem {
                Memory::Hbm => (8.5, 1.0),
                Memory::Ddr => (8.5, 1.35),
            };
            oh + t * per_token * derate
        }
    }
}

/// Build Fig. 6's fused operator sequence for one transformer block.
pub fn block_ops(arch: &LlmArch, strat: &SparseStrategy) -> Vec<OpInstance> {
    let d = arch.d_model;
    let kv = arch.kv_dim();
    let f = arch.d_ffn;
    let h = arch.n_heads;
    vec![
        OpInstance { class: OpClass::LayerNorm, name: "RMSNorm", k: d, n: d, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN(Q)", k: d, n: d, sparsity: strat.q },
        OpInstance { class: OpClass::Rope, name: "PosEmb(Q)", k: d, n: d, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN(K)", k: d, n: kv, sparsity: strat.k },
        OpInstance { class: OpClass::Rope, name: "PosEmb(K)", k: kv, n: kv, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::Dat2Hbm, name: "KcacheHBM", k: kv, n: kv, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::MhaMatmul, name: "VMM(Q*K^T)", k: kv, n: h * arch.head_dim, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::Softmax, name: "Softmax", k: h, n: h, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN(V)", k: d, n: kv, sparsity: strat.v },
        OpInstance { class: OpClass::Dat2Hbm, name: "VcacheHBM", k: kv, n: kv, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::MhaMatmul, name: "VMM(SFT*V)", k: kv, n: h * arch.head_dim, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN-RES(O)", k: d, n: d, sparsity: strat.o },
        OpInstance { class: OpClass::LayerNorm, name: "RMSNorm", k: d, n: d, sparsity: Sparsity::Dense },
        // h→4h covers SwiGLU's gate and up projections (steps 14 and 16
        // in Table III — two separate ~27 MB streams in GLM-6B)
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN(gate)", k: d, n: f, sparsity: strat.h_to_4h },
        OpInstance { class: OpClass::Act, name: "Swiglu", k: f, n: f, sparsity: Sparsity::Dense },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN(up)", k: d, n: f, sparsity: strat.h_to_4h },
        OpInstance { class: OpClass::VmmBn, name: "VMM-BN-RES(4h-h)", k: f, n: d, sparsity: strat.h4_to_h },
    ]
}

/// Output head: final norm + LM head VMM (paper steps 18–19). The
/// compiler's last-token optimization makes these run at tokens=1 even in
/// prefill.
pub fn output_ops(arch: &LlmArch) -> Vec<OpInstance> {
    vec![
        OpInstance {
            class: OpClass::LayerNorm,
            name: "Outlayer_LN",
            k: arch.d_model,
            n: arch.d_model,
            sparsity: Sparsity::Dense,
        },
        OpInstance {
            class: OpClass::VmmBn,
            name: "VMMBN_Arg",
            k: arch.d_model,
            n: arch.vocab,
            sparsity: Sparsity::Dense,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DENSE, GLM_6B};

    fn hw() -> HwConfig {
        HwConfig::default()
    }

    #[test]
    fn decode_q_vmm_near_table3() {
        // Table III step-2 decode (HBM): 47.12 µs for the 4096×4096 Q VMM.
        let op = OpInstance {
            class: OpClass::VmmBn,
            name: "Q",
            k: 4096,
            n: 4096,
            sparsity: Sparsity::Dense,
        };
        let t = latency_us(&hw(), &op, 1, 128, Memory::Hbm);
        assert!((t - 47.12).abs() / 47.12 < 0.15, "Q decode {t} µs");
        // DDR: 181.66 µs
        let td = latency_us(&hw(), &op, 1, 128, Memory::Ddr);
        assert!((td - 181.66).abs() / 181.66 < 0.15, "Q decode DDR {td} µs");
    }

    #[test]
    fn prefill_q_vmm_near_table3() {
        // Table III step-2 prefill@128 (HBM): 4770 µs — compute-bound.
        let op = OpInstance {
            class: OpClass::VmmBn,
            name: "Q",
            k: 4096,
            n: 4096,
            sparsity: Sparsity::Dense,
        };
        let t = latency_us(&hw(), &op, 128, 128, Memory::Hbm);
        assert!((t - 4770.0).abs() / 4770.0 < 0.25, "Q prefill {t} µs");
        let td = latency_us(&hw(), &op, 128, 128, Memory::Ddr);
        assert!((td - 7841.0).abs() / 7841.0 < 0.25, "Q prefill DDR {td} µs");
    }

    #[test]
    fn ffn_vmm_near_table3() {
        // Table III step-14 (gate proj, 4096×13696): decode 137.98 µs.
        let op = OpInstance {
            class: OpClass::VmmBn,
            name: "gate",
            k: 4096,
            n: 13696,
            sparsity: Sparsity::Dense,
        };
        let t = latency_us(&hw(), &op, 1, 128, Memory::Hbm);
        assert!((t - 137.98).abs() / 137.98 < 0.2, "gate decode {t} µs");
        // DDR: 596.56 µs
        let td = latency_us(&hw(), &op, 1, 128, Memory::Ddr);
        assert!((td - 596.56).abs() / 596.56 < 0.2, "gate decode DDR {td} µs");
    }

    #[test]
    fn layernorm_matches_both_calibration_points() {
        let op = OpInstance {
            class: OpClass::LayerNorm,
            name: "LN",
            k: 4096,
            n: 4096,
            sparsity: Sparsity::Dense,
        };
        let dec = latency_us(&hw(), &op, 1, 128, Memory::Hbm);
        assert!((dec - 9.55).abs() < 0.5, "{dec}");
        let pre = latency_us(&hw(), &op, 128, 128, Memory::Hbm);
        assert!((pre - 533.0).abs() / 533.0 < 0.05, "{pre}");
    }

    #[test]
    fn softmax_matches_calibration() {
        let op = OpInstance {
            class: OpClass::Softmax,
            name: "SM",
            k: 32,
            n: 32,
            sparsity: Sparsity::Dense,
        };
        let dec = latency_us(&hw(), &op, 1, 128, Memory::Hbm);
        assert!((dec - 43.38).abs() / 43.38 < 0.05, "{dec}");
        let pre = latency_us(&hw(), &op, 128, 128, Memory::Hbm);
        assert!((pre - 872.5).abs() / 872.5 < 0.05, "{pre}");
    }

    #[test]
    fn sparsity_cuts_vmm_decode_time() {
        let mk = |s: Sparsity| OpInstance {
            class: OpClass::VmmBn,
            name: "x",
            k: 4096,
            n: 4096,
            sparsity: s,
        };
        let hwc = hw();
        let dense = latency_us(&hwc, &mk(Sparsity::Dense), 1, 1, Memory::Hbm);
        let half = latency_us(&hwc, &mk(Sparsity::Half), 1, 1, Memory::Hbm);
        let eighth = latency_us(&hwc, &mk(Sparsity::Eighth), 1, 1, Memory::Hbm);
        assert!(half < dense * 0.82, "50% sparse {half} vs dense {dense}");
        assert!(eighth < dense * 0.45, "87.5% sparse {eighth} vs {dense}");
    }

    #[test]
    fn mha_latency_grows_linearly_with_ctx() {
        let op = OpInstance {
            class: OpClass::MhaMatmul,
            name: "qk",
            k: 256,
            n: 4096,
            sparsity: Sparsity::Dense,
        };
        let hwc = hw();
        let t128 = latency_us(&hwc, &op, 1, 128, Memory::Hbm);
        let t1024 = latency_us(&hwc, &op, 1, 1024, Memory::Hbm);
        let grow = (t1024 - 2.0) / (t128 - 2.0); // subtract fixed overhead
        assert!((grow - 8.0).abs() < 0.5, "growth {grow}");
    }

    #[test]
    fn block_has_17_steps() {
        // Fig. 6 / Table III: one block = 17 fused hardware steps.
        let ops = block_ops(&GLM_6B, &DENSE);
        assert_eq!(ops.len(), 17);
        assert_eq!(output_ops(&GLM_6B).len(), 2);
    }
}
