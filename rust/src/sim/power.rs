//! Power/energy model (Table IV, Table V).
//!
//! The FPGA idles at 40.36 W once the 140/280 MHz bitstream is loaded;
//! each operator adds an activity-dependent increment:
//!
//! * element-wise operators: small control+BRAM activity (~0.3–0.8 W)
//! * KV-path ops: one HBM pseudo-channel active (~0.5 W)
//! * weight VMMs: the full HBM interface plus the PE array — the paper
//!   measures up to ~18 W over standby, scaling with streamed bandwidth
//!   and array occupancy.
//!
//! Energy per token integrates power over the per-operator latencies from
//! the timing model; "normalized average power" is the duty-cycle-weighted
//! mean the paper reports as 56.86 W.

use super::engine::Simulator;
use super::operators::{block_ops, latency_us, output_ops, OpClass, OpInstance};

/// Idle power after bitstream load (Table IV "standby").
pub const STANDBY_W: f64 = 40.36;

/// Active-power increment (W over standby) while an operator runs.
pub fn active_increment_w(op: &OpInstance) -> f64 {
    match op.class {
        OpClass::LayerNorm => 0.64,
        OpClass::Rope => 0.36,
        OpClass::Softmax => 0.29,
        OpClass::Act => 0.75,
        OpClass::Dat2Hbm => 0.26,
        // KV-cache matmuls keep only a slice of HBM + the MHA array busy
        OpClass::MhaMatmul => 0.60,
        // weight VMMs: HBM interface + PE array, scaled by output width
        // (how much of the 4096-lane array a column tile keeps busy) —
        // calibrated to Table IV's 54.02 W for Q (n=4096) and ~42.8 W for
        // K/V (n=256).
        OpClass::VmmBn => {
            let occupancy = (op.n as f64 / 4096.0).min(1.0);
            let base = 1.5; // HBM PHY + DMA engines clocked up
            let stream = 7.16 * occupancy.max(0.0875); // interface activity
            let array = 5.0 * occupancy; // PE array switching
            base + stream + array
        }
    }
}

/// Power while executing `op` (Table IV rows).
pub fn op_power_w(op: &OpInstance) -> f64 {
    STANDBY_W + active_increment_w(op)
}

/// Energy and duty-cycle-weighted power of one forward pass.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub energy_j: f64,
    pub time_s: f64,
    /// duty-cycle-weighted mean power (paper's "normalized average")
    pub avg_power_w: f64,
}

/// Integrate power over one decode step at context `ctx`.
pub fn decode_energy(sim: &Simulator, ctx: usize) -> EnergyReport {
    energy_of_pass(sim, 1, ctx)
}

fn energy_of_pass(sim: &Simulator, tokens: usize, ctx: usize) -> EnergyReport {
    let mut energy = 0.0f64;
    let mut time = 0.0f64;
    let layers = sim.arch.n_layers as f64;
    for op in &block_ops(&sim.arch, &sim.strat) {
        let us = latency_us(&sim.hw, op, tokens, ctx, sim.mem) * layers;
        energy += op_power_w(op) * us * 1e-6;
        time += us * 1e-6;
    }
    for op in &output_ops(&sim.arch) {
        let us = latency_us(&sim.hw, op, 1, ctx, sim.mem);
        energy += op_power_w(op) * us * 1e-6;
        time += us * 1e-6;
    }
    EnergyReport { energy_j: energy, time_s: time, avg_power_w: energy / time }
}

/// Tokens per joule at steady-state decode (Table V's efficiency metric).
pub fn tokens_per_joule(sim: &Simulator, ctx: usize) -> f64 {
    let rep = decode_energy(sim, ctx);
    1.0 / rep.energy_j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{GLM_6B, STRATEGY_3};
    use crate::quant::Sparsity;
    use crate::sim::Memory;

    fn q_op() -> OpInstance {
        OpInstance {
            class: OpClass::VmmBn,
            name: "Q",
            k: 4096,
            n: 4096,
            sparsity: Sparsity::Dense,
        }
    }

    #[test]
    fn table4_vmm_q_power() {
        // Table IV: VMM-BN(Q) 54.02 W.
        let p = op_power_w(&q_op());
        assert!((p - 54.02).abs() < 1.5, "Q power {p} W");
    }

    #[test]
    fn table4_kv_vmm_power() {
        // Table IV: VMM-BN(K/V) ≈ 42.8 W (narrow output).
        let op = OpInstance {
            class: OpClass::VmmBn,
            name: "K",
            k: 4096,
            n: 256,
            sparsity: Sparsity::Dense,
        };
        let p = op_power_w(&op);
        assert!((p - 42.8).abs() < 1.5, "K power {p} W");
    }

    #[test]
    fn table4_nonlinear_powers_small() {
        // Table IV: nonlinear operators all land between 40.6 and 41.2 W.
        for (class, lo, hi) in [
            (OpClass::LayerNorm, 40.6, 41.2),
            (OpClass::Rope, 40.6, 41.2),
            (OpClass::Softmax, 40.6, 41.2),
            (OpClass::Act, 40.6, 41.3),
        ] {
            let op = OpInstance { class, name: "x", k: 4096, n: 4096, sparsity: Sparsity::Dense };
            let p = op_power_w(&op);
            assert!(p >= lo && p <= hi, "{class:?}: {p} W");
        }
    }

    #[test]
    fn normalized_average_near_paper() {
        // Table IV: normalized average 56.86 W. Our duty-cycle-weighted
        // decode average must land in the same regime (±15%): VMM-heavy
        // steps dominate the time axis.
        let sim = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
        let rep = decode_energy(&sim, 128);
        assert!(
            (rep.avg_power_w - 56.86).abs() / 56.86 < 0.15,
            "avg power {} W",
            rep.avg_power_w
        );
    }

    #[test]
    fn sparse3_tokens_per_joule_near_paper() {
        // Table V: EdgeLLM 1.51 token/J on the 6B model.
        let sim = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
        let tpj = tokens_per_joule(&sim, 128);
        assert!((tpj - 1.51).abs() / 1.51 < 0.2, "{tpj} token/J");
    }
}
