//! Cycle-approximate model of the EdgeLLM accelerator on the VCU128.
//!
//! This is the substitution for the physical FPGA (DESIGN.md §3): it
//! implements the paper's own roofline arithmetic —
//!
//! * HBM: 32 AXI ports × 256 bit/cycle at 280 MHz feed the MatMUL/MHA
//!   operators (8192 bits per AXI cycle; the compute array at 140 MHz
//!   consumes 16384 bits per compute cycle — "twice higher frequency").
//! * DDR: ~60 GB/s for activations and the non-HBM operators.
//! * PE array: 4096 FP16×INT4 MACs/cycle (FFN), 1024 FP16×FP16
//!   MACs/cycle (MHA) at 140 MHz.
//! * Per-operator latency = max(memory streaming, compute) / utilization
//!   + DMA/instruction overhead, calibrated against Table III.
//!
//! Modules: [`operators`] per-op latency, [`engine`] instruction-stream
//! execution with latency hiding, [`power`] Table-IV power/energy.

pub mod engine;
pub mod operators;
pub mod power;

/// Clock and bandwidth constants of the paper's operating point.
#[derive(Debug, Clone)]
pub struct HwConfig {
    /// compute clock (Hz) — paper: 140 MHz
    pub compute_hz: f64,
    /// AXI/DMA clock (Hz) — paper: 280 MHz
    pub axi_hz: f64,
    /// HBM bits per AXI cycle (32 ports × 256 bit)
    pub hbm_bits_per_axi_cycle: f64,
    /// DDR bandwidth (bytes/s) — paper: ~60 GB/s edge DDR
    pub ddr_bytes_per_s: f64,
    /// FP16×INT4 MACs per compute cycle (FFN mode)
    pub ffn_macs_per_cycle: f64,
    /// FP16×FP16 MACs per compute cycle (MHA mode)
    pub mha_macs_per_cycle: f64,
    /// sustained fraction of peak HBM bandwidth (paper measures 70–80%)
    pub hbm_utilization: f64,
    /// sustained fraction of peak DDR bandwidth
    pub ddr_utilization: f64,
    /// elements/s for the element-wise nonlinear pipelines at 140 MHz
    pub elemwise_per_cycle: f64,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            compute_hz: 140e6,
            axi_hz: 280e6,
            hbm_bits_per_axi_cycle: 8192.0,
            ddr_bytes_per_s: 60e9,
            ffn_macs_per_cycle: 4096.0,
            mha_macs_per_cycle: 1024.0,
            hbm_utilization: 0.75,
            ddr_utilization: 0.79,
            elemwise_per_cycle: 1.0,
        }
    }
}

impl HwConfig {
    /// Peak HBM streaming rate in bytes/s (the paper's ideal_operation_time
    /// denominator: 8192 bit per 3.571 ns cycle ≈ 286.7 GB/s).
    pub fn hbm_bytes_per_s(&self) -> f64 {
        self.hbm_bits_per_axi_cycle / 8.0 * self.axi_hz
    }
}

/// Which memory system backs the weight/KV stream (Table III's HBM vs DDR
/// ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Memory {
    Hbm,
    Ddr,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hbm_peak_matches_paper_ideal() {
        // Paper: ideal time of the 4096×4096 INT4 VMM = 29.25 µs
        // (4096·4096·4 bit / 8192 bit/cycle × 3.571 ns).
        let hw = HwConfig::default();
        let bytes = 4096.0 * 4096.0 * 4.0 / 8.0;
        let t = bytes / hw.hbm_bytes_per_s() * 1e6;
        assert!((t - 29.25).abs() < 0.1, "ideal Q time {t} µs");
    }
}
