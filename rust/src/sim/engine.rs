//! Instruction-stream execution: run the compiled block sequence through
//! the latency model, with the paper's instruction-pipeline latency
//! hiding (Fig. 9) as a switchable feature.
//!
//! Operators execute temporally (the paper: "one operator starting only
//! after the previous one has finished"); what the auxiliary-path
//! pipeline hides is the *host-side* instruction update — without it,
//! every operator pays a PCIe register-programming gap.

use super::operators::{block_ops, latency_us, output_ops, OpClass, OpInstance};
use super::{HwConfig, Memory};
use crate::models::{LlmArch, SparseStrategy};

/// Host instruction-update latency per operator when latency hiding is
/// OFF (PCIe register writes from the CPU, Fig. 9 top).
pub const HOST_GAP_US: f64 = 15.0;

#[derive(Debug, Clone)]
pub struct Simulator {
    pub hw: HwConfig,
    pub arch: LlmArch,
    pub strat: SparseStrategy,
    pub mem: Memory,
    /// Fig. 9 instruction-pipeline latency hiding (auxiliary path).
    pub latency_hiding: bool,
}

/// Latency breakdown of one forward pass (Fig. 11(b)'s categories).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub mha_us: f64,
    pub ffn_us: f64,
    pub other_us: f64,
    pub host_us: f64,
}

impl Breakdown {
    pub fn total_us(&self) -> f64 {
        self.mha_us + self.ffn_us + self.other_us + self.host_us
    }
}

/// Per-step simulation report.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub breakdown: Breakdown,
    /// (name, µs) per operator of one block (for Table III dumps)
    pub block_steps: Vec<(&'static str, f64)>,
}

impl Simulator {
    pub fn new(arch: &LlmArch, strat: &SparseStrategy, mem: Memory) -> Self {
        Simulator {
            hw: HwConfig::default(),
            arch: arch.clone(),
            strat: *strat,
            mem,
            latency_hiding: true,
        }
    }

    fn host_gap(&self) -> f64 {
        if self.latency_hiding {
            0.0
        } else {
            HOST_GAP_US
        }
    }

    fn category(op: &OpInstance) -> Category {
        match (op.class, op.name) {
            (OpClass::MhaMatmul, _) | (OpClass::Softmax, _) | (OpClass::Dat2Hbm, _) => {
                Category::Mha
            }
            (OpClass::VmmBn, n)
                if n.contains("gate") || n.contains("up") || n.contains("4h") =>
            {
                Category::Ffn
            }
            (OpClass::Act, _) => Category::Ffn,
            _ => Category::Other,
        }
    }

    /// One pass over all layers: `tokens` processed against `ctx` cache
    /// entries. Decode: tokens=1; prefill: tokens=T, ctx=T.
    pub fn forward(&self, tokens: usize, ctx: usize) -> StepReport {
        let mut bd = Breakdown::default();
        let mut block_steps = Vec::new();
        let ops = block_ops(&self.arch, &self.strat);
        for op in &ops {
            let us = latency_us(&self.hw, op, tokens, ctx, self.mem);
            block_steps.push((op.name, us));
            let us_all = us * self.arch.n_layers as f64;
            match Self::category(op) {
                Category::Mha => bd.mha_us += us_all,
                Category::Ffn => bd.ffn_us += us_all,
                Category::Other => bd.other_us += us_all,
            }
            bd.host_us += self.host_gap() * self.arch.n_layers as f64;
        }
        for op in &output_ops(&self.arch) {
            // compiler's last-token optimization: output head always
            // runs on a single token (paper §IV.B)
            let us = latency_us(&self.hw, op, 1, ctx, self.mem);
            block_steps.push((op.name, us));
            bd.other_us += us;
            bd.host_us += self.host_gap();
        }
        StepReport { breakdown: bd, block_steps }
    }

    /// Decode one token with `ctx` entries already cached.
    pub fn decode_step(&self, ctx: usize) -> StepReport {
        self.forward(1, ctx.max(1))
    }

    /// Prefill a prompt of `t` tokens.
    pub fn prefill(&self, t: usize) -> StepReport {
        self.forward(t, t)
    }

    /// Full generation: prefill `prompt` tokens then decode `n_new`.
    /// Returns (first-token latency µs, total µs, decode tokens/s).
    pub fn generate(&self, prompt: usize, n_new: usize) -> GenReport {
        let first_us = self.prefill(prompt).breakdown.total_us();
        let mut decode_us = 0.0;
        let mut per_token = Vec::with_capacity(n_new);
        for i in 0..n_new {
            let t = self.decode_step(prompt + i).breakdown.total_us();
            decode_us += t;
            per_token.push(t);
        }
        GenReport {
            first_token_us: first_us,
            decode_us,
            total_us: first_us + decode_us,
            tokens_per_s: n_new as f64 / (decode_us * 1e-6),
            per_token_us: per_token,
        }
    }

    /// Average decode speed at a given context length (Fig. 10/11's
    /// "decode speed" operating points).
    pub fn decode_tokens_per_s(&self, ctx: usize) -> f64 {
        1e6 / self.decode_step(ctx).breakdown.total_us()
    }

    /// One **batched** decode round: one token for each of `ctxs.len()`
    /// live sessions, where `ctxs[i]` is session *i*'s cache length.
    ///
    /// Continuous batching changes the accounting, not the datapath:
    /// decode is dominated by streaming the (shared, read-only) weights,
    /// so the weight-bound operators are charged **once per round** with
    /// the batch as the token tile — exactly like a prefill tile reuses
    /// the stream across tokens. Only the per-session state is charged
    /// per session: each session attends to its *own* KV cache
    /// (`MhaMatmul`, `Softmax`) and writes its own cache rows
    /// (`Dat2Hbm`). The host instruction update is one shared stream per
    /// round.
    ///
    /// `decode_round(&[c])` equals `decode_step(c)` — batch 1 degenerates
    /// to the paper's Table III single-request numbers.
    pub fn decode_round(&self, ctxs: &[usize]) -> RoundReport {
        let b = ctxs.len().max(1);
        let mut bd = Breakdown::default();
        for op in &block_ops(&self.arch, &self.strat) {
            let us = match op.class {
                // weight / activation stream shared by the whole batch
                OpClass::VmmBn | OpClass::LayerNorm | OpClass::Rope | OpClass::Act => {
                    latency_us(&self.hw, op, b, 1, self.mem)
                }
                // per-session KV state
                OpClass::MhaMatmul | OpClass::Softmax | OpClass::Dat2Hbm => ctxs
                    .iter()
                    .map(|&c| latency_us(&self.hw, op, 1, c.max(1), self.mem))
                    .sum(),
            };
            let us_all = us * self.arch.n_layers as f64;
            match Self::category(op) {
                Category::Mha => bd.mha_us += us_all,
                Category::Ffn => bd.ffn_us += us_all,
                Category::Other => bd.other_us += us_all,
            }
            bd.host_us += self.host_gap() * self.arch.n_layers as f64;
        }
        let max_ctx = ctxs.iter().copied().max().unwrap_or(1).max(1);
        for op in &output_ops(&self.arch) {
            // last-token optimization: the output head sees one token per
            // session, i.e. a b-token tile
            let us = latency_us(&self.hw, op, b, max_ctx, self.mem);
            bd.other_us += us;
            bd.host_us += self.host_gap();
        }
        RoundReport {
            batch: b,
            breakdown: bd,
        }
    }
}

/// Simulated cost of one batched decode round.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// sessions served this round (one token each)
    pub batch: usize,
    pub breakdown: Breakdown,
}

impl RoundReport {
    pub fn total_us(&self) -> f64 {
        self.breakdown.total_us()
    }

    /// Aggregate decode throughput of the round: batch tokens per round
    /// latency.
    pub fn tokens_per_s(&self) -> f64 {
        self.batch as f64 / (self.breakdown.total_us() * 1e-6)
    }
}

enum Category {
    Mha,
    Ffn,
    Other,
}

#[derive(Debug, Clone)]
pub struct GenReport {
    pub first_token_us: f64,
    pub decode_us: f64,
    pub total_us: f64,
    pub tokens_per_s: f64,
    pub per_token_us: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DENSE, GLM_6B, QWEN_7B, STRATEGY_3};

    #[test]
    fn dense_glm_decode_speed_near_paper() {
        // Fig. 10 / Table III: dense GLM-6B decodes at ~52 token/s
        // (51.42 in Table III at ctx=128).
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let tps = sim.decode_tokens_per_s(128);
        assert!((tps - 52.0).abs() / 52.0 < 0.12, "dense GLM: {tps} tok/s");
    }

    #[test]
    fn sparse3_glm_decode_speed_near_paper() {
        // Fig. 10: sparse strategy-3 reaches ~85.8 token/s.
        let sim = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
        let tps = sim.decode_tokens_per_s(128);
        assert!((tps - 85.8).abs() / 85.8 < 0.15, "sparse-3 GLM: {tps} tok/s");
    }

    #[test]
    fn ddr_decode_is_about_4x_slower() {
        // Table III: DDR decode ≈ 25% of HBM speed (14.11 vs 51.42 tok/s).
        let hbm = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let ddr = Simulator::new(&GLM_6B, &DENSE, Memory::Ddr);
        let ratio = hbm.decode_tokens_per_s(128) / ddr.decode_tokens_per_s(128);
        assert!(ratio > 3.0 && ratio < 4.5, "HBM/DDR ratio {ratio}");
    }

    #[test]
    fn ddr_prefill_penalty_smaller_than_decode_penalty() {
        // Table III: prefill slows ~2.1× on DDR vs decode's ~3.6× (weight
        // reuse shields prefill from the bandwidth loss).
        let hbm = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let ddr = Simulator::new(&GLM_6B, &DENSE, Memory::Ddr);
        let dec_ratio = ddr.decode_step(128).breakdown.total_us()
            / hbm.decode_step(128).breakdown.total_us();
        let pre_ratio = ddr.prefill(128).breakdown.total_us()
            / hbm.prefill(128).breakdown.total_us();
        assert!(pre_ratio < dec_ratio, "prefill {pre_ratio} vs decode {dec_ratio}");
    }

    #[test]
    fn mha_latency_becomes_dominant_at_long_context() {
        // Fig. 11(b): FFN flat in ctx, MHA grows linearly per step —
        // by ctx=2048 MHA overtakes.
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let short = sim.decode_step(64).breakdown;
        let long = sim.decode_step(2048).breakdown;
        assert!((short.ffn_us - long.ffn_us).abs() / short.ffn_us < 0.01);
        assert!(long.mha_us > short.mha_us * 4.0);
        assert!(short.mha_us < short.ffn_us);
    }

    #[test]
    fn decode_speed_flat_below_512() {
        // Fig. 11(a): decode speed roughly stable for ctx < 512.
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let a = sim.decode_tokens_per_s(64);
        let b = sim.decode_tokens_per_s(512);
        assert!((a - b) / a < 0.15, "{a} vs {b}");
    }

    #[test]
    fn prefill_scales_linearly() {
        // Fig. 11(c/d): prefill runtime grows ~proportionally with tokens.
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let t64 = sim.prefill(64).breakdown.total_us();
        let t128 = sim.prefill(128).breakdown.total_us();
        let ratio = t128 / t64;
        assert!(ratio > 1.6 && ratio < 2.4, "prefill scaling {ratio}");
    }

    #[test]
    fn latency_hiding_saves_host_gaps() {
        let mut sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let hidden = sim.decode_step(128).breakdown.total_us();
        sim.latency_hiding = false;
        let exposed = sim.decode_step(128).breakdown.total_us();
        // 17 ops × 28 layers × 15 µs ≈ 7.1 ms of exposed host latency
        assert!(exposed > hidden + 6000.0, "{exposed} vs {hidden}");
    }

    #[test]
    fn qwen_slower_than_glm_when_sparse() {
        // §V.A: Qwen-7B decodes slower (69.4 vs 85.8 tok/s at strategy-3)
        // — more VMM parameters and more KV heads.
        let glm = Simulator::new(&GLM_6B, &STRATEGY_3, Memory::Hbm);
        let qwen = Simulator::new(&QWEN_7B, &STRATEGY_3, Memory::Hbm);
        let g = glm.decode_tokens_per_s(128);
        let q = qwen.decode_tokens_per_s(128);
        assert!(q < g, "qwen {q} should be slower than glm {g}");
        assert!((q - 69.4).abs() / 69.4 < 0.25, "qwen {q} tok/s");
    }

    #[test]
    fn decode_round_batch1_equals_decode_step() {
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let round = sim.decode_round(&[128]).total_us();
        let step = sim.decode_step(128).breakdown.total_us();
        assert!((round - step).abs() < 1e-6, "{round} vs {step}");
    }

    #[test]
    fn batching_amortizes_weight_stream() {
        // batch-1 decode is weight-stream bound, so sharing one stream
        // across 8 sessions beats 8 sequential rounds — but only until
        // the 140 MHz PE array becomes the bottleneck. For GLM-6B the
        // stream/compute crossover sits near batch 2 (Q VMM: 47 µs
        // stream vs 29 µs/token compute), so the aggregate gain
        // saturates around 1.5x, not 8x. The model must show both the
        // gain and the roofline ceiling.
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let one = sim.decode_round(&[128]);
        let eight = sim.decode_round(&[128; 8]);
        assert!(
            eight.total_us() < 8.0 * one.total_us() * 0.7,
            "one round of 8 must amortize vs 8 rounds of 1: {} vs {}",
            eight.total_us(),
            8.0 * one.total_us()
        );
        let gain = eight.tokens_per_s() / one.tokens_per_s();
        assert!(
            gain > 1.4 && gain < 2.5,
            "GLM batch-8 aggregate gain should sit near the compute \
             roofline (~1.5x), got {gain}"
        );
    }

    #[test]
    fn round_charges_each_sessions_own_context() {
        // a long-context straggler inflates the round by *its* MHA cost
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let uniform = sim.decode_round(&[128; 4]).total_us();
        let skewed = sim.decode_round(&[128, 128, 128, 2048]).total_us();
        assert!(skewed > uniform);
        let delta = skewed - uniform;
        let mha_alone = sim.decode_round(&[2048]).breakdown.mha_us
            - sim.decode_round(&[128]).breakdown.mha_us;
        assert!((delta - mha_alone).abs() / mha_alone < 0.05, "{delta} vs {mha_alone}");
    }

    #[test]
    fn table3_block_totals_near_paper() {
        // Table III: single-block decode delay 674.83 µs, total LLM delay
        // 19449 µs (HBM, ctx=128); DDR total 70873 µs.
        let sim = Simulator::new(&GLM_6B, &DENSE, Memory::Hbm);
        let rep = sim.decode_step(128);
        let block: f64 = rep
            .block_steps
            .iter()
            .take(17)
            .map(|(_, us)| us)
            .sum();
        assert!((block - 674.83).abs() / 674.83 < 0.12, "block {block} µs");
        let total = rep.breakdown.total_us();
        assert!((total - 19449.0).abs() / 19449.0 < 0.12, "total {total} µs");
    }
}
