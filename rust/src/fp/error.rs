//! Table-I error-rate harness: measure each computing-unit design against
//! the exact (f64) dot product over random vectors.
//!
//! The paper reports, over 100 000 random input tests:
//!   this work   : 0.0472 % (FP16×INT4)   0.0044 % (FP16×FP16)
//!   baseline-1  : 2.864  %               14.470  %
//!   baseline-2  : 2.644  %               0.020   %
//!
//! Metric: per-trial relative error |got − exact| / |exact|, capped at
//! 100%, averaged over trials, reported in %. Inputs span a wide dynamic
//! range (normal mantissa × 2^U[-4,4]), the regime of attention logits
//! and post-GELU activations.
//!
//! Why this separates the designs (and matches the paper's ordering):
//! near-cancellation trials dominate the mean. A *fused* alignment tree
//! keeps 18 bits below the running maximum exponent, so after massive
//! cancellation the residual is still accurate to ~2^-18 of the largest
//! term. Sequential FP trees swamp: FP16 keeps 2^-11, FP20 keeps 2^-14
//! of each partial sum, and FP16 additionally overflows to ±inf in
//! FP16×FP16 mode (counted as 100% error) — the paper's catastrophic
//! 14.47% cell. Exact percentages depend on the unpublished input
//! distribution; ordering and orders of magnitude are the claim.

use super::baseline;
use super::minifloat::{f16_decode, f16_encode};
use super::mixpe::{self, PeConfig, T_IN};
use crate::util::rng::Rng;

/// Which computing-unit design to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Design {
    /// This work: full-mantissa products + 19-bit aligned adder tree.
    MixPe,
    /// baseline-1: FP16 pairwise adder tree.
    B1Fp16Tree,
    /// baseline-2: FP20 (S1-E6-M13) pairwise adder tree.
    B2Fp20Tree,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Fp16Int4,
    Fp16Fp16,
}

/// Error rate (% of trials whose FP16 output is not the correctly-rounded
/// exact result) of `design` in `mode` over `trials` random T_in-lane dot
/// products. Deterministic in `seed`.
pub fn error_rate(
    design: Design,
    mode: Mode,
    cfg: &PeConfig,
    trials: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut total = 0.0f64;
    let one = f16_encode(1.0);
    for _ in 0..trials {
        let (got, exact) = match mode {
            Mode::Fp16Int4 => {
                let a: Vec<u16> = (0..T_IN).map(|_| wide_f16(&mut rng)).collect();
                let w: Vec<i8> =
                    (0..T_IN).map(|_| rng.int_in(-8, 7) as i8).collect();
                let got = match design {
                    Design::MixPe => mixpe::mac_fp16_int4(cfg, &a, &w, one),
                    Design::B1Fp16Tree => baseline::b1_mac_fp16_int4(&a, &w, one),
                    Design::B2Fp20Tree => baseline::b2_mac_fp16_int4(&a, &w, one),
                };
                (got, mixpe::exact_dot_fp16_int4(&a, &w, 1.0))
            }
            Mode::Fp16Fp16 => {
                // MHA mode uses T_in/4 pairs (the HBM bit budget is fixed).
                let lanes = T_IN / 4;
                let a: Vec<u16> = (0..lanes).map(|_| wide_f16(&mut rng)).collect();
                let b: Vec<u16> = (0..lanes).map(|_| wide_f16(&mut rng)).collect();
                let got = match design {
                    Design::MixPe => mixpe::mac_fp16_fp16(cfg, &a, &b, one),
                    Design::B1Fp16Tree => baseline::b1_mac_fp16_fp16(&a, &b, one),
                    Design::B2Fp20Tree => baseline::b2_mac_fp16_fp16(&a, &b, one),
                };
                (got, mixpe::exact_dot_fp16_fp16(&a, &b, 1.0))
            }
        };
        let gotv = f16_decode(got);
        let err = if gotv.is_finite() && exact.abs() > 0.0 {
            ((gotv - exact).abs() / exact.abs()).min(1.0)
        } else if gotv.is_finite() {
            if gotv == 0.0 { 0.0 } else { 1.0 }
        } else {
            1.0 // overflow to ±inf: total loss
        };
        total += err;
    }
    100.0 * total / trials as f64
}

/// Wide-dynamic-range FP16 sample: normal mantissa × 2^U[-4,4].
fn wide_f16(rng: &mut Rng) -> u16 {
    let e = rng.int_in(-4, 4) as i32;
    f16_encode(rng.normal() * (e as f64).exp2())
}

/// Full Table-I error sweep at the paper's operating point.
pub fn table1_errors(trials: usize, seed: u64) -> Vec<(Design, Mode, f64)> {
    let cfg = mixpe::PAPER_PE;
    let mut out = Vec::new();
    for design in [Design::MixPe, Design::B1Fp16Tree, Design::B2Fp20Tree] {
        for mode in [Mode::Fp16Int4, Mode::Fp16Fp16] {
            out.push((design, mode, error_rate(design, mode, &cfg, trials, seed)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ordering_holds() {
        // The paper's headline: this work beats both baselines in both
        // modes, and baseline-1 is catastrophically bad in FP16×FP16.
        let t = 2000; // enough to stabilize the ordering, fast in CI
        let ours_i4 = error_rate(Design::MixPe, Mode::Fp16Int4, &mixpe::PAPER_PE, t, 1);
        let b1_i4 = error_rate(Design::B1Fp16Tree, Mode::Fp16Int4, &mixpe::PAPER_PE, t, 1);
        let b2_i4 = error_rate(Design::B2Fp20Tree, Mode::Fp16Int4, &mixpe::PAPER_PE, t, 1);
        assert!(ours_i4 < b1_i4, "ours {ours_i4} vs b1 {b1_i4}");
        assert!(ours_i4 < b2_i4, "ours {ours_i4} vs b2 {b2_i4}");

        let ours_ff = error_rate(Design::MixPe, Mode::Fp16Fp16, &mixpe::PAPER_PE, t, 2);
        let b1_ff = error_rate(Design::B1Fp16Tree, Mode::Fp16Fp16, &mixpe::PAPER_PE, t, 2);
        let b2_ff = error_rate(Design::B2Fp20Tree, Mode::Fp16Fp16, &mixpe::PAPER_PE, t, 2);
        assert!(ours_ff < b1_ff, "ours {ours_ff} vs b1 {b1_ff}");
        assert!(ours_ff < b2_ff * 10.0, "ours {ours_ff} vs b2 {b2_ff}");
        // baseline-2 fixes most of baseline-1's FP16×FP16 pain
        assert!(b2_ff < b1_ff);
    }

    #[test]
    fn our_error_in_paper_ballpark() {
        // Paper: 0.047% / 0.0044%. Accept the same order of magnitude.
        let e = error_rate(Design::MixPe, Mode::Fp16Int4, &mixpe::PAPER_PE, 3000, 3);
        assert!(e < 0.5, "FP16xINT4 error {e}% too large");
        let e2 = error_rate(Design::MixPe, Mode::Fp16Fp16, &mixpe::PAPER_PE, 3000, 3);
        assert!(e2 < 0.5, "FP16xFP16 error {e2}% too large");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = error_rate(Design::MixPe, Mode::Fp16Int4, &mixpe::PAPER_PE, 200, 9);
        let b = error_rate(Design::MixPe, Mode::Fp16Int4, &mixpe::PAPER_PE, 200, 9);
        assert_eq!(a, b);
    }
}
