//! Structural PPA (power/performance/area) model for Table I's hardware
//! columns.
//!
//! We cannot run the paper's 28nm ASIC flow or Vivado, so area/power/
//! frequency are *modeled* from structure: gate-count proxies for the
//! multiplier array, alignment shifters, and adder tree of each design,
//! calibrated so that "this work" matches the paper's published absolute
//! numbers. What the model genuinely predicts is the *relative* cost of
//! the baselines (both add per-node FP alignment/normalization logic the
//! fused tree does not need), reproducing Table I's ordering:
//! ours < baseline-1 < baseline-2 in area, ours highest frequency.

use super::mixpe::T_IN;

#[derive(Debug, Clone)]
pub struct PpaEstimate {
    pub design: &'static str,
    /// gate-equivalents (structural proxy)
    pub gates: f64,
    /// µm² in a 28nm-class process (calibrated)
    pub area_um2: f64,
    /// mW at the calibrated activity factor
    pub power_mw: f64,
    /// achievable clock in GHz (inverse critical-path proxy)
    pub freq_ghz: f64,
    /// FPGA LUT-equivalents
    pub luts: f64,
}

/// Gate cost of an n×m integer multiplier (array multiplier ~ n*m cells).
fn mult_gates(n: u32, m: u32) -> f64 {
    (n * m) as f64 * 6.0
}

/// Gate cost of a w-bit integer adder.
fn int_add_gates(w: u32) -> f64 {
    w as f64 * 8.0
}

/// Gate cost of a floating-point adder of given mantissa/exponent widths:
/// alignment shifter + integer add + LZA normalize + rounding. The
/// shifter and normalizer dominate (barrel shifters are ~w·log w).
fn fp_add_gates(ebits: u32, mbits: u32) -> f64 {
    let w = mbits + 3; // guard/round/sticky
    let shifter = w as f64 * (w as f64).log2() * 4.0;
    let adder = int_add_gates(w + 1);
    let lza = w as f64 * 10.0;
    let expo = ebits as f64 * 12.0;
    2.0 * shifter + adder + lza + expo
}

/// Critical path proxy in "gate delays".
fn fp_add_delay(mbits: u32) -> f64 {
    // align + add + normalize, each ~log2 terms
    3.0 * ((mbits + 3) as f64).log2() + 8.0
}

fn int_add_delay(w: u32) -> f64 {
    (w as f64).log2() + 2.0
}

/// Structural model of each Table-I design at T_in lanes.
pub fn estimate(design: &'static str) -> PpaEstimate {
    let lanes = T_IN as u32;
    let tree_nodes = lanes - 1;
    let (gates, delay) = match design {
        // this work: 128 11×4 multipliers (DSP-shared for FP16 mode),
        // ONE exponent max-scan + per-lane 19-bit shifters, integer tree.
        "this_work" => {
            let mults = lanes as f64 * mult_gates(11, 4);
            let shifters = lanes as f64 * 19.0 * (19f64).log2() * 4.0;
            let expcmp = lanes as f64 * 14.0; // max-scan comparators
            let tree = tree_nodes as f64 * int_add_gates(19);
            let norm = fp_add_gates(5, 10); // single LZA at the root
            (mults + shifters + expcmp + tree + norm,
             int_add_delay(19) + (19f64).log2()) // int add + shift stage
        }
        // baseline-1: same multipliers + FP16 rounding per product +
        // full FP16 adder at every tree node.
        "baseline1" => {
            let mults = lanes as f64 * mult_gates(11, 4);
            let round = lanes as f64 * fp_add_gates(5, 10) * 0.3;
            let tree = tree_nodes as f64 * fp_add_gates(5, 10);
            (mults + round + tree, fp_add_delay(10))
        }
        // baseline-2: FP20 adders are wider still.
        "baseline2" => {
            let mults = lanes as f64 * mult_gates(11, 4);
            let round = lanes as f64 * fp_add_gates(6, 13) * 0.3;
            let tree = tree_nodes as f64 * fp_add_gates(6, 13);
            (mults + round + tree, fp_add_delay(13))
        }
        _ => panic!("unknown design {design}"),
    };
    // Calibration anchors: this work = 71664 µm², 1.11 GHz, 40.34 mW,
    // 24714 LUT (paper Table I).
    let anchor = {
        let mults = lanes as f64 * mult_gates(11, 4);
        let shifters = lanes as f64 * 19.0 * (19f64).log2() * 4.0;
        let expcmp = lanes as f64 * 14.0;
        let tree = tree_nodes as f64 * int_add_gates(19);
        let norm = fp_add_gates(5, 10);
        mults + shifters + expcmp + tree + norm
    };
    let anchor_delay = int_add_delay(19) + (19f64).log2();
    let area_um2 = 71664.0 * gates / anchor;
    let power_mw = 40.34 * gates / anchor;
    let freq_ghz = 1.11 * anchor_delay / delay;
    let luts = 24714.0 * gates / anchor;
    PpaEstimate { design, gates, area_um2, power_mw, freq_ghz, luts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_table1() {
        let ours = estimate("this_work");
        let b1 = estimate("baseline1");
        let b2 = estimate("baseline2");
        // Table I: 71664 < 107437 < 140677 µm²; ours fastest clock.
        assert!(ours.area_um2 < b1.area_um2, "{} vs {}", ours.area_um2, b1.area_um2);
        assert!(b1.area_um2 < b2.area_um2);
        assert!(ours.freq_ghz > b1.freq_ghz);
        assert!(ours.freq_ghz > b2.freq_ghz);
        assert!(ours.luts < b1.luts && b1.luts < b2.luts);
    }

    #[test]
    fn calibration_anchor_exact() {
        let ours = estimate("this_work");
        assert!((ours.area_um2 - 71664.0).abs() < 1.0);
        assert!((ours.freq_ghz - 1.11).abs() < 1e-6);
    }

    #[test]
    fn baseline_area_in_paper_ballpark() {
        // Paper: baseline-1 = 107437 µm² (1.50× ours),
        //        baseline-2 = 140677 µm² (1.96× ours).
        let ours = estimate("this_work").area_um2;
        let b1 = estimate("baseline1").area_um2 / ours;
        let b2 = estimate("baseline2").area_um2 / ours;
        assert!(b1 > 1.2 && b1 < 2.2, "b1 ratio {b1}");
        assert!(b2 > 1.4 && b2 < 2.8, "b2 ratio {b2}");
        assert!(b2 > b1);
    }
}
