//! Bit-accurate model of EdgeLLM's mix-precision computing unit (§III.B).
//!
//! * [`minifloat`] — parametric FP16/FP20 codecs with exact single-rounding
//!   arithmetic
//! * [`mixpe`] — this work's 4-stage MAC datapath (19-bit aligned adder
//!   tree, LZA normalize, FP16 scale multiply)
//! * [`baseline`] — Table I's baseline-1 (FP16 tree) and baseline-2 (FP20
//!   tree) control designs
//! * [`error`] — the 100k-random-trial error-rate harness (Table I)
//! * [`ppa`] — structural area/power/frequency model (Table I PPA columns)

pub mod baseline;
pub mod error;
pub mod minifloat;
pub mod mixpe;
pub mod ppa;
