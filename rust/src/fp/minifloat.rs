//! Parametric small-float codec (software model of the PE's float formats).
//!
//! The paper's datapath manipulates three formats:
//!   * FP16 (S1-E5-M10) — activations, KV cache, scales, outputs
//!   * FP20 (S1-E6-M13) — baseline-2's wide adder-tree format
//! Both are instances of `MiniFloat { ebits, mbits }` with IEEE semantics:
//! hidden bit, subnormals, round-to-nearest-even, saturation to ±inf.
//!
//! All arithmetic is emulated *exactly* through f64 (every MiniFloat value
//! and every pairwise product/sum of two of them is exactly representable
//! in f64 for the formats used here), so rounding happens exactly once per
//! hardware operation, as in RTL.

/// A small IEEE-like binary float format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MiniFloat {
    pub ebits: u32,
    pub mbits: u32,
}

pub const FP16: MiniFloat = MiniFloat { ebits: 5, mbits: 10 };
/// Baseline-2's custom accumulator format (S1-E6-M13), paper §III.B.
pub const FP20: MiniFloat = MiniFloat { ebits: 6, mbits: 13 };

impl MiniFloat {
    pub fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }

    pub fn total_bits(&self) -> u32 {
        1 + self.ebits + self.mbits
    }

    fn emax_field(&self) -> u32 {
        (1 << self.ebits) - 1
    }

    /// Decode a bit pattern to f64 (exact).
    pub fn decode(&self, bits: u32) -> f64 {
        let sign = if bits >> (self.ebits + self.mbits) & 1 == 1 { -1.0 } else { 1.0 };
        let e = (bits >> self.mbits) & self.emax_field();
        let m = bits & ((1 << self.mbits) - 1);
        if e == self.emax_field() {
            if m == 0 {
                return sign * f64::INFINITY;
            }
            return f64::NAN;
        }
        let (mant, exp) = if e == 0 {
            (m as f64, 1 - self.bias() - self.mbits as i32)
        } else {
            ((m + (1 << self.mbits)) as f64, e as i32 - self.bias() - self.mbits as i32)
        };
        sign * mant * (exp as f64).exp2()
    }

    /// Encode an f64 to the nearest representable value (RNE); overflows
    /// saturate to ±inf like the hardware's output integration stage.
    pub fn encode(&self, x: f64) -> u32 {
        let sign_bit = if x.is_sign_negative() { 1u32 << (self.ebits + self.mbits) } else { 0 };
        if x.is_nan() {
            return sign_bit | (self.emax_field() << self.mbits) | 1;
        }
        let a = x.abs();
        if a == 0.0 {
            return sign_bit;
        }
        if a.is_infinite() {
            return sign_bit | (self.emax_field() << self.mbits);
        }
        // Find the unbiased exponent of the leading bit.
        let e = a.log2().floor() as i32;
        // Normal range: e in [1-bias, emax_field-1-bias]
        let emin = 1 - self.bias();
        let emax = self.emax_field() as i32 - 1 - self.bias();
        let e_clamped = e.max(emin);
        // Quantum for this exponent.
        let q = ((e_clamped - self.mbits as i32) as f64).exp2();
        let scaled = a / q;
        let rounded = round_half_even(scaled);
        let mut mant = rounded as u64;
        let mut e_final = e_clamped;
        // Rounding may carry into the next binade.
        if mant >= (2u64 << self.mbits) {
            mant >>= 1;
            e_final += 1;
        }
        if e_final > emax || (e_final == e_clamped && mant >= (2u64 << self.mbits)) {
            // overflow -> inf
            return sign_bit | (self.emax_field() << self.mbits);
        }
        if mant < (1u64 << self.mbits) {
            // subnormal (or zero after rounding)
            return sign_bit | (mant as u32);
        }
        let e_field = (e_final + self.bias()) as u32;
        if e_field >= self.emax_field() {
            return sign_bit | (self.emax_field() << self.mbits);
        }
        sign_bit | (e_field << self.mbits) | ((mant as u32) & ((1 << self.mbits) - 1))
    }

    /// Round an f64 through this format (decode(encode(x))).
    pub fn round(&self, x: f64) -> f64 {
        self.decode(self.encode(x))
    }

    /// One hardware multiply: exact product, single rounding.
    pub fn mul(&self, a_bits: u32, b_bits: u32) -> u32 {
        self.encode(self.decode(a_bits) * self.decode(b_bits))
    }

    /// One hardware add: exact sum, single rounding.
    pub fn add(&self, a_bits: u32, b_bits: u32) -> u32 {
        self.encode(self.decode(a_bits) + self.decode(b_bits))
    }

    /// Split into (sign, biased_exponent_effective, mantissa_with_hidden).
    /// Subnormals report exponent 1 and no hidden bit, matching the
    /// stage-0 field splitter in Fig. 4(b).
    pub fn split(&self, bits: u32) -> (bool, i32, u32) {
        let sign = bits >> (self.ebits + self.mbits) & 1 == 1;
        let e = (bits >> self.mbits) & self.emax_field();
        let m = bits & ((1 << self.mbits) - 1);
        if e == 0 {
            (sign, 1, m)
        } else {
            (sign, e as i32, m | (1 << self.mbits))
        }
    }
}

fn round_half_even(x: f64) -> f64 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Convenience FP16 helpers used across the crate.
pub fn f16_encode(x: f64) -> u16 {
    FP16.encode(x) as u16
}

pub fn f16_decode(bits: u16) -> f64 {
    FP16.decode(bits as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_roundtrip_all_finite_patterns() {
        // Exhaustive: every finite FP16 bit pattern decodes and re-encodes
        // to itself (the codec is a bijection on finite values).
        for bits in 0u32..=0xFFFF {
            let e = (bits >> 10) & 0x1F;
            if e == 0x1F {
                continue; // inf/nan
            }
            let x = FP16.decode(bits);
            let back = FP16.encode(x);
            // +0 and -0 both map to themselves via sign handling
            assert_eq!(back, bits, "pattern {bits:#06x} -> {x} -> {back:#06x}");
        }
    }

    #[test]
    fn fp16_known_values() {
        assert_eq!(f16_decode(0x3C00), 1.0);
        assert_eq!(f16_decode(0xC000), -2.0);
        assert_eq!(f16_decode(0x7BFF), 65504.0);
        assert_eq!(f16_encode(1.0), 0x3C00);
        assert_eq!(f16_encode(65504.0), 0x7BFF);
        assert_eq!(f16_encode(65520.0), 0x7C00); // overflow -> inf
        assert_eq!(f16_decode(0x0001), (2.0f64).powi(-24)); // smallest subnormal
    }

    #[test]
    fn fp16_rne_ties() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10: ties to even (1.0)
        let tie = 1.0 + (2.0f64).powi(-11);
        assert_eq!(f16_encode(tie), 0x3C00);
        // 1 + 3*2^-11 ties up to 1+2^-9's neighbour (even mantissa 2)
        let tie2 = 1.0 + 3.0 * (2.0f64).powi(-11);
        assert_eq!(f16_encode(tie2), 0x3C02);
    }

    #[test]
    fn fp20_wider_than_fp16() {
        // FP20 must represent values FP16 cannot (more mantissa + exponent)
        let x = 1.0 + (2.0f64).powi(-12);
        assert_eq!(FP16.round(x), 1.0);
        assert_eq!(FP20.round(x), x);
        // FP20 range exceeds FP16 range (E6 vs E5)
        assert!(FP20.round(1e6).is_finite());
        assert!(FP16.round(1e6).is_infinite());
    }

    #[test]
    fn split_matches_decode() {
        for bits in [0x3C00u32, 0x0001, 0x03FF, 0x7BFF, 0x8400, 0x0400] {
            let (s, e, m) = FP16.split(bits);
            let v = (if s { -1.0 } else { 1.0 })
                * m as f64
                * ((e - FP16.bias() - FP16.mbits as i32) as f64).exp2();
            assert_eq!(v, FP16.decode(bits), "bits={bits:#06x}");
        }
    }

    #[test]
    fn single_rounding_mul_add() {
        let a = f16_encode(1.5) as u32;
        let b = f16_encode(2.5) as u32;
        assert_eq!(FP16.decode(FP16.mul(a, b)), 3.75);
        assert_eq!(FP16.decode(FP16.add(a, b)), 4.0);
    }
}
