//! The two control designs of Table I: standard pairwise adder trees with
//! rounded intermediate results.
//!
//!   baseline-1: every product and every tree node rounded to FP16
//!   baseline-2: accumulation in FP20 (S1-E6-M13) to dodge FP16 overflow,
//!               converted to FP16 only at the output
//!
//! Both share the PE's multiplier front-end (exact product before the
//! first rounding), matching the paper's "standard pairwise addition-based
//! adder tree ... precision of intermediate calculations varied".

use super::minifloat::{FP16, FP20, MiniFloat};

/// Pairwise tree reduction where every node result is rounded to `fmt`.
fn tree_sum_fmt(fmt: &MiniFloat, mut lanes: Vec<u32>) -> u32 {
    if lanes.is_empty() {
        return 0;
    }
    while lanes.len() > 1 {
        let mut next = Vec::with_capacity(lanes.len().div_ceil(2));
        for pair in lanes.chunks(2) {
            next.push(if pair.len() == 2 {
                fmt.add(pair[0], pair[1])
            } else {
                pair[0]
            });
        }
        lanes = next;
    }
    lanes[0]
}

fn dot_via_tree(fmt: &MiniFloat, products: Vec<f64>, scale: u16) -> u16 {
    let lanes: Vec<u32> = products.into_iter().map(|p| fmt.encode(p)).collect();
    let acc = tree_sum_fmt(fmt, lanes);
    // convert accumulator format -> FP16, then the FP16 scale multiply
    let r16 = FP16.encode(fmt.decode(acc));
    FP16.mul(r16, scale as u32) as u16
}

/// baseline-1, MODE-1: FP16 adder tree, FP16×INT4 products.
pub fn b1_mac_fp16_int4(a: &[u16], w: &[i8], scale: u16) -> u16 {
    let products: Vec<f64> = a
        .iter()
        .zip(w)
        .map(|(&ai, &wi)| FP16.decode(ai as u32) * wi as f64)
        .collect();
    dot_via_tree(&FP16, products, scale)
}

/// baseline-1, MODE-0: FP16 adder tree, FP16×FP16 products.
pub fn b1_mac_fp16_fp16(a: &[u16], b: &[u16], scale: u16) -> u16 {
    let products: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(&ai, &bi)| FP16.decode(ai as u32) * FP16.decode(bi as u32))
        .collect();
    dot_via_tree(&FP16, products, scale)
}

/// baseline-2, MODE-1: FP20 adder tree.
pub fn b2_mac_fp16_int4(a: &[u16], w: &[i8], scale: u16) -> u16 {
    let products: Vec<f64> = a
        .iter()
        .zip(w)
        .map(|(&ai, &wi)| FP16.decode(ai as u32) * wi as f64)
        .collect();
    dot_via_tree(&FP20, products, scale)
}

/// baseline-2, MODE-0: FP20 adder tree.
pub fn b2_mac_fp16_fp16(a: &[u16], b: &[u16], scale: u16) -> u16 {
    let products: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(&ai, &bi)| FP16.decode(ai as u32) * FP16.decode(bi as u32))
        .collect();
    dot_via_tree(&FP20, products, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::minifloat::{f16_decode, f16_encode};

    const ONE: u16 = 0x3C00;

    #[test]
    fn small_sums_exact_in_both_baselines() {
        let a = [f16_encode(1.0), f16_encode(2.0)];
        let w = [3i8, -1];
        assert_eq!(f16_decode(b1_mac_fp16_int4(&a, &w, ONE)), 1.0);
        assert_eq!(f16_decode(b2_mac_fp16_int4(&a, &w, ONE)), 1.0);
    }

    #[test]
    fn fp16_tree_overflows_where_fp20_survives() {
        // 128 lanes of 600*7 = 4200 each: true sum 537600 overflows FP16
        // (max 65504) mid-tree; FP20's E6 range keeps it finite.
        let a = vec![f16_encode(600.0); 128];
        let w = vec![7i8; 128];
        let b1 = f16_decode(b1_mac_fp16_int4(&a, &w, ONE));
        let b2 = f16_decode(b2_mac_fp16_int4(&a, &w, ONE));
        assert!(b1.is_infinite(), "baseline-1 should overflow, got {b1}");
        assert!(b2.is_infinite() || b2 > 60000.0); // FP16 output saturates
    }

    #[test]
    fn fp16_tree_loses_small_terms() {
        // One big lane + many tiny ones: FP16 accumulation drops the tiny
        // contributions that the exact sum keeps.
        let mut a = vec![f16_encode(1024.0)];
        let mut w = vec![7i8];
        for _ in 0..127 {
            a.push(f16_encode(0.25));
            w.push(1i8);
        }
        let exact = crate::fp::mixpe::exact_dot_fp16_int4(&a, &w, 1.0);
        let b1 = f16_decode(b1_mac_fp16_int4(&a, &w, ONE));
        let b2 = f16_decode(b2_mac_fp16_int4(&a, &w, ONE));
        let e1 = ((b1 - exact) / exact).abs();
        let e2 = ((b2 - exact) / exact).abs();
        assert!(e2 <= e1, "FP20 ({e2}) should beat FP16 ({e1})");
    }
}
