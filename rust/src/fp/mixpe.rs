//! Bit-accurate model of the mix-precision vector MAC unit (Fig. 4b).
//!
//! The unit computes `scale * Σ_i a_i * w_i` over a T_in-lane vector in
//! four pipeline stages:
//!
//!   Stage-0  field split: FP16 -> (S, E, M+hidden); INT4 -> (S, |w|).
//!   Stage-1  sign XOR; exponent max-scan + per-lane distance;
//!            full-mantissa integer multiply (nothing truncated here —
//!            "no fractional detail is lost in the arithmetic processes").
//!   Stage-2  alignment shifter: each product is shifted right by its
//!            exponent distance and enters the **19-bit** adder tree;
//!            the width cap is the paper's deliberate accuracy/area
//!            trade-off and the sole source of arithmetic error.
//!   Stage-3  LZA normalization -> FP16, then FP16 multiply by the
//!            block-quantization scale.
//!
//! Two operand modes (Fig. 4 table):
//!   MODE-1 (FFN): T_in   lanes of FP16 (DAT) × INT4 (WT)
//!   MODE-0 (MHA): T_in/4 lanes of FP16 (DAT) × FP16 (KV cache; each FP16
//!          occupies the HBM bits of four INT4s, so lane count drops 4×)

use super::minifloat::FP16;

/// Vector length of the PE (paper: T_in = 128).
pub const T_IN: usize = 128;

/// Adder-tree configuration: the paper fixes 19 bits; the harness sweeps
/// this to show the accuracy/width trade-off (DESIGN.md ablation).
#[derive(Debug, Clone, Copy)]
pub struct PeConfig {
    /// Total adder-tree operand width in bits, including sign.
    pub tree_bits: u32,
}

pub const PAPER_PE: PeConfig = PeConfig { tree_bits: 19 };

impl PeConfig {
    fn max_mag(&self) -> i64 {
        (1i64 << (self.tree_bits - 1)) - 1
    }

    /// Guard bits: how far a product is up-shifted so the max-exponent
    /// lane occupies the full tree operand width (sign + tree_bits−1
    /// magnitude bits). With 14-bit MODE-1 products and the paper's
    /// 19-bit operands this is 4 — the aligned-lane precision floor is
    /// 2^-18 of the largest product, which is what lets the fused tree
    /// beat even an FP20 accumulator after heavy cancellation (Table I).
    /// Internally the tree grows like any synthesized adder tree
    /// (19 + log2(T_in) bits at the root); "19 bits" caps the *operand*
    /// width, i.e. the alignment-shifter output.
    fn guard(&self, product_bits: u32) -> i32 {
        self.tree_bits as i32 - 1 - product_bits as i32
    }
}

/// Alignment shifter: up-shift by `guard` (to fill the operand width),
/// down-shift by the exponent distance `d`, with round-to-nearest — the
/// shifted-out MSB is added back (one extra adder input in RTL),
/// de-biasing the truncation. Output clamped to the operand width.
fn align(cfg: &PeConfig, p: i64, d: u32, guard: i32) -> i64 {
    let sh = guard - d as i32;
    let v = if sh >= 0 {
        p << sh
    } else {
        let d = (-sh) as u32;
        if d >= 63 {
            0
        } else {
            (p + (1i64 << (d - 1))) >> d
        }
    };
    v.clamp(-cfg.max_mag(), cfg.max_mag())
}

// NOTE on the adder tree: the hardware reduces pairwise, but its internal
// nodes grow wide enough to be exact (root 19 + log2(T_in) ≤ 26 bits), so
// integer addition order cannot change the result — we fold directly.
// (§Perf: the explicit Vec-of-levels tree was 2 allocations + O(n) moves
// per MAC; the fold is allocation-free and bit-identical.)

/// MODE-1: FP16 activations × INT4 weights (FFN layers), then × scale.
///
/// `a` are FP16 bit patterns, `w` are INT4 values in [-8, 7], `scale` is
/// the FP16 block-quantization scale. Returns the FP16 result bits.
pub fn mac_fp16_int4(cfg: &PeConfig, a: &[u16], w: &[i8], scale: u16) -> u16 {
    assert_eq!(a.len(), w.len());
    // Stage 0/1 (first sweep): exponent max-scan over active lanes.
    // (§Perf: two sweeps over the inputs instead of building a lane Vec —
    // the split is cheap, the allocation was not.)
    let mut e_max = i32::MIN;
    let mut any = false;
    for (&ai, &wi) in a.iter().zip(w) {
        if wi == 0 {
            continue;
        }
        let (_, e_a, m_a) = FP16.split(ai as u32);
        if m_a == 0 {
            continue;
        }
        e_max = e_max.max(e_a);
        any = true;
    }
    if !any {
        return 0;
    }
    // Stage 1/2 (second sweep): multiply, align into 19-bit operands, sum.
    // MODE-1 products are ≤ 2^14 (11-bit mantissa × 8) → guard 4.
    let guard = cfg.guard(14);
    let mut sum = 0i64;
    for (&ai, &wi) in a.iter().zip(w) {
        if wi == 0 {
            continue;
        }
        let (s_a, e_a, m_a) = FP16.split(ai as u32);
        if m_a == 0 {
            continue;
        }
        let neg = s_a ^ (wi < 0);
        let p = (m_a as i64) * (wi.unsigned_abs() as i64);
        let p = if neg { -p } else { p };
        sum += align(cfg, p, (e_max - e_a) as u32, guard);
    }
    // Stage 3: LZA normalize to FP16. The integer sum carries scale
    // 2^(e_max - bias - mbits - guard).
    let exp = e_max - FP16.bias() - FP16.mbits as i32 - guard;
    let result = sum as f64 * (exp as f64).exp2();
    let r16 = FP16.encode(result);
    // Final FP16 multiply by the quantization scale.
    FP16.mul(r16, scale as u32) as u16
}

/// MODE-0: FP16 activations × FP16 KV-cache data (MHA blocks).
///
/// Fig. 4's MODE-0 row: each FP16 operand occupies the HBM bits of four
/// INT4s and is processed by **three** of the shared 11×4 multipliers
/// (75% DSP utilization): the 11-bit mantissa (hidden bit included) is
/// decomposed into INT4 digits `m = d2·2^8 + d1·2^4 + d0`, each digit
/// producing one ≤15-bit partial product that enters the common
/// alignment shifter + 19-bit adder tree as its own lane with exponent
/// offset {+8, +4, +0}. The full 22-bit product is therefore represented
/// exactly across three tree lanes — the reason MODE-0's error rate is an
/// order of magnitude below MODE-1's in Table I.
///
/// No quantization scale in MHA; pass `scale = 0x3C00` (1.0) to model the
/// shared datapath exactly.
pub fn mac_fp16_fp16(cfg: &PeConfig, a: &[u16], b: &[u16], scale: u16) -> u16 {
    assert_eq!(a.len(), b.len());
    // First sweep: exponent max over digit lanes.
    let mut e_max = i32::MIN;
    let mut any = false;
    for (&ai, &bi) in a.iter().zip(b) {
        let (_, e_a, m_a) = FP16.split(ai as u32);
        let (_, e_b, m_b) = FP16.split(bi as u32);
        if m_a == 0 || m_b == 0 {
            continue;
        }
        let e = e_a + e_b;
        for (digit, shift) in [(m_b >> 8, 8), ((m_b >> 4) & 0xF, 4), (m_b & 0xF, 0)] {
            if digit != 0 {
                e_max = e_max.max(e + shift);
                any = true;
            }
        }
    }
    if !any {
        return 0;
    }
    // Second sweep: multiply digits, align, sum.
    // Digit partial products occupy ≤15 bits → guard 3 at 19-bit operands.
    let guard = cfg.guard(15);
    let mut sum = 0i64;
    for (&ai, &bi) in a.iter().zip(b) {
        let (s_a, e_a, m_a) = FP16.split(ai as u32);
        let (s_b, e_b, m_b) = FP16.split(bi as u32);
        if m_a == 0 || m_b == 0 {
            continue;
        }
        let neg = s_a ^ s_b;
        let e = e_a + e_b;
        for (digit, shift) in [(m_b >> 8, 8), ((m_b >> 4) & 0xF, 4), (m_b & 0xF, 0)] {
            if digit == 0 {
                continue;
            }
            let p = (m_a as i64) * (digit as i64); // ≤ 2047·15 < 2^15
            let p = if neg { -p } else { p };
            sum += align(cfg, p, (e_max - (e + shift)) as u32, guard);
        }
    }
    // Lane value = p·2^(e_lane − 2·bias − 2·mbits): the digit-grid offset
    // is already folded into e_lane (= e_a + e_b + digit_shift).
    let exp = e_max - 2 * FP16.bias() - 2 * FP16.mbits as i32 - guard;
    let result = sum as f64 * (exp as f64).exp2();
    let r16 = FP16.encode(result);
    FP16.mul(r16, scale as u32) as u16
}

/// Exact (f64, Neumaier-compensated) dot product — the harness oracle.
pub fn exact_dot_fp16_int4(a: &[u16], w: &[i8], scale: f64) -> f64 {
    let mut sum = 0.0f64;
    let mut c = 0.0f64;
    for (&ai, &wi) in a.iter().zip(w) {
        let x = FP16.decode(ai as u32) * wi as f64;
        let t = sum + x;
        c += if sum.abs() >= x.abs() { (sum - t) + x } else { (x - t) + sum };
        sum = t;
    }
    (sum + c) * scale
}

/// Exact FP16×FP16 oracle.
pub fn exact_dot_fp16_fp16(a: &[u16], b: &[u16], scale: f64) -> f64 {
    let mut sum = 0.0f64;
    let mut c = 0.0f64;
    for (&ai, &bi) in a.iter().zip(b) {
        let x = FP16.decode(ai as u32) * FP16.decode(bi as u32);
        let t = sum + x;
        c += if sum.abs() >= x.abs() { (sum - t) + x } else { (x - t) + sum };
        sum = t;
    }
    (sum + c) * scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::minifloat::{f16_decode, f16_encode};
    use crate::util::rng::Rng;

    const ONE: u16 = 0x3C00;

    #[test]
    fn single_lane_exact() {
        // One lane, no alignment, no saturation: result must be exact.
        let a = [f16_encode(1.5)];
        let w = [3i8];
        let out = mac_fp16_int4(&PAPER_PE, &a, &w, ONE);
        assert_eq!(f16_decode(out), 4.5);
    }

    #[test]
    fn zero_weights_skip_lanes() {
        let a = [f16_encode(7.0), f16_encode(1e4)];
        let w = [0i8, 0];
        assert_eq!(mac_fp16_int4(&PAPER_PE, &a, &w, ONE), 0);
    }

    #[test]
    fn equal_exponent_sums_exact() {
        // All lanes same exponent: shifter distance 0, tree adds exactly.
        let a = vec![f16_encode(1.0); 8];
        let w = vec![2i8; 8];
        let out = mac_fp16_int4(&PAPER_PE, &a, &w, ONE);
        assert_eq!(f16_decode(out), 16.0);
    }

    #[test]
    fn scale_applied_in_fp16() {
        let a = [f16_encode(2.0)];
        let w = [4i8];
        let scale = f16_encode(0.25);
        let out = mac_fp16_int4(&PAPER_PE, &a, &w, scale);
        assert_eq!(f16_decode(out), 2.0);
    }

    #[test]
    fn fp16_mode_single_lane() {
        let a = [f16_encode(1.5)];
        let b = [f16_encode(-2.0)];
        let out = mac_fp16_fp16(&PAPER_PE, &a, &b, ONE);
        assert_eq!(f16_decode(out), -3.0);
    }

    #[test]
    fn random_vectors_close_to_exact() {
        // Error must be tiny relative to the absolute-sum scale (robust to
        // cancellation, which inflates relative-to-result metrics).
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let a: Vec<u16> = (0..T_IN)
                .map(|_| f16_encode(rng.normal()))
                .collect();
            let w: Vec<i8> = (0..T_IN).map(|_| rng.int_in(-8, 7) as i8).collect();
            let got = f16_decode(mac_fp16_int4(&PAPER_PE, &a, &w, ONE));
            let exact = exact_dot_fp16_int4(&a, &w, 1.0);
            let norm: f64 = a
                .iter()
                .zip(&w)
                .map(|(&ai, &wi)| (f16_decode(ai) * wi as f64).abs())
                .sum();
            assert!(
                (got - exact).abs() <= 1e-3 * norm.max(1.0),
                "got={got} exact={exact} norm={norm}"
            );
        }
    }

    #[test]
    fn wider_tree_is_more_accurate() {
        // Ablation invariant: growing the tree width cannot hurt accuracy
        // on average (DESIGN.md §ablation).
        let mut rng = Rng::new(5);
        let wide = PeConfig { tree_bits: 30 };
        let mut err19 = 0.0;
        let mut err30 = 0.0;
        let mut n = 0;
        for _ in 0..300 {
            let a: Vec<u16> = (0..T_IN)
                .map(|_| f16_encode(rng.normal() * (2.0f64).powi(rng.int_in(-8, 8) as i32)))
                .collect();
            let w: Vec<i8> = (0..T_IN).map(|_| rng.int_in(-8, 7) as i8).collect();
            let exact = exact_dot_fp16_int4(&a, &w, 1.0);
            if exact.abs() < 1e-6 {
                continue;
            }
            let g19 = f16_decode(mac_fp16_int4(&PAPER_PE, &a, &w, ONE));
            let g30 = f16_decode(mac_fp16_int4(&wide, &a, &w, ONE));
            err19 += ((g19 - exact) / exact).abs();
            err30 += ((g30 - exact) / exact).abs();
            n += 1;
        }
        assert!(n > 100);
        assert!(
            err30 <= err19 * 1.05,
            "30b mean err {} should not exceed 19b {}",
            err30 / n as f64,
            err19 / n as f64
        );
    }

    #[test]
    fn saturation_clamps_not_wraps() {
        // Huge same-sign inputs: the 19-bit tree saturates; the result
        // must stay the right sign and be finite-or-inf, never flip sign.
        let a = vec![f16_encode(60000.0); T_IN];
        let w = vec![7i8; T_IN];
        let out = mac_fp16_int4(&PAPER_PE, &a, &w, ONE);
        assert!(f16_decode(out) > 0.0);
    }
}
