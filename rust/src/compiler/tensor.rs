//! The unified data format (paper §IV.A): every activation tensor lives
//! in memory as `[batch/head, CH/T_out, (H, W | token), T_out]` with the
//! channel-parallel dimension T_out innermost.
//!
//! Properties the compiler relies on (and this module checks):
//! * text and image tensors share the layout, so *no operator ever needs
//!   a data rearrangement* between steps;
//! * the innermost `[token, T_out]` (or `[W, T_out]`) plane is contiguous,
//!   so AXI bursts of width T_out×16 bit advance along it with unit
//!   stride ("the incremental address in AXI burst transfer will exactly
//!   be the width-dimension or token-dimension");
//! * transposes (the K^T in attention) become *segmented continuous*
//!   reads over that plane instead of physical data movement.

/// Channel-direction hardware parallelism (elements per AXI beat at FP16).
pub const T_OUT: usize = 16;

/// Unified tensor descriptor. `outer` is head (attention) or batch; text
/// tensors set `h = 1, w = token`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorDesc {
    pub name: String,
    pub outer: usize,
    pub channels: usize,
    pub h: usize,
    /// tokens (text) or width (image)
    pub w: usize,
    pub t_out: usize,
    /// base address in the activation arena (bytes)
    pub base: usize,
}

impl TensorDesc {
    /// Text-type tensor: (tokens, channels) → [CH/Tout, token, Tout].
    pub fn text(name: &str, tokens: usize, channels: usize, base: usize) -> Self {
        assert!(channels % T_OUT == 0, "channels {channels} % {T_OUT} != 0");
        TensorDesc { name: name.into(), outer: 1, channels, h: 1, w: tokens, t_out: T_OUT, base }
    }

    /// Image-type tensor: (H, W, CH) → [CH/Tout, H, W, Tout].
    pub fn image(name: &str, h: usize, w: usize, channels: usize, base: usize) -> Self {
        assert!(channels % T_OUT == 0);
        TensorDesc { name: name.into(), outer: 1, channels, h, w, t_out: T_OUT, base }
    }

    /// Per-head view (MHA): adds the head dimension outermost without
    /// moving data — channels divide into heads.
    pub fn with_heads(mut self, heads: usize) -> Self {
        assert!(self.channels % heads == 0);
        self.outer = heads;
        self.channels /= heads;
        self
    }

    pub fn ch_groups(&self) -> usize {
        self.channels / self.t_out
    }

    /// Total elements.
    pub fn elements(&self) -> usize {
        self.outer * self.channels * self.h * self.w
    }

    /// FP16 bytes.
    pub fn bytes(&self) -> usize {
        self.elements() * 2
    }

    /// Linear element offset of (outer o, channel c, row y, col x) under
    /// the unified layout.
    pub fn offset(&self, o: usize, c: usize, y: usize, x: usize) -> usize {
        assert!(o < self.outer && c < self.channels && y < self.h && x < self.w);
        let (g, t) = (c / self.t_out, c % self.t_out);
        (((o * self.ch_groups() + g) * self.h + y) * self.w + x) * self.t_out + t
    }

    /// Byte address of an element.
    pub fn addr(&self, o: usize, c: usize, y: usize, x: usize) -> usize {
        self.base + 2 * self.offset(o, c, y, x)
    }

    /// One AXI burst descriptor: (start element offset, beats) covering
    /// the full `[w, t_out]` plane of (outer, group, row) — the paper's
    /// burst unit. Each beat carries T_OUT FP16 values.
    pub fn burst_of_plane(&self, o: usize, g: usize, y: usize) -> (usize, usize) {
        let start = (((o * self.ch_groups() + g) * self.h + y) * self.w) * self.t_out;
        (start, self.w)
    }

    /// Check two descriptors are layout-compatible (an operator can
    /// stream one into the other with no rearrangement): same T_out and
    /// same innermost plane length.
    pub fn chains_with(&self, next: &TensorDesc) -> bool {
        self.t_out == next.t_out
    }

    /// The segmented-continuous transpose read schedule for K^T: returns,
    /// for each (head, channel-group), the burst covering all tokens of
    /// that group — consecutive addresses, so no reshape is required.
    pub fn transpose_bursts(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for o in 0..self.outer {
            for g in 0..self.ch_groups() {
                for y in 0..self.h {
                    out.push(self.burst_of_plane(o, g, y));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_and_image_share_layout() {
        // text (tokens=7, ch=64) and image (1×7, ch=64) produce identical
        // addressing — the unification claim.
        let t = TensorDesc::text("t", 7, 64, 0);
        let i = TensorDesc::image("i", 1, 7, 64, 0);
        for c in [0usize, 15, 16, 63] {
            for x in [0usize, 3, 6] {
                assert_eq!(t.offset(0, c, 0, x), i.offset(0, c, 0, x));
            }
        }
    }

    #[test]
    fn innermost_plane_is_contiguous() {
        // walking token-then-lane must touch consecutive element offsets
        let t = TensorDesc::text("x", 4, 32, 0);
        let mut last = None;
        for tok in 0..4 {
            for lane in 0..T_OUT {
                let off = t.offset(0, lane, 0, tok);
                if let Some(l) = last {
                    assert_eq!(off, l + 1, "burst not contiguous");
                }
                last = Some(off);
            }
        }
    }

    #[test]
    fn burst_covers_whole_plane() {
        let t = TensorDesc::text("x", 9, 48, 0x100);
        let (start, beats) = t.burst_of_plane(0, 2, 0);
        assert_eq!(beats, 9);
        assert_eq!(start, 2 * 9 * T_OUT);
        // last element of the burst = last token of group 2's last lane
        let last = t.offset(0, 2 * T_OUT + (T_OUT - 1), 0, 8);
        assert_eq!(start + beats * T_OUT - 1, last);
    }

    #[test]
    fn head_view_does_not_move_data() {
        // Reinterpreting (tokens, 128) as 4 heads × 32 channels keeps
        // every element at the same address.
        let flat = TensorDesc::text("qkv", 5, 128, 0);
        let headed = flat.clone().with_heads(4);
        for head in 0..4 {
            for c in 0..32 {
                for tok in 0..5 {
                    assert_eq!(
                        headed.offset(head, c, 0, tok),
                        flat.offset(0, head * 32 + c, 0, tok)
                    );
                }
            }
        }
    }

    #[test]
    fn transpose_bursts_are_sorted_and_disjoint() {
        let t = TensorDesc::text("k", 16, 64, 0).with_heads(2);
        let bursts = t.transpose_bursts();
        assert_eq!(bursts.len(), 2 * 2); // 2 heads × (32/16) groups
        let mut end = 0;
        for (start, beats) in bursts {
            assert!(start >= end, "overlapping bursts");
            end = start + beats * T_OUT;
        }
        assert_eq!(end, t.elements());
    }

    #[test]
    fn chains_without_rearrangement() {
        let a = TensorDesc::text("a", 3, 64, 0);
        let b = TensorDesc::text("b", 3, 256, 4096);
        assert!(a.chains_with(&b));
    }

    #[test]
    #[should_panic]
    fn rejects_unaligned_channels() {
        TensorDesc::text("bad", 3, 60, 0);
    }
}
