//! The end-to-end compiler (paper §IV): unified data format, operator
//! graph, dynamic-token instruction generation, and the instruction-
//! pipeline latency-hiding schedule.
//!
//! * [`tensor`] — the `[CH/T_out, token, T_out]` universal layout
//! * [`expr`] — symbolic token-expressions (DAG form, partial evaluation)
//! * [`graph`] — Fig. 6's fused 17-step block graph + invariants
//! * [`codegen`] — static-address instruction generation (MAX_TOKEN plan)
//! * [`pipeline`] — Fig. 9's auxiliary-path latency hiding

pub mod codegen;
pub mod expr;
pub mod graph;
pub mod pipeline;
pub mod tensor;
