//! Dynamic-token symbolic expressions (paper §IV.B).
//!
//! The compiler records every instruction parameter that depends on the
//! runtime token count as a numeric expression over the `token` variable,
//! kept as a small DAG. At compile time everything reducible is folded
//! (`MAX_TOKEN` makes addresses static); what remains is embedded in the
//! runtime code and evaluated per inference — "if this parameter can be
//! evaluated directly, the compiler returns the result of this
//! instruction, otherwise it is embedded in the runtime code".

use std::fmt;
use std::rc::Rc;

/// Expression node. `Token` is the only runtime variable; `MaxToken` is a
/// compile-time macro constant (RTL Macro Define).
#[derive(Debug, Clone)]
pub enum Expr {
    Const(i64),
    Token,
    Add(Rc<Expr>, Rc<Expr>),
    Sub(Rc<Expr>, Rc<Expr>),
    Mul(Rc<Expr>, Rc<Expr>),
    /// integer division (exact in practice: strides divide evenly)
    Div(Rc<Expr>, Rc<Expr>),
    Max(Rc<Expr>, Rc<Expr>),
}

impl Expr {
    pub fn c(v: i64) -> Rc<Expr> {
        Rc::new(Expr::Const(v))
    }

    pub fn token() -> Rc<Expr> {
        Rc::new(Expr::Token)
    }

    pub fn add(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Add(a, b))
    }

    pub fn sub(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Sub(a, b))
    }

    pub fn mul(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Mul(a, b))
    }

    pub fn div(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Div(a, b))
    }

    pub fn max(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Max(a, b))
    }

    /// Evaluate with a concrete token count.
    pub fn eval(&self, token: i64) -> i64 {
        match self {
            Expr::Const(v) => *v,
            Expr::Token => token,
            Expr::Add(a, b) => a.eval(token) + b.eval(token),
            Expr::Sub(a, b) => a.eval(token) - b.eval(token),
            Expr::Mul(a, b) => a.eval(token) * b.eval(token),
            Expr::Div(a, b) => a.eval(token) / b.eval(token),
            Expr::Max(a, b) => a.eval(token).max(b.eval(token)),
        }
    }

    /// Constant-fold: returns Some(v) iff the expression does not depend
    /// on `token` (the compiler's "can be evaluated directly" test).
    pub fn fold(&self) -> Option<i64> {
        match self {
            Expr::Const(v) => Some(*v),
            Expr::Token => None,
            Expr::Add(a, b) => Some(a.fold()? + b.fold()?),
            Expr::Sub(a, b) => Some(a.fold()? - b.fold()?),
            Expr::Mul(a, b) => Some(a.fold()? * b.fold()?),
            Expr::Div(a, b) => Some(a.fold()? / b.fold()?),
            Expr::Max(a, b) => Some(a.fold()?.max(b.fold()?)),
        }
    }

    /// Simplify: fold constant subtrees, drop identities (x+0, x*1, x*0).
    pub fn simplify(e: &Rc<Expr>) -> Rc<Expr> {
        if let Some(v) = e.fold() {
            return Expr::c(v);
        }
        match &**e {
            Expr::Add(a, b) => {
                let (a, b) = (Self::simplify(a), Self::simplify(b));
                match (a.fold(), b.fold()) {
                    (Some(0), _) => b,
                    (_, Some(0)) => a,
                    _ => Expr::add(a, b),
                }
            }
            Expr::Sub(a, b) => {
                let (a, b) = (Self::simplify(a), Self::simplify(b));
                if b.fold() == Some(0) {
                    a
                } else {
                    Expr::sub(a, b)
                }
            }
            Expr::Mul(a, b) => {
                let (a, b) = (Self::simplify(a), Self::simplify(b));
                match (a.fold(), b.fold()) {
                    (Some(0), _) | (_, Some(0)) => Expr::c(0),
                    (Some(1), _) => b,
                    (_, Some(1)) => a,
                    _ => Expr::mul(a, b),
                }
            }
            Expr::Div(a, b) => {
                let (a, b) = (Self::simplify(a), Self::simplify(b));
                if b.fold() == Some(1) {
                    a
                } else {
                    Expr::div(a, b)
                }
            }
            Expr::Max(a, b) => Expr::max(Self::simplify(a), Self::simplify(b)),
            _ => e.clone(),
        }
    }

    /// Number of nodes (instruction-space cost of a runtime expression).
    pub fn size(&self) -> usize {
        match self {
            Expr::Const(_) | Expr::Token => 1,
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Max(a, b) => 1 + a.size() + b.size(),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(v) => write!(f, "{v}"),
            Expr::Token => write!(f, "token"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a} * {b})"),
            Expr::Div(a, b) => write!(f, "({a} / {b})"),
            Expr::Max(a, b) => write!(f, "max({a}, {b})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_and_fold() {
        // bytes of a [CH/Tout, token, Tout] activation: token * 4096 * 2
        let e = Expr::mul(Expr::token(), Expr::c(8192));
        assert_eq!(e.eval(1), 8192);
        assert_eq!(e.eval(128), 1048576);
        assert_eq!(e.fold(), None);
        let c = Expr::mul(Expr::c(64), Expr::c(128));
        assert_eq!(c.fold(), Some(8192));
    }

    #[test]
    fn simplify_folds_constants() {
        let e = Expr::add(
            Expr::mul(Expr::c(2), Expr::c(3)),
            Expr::mul(Expr::token(), Expr::c(1)),
        );
        let s = Expr::simplify(&e);
        assert_eq!(s.to_string(), "(6 + token)");
        assert_eq!(s.size(), 3);
    }

    #[test]
    fn simplify_identities() {
        let e = Expr::mul(Expr::token(), Expr::c(0));
        assert_eq!(Expr::simplify(&e).fold(), Some(0));
        let e2 = Expr::add(Expr::token(), Expr::c(0));
        assert_eq!(Expr::simplify(&e2).to_string(), "token");
        let e3 = Expr::div(Expr::token(), Expr::c(1));
        assert_eq!(Expr::simplify(&e3).to_string(), "token");
    }

    #[test]
    fn max_token_makes_addresses_static() {
        // address = base + MAX_TOKEN·stride is constant-foldable even
        // though the live token count is dynamic (paper's key trick).
        const MAX_TOKEN: i64 = 256;
        let addr = Expr::add(Expr::c(0x1000), Expr::mul(Expr::c(MAX_TOKEN), Expr::c(8192)));
        assert_eq!(addr.fold(), Some(0x1000 + 256 * 8192));
    }

    #[test]
    fn display_roundtrips_structure() {
        let e = Expr::max(Expr::token(), Expr::c(1));
        assert_eq!(e.to_string(), "max(token, 1)");
    }
}
