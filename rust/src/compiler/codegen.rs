//! Instruction generation (paper §IV.B, Fig. 8).
//!
//! Each graph node compiles to one hardware instruction: an opcode, a
//! register image whose *addresses are static* (planned at MAX_TOKEN),
//! and a small list of dynamic fields left as token-expressions. At
//! inference time the runtime evaluates only those residual expressions —
//! "the hardware instructions require very little space, making the
//! inference space of KVcache very sufficient".

use std::rc::Rc;

use super::expr::Expr;
use super::graph::{build_graph, Graph};
use crate::models::{LlmArch, SparseStrategy};
use crate::sim::operators::OpClass;

/// Register image of one instruction.
#[derive(Debug, Clone)]
pub struct Instruction {
    pub opcode: u8,
    pub name: &'static str,
    pub layer: usize,
    /// static fields (resolved at compile time)
    pub src_addr: usize,
    pub dst_addr: usize,
    pub weight_addr: usize,
    /// dynamic fields: (register name, expression)
    pub dynamic: Vec<(&'static str, Rc<Expr>)>,
}

impl Instruction {
    /// Bytes of instruction storage: 32-byte register image + 8 bytes per
    /// residual dynamic expression node.
    pub fn storage_bytes(&self) -> usize {
        32 + self
            .dynamic
            .iter()
            .map(|(_, e)| 8 * e.size())
            .sum::<usize>()
    }

    /// Resolve the dynamic fields for a concrete token count.
    pub fn resolve(&self, token: i64) -> Vec<(&'static str, i64)> {
        self.dynamic.iter().map(|(n, e)| (*n, e.eval(token))).collect()
    }
}

pub fn opcode_of(class: OpClass) -> u8 {
    match class {
        OpClass::LayerNorm => 0x01,
        OpClass::VmmBn => 0x02,
        OpClass::Rope => 0x03,
        OpClass::MhaMatmul => 0x04,
        OpClass::Softmax => 0x05,
        OpClass::Dat2Hbm => 0x06,
        OpClass::Act => 0x07,
    }
}

/// Compiled program: instruction stream + weight-region plan.
#[derive(Debug)]
pub struct Program {
    pub graph: Graph,
    pub instructions: Vec<Instruction>,
    pub max_token: usize,
}

/// Compile a model into its instruction stream.
pub fn compile(arch: &LlmArch, strat: &SparseStrategy, max_token: usize) -> Program {
    let graph = build_graph(arch, strat, max_token);
    graph
        .check_chaining()
        .expect("unified data format violated");
    graph.check_arena(max_token).expect("activation arena overflow");

    // weight regions: HBM planned per VMM in graph order
    let mut weight_cursor = 0usize;
    let mut instructions = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let weight_addr = match node.op.class {
            OpClass::VmmBn => {
                let bytes =
                    crate::pack::matrix_bytes(node.op.k, node.op.n, node.op.sparsity);
                let at = weight_cursor;
                weight_cursor += bytes.next_multiple_of(4096);
                at
            }
            _ => 0,
        };
        // residual dynamic fields by op class
        let tok = Expr::token();
        let dynamic: Vec<(&'static str, Rc<Expr>)> = match node.op.class {
            OpClass::VmmBn => vec![
                // number of activation rows to stream
                ("rows", tok.clone()),
            ],
            OpClass::MhaMatmul | OpClass::Softmax => vec![
                // context length visible to attention
                ("ctx", tok.clone()),
            ],
            OpClass::Dat2Hbm => vec![
                // KV write offset = pos × row stride (token-dependent)
                ("kv_off", Expr::simplify(&Expr::mul(
                    tok.clone(),
                    Expr::c((node.op.k * 2) as i64),
                ))),
            ],
            _ => vec![("rows", tok.clone())],
        };
        instructions.push(Instruction {
            opcode: opcode_of(node.op.class),
            name: node.op.name,
            layer: node.layer,
            src_addr: node.input.base,
            dst_addr: node.output.base,
            weight_addr,
            dynamic,
        });
    }
    Program { graph, instructions, max_token }
}

impl Program {
    /// Total instruction storage (paper: small enough to leave HBM to the
    /// KV cache).
    pub fn instruction_bytes(&self) -> usize {
        self.instructions.iter().map(|i| i.storage_bytes()).sum()
    }

    /// Total planned HBM weight bytes.
    pub fn weight_bytes(&self) -> usize {
        self.graph
            .nodes
            .iter()
            .filter(|n| n.op.class == OpClass::VmmBn)
            .map(|n| {
                crate::pack::matrix_bytes(n.op.k, n.op.n, n.op.sparsity)
                    .next_multiple_of(4096)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DENSE, GLM_6B, STRATEGY_3, TINY};

    #[test]
    fn instruction_stream_covers_graph() {
        let p = compile(&TINY, &DENSE, 64);
        assert_eq!(p.instructions.len(), p.graph.nodes.len());
    }

    #[test]
    fn instruction_storage_is_small() {
        // Paper: instructions must leave HBM space for the KV cache —
        // the full GLM-6B program must compile to well under 1 MB.
        let p = compile(&GLM_6B, &STRATEGY_3, 256);
        let bytes = p.instruction_bytes();
        assert!(bytes < 1 << 20, "instruction stream {bytes} bytes");
    }

    #[test]
    fn weight_regions_are_disjoint_and_ordered() {
        let p = compile(&TINY, &DENSE, 64);
        let mut last_end = 0usize;
        for i in &p.instructions {
            if i.opcode == opcode_of(OpClass::VmmBn) {
                assert!(i.weight_addr >= last_end, "overlapping weight regions");
                last_end = i.weight_addr + 1; // ordering check only
            }
        }
    }

    #[test]
    fn dynamic_fields_resolve_per_token() {
        let p = compile(&TINY, &DENSE, 64);
        let vmm = p
            .instructions
            .iter()
            .find(|i| i.opcode == opcode_of(OpClass::VmmBn))
            .unwrap();
        assert_eq!(vmm.resolve(1), vec![("rows", 1)]);
        assert_eq!(vmm.resolve(37), vec![("rows", 37)]);
        let kv = p
            .instructions
            .iter()
            .find(|i| i.opcode == opcode_of(OpClass::Dat2Hbm))
            .unwrap();
        let off = kv.resolve(10)[0].1;
        assert_eq!(off, 10 * (TINY.kv_dim() * 2) as i64);
    }

    #[test]
    fn addresses_are_static_across_token_counts() {
        // the whole point of MAX_TOKEN planning: src/dst/weight addresses
        // do not depend on the runtime token count
        let p = compile(&TINY, &DENSE, 64);
        for i in &p.instructions {
            // static fields are plain usizes — nothing to re-evaluate; the
            // dynamic list must be tiny
            assert!(i.dynamic.len() <= 2, "{}: too many dynamic fields", i.name);
        }
    }

    #[test]
    fn weight_plan_matches_pack_accounting() {
        let p = compile(&GLM_6B, &DENSE, 64);
        let total = p.weight_bytes();
        let expect: usize = crate::models::block_weight_bytes(&GLM_6B, &DENSE)
            * GLM_6B.n_layers;
        // alignment padding only
        assert!(total >= expect);
        assert!(total < expect + expect / 10 + (1 << 24));
    }
}
