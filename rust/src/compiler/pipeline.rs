//! Instruction-pipeline latency hiding (paper Fig. 9).
//!
//! The accelerator's auxiliary path DMA-streams serialized instruction
//! batches from on-chip DDR; the host only writes the batch descriptor.
//! While the accelerator computes batch *i*, the host prepares (evaluates
//! residual token-expressions of) batch *i+1* — so dynamic-control
//! updates are hidden behind accelerator time. Without the auxiliary
//! path, every instruction pays its host programming latency in-line.

use super::codegen::Program;
use crate::sim::engine::HOST_GAP_US;
use crate::sim::operators::latency_us;
use crate::sim::{HwConfig, Memory};

/// Timeline of one inference pass under a pipelining mode.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub accel_us: f64,
    /// host time *exposed* on the critical path
    pub exposed_host_us: f64,
    /// host time overlapped with accelerator execution
    pub hidden_host_us: f64,
}

impl Timeline {
    pub fn total_us(&self) -> f64 {
        self.accel_us + self.exposed_host_us
    }
}

/// Host cost to prepare one instruction (expression evaluation + batch
/// descriptor bookkeeping) when pipelined — much cheaper than the
/// register-by-register programming it replaces.
pub const PREP_US: f64 = 0.8;

/// Execute the program's timeline for one pass.
///
/// `tokens`/`ctx` follow the simulator convention; `pipelined` selects
/// Fig. 9's auxiliary-path mode.
pub fn run_timeline(
    p: &Program,
    hw: &HwConfig,
    tokens: usize,
    ctx: usize,
    mem: Memory,
    pipelined: bool,
) -> Timeline {
    let mut accel = 0.0f64;
    let mut exposed = 0.0f64;
    let mut hidden = 0.0f64;
    // batch granularity: one layer's instructions per auxiliary DMA batch
    let mut pending_prep = 0.0f64;
    for (node, _inst) in p.graph.nodes.iter().zip(&p.instructions) {
        let t = latency_us(hw, &node.op, tokens, ctx, mem);
        if pipelined {
            // host preps the NEXT instruction while this one runs
            let prep = PREP_US;
            let overlap = prep.min(t);
            hidden += overlap;
            exposed += prep - overlap;
            pending_prep = 0.0;
        } else {
            // in-line register programming before each op
            exposed += HOST_GAP_US;
        }
        accel += t;
        let _ = pending_prep;
    }
    if pipelined {
        // the very first batch cannot be hidden (paper: "we only need to
        // update the complete instruction before the first model
        // inference")
        exposed += PREP_US;
    }
    Timeline { accel_us: accel, exposed_host_us: exposed, hidden_host_us: hidden }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::codegen::compile;
    use crate::models::{DENSE, GLM_6B};

    fn program() -> Program {
        compile(&GLM_6B, &DENSE, 256)
    }

    #[test]
    fn pipelining_hides_host_latency() {
        let p = program();
        let hw = HwConfig::default();
        let piped = run_timeline(&p, &hw, 1, 128, Memory::Hbm, true);
        let unpiped = run_timeline(&p, &hw, 1, 128, Memory::Hbm, false);
        assert!(piped.total_us() < unpiped.total_us());
        // Fig. 9: essentially all dynamic-control latency disappears
        assert!(
            piped.exposed_host_us < 0.05 * unpiped.exposed_host_us,
            "exposed {} vs {}",
            piped.exposed_host_us,
            unpiped.exposed_host_us
        );
    }

    #[test]
    fn accel_time_is_mode_independent() {
        let p = program();
        let hw = HwConfig::default();
        let a = run_timeline(&p, &hw, 1, 128, Memory::Hbm, true).accel_us;
        let b = run_timeline(&p, &hw, 1, 128, Memory::Hbm, false).accel_us;
        assert_eq!(a, b);
    }

    #[test]
    fn hidden_work_accounted() {
        let p = program();
        let hw = HwConfig::default();
        let t = run_timeline(&p, &hw, 1, 128, Memory::Hbm, true);
        // every instruction's prep happens somewhere
        let total_prep = PREP_US * p.instructions.len() as f64;
        let seen = t.hidden_host_us + t.exposed_host_us;
        assert!((seen - total_prep).abs() < PREP_US + 1e-9, "{seen} vs {total_prep}");
    }

    #[test]
    fn unpipelined_cost_matches_host_gap() {
        let p = program();
        let hw = HwConfig::default();
        let t = run_timeline(&p, &hw, 1, 128, Memory::Hbm, false);
        let want = HOST_GAP_US * p.instructions.len() as f64;
        assert!((t.exposed_host_us - want).abs() < 1e-6);
    }
}
