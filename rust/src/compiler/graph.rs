//! The fused operator graph (Fig. 6) and its compilation invariants.
//!
//! A `Graph` is a linear chain of fused hardware steps (the paper executes
//! temporally — "one operator starting only after the previous one has
//! finished"). Compilation checks the unified-data-format contract: every
//! edge chains without rearrangement, dynamic shapes are expressions over
//! `token`, and every activation fits the statically-planned arena.

use std::rc::Rc;

use super::expr::Expr;
use super::tensor::{TensorDesc, T_OUT};
use crate::models::{LlmArch, SparseStrategy};
use crate::sim::operators::{block_ops, output_ops, OpClass, OpInstance};

/// One node of the compiled graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: OpInstance,
    /// layer this node belongs to (output head = n_layers)
    pub layer: usize,
    /// input activation descriptor (shape at MAX_TOKEN for planning)
    pub input: TensorDesc,
    pub output: TensorDesc,
    /// dynamic byte counts as token-expressions
    pub in_bytes: Rc<Expr>,
    pub out_bytes: Rc<Expr>,
}

#[derive(Debug, Clone)]
pub struct Graph {
    pub arch: LlmArch,
    pub nodes: Vec<Node>,
    /// bytes of activation arena consumed by static planning
    pub arena_bytes: usize,
}

/// Build the full-model operator graph at a planning MAX_TOKEN.
pub fn build_graph(arch: &LlmArch, strat: &SparseStrategy, max_token: usize) -> Graph {
    let mut nodes = Vec::new();
    // Double-buffered activation arena: ping/pong between steps.
    let act_bytes = |ch: usize| max_token * ch.max(T_OUT) * 2;
    let max_ch = (2 * arch.d_ffn).max(arch.d_model).max(arch.vocab);
    let slot = act_bytes(max_ch).next_multiple_of(4096);
    let ping = 0usize;
    let pong = slot;
    let arena_bytes = 2 * slot;

    let tok = Expr::token();
    let mut flip = false;
    let mut push = |op: &OpInstance, layer: usize, in_ch: usize, out_ch: usize| {
        let (src, dst) = if flip { (pong, ping) } else { (ping, pong) };
        flip = !flip;
        let input = TensorDesc::text(op.name, max_token, in_ch.max(T_OUT), src);
        let output = TensorDesc::text(op.name, max_token, out_ch.max(T_OUT), dst);
        let in_bytes = Expr::simplify(&Expr::mul(tok.clone(), Expr::c((in_ch * 2) as i64)));
        let out_bytes = Expr::simplify(&Expr::mul(tok.clone(), Expr::c((out_ch * 2) as i64)));
        nodes.push(Node { op: op.clone(), layer, input, output, in_bytes, out_bytes });
    };

    for layer in 0..arch.n_layers {
        for op in block_ops(arch, strat) {
            let (in_ch, out_ch) = io_channels(arch, &op);
            push(&op, layer, in_ch, out_ch);
        }
    }
    for op in output_ops(arch) {
        let (in_ch, out_ch) = io_channels(arch, &op);
        push(&op, arch.n_layers, in_ch, out_ch);
    }
    Graph { arch: arch.clone(), nodes, arena_bytes }
}

/// Channel widths of an operator's activation input/output.
fn io_channels(arch: &LlmArch, op: &OpInstance) -> (usize, usize) {
    match op.class {
        OpClass::VmmBn => (op.k, op.n),
        OpClass::MhaMatmul => (arch.kv_dim(), arch.d_model),
        OpClass::Softmax => (arch.n_heads * T_OUT, arch.n_heads * T_OUT),
        OpClass::LayerNorm | OpClass::Rope | OpClass::Act | OpClass::Dat2Hbm => (op.n, op.n),
    }
}

impl Graph {
    /// The unified-format invariant: every adjacent pair chains without a
    /// data rearrangement. Returns the offending edge if any.
    pub fn check_chaining(&self) -> Result<(), (usize, String)> {
        for (i, pair) in self.nodes.windows(2).enumerate() {
            if !pair[0].output.chains_with(&pair[1].input) {
                return Err((i, format!(
                    "{} -> {} requires a rearrangement",
                    pair[0].op.name, pair[1].op.name
                )));
            }
        }
        Ok(())
    }

    /// Steps per block (Fig. 6: 17) and total node count.
    pub fn steps_per_block(&self) -> usize {
        self.nodes.len().saturating_sub(2) / self.arch.n_layers
    }

    /// All dynamic byte expressions must fit the arena at token=MAX.
    pub fn check_arena(&self, max_token: usize) -> Result<(), String> {
        for n in &self.nodes {
            let need = n.out_bytes.eval(max_token as i64) as usize;
            let avail = self.arena_bytes / 2;
            if need > avail {
                return Err(format!(
                    "{}: needs {need} bytes > slot {avail}",
                    n.op.name
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DENSE, GLM_6B, STRATEGY_3, TINY};

    #[test]
    fn glm_graph_has_17_steps_per_block() {
        let g = build_graph(&GLM_6B, &DENSE, 256);
        assert_eq!(g.steps_per_block(), 17);
        assert_eq!(g.nodes.len(), 17 * 28 + 2);
    }

    #[test]
    fn chaining_holds_everywhere() {
        for (arch, strat) in [(&GLM_6B, &DENSE), (&TINY, &STRATEGY_3)] {
            let g = build_graph(arch, strat, 128);
            assert!(g.check_chaining().is_ok());
        }
    }

    #[test]
    fn arena_fits_max_token() {
        let g = build_graph(&GLM_6B, &DENSE, 256);
        assert!(g.check_arena(256).is_ok());
    }

    #[test]
    fn dynamic_bytes_scale_with_token() {
        let g = build_graph(&TINY, &DENSE, 64);
        let n = &g.nodes[1]; // VMM-BN(Q)
        assert_eq!(
            n.out_bytes.eval(64) / n.out_bytes.eval(1),
            64,
            "activation bytes must be linear in token"
        );
    }

    #[test]
    fn ping_pong_buffers_alternate() {
        let g = build_graph(&TINY, &DENSE, 64);
        for pair in g.nodes.windows(2) {
            assert_ne!(
                pair[0].output.base, pair[1].output.base,
                "consecutive steps must not overwrite their own input"
            );
            assert_eq!(pair[0].output.base, pair[1].input.base);
        }
    }
}
