//! Fixed log2-bucket latency histograms.
//!
//! One [`Hist`] is 64 `AtomicU64` buckets plus count/sum/max — no
//! allocation ever, recording is three relaxed atomic ops on `&self`,
//! so a histogram can sit behind an `Arc` and take samples from the
//! engine round loop, the bridge client, and the device daemon without
//! a lock. Bucket `i ≥ 1` covers values in `[2^(i-1), 2^i)`; bucket 0
//! holds exact zeros. Percentile extraction snapshots the buckets and
//! interpolates linearly inside the target bucket, capped by the true
//! observed maximum, so the answer is within one power of two of the
//! exact order statistic (in practice much closer — the benches assert
//! agreement with offline-sorted percentiles in `benches/overload.rs`).
//!
//! All serving histograms record **microseconds** by convention; the
//! field names exported on the stats line carry a `_us` suffix.

#![deny(missing_docs)]

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Number of log2 buckets. 64 buckets cover every `u64` value.
pub const N_BUCKETS: usize = 64;

/// A lock-free fixed-footprint latency histogram (see module docs).
pub struct Hist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// `Hist::record` never blocks and never allocates; the snapshot side
/// (`percentile`/`summary`) tolerates racing recorders — it reads a
/// consistent-enough view for monitoring, not an atomic cut.
impl Hist {
    /// Empty histogram.
    pub fn new() -> Self {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index for a value: 0 for 0, else `⌊log2 v⌋ + 1`, capped.
    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(N_BUCKETS - 1)
        }
    }

    /// Inclusive lower bound of bucket `i`.
    fn bucket_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Exclusive upper bound of bucket `i` (saturating at the top).
    fn bucket_hi(i: usize) -> u64 {
        if i == 0 {
            1
        } else if i >= N_BUCKETS - 1 {
            u64::MAX
        } else {
            1u64 << i
        }
    }

    /// Record one sample. Three relaxed atomic RMW ops; hot-path safe.
    pub fn record(&self, v: u64) {
        if let Some(b) = self.buckets.get(Self::bucket_of(v)) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// The `p`-th percentile (`p` in `[0, 1]`), linearly interpolated
    /// inside the target log2 bucket and capped at the observed max.
    /// Returns 0.0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let lo = Self::bucket_lo(i) as f64;
                let hi = (Self::bucket_hi(i).min(self.max().max(1))) as f64;
                let frac = (rank - seen) as f64 / c as f64;
                let est = lo + (hi.max(lo) - lo) * frac;
                return est.min(self.max() as f64);
            }
            seen += c;
        }
        self.max() as f64
    }

    /// p50/p90/p99 plus count/sum/max in one snapshot.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p99: self.percentile(0.99),
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

/// One-shot summary of a [`Hist`]: what the stats line and the device
/// `InfoResp` tail export.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    /// Total samples.
    pub count: u64,
    /// Sum of all samples (same unit as the samples).
    pub sum: u64,
    /// Largest sample.
    pub max: u64,
    /// Median estimate.
    pub p50: f64,
    /// 90th-percentile estimate.
    pub p90: f64,
    /// 99th-percentile estimate.
    pub p99: f64,
}

impl HistSummary {
    /// `{"count":..,"p50":..,"p90":..,"p99":..,"max":..}` for the
    /// serving stats line.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50", Json::Num(self.p50)),
            ("p90", Json::Num(self.p90)),
            ("p99", Json::Num(self.p99)),
            ("max", Json::Num(self.max as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(2), 2);
        assert_eq!(Hist::bucket_of(3), 2);
        assert_eq!(Hist::bucket_of(4), 3);
        assert_eq!(Hist::bucket_of(7), 3);
        assert_eq!(Hist::bucket_of(8), 4);
        assert_eq!(Hist::bucket_of(u64::MAX), N_BUCKETS - 1);
        // every bucket's bounds nest: lo(i) < hi(i) == lo(i+1)
        for i in 1..N_BUCKETS - 1 {
            assert_eq!(Hist::bucket_hi(i), Hist::bucket_lo(i + 1), "bucket {i}");
            assert!(Hist::bucket_lo(i) < Hist::bucket_hi(i), "bucket {i}");
        }
    }

    #[test]
    fn recorded_values_land_in_their_bucket_bounds() {
        for v in [0u64, 1, 2, 3, 15, 16, 17, 1000, 1 << 40] {
            let i = Hist::bucket_of(v);
            assert!(Hist::bucket_lo(i) <= v, "v={v} bucket {i}");
            assert!(v < Hist::bucket_hi(i) || i == N_BUCKETS - 1, "v={v} bucket {i}");
        }
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0.0);
        let s = h.summary();
        assert_eq!((s.count, s.max), (0, 0));
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn single_value_percentiles_are_exactish() {
        let h = Hist::new();
        for _ in 0..1000 {
            h.record(700);
        }
        // 700 lives in [512, 1024); interpolation is capped by max=700
        for p in [0.5, 0.9, 0.99] {
            let est = h.percentile(p);
            assert!((512.0..=700.0).contains(&est), "p{p}: {est}");
        }
        assert_eq!(h.max(), 700);
        assert_eq!(h.sum(), 700_000);
    }

    #[test]
    fn percentiles_are_monotonic_and_bounded_by_max() {
        let h = Hist::new();
        for v in 1..=1024u64 {
            h.record(v);
        }
        let (p50, p90, p99) = (h.percentile(0.5), h.percentile(0.9), h.percentile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p99 <= h.max() as f64);
        // uniform 1..=1024: true p50 = 512; log2 quantization keeps the
        // estimate within its bucket's factor-of-two band
        assert!((256.0..=1024.0).contains(&p50), "{p50}");
        assert!(p99 >= 512.0, "{p99}");
    }

    #[test]
    fn p0_and_p100_hit_the_extremes() {
        let h = Hist::new();
        h.record(10);
        h.record(1_000_000);
        assert!(h.percentile(0.0) <= 16.0);
        assert_eq!(h.percentile(1.0), 1_000_000.0);
    }

    #[test]
    fn summary_json_has_the_stats_line_fields() {
        let h = Hist::new();
        h.record(100);
        let j = h.summary().to_json();
        for k in ["count", "p50", "p90", "p99", "max"] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        use std::sync::Arc;
        let h = Arc::new(Hist::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("recorder thread");
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }
}
