//! Observability: latency histograms + request-lifecycle tracing.
//!
//! The serving stack's lifetime counters (`EngineMetrics`,
//! `MemoryStats`, `TransferMeter`) say *how much* work happened; this
//! module says *how long it took* and *in what order*. One shared
//! [`Obs`] registry per process side holds:
//!
//! * log2-bucket [`Hist`]ograms (microseconds) for queue wait, TTFT,
//!   inter-token latency, decode-round duration, per-opcode bridge
//!   frame RTT, and device frame service time;
//! * a bounded [`TraceRing`] of lifecycle [`Span`]s, exportable as
//!   Chrome-trace JSON via `edgellm trace-dump` or the v2 `{"trace":N}`
//!   query.
//!
//! The registry is deliberately pull-based and allocation-free on the
//! hot path: recorders touch pre-sized atomics or overwrite ring slots;
//! aggregation (percentiles, JSON) happens only when a stats or trace
//! query asks. The engine creates the registry, hands an `Arc` clone to
//! its backend via `Backend::attach_obs`, and the device daemon keeps
//! its own — device-side figures travel back in the backward-compatible
//! [`ObsStats`] tail of the `InfoResp` frame.
//!
//! See `docs/observability.md` for the field tables and workflows.

#![deny(missing_docs)]

use std::time::Instant;

use crate::util::json::Json;

pub mod hist;
pub mod trace;

pub use hist::{Hist, HistSummary, N_BUCKETS};
pub use trace::{chrome_trace_json, Span, SpanKind, TraceRing};

/// Number of request opcodes the bridge RTT histograms cover
/// (`Info` 0x01 … `CloseSession` 0x06).
pub const N_FRAME_OPS: usize = 6;

/// Stats-line / trace-viewer names for the request opcodes, indexed by
/// `opcode - 1`.
pub const FRAME_OP_NAMES: [&str; N_FRAME_OPS] = [
    "info",
    "open_session",
    "prefill",
    "decode",
    "decode_batch",
    "close_session",
];

/// Default span capacity for a serving-side trace ring.
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// Device-side observability figures carried in the backward-compatible
/// second tail of the `InfoResp` frame (after the memory tail). Old
/// devices omit it; old coordinators ignore it. The field list is
/// wire-anchored: the analyzer's wire-drift lint cross-checks the
/// encoder, the decoder, and the python mirror's `OBS_FIELDS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsStats {
    /// KV-arena allocation attempts that failed for want of free blocks.
    pub alloc_stalls: u64,
    /// Copy-on-write block copies performed by the arena.
    pub cow_copies: u64,
    /// Frames the device served since start.
    pub frames_served: u64,
    /// p50 frame service time, microseconds.
    pub frame_p50_us: u64,
    /// p90 frame service time, microseconds.
    pub frame_p90_us: u64,
    /// p99 frame service time, microseconds.
    pub frame_p99_us: u64,
    /// Worst observed frame service time, microseconds.
    pub frame_max_us: u64,
}

impl ObsStats {
    /// Render for the stats line / `edgellm info` output.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alloc_stalls", Json::Num(self.alloc_stalls as f64)),
            ("cow_copies", Json::Num(self.cow_copies as f64)),
            ("frames_served", Json::Num(self.frames_served as f64)),
            ("frame_p50_us", Json::Num(self.frame_p50_us as f64)),
            ("frame_p90_us", Json::Num(self.frame_p90_us as f64)),
            ("frame_p99_us", Json::Num(self.frame_p99_us as f64)),
            ("frame_max_us", Json::Num(self.frame_max_us as f64)),
        ])
    }
}

/// KV-arena pressure counters a backend can surface without exposing
/// the arena itself (`Backend::kv_pressure`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvPressure {
    /// Allocation attempts refused for want of free blocks.
    pub alloc_stalls: u64,
    /// Copy-on-write block copies performed.
    pub cow_copies: u64,
}

/// One process side's observability registry (see module docs). Share
/// it behind an `Arc`; every member records through `&self`.
pub struct Obs {
    origin: Instant,
    /// Submit → admission decision, per admitted request.
    pub queue_wait_us: Hist,
    /// Submit → first token, fresh admissions only (a resumed victim
    /// already streamed its first token before preemption).
    pub ttft_us: Hist,
    /// Gap between consecutive streamed tokens of one request.
    pub itl_us: Hist,
    /// Wall time of one full `step_round`.
    pub round_us: Hist,
    /// Bridge-client frame round-trip time, one histogram per request
    /// opcode (`FRAME_OP_NAMES` order).
    pub frame_rtt_us: [Hist; N_FRAME_OPS],
    /// Device-side request handling time (decode → reply written).
    pub frame_service_us: Hist,
    /// Lifecycle span ring.
    pub trace: TraceRing,
}

impl Obs {
    /// Registry with the default trace capacity.
    pub fn new() -> Self {
        Self::with_trace_cap(DEFAULT_TRACE_CAP)
    }

    /// Registry retaining the most recent `trace_cap` spans.
    pub fn with_trace_cap(trace_cap: usize) -> Self {
        Obs {
            origin: Instant::now(),
            queue_wait_us: Hist::new(),
            ttft_us: Hist::new(),
            itl_us: Hist::new(),
            round_us: Hist::new(),
            frame_rtt_us: std::array::from_fn(|_| Hist::new()),
            frame_service_us: Hist::new(),
            trace: TraceRing::new(trace_cap),
        }
    }

    /// Monotonic nanoseconds since this registry was created — the
    /// epoch every span timestamp is relative to.
    pub fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    /// RTT histogram for a request opcode (`0x01..=0x06`), `None` for
    /// anything else — unknown opcodes are dropped, not misfiled.
    pub fn frame_rtt(&self, opcode: u8) -> Option<&Hist> {
        self.frame_rtt_us.get(opcode.wrapping_sub(1) as usize)
    }

    /// The nested `latency` object for the `{"stats":true}` line:
    /// engine histograms always, per-opcode frame RTTs only once that
    /// opcode has samples (an in-process backend contributes none).
    pub fn latency_json(&self) -> Json {
        let mut pairs = vec![
            ("queue_wait_us", self.queue_wait_us.summary().to_json()),
            ("ttft_us", self.ttft_us.summary().to_json()),
            ("itl_us", self.itl_us.summary().to_json()),
            ("round_us", self.round_us.summary().to_json()),
        ];
        let mut rtt = Vec::new();
        for (i, h) in self.frame_rtt_us.iter().enumerate() {
            if h.count() > 0 {
                if let Some(name) = FRAME_OP_NAMES.get(i) {
                    rtt.push((*name, h.summary().to_json()));
                }
            }
        }
        if !rtt.is_empty() {
            pairs.push(("frame_rtt_us", Json::obj(rtt)));
        }
        Json::obj(pairs)
    }

    /// Build the `InfoResp` [`ObsStats`] tail from this (device-side)
    /// registry plus the backend's arena counters.
    pub fn device_stats(&self, kv: Option<KvPressure>) -> ObsStats {
        let s = self.frame_service_us.summary();
        let kv = kv.unwrap_or_default();
        ObsStats {
            alloc_stalls: kv.alloc_stalls,
            cow_copies: kv.cow_copies,
            frames_served: s.count,
            frame_p50_us: s.p50 as u64,
            frame_p90_us: s.p90 as u64,
            frame_p99_us: s.p99 as u64,
            frame_max_us: s.max,
        }
    }
}

impl Default for Obs {
    fn default() -> Self {
        Obs::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_ns_is_monotonic() {
        let o = Obs::new();
        let a = o.now_ns();
        let b = o.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn frame_rtt_maps_request_opcodes_only() {
        let o = Obs::new();
        for op in 1u8..=6 {
            assert!(o.frame_rtt(op).is_some(), "opcode {op:#x}");
        }
        assert!(o.frame_rtt(0).is_none());
        assert!(o.frame_rtt(7).is_none());
        assert!(o.frame_rtt(0x81).is_none());
    }

    #[test]
    fn latency_json_hides_empty_frame_rtt() {
        let o = Obs::new();
        o.queue_wait_us.record(100);
        let j = o.latency_json();
        assert!(j.get("queue_wait_us").is_some());
        assert!(j.get("ttft_us").is_some());
        assert!(j.get("frame_rtt_us").is_none(), "no samples, no section");
        // one decode RTT sample brings the section in under its name
        if let Some(h) = o.frame_rtt(0x04) {
            h.record(250);
        }
        let j = o.latency_json();
        let rtt = j.get("frame_rtt_us").expect("section appears");
        assert!(rtt.get("decode").is_some());
        assert!(rtt.get("info").is_none());
    }

    #[test]
    fn device_stats_reflects_service_hist_and_kv() {
        let o = Obs::new();
        for v in [100u64, 200, 300] {
            o.frame_service_us.record(v);
        }
        let s = o.device_stats(Some(KvPressure { alloc_stalls: 4, cow_copies: 9 }));
        assert_eq!(s.frames_served, 3);
        assert_eq!(s.frame_max_us, 300);
        assert_eq!((s.alloc_stalls, s.cow_copies), (4, 9));
        assert!(s.frame_p50_us <= s.frame_p99_us);
        let none = o.device_stats(None);
        assert_eq!((none.alloc_stalls, none.cow_copies), (0, 0));
    }

    #[test]
    fn obs_stats_json_has_all_wire_fields() {
        let s = ObsStats {
            alloc_stalls: 1,
            cow_copies: 2,
            frames_served: 3,
            frame_p50_us: 4,
            frame_p90_us: 5,
            frame_p99_us: 6,
            frame_max_us: 7,
        };
        let j = s.to_json();
        for k in [
            "alloc_stalls",
            "cow_copies",
            "frames_served",
            "frame_p50_us",
            "frame_p90_us",
            "frame_p99_us",
            "frame_max_us",
        ] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
