//! Bounded ring buffer of per-request lifecycle spans.
//!
//! Every state transition a request goes through — submitted, queued,
//! admitted, prefill chunks, first token, per-round decode, preempt /
//! requeue / resume, bridge reconnects, done — is recorded as one
//! fixed-size [`Span`] carrying the request id and monotonic
//! nanosecond timestamps. Spans land in a pre-sized overwrite ring
//! ([`TraceRing`]): recording in steady state is one short mutex hold
//! and a `Copy` into an existing slot, never an allocation, so the
//! engine round loop can trace unconditionally.
//!
//! The ring exports [Chrome trace format] JSON (`chrome://tracing`,
//! Perfetto) through `edgellm trace-dump` and the v2 `{"trace":N}`
//! server query: complete events (`"ph":"X"`) with microsecond
//! timestamps, one trace `tid` per request id.
//!
//! [Chrome trace format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

#![deny(missing_docs)]

use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::lock_unpoisoned;

/// What a [`Span`] marks in the request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Request entered the bounded queue (instant; `detail` = queue
    /// depth after the push).
    Submitted,
    /// Time spent waiting in the queue: submit → admission decision.
    Queued,
    /// Admission into the active pool, spanning the admission prefill
    /// (`detail` = prompt tokens).
    Admitted,
    /// One chunked-prefill warming slice (`detail` = tokens warmed so
    /// far, including this chunk).
    PrefillChunk,
    /// First token produced (instant; the span from `Submitted` to
    /// here is the TTFT the histogram records).
    FirstToken,
    /// One batched decode round (`req_id` 0 — the round is pool-wide;
    /// `detail` = live sessions in the round).
    DecodeRound,
    /// Mid-stream eviction under memory pressure (instant; `detail` =
    /// tokens generated so far).
    Preempted,
    /// Victim pushed back onto the queue head (instant).
    Requeued,
    /// Requeued victim re-admitted, spanning its recompute prefill
    /// (`detail` = tokens re-prefetched into KV).
    Resumed,
    /// Bridge client lost the device connection and re-established it,
    /// spanning the backoff (`detail` = reconnect cycle count).
    Reconnect,
    /// Request cancelled by the client (instant).
    Cancelled,
    /// Terminal retirement (`detail` = tokens generated).
    Done,
}

impl SpanKind {
    /// Chrome-trace event name.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submitted => "submitted",
            SpanKind::Queued => "queued",
            SpanKind::Admitted => "admitted",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::FirstToken => "first_token",
            SpanKind::DecodeRound => "decode_round",
            SpanKind::Preempted => "preempted",
            SpanKind::Requeued => "requeued",
            SpanKind::Resumed => "resumed",
            SpanKind::Reconnect => "reconnect",
            SpanKind::Cancelled => "cancelled",
            SpanKind::Done => "done",
        }
    }

    /// Chrome-trace category: groups lifecycle vs scheduler vs bridge
    /// rows in the viewer.
    pub fn cat(self) -> &'static str {
        match self {
            SpanKind::DecodeRound => "scheduler",
            SpanKind::Reconnect => "bridge",
            SpanKind::Preempted | SpanKind::Requeued | SpanKind::Resumed => "preemption",
            _ => "lifecycle",
        }
    }
}

/// One lifecycle event: fixed-size, `Copy`, ring-storable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// Engine request id (`Completion::id`); 0 for pool-wide spans.
    pub req_id: u64,
    /// What happened.
    pub kind: SpanKind,
    /// Monotonic start, nanoseconds since the owning registry's epoch.
    pub start_ns: u64,
    /// Monotonic end; equal to `start_ns` for instant events.
    pub end_ns: u64,
    /// Kind-specific payload (see [`SpanKind`] variants).
    pub detail: u64,
    /// Global record order — ties on `start_ns` are broken by `seq`,
    /// so per-request event order is always reconstructible.
    pub seq: u64,
}

struct Ring {
    buf: Vec<Span>,
    next: u64,
}

/// Pre-sized overwrite ring of [`Span`]s (see module docs). Recording
/// holds the mutex only for the slot copy; snapshots clone out.
pub struct TraceRing {
    cap: usize,
    inner: Mutex<Ring>,
}

impl TraceRing {
    /// Ring holding the most recent `cap` spans (`cap` is clamped to at
    /// least 16 so preempt/requeue/resume chains survive bursts).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(16);
        TraceRing {
            cap,
            inner: Mutex::new(Ring { buf: Vec::with_capacity(cap), next: 0 }),
        }
    }

    /// Record one span; assigns its `seq`. Allocation-free once the
    /// ring has filled (`Copy` into the recycled slot).
    pub fn record(&self, req_id: u64, kind: SpanKind, start_ns: u64, end_ns: u64, detail: u64) {
        let mut r = lock_unpoisoned(&self.inner);
        let seq = r.next;
        r.next += 1;
        let span = Span { req_id, kind, start_ns, end_ns, detail, seq };
        let at = (seq % self.cap as u64) as usize;
        if at < r.buf.len() {
            r.buf[at] = span;
        } else {
            r.buf.push(span);
        }
    }

    /// Instant event: start == end.
    pub fn mark(&self, req_id: u64, kind: SpanKind, at_ns: u64, detail: u64) {
        self.record(req_id, kind, at_ns, at_ns, detail);
    }

    /// Total spans ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        lock_unpoisoned(&self.inner).next
    }

    /// The retained spans, oldest first (record order).
    pub fn snapshot(&self) -> Vec<Span> {
        let r = lock_unpoisoned(&self.inner);
        let mut out = r.buf.clone();
        drop(r);
        out.sort_by_key(|s| s.seq);
        out
    }

    /// The most recent `n` spans, oldest first.
    pub fn last(&self, n: usize) -> Vec<Span> {
        let mut all = self.snapshot();
        if all.len() > n {
            all.drain(..all.len() - n);
        }
        all
    }
}

/// Render spans as one Chrome-trace JSON object
/// (`{"traceEvents":[...]}`): complete events, microsecond floats,
/// `pid` 1, `tid` = request id, kind detail and seq under `args`.
pub fn chrome_trace_json(spans: &[Span]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.kind.name().to_string())),
                ("cat", Json::Str(s.kind.cat().to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.start_ns as f64 / 1e3)),
                ("dur", Json::Num((s.end_ns.saturating_sub(s.start_ns)) as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.req_id as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("detail", Json::Num(s.detail as f64)),
                        ("seq", Json::Num(s.seq as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_retains_the_most_recent_spans_in_order() {
        let ring = TraceRing::new(16);
        for i in 0..40u64 {
            ring.mark(i, SpanKind::Submitted, i * 10, 0);
        }
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 16);
        let seqs: Vec<u64> = spans.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, (24..40).collect::<Vec<u64>>(), "oldest evicted first");
        assert_eq!(ring.recorded(), 40);
    }

    #[test]
    fn last_n_trims_from_the_front() {
        let ring = TraceRing::new(64);
        for i in 0..10u64 {
            ring.mark(1, SpanKind::Submitted, i, 0);
        }
        let tail = ring.last(3);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail.first().map(|s| s.seq), Some(7));
        assert_eq!(ring.last(100).len(), 10);
    }

    #[test]
    fn seq_breaks_timestamp_ties() {
        let ring = TraceRing::new(16);
        ring.mark(5, SpanKind::Preempted, 1000, 0);
        ring.mark(5, SpanKind::Requeued, 1000, 0);
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans.first().map(|s| s.kind), Some(SpanKind::Preempted));
        assert_eq!(spans.get(1).map(|s| s.kind), Some(SpanKind::Requeued));
        assert!(spans.first().map(|s| s.seq) < spans.get(1).map(|s| s.seq));
    }

    #[test]
    fn chrome_json_shape() {
        let ring = TraceRing::new(16);
        ring.record(7, SpanKind::Admitted, 2_000, 5_000, 12);
        let j = chrome_trace_json(&ring.snapshot());
        let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(events.len(), 1);
        let e = events.first().expect("one event");
        assert_eq!(e.get("name").and_then(Json::as_str), Some("admitted"));
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(e.get("ts").and_then(Json::as_f64), Some(2.0));
        assert_eq!(e.get("dur").and_then(Json::as_f64), Some(3.0));
        assert_eq!(e.get("tid").and_then(Json::as_usize), Some(7));
        // the line parses back — the server sends it verbatim
        let line = j.to_string();
        assert_eq!(Json::parse(&line).expect("valid json"), j);
    }

    #[test]
    fn every_kind_has_a_name_and_category() {
        for k in [
            SpanKind::Submitted,
            SpanKind::Queued,
            SpanKind::Admitted,
            SpanKind::PrefillChunk,
            SpanKind::FirstToken,
            SpanKind::DecodeRound,
            SpanKind::Preempted,
            SpanKind::Requeued,
            SpanKind::Resumed,
            SpanKind::Reconnect,
            SpanKind::Cancelled,
            SpanKind::Done,
        ] {
            assert!(!k.name().is_empty());
            assert!(!k.cat().is_empty());
        }
    }
}
