//! Architecture descriptions of the evaluated LLMs (shape-accurate; the
//! simulator needs only tensor shapes, precisions and sparsity).
//!
//! * GLM-6B (ChatGLM2-6B, ref. [38]): d=4096, 32 heads, 2 KV heads
//!   (multi-query groups), SwiGLU FFN 13696, 28 layers.
//! * Qwen-7B (Qwen2-7B, ref. [39]): d=3584, 28 heads, 4 KV heads,
//!   FFN 18944, 28 layers — more VMM parameters and more KV heads,
//!   which is why the paper measures it slower than GLM-6B.
//! * tiny: the ~100M functional model served end-to-end through the AOT
//!   artifacts (see python/compile/model.py::TINY).

use crate::quant::Sparsity;

#[derive(Debug, Clone)]
pub struct LlmArch {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub head_dim: usize,
}

impl LlmArch {
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Weight-matrix shapes of one block: (name, k, n).
    pub fn block_matrices(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("Q", self.d_model, self.d_model),
            ("K", self.d_model, self.kv_dim()),
            ("V", self.d_model, self.kv_dim()),
            ("O", self.d_model, self.d_model),
            // "h to 4h" covers gate+up in SwiGLU models
            ("h_to_4h", self.d_model, 2 * self.d_ffn),
            ("4h_to_h", self.d_ffn, self.d_model),
        ]
    }

    pub fn n_params(&self) -> usize {
        let per: usize = self
            .block_matrices()
            .iter()
            .map(|(_, k, n)| k * n)
            .sum();
        self.n_layers * per + 2 * self.vocab * self.d_model
    }
}

pub const GLM_6B: LlmArch = LlmArch {
    name: "GLM-6B",
    d_model: 4096,
    n_layers: 28,
    n_heads: 32,
    n_kv_heads: 2,
    d_ffn: 13696,
    vocab: 65024,
    head_dim: 128,
};

pub const QWEN_7B: LlmArch = LlmArch {
    name: "Qwen-7B",
    d_model: 3584,
    n_layers: 28,
    n_heads: 28,
    n_kv_heads: 4,
    d_ffn: 18944,
    vocab: 152064,
    head_dim: 128,
};

/// The AOT-served functional model (must mirror python TINY config).
pub const TINY: LlmArch = LlmArch {
    name: "tiny",
    d_model: 768,
    n_layers: 12,
    n_heads: 12,
    n_kv_heads: 2,
    d_ffn: 3072,
    vocab: 256,
    head_dim: 64,
};

/// Per-matrix sparsity assignment — Table II's three strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparseStrategy {
    pub name: &'static str,
    pub q: Sparsity,
    pub k: Sparsity,
    pub v: Sparsity,
    pub o: Sparsity,
    pub h_to_4h: Sparsity,
    pub h4_to_h: Sparsity,
}

impl SparseStrategy {
    pub fn for_matrix(&self, name: &str) -> Sparsity {
        match name {
            "Q" => self.q,
            "K" => self.k,
            "V" => self.v,
            "O" => self.o,
            "h_to_4h" => self.h_to_4h,
            "4h_to_h" => self.h4_to_h,
            _ => Sparsity::Dense,
        }
    }

    pub fn all() -> [SparseStrategy; 4] {
        [DENSE, STRATEGY_1, STRATEGY_2, STRATEGY_3]
    }
}

pub const DENSE: SparseStrategy = SparseStrategy {
    name: "dense",
    q: Sparsity::Dense,
    k: Sparsity::Dense,
    v: Sparsity::Dense,
    o: Sparsity::Dense,
    h_to_4h: Sparsity::Dense,
    h4_to_h: Sparsity::Dense,
};

/// Table II strategy-1: O/h4h/4hh at 50%.
pub const STRATEGY_1: SparseStrategy = SparseStrategy {
    name: "strategy-1",
    q: Sparsity::Dense,
    k: Sparsity::Dense,
    v: Sparsity::Dense,
    o: Sparsity::Half,
    h_to_4h: Sparsity::Half,
    h4_to_h: Sparsity::Half,
};

/// Table II strategy-2: h4h at 75%.
pub const STRATEGY_2: SparseStrategy = SparseStrategy {
    name: "strategy-2",
    q: Sparsity::Dense,
    k: Sparsity::Dense,
    v: Sparsity::Dense,
    o: Sparsity::Half,
    h_to_4h: Sparsity::Quarter,
    h4_to_h: Sparsity::Half,
};

/// Table II strategy-3: h4h and 4hh at 75%.
pub const STRATEGY_3: SparseStrategy = SparseStrategy {
    name: "strategy-3",
    q: Sparsity::Dense,
    k: Sparsity::Dense,
    v: Sparsity::Dense,
    o: Sparsity::Half,
    h_to_4h: Sparsity::Quarter,
    h4_to_h: Sparsity::Quarter,
};

/// Total packaged weight bytes of one block under a strategy (Table II's
/// "total wt in a Block" column).
pub fn block_weight_bytes(arch: &LlmArch, strat: &SparseStrategy) -> usize {
    arch.block_matrices()
        .iter()
        .map(|(name, k, n)| crate::pack::matrix_bytes(*k, *n, strat.for_matrix(name)))
        .sum()
}

/// Weight-streaming speedup vs dense (Table II's "speedup" row): decode
/// VMMs are weight-bandwidth-bound, so bytes ∝ time.
pub fn strategy_speedup(arch: &LlmArch, strat: &SparseStrategy) -> f64 {
    block_weight_bytes(arch, &DENSE) as f64 / block_weight_bytes(arch, strat) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_sane() {
        // GLM-6B ≈ 6.2B, Qwen-7B ≈ 7.0B (±15%: embeddings & layout detail)
        let glm = GLM_6B.n_params() as f64 / 1e9;
        assert!(glm > 5.5 && glm < 7.0, "GLM params {glm}B");
        let qwen = QWEN_7B.n_params() as f64 / 1e9;
        assert!(qwen > 6.3 && qwen < 8.0, "Qwen params {qwen}B");
        let tiny = TINY.n_params() as f64 / 1e6;
        assert!(tiny > 80.0 && tiny < 120.0, "tiny params {tiny}M");
    }

    #[test]
    fn table2_block_bytes() {
        // Paper: dense 100.33 MB, s1 79.22, s2 61.50, s3 53.15 (±3%:
        // the paper folds positional-encoding params in).
        let mb = |s: &SparseStrategy| {
            block_weight_bytes(&GLM_6B, s) as f64 / (1024.0 * 1024.0)
        };
        let dense = mb(&DENSE);
        assert!((dense - 100.33).abs() / 100.33 < 0.03, "dense {dense}");
        let s1 = mb(&STRATEGY_1);
        assert!((s1 - 79.22).abs() / 79.22 < 0.03, "s1 {s1}");
        let s2 = mb(&STRATEGY_2);
        assert!((s2 - 61.50).abs() / 61.50 < 0.04, "s2 {s2}");
        let s3 = mb(&STRATEGY_3);
        assert!((s3 - 53.15).abs() / 53.15 < 0.04, "s3 {s3}");
    }

    #[test]
    fn table2_speedups() {
        // Paper: 1.27×, 1.63×, 1.89×.
        let s1 = strategy_speedup(&GLM_6B, &STRATEGY_1);
        assert!((s1 - 1.27).abs() < 0.05, "{s1}");
        let s2 = strategy_speedup(&GLM_6B, &STRATEGY_2);
        assert!((s2 - 1.63).abs() < 0.07, "{s2}");
        let s3 = strategy_speedup(&GLM_6B, &STRATEGY_3);
        assert!((s3 - 1.89).abs() < 0.08, "{s3}");
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let mut last = 0.0;
        for s in SparseStrategy::all() {
            let v = strategy_speedup(&GLM_6B, &s);
            assert!(v >= last, "{} regressed: {v} < {last}", s.name);
            last = v;
        }
    }
}
