//! Bit-level serialization of weight packages (Fig. 5's [scale|mask|wt]
//! order) and the per-port HBM stream assembly.
//!
//! This is what the compiler's weight pre-processing step emits and what
//! the accelerator's sparse DMA consumes; the decoder here doubles as the
//! model of that DMA for tests.

use super::{best_encoding, MaskEncoding, CH_GROUP, HBM_PORTS};
use crate::quant::{QuantMatrix, Sparsity, QBLOCK, SGROUP};

/// Append `bits` low-order bits of `v` to a bit vector (LSB-first).
/// Word-level writes: one shift/or per field instead of per bit
/// (§Perf: ~8× on port_streams assembly).
fn push_bits(out: &mut Vec<u8>, bitpos: &mut usize, v: u64, bits: usize) {
    debug_assert!(bits <= 56, "field too wide for the single-splice path");
    let byte = *bitpos / 8;
    let shift = *bitpos % 8;
    let need = (shift + bits + 7) / 8;
    if out.len() < byte + need {
        out.resize(byte + need, 0);
    }
    let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let v = (v & mask) << shift;
    for (i, slot) in out[byte..byte + need].iter_mut().enumerate() {
        *slot |= (v >> (8 * i)) as u8;
    }
    *bitpos += bits;
}

fn read_bits(data: &[u8], bitpos: &mut usize, bits: usize) -> u64 {
    debug_assert!(bits <= 56);
    let byte = *bitpos / 8;
    let shift = *bitpos % 8;
    let need = (shift + bits + 7) / 8;
    let mut raw = 0u64;
    for (i, &b) in data[byte..byte + need].iter().enumerate() {
        raw |= (b as u64) << (8 * i);
    }
    *bitpos += bits;
    (raw >> shift) & if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 }
}

/// One serialized package: CH_GROUP input channels of one output column.
pub struct Package {
    pub data: Vec<u8>,
    pub sparsity: Sparsity,
    pub encoding: MaskEncoding,
}

/// Serialize the package for output column `col`, input channels
/// `[group_base, group_base + CH_GROUP)` (rows past `m.k` are padding).
pub fn encode_package(
    m: &QuantMatrix,
    col: usize,
    group_base: usize,
    sparsity: Sparsity,
) -> Package {
    let encoding = best_encoding(sparsity);
    let mut data = Vec::new();
    let mut pos = 0usize;
    // [scale]: 16 FP16 scales
    for b in 0..CH_GROUP / QBLOCK {
        let row = group_base + b * QBLOCK;
        let s = if row < m.k { m.scales[(row / QBLOCK) * m.n + col] } else { 0 };
        push_bits(&mut data, &mut pos, s as u64, 16);
    }
    // collect the group's values
    let val_at = |row: usize| -> i8 {
        if row < m.k { m.q[row * m.n + col] } else { 0 }
    };
    // [mask]
    match encoding {
        MaskEncoding::None => {}
        MaskEncoding::OneHot => {
            for r in 0..CH_GROUP {
                let bit = (val_at(group_base + r) != 0) as u64;
                push_bits(&mut data, &mut pos, bit, 1);
            }
        }
        MaskEncoding::AddrInBlock => {
            let bits_per = if sparsity == Sparsity::Eighth { 4 } else { 3 };
            let keep = sparsity.keep_of_8();
            for g in 0..CH_GROUP / SGROUP {
                let mut written = 0;
                for r in 0..SGROUP {
                    let row = group_base + g * SGROUP + r;
                    if val_at(row) != 0 {
                        push_bits(&mut data, &mut pos, r as u64, bits_per);
                        written += 1;
                    }
                }
                for _ in written..keep {
                    // pad empty slots with offset 0 (value 0 ignored)
                    push_bits(&mut data, &mut pos, 0, bits_per);
                }
            }
        }
    }
    // [wt]: kept INT4 values (dense: all values)
    let keep = sparsity.keep_of_8();
    for g in 0..CH_GROUP / SGROUP {
        let mut written = 0;
        for r in 0..SGROUP {
            let row = group_base + g * SGROUP + r;
            let v = val_at(row);
            if sparsity == Sparsity::Dense {
                push_bits(&mut data, &mut pos, (v as u8 & 0xF) as u64, 4);
            } else if v != 0 {
                push_bits(&mut data, &mut pos, (v as u8 & 0xF) as u64, 4);
                written += 1;
            }
        }
        if sparsity != Sparsity::Dense {
            for _ in written..keep {
                push_bits(&mut data, &mut pos, 0, 4);
            }
        }
    }
    Package { data, sparsity, encoding }
}

/// Decode a package back to (scales, dense group values) — the sparse
/// DMA's activation-select inverse. Returns (16 scales, CH_GROUP values).
pub fn decode_package(p: &Package) -> (Vec<u16>, Vec<i8>) {
    let mut pos = 0usize;
    let mut scales = Vec::with_capacity(CH_GROUP / QBLOCK);
    for _ in 0..CH_GROUP / QBLOCK {
        scales.push(read_bits(&p.data, &mut pos, 16) as u16);
    }
    let keep = p.sparsity.keep_of_8();
    let mut vals = vec![0i8; CH_GROUP];
    let sign_extend = |v: u64| -> i8 { nibble_i8(v as u8) };
    match p.encoding {
        MaskEncoding::None => {
            for (r, slot) in vals.iter_mut().enumerate() {
                let _ = r;
                *slot = sign_extend(read_bits(&p.data, &mut pos, 4));
            }
        }
        MaskEncoding::OneHot => {
            let mut mask = vec![false; CH_GROUP];
            for m in mask.iter_mut() {
                *m = read_bits(&p.data, &mut pos, 1) == 1;
            }
            // wt section: fixed keep slots per group
            for g in 0..CH_GROUP / SGROUP {
                let rows: Vec<usize> =
                    (0..SGROUP).filter(|&r| mask[g * SGROUP + r]).collect();
                for s in 0..keep {
                    let v = sign_extend(read_bits(&p.data, &mut pos, 4));
                    if let Some(&r) = rows.get(s) {
                        vals[g * SGROUP + r] = v;
                    }
                }
            }
        }
        MaskEncoding::AddrInBlock => {
            let bits_per = if p.sparsity == Sparsity::Eighth { 4 } else { 3 };
            let mut addrs = vec![0usize; CH_GROUP / SGROUP * keep];
            for a in addrs.iter_mut() {
                *a = read_bits(&p.data, &mut pos, bits_per) as usize;
            }
            for g in 0..CH_GROUP / SGROUP {
                for s in 0..keep {
                    let r = addrs[g * keep + s] & (SGROUP - 1);
                    let v = sign_extend(read_bits(&p.data, &mut pos, 4));
                    if v != 0 {
                        vals[g * SGROUP + r] = v;
                    }
                }
            }
        }
    }
    (scales, vals)
}

/// Assemble the per-port HBM streams for a whole matrix: stream[p] holds
/// the packages of output channels p, p+32, p+64, … in order, each
/// column's CH_GROUP portions contiguous (the AXI burst unit).
pub fn port_streams(m: &QuantMatrix, sparsity: Sparsity) -> Vec<Vec<u8>> {
    let mut streams: Vec<Vec<u8>> = vec![Vec::new(); HBM_PORTS];
    let groups = m.k.div_ceil(CH_GROUP);
    for col in 0..m.n {
        let port = super::port_of(col);
        for g in 0..groups {
            let pkg = encode_package(m, col, g * CH_GROUP, sparsity);
            streams[port].extend_from_slice(&pkg.data);
        }
    }
    streams
}

/// Nibble-packed row-major INT4 weight matrix — the CPU-side mirror of
/// the dense HBM stream, laid out for the runtime's dequant-on-the-fly
/// GEMM ([`crate::runtime::kernels::q4_gemm_into`]).
///
/// Each row of `k × n` holds the `n` output-channel values of one input
/// channel, two INT4 values per byte (even column in the low nibble).
/// Scales are pre-decoded to f32 — one per (QBLOCK input channels ×
/// output channel), same blocking as [`QuantMatrix`] — so the hot loop
/// never touches the FP16 codec. Walking rows top to bottom streams the
/// weight matrix exactly once, which is the access pattern the batched
/// decode round amortizes across sessions.
#[derive(Debug, Clone)]
pub struct PackedQ4 {
    /// input channels (multiple of QBLOCK)
    pub k: usize,
    /// output channels (even, so rows pack to whole bytes)
    pub n: usize,
    /// row-major `k × n/2` bytes: byte `r*n/2 + j` holds columns
    /// `2j` (low nibble) and `2j+1` (high nibble) of row `r`
    pub data: Vec<u8>,
    /// row-major `(k/QBLOCK) × n` pre-decoded f32 scales
    pub scales: Vec<f32>,
}

/// Sign-extend a 4-bit two's-complement nibble.
#[inline(always)]
pub fn nibble_i8(v: u8) -> i8 {
    ((v << 4) as i8) >> 4
}

impl PackedQ4 {
    /// Pack a [`QuantMatrix`] into the nibble layout.
    pub fn from_quant(m: &QuantMatrix) -> PackedQ4 {
        assert!(m.n % 2 == 0, "n={} must be even to nibble-pack", m.n);
        let mut data = vec![0u8; m.k * m.n / 2];
        for r in 0..m.k {
            let row = &m.q[r * m.n..(r + 1) * m.n];
            let dst = &mut data[r * m.n / 2..(r + 1) * m.n / 2];
            for (j, b) in dst.iter_mut().enumerate() {
                let lo = (row[2 * j] as u8) & 0xF;
                let hi = (row[2 * j + 1] as u8) & 0xF;
                *b = lo | (hi << 4);
            }
        }
        let scales = m
            .scales
            .iter()
            .map(|&s| crate::fp::minifloat::f16_decode(s) as f32)
            .collect();
        PackedQ4 { k: m.k, n: m.n, data, scales }
    }

    /// Dequantized value at (row, col) — test/reference path only.
    pub fn dequant(&self, row: usize, col: usize) -> f32 {
        let b = self.data[row * self.n / 2 + col / 2];
        let v = if col % 2 == 0 { b & 0xF } else { b >> 4 };
        nibble_i8(v) as f32 * self.scales[(row / QBLOCK) * self.n + col]
    }

    /// Weight bytes resident for this matrix (values + f32 scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pack::package_bits;
    use crate::quant::{prune_log_scale, quantize};
    use crate::util::rng::Rng;

    fn pruned(k: usize, n: usize, keep: usize, seed: u64) -> QuantMatrix {
        let mut rng = Rng::new(seed);
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        prune_log_scale(&mut w, k, n, keep);
        quantize(&w, k, n)
    }

    #[test]
    fn package_size_matches_fig5() {
        for (keep, sp) in [
            (8, Sparsity::Dense),
            (4, Sparsity::Half),
            (2, Sparsity::Quarter),
            (1, Sparsity::Eighth),
        ] {
            let m = pruned(CH_GROUP, 4, keep, 42);
            let p = encode_package(&m, 0, 0, sp);
            let want = package_bits(sp, best_encoding(sp)).total().div_ceil(8);
            assert_eq!(p.data.len(), want, "sparsity {sp:?}");
        }
    }

    #[test]
    fn roundtrip_all_sparsities() {
        for (keep, sp) in [
            (8, Sparsity::Dense),
            (4, Sparsity::Half),
            (2, Sparsity::Quarter),
            (1, Sparsity::Eighth),
        ] {
            let m = pruned(CH_GROUP, 4, keep, keep as u64 * 3 + 1);
            for col in 0..4 {
                let p = encode_package(&m, col, 0, sp);
                let (scales, vals) = decode_package(&p);
                for b in 0..CH_GROUP / QBLOCK {
                    assert_eq!(scales[b], m.scales[b * m.n + col]);
                }
                for r in 0..CH_GROUP {
                    assert_eq!(
                        vals[r],
                        m.q[r * m.n + col],
                        "sparsity {sp:?} col {col} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn padding_rows_decode_to_zero() {
        // matrix shorter than CH_GROUP: the padded tail must be zeros
        let m = pruned(QBLOCK * 2, 2, 4, 9);
        let p = encode_package(&m, 1, 0, Sparsity::Half);
        let (_, vals) = decode_package(&p);
        for r in m.k..CH_GROUP {
            assert_eq!(vals[r], 0);
        }
    }

    #[test]
    fn port_streams_cover_all_columns() {
        let m = pruned(CH_GROUP, 64, 4, 11);
        let streams = port_streams(&m, Sparsity::Half);
        let per_pkg = package_bits(Sparsity::Half, MaskEncoding::OneHot)
            .total()
            .div_ceil(8);
        // 64 columns over 32 ports = 2 packages per port
        assert!(streams.iter().all(|s| s.len() == 2 * per_pkg));
    }

    #[test]
    fn packed_q4_roundtrips_every_value() {
        let m = pruned(QBLOCK * 2, 16, 8, 21);
        let p = PackedQ4::from_quant(&m);
        for r in 0..m.k {
            for c in 0..m.n {
                assert!(
                    (p.dequant(r, c) - m.dequant(r, c) as f32).abs() < 1e-7,
                    "({r},{c}): packed {} vs quant {}",
                    p.dequant(r, c),
                    m.dequant(r, c)
                );
            }
        }
    }

    #[test]
    fn packed_q4_nibble_sign_extension() {
        for v in -8i8..=7 {
            assert_eq!(nibble_i8((v as u8) & 0xF), v, "nibble {v}");
        }
    }

    #[test]
    fn packed_q4_halves_value_bytes() {
        let m = pruned(QBLOCK, 32, 8, 22);
        let p = PackedQ4::from_quant(&m);
        assert_eq!(p.data.len(), m.q.len() / 2);
    }

    #[test]
    fn negative_values_roundtrip() {
        // INT4 sign extension: -8..-1 must survive the nibble trip.
        let mut m = pruned(CH_GROUP, 1, 8, 13);
        for r in 0..16 {
            m.q[r] = -8 + (r % 8) as i8 - 0; // includes -8 and 0..-1 range
        }
        let p = encode_package(&m, 0, 0, Sparsity::Dense);
        let (_, vals) = decode_package(&p);
        for r in 0..16 {
            assert_eq!(vals[r], m.q[r], "row {r}");
        }
    }
}
