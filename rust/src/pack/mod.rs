//! HBM weight packaging (paper Fig. 5): the (scale, mask, wt) package
//! layout, the two mask encodings, effective bit-width accounting, and
//! the CH_out → AXI-port interleave.
//!
//! Geometry: one package covers CH_GROUP = 2048 input channels for one
//! output channel — sized so its 16 FP16 block scales fill exactly one
//! 256-bit HBM AXI beat. Packages for output channel c stream through
//! AXI port (c mod 32); channels c, c+32, c+64… share a port in sequence.

pub mod layout;

use crate::quant::{Sparsity, QBLOCK, SGROUP};

/// Input channels covered by one weight package (Fig. 5: 2048).
pub const CH_GROUP: usize = 2048;
/// HBM AXI ports on the VCU128 (32 × 256-bit).
pub const HBM_PORTS: usize = 32;
/// Bits per AXI beat per port.
pub const AXI_BEAT_BITS: usize = 256;

/// Mask encoding scheme for non-zero positions (paper's hybrid choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskEncoding {
    /// No mask (dense).
    None,
    /// 1 bit per input channel.
    OneHot,
    /// Offset-in-group address per kept weight (3 bits for 1-of-8
    /// granularity, nibble-aligned to 4 bits at 87.5% — Fig. 5's numbers).
    AddrInBlock,
}

/// Bit budget of one CH_GROUP package at a given sparsity + encoding.
#[derive(Debug, Clone, Copy)]
pub struct PackageBits {
    pub scale_bits: usize,
    pub mask_bits: usize,
    pub wt_bits: usize,
}

impl PackageBits {
    pub fn total(&self) -> usize {
        self.scale_bits + self.mask_bits + self.wt_bits
    }

    /// Fig. 5's "effective bit-width": package bits per input channel.
    pub fn effective_bitwidth(&self) -> f64 {
        self.total() as f64 / CH_GROUP as f64
    }

    /// Fig. 5's "performance enhancement": dense-package bits / ours
    /// (decode VMMs are weight-bandwidth-bound, so bytes = time).
    pub fn enhancement(&self) -> f64 {
        package_bits(Sparsity::Dense, MaskEncoding::None).total() as f64
            / self.total() as f64
    }
}

/// Mask bits for `CH_GROUP` channels at `sparsity` under `encoding`.
pub fn mask_bits(sparsity: Sparsity, encoding: MaskEncoding) -> usize {
    let kept = CH_GROUP * sparsity.keep_of_8() / SGROUP;
    match encoding {
        MaskEncoding::None => 0,
        MaskEncoding::OneHot => {
            if sparsity == Sparsity::Dense {
                0
            } else {
                CH_GROUP
            }
        }
        MaskEncoding::AddrInBlock => {
            if sparsity == Sparsity::Dense {
                return 0;
            }
            // 3 address bits resolve 1-of-8; at 87.5% (one survivor per
            // group) the paper nibble-aligns to 4 bits (Fig. 5: 1024 bits
            // for 256 kept weights).
            let bits_per = if sparsity == Sparsity::Eighth { 4 } else { 3 };
            kept * bits_per
        }
    }
}

/// Full package bit budget (Fig. 5 rows).
pub fn package_bits(sparsity: Sparsity, encoding: MaskEncoding) -> PackageBits {
    let scale_bits = CH_GROUP / QBLOCK * 16; // 16 FP16 scales = 256 bits
    let wt_bits = CH_GROUP * sparsity.keep_of_8() / SGROUP * 4;
    PackageBits { scale_bits, mask_bits: mask_bits(sparsity, encoding), wt_bits }
}

/// The hybrid scheme the paper ships: one-hot at low sparsity, addr-in-
/// block at high sparsity — whichever is smaller.
pub fn best_encoding(sparsity: Sparsity) -> MaskEncoding {
    if sparsity == Sparsity::Dense {
        return MaskEncoding::None;
    }
    let oh = mask_bits(sparsity, MaskEncoding::OneHot);
    let ab = mask_bits(sparsity, MaskEncoding::AddrInBlock);
    if ab < oh { MaskEncoding::AddrInBlock } else { MaskEncoding::OneHot }
}

/// Weight bytes of a k×n matrix at `sparsity` using the best encoding,
/// including scales and masks, padding partial CH_GROUPs (Fig. 5 note).
pub fn matrix_bytes(k: usize, n: usize, sparsity: Sparsity) -> usize {
    let groups_per_col = k.div_ceil(CH_GROUP);
    let pkg = package_bits(sparsity, best_encoding(sparsity));
    groups_per_col * n * pkg.total() / 8
}

/// AXI port assignment for an output channel (paper: CH_out 0,32,64…
/// → port 0; 1,33,65… → port 1; …).
pub fn port_of(ch_out: usize) -> usize {
    ch_out % HBM_PORTS
}

/// Position of a CH_out's packages within its port's stream.
pub fn seq_in_port(ch_out: usize) -> usize {
    ch_out / HBM_PORTS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_bit_budgets_exact() {
        // Fig. 5's table, verbatim.
        let dense = package_bits(Sparsity::Dense, MaskEncoding::None);
        assert_eq!((dense.scale_bits, dense.mask_bits, dense.wt_bits), (256, 0, 8192));
        assert_eq!(dense.total(), 8448);

        let s50 = package_bits(Sparsity::Half, MaskEncoding::OneHot);
        assert_eq!((s50.scale_bits, s50.mask_bits, s50.wt_bits), (256, 2048, 4096));
        assert_eq!(s50.total(), 6400);

        let s75 = package_bits(Sparsity::Quarter, MaskEncoding::AddrInBlock);
        assert_eq!(s75.mask_bits, 1536);
        assert_eq!(s75.total(), 3840);

        let s875_oh = package_bits(Sparsity::Eighth, MaskEncoding::OneHot);
        assert_eq!(s875_oh.total(), 3328);
        let s875_ab = package_bits(Sparsity::Eighth, MaskEncoding::AddrInBlock);
        assert_eq!(s875_ab.mask_bits, 1024);
        assert_eq!(s875_ab.total(), 2304);
    }

    #[test]
    fn fig5_effective_bitwidths() {
        let cases = [
            (Sparsity::Dense, MaskEncoding::None, 4.125),
            (Sparsity::Half, MaskEncoding::OneHot, 3.125),
            (Sparsity::Quarter, MaskEncoding::AddrInBlock, 1.875),
            (Sparsity::Eighth, MaskEncoding::OneHot, 1.625),
            (Sparsity::Eighth, MaskEncoding::AddrInBlock, 1.125),
        ];
        for (s, e, want) in cases {
            let got = package_bits(s, e).effective_bitwidth();
            assert!((got - want).abs() < 1e-9, "{s:?}/{e:?}: {got} != {want}");
        }
    }

    #[test]
    fn fig5_enhancements() {
        // 1.32×, 2.2×, 2.54×, 3.67× (paper rounds the last to 3.67/3.66)
        let e50 = package_bits(Sparsity::Half, MaskEncoding::OneHot).enhancement();
        assert!((e50 - 1.32).abs() < 0.01, "{e50}");
        let e75 = package_bits(Sparsity::Quarter, MaskEncoding::AddrInBlock).enhancement();
        assert!((e75 - 2.2).abs() < 0.01, "{e75}");
        let e875_oh = package_bits(Sparsity::Eighth, MaskEncoding::OneHot).enhancement();
        assert!((e875_oh - 2.54).abs() < 0.01, "{e875_oh}");
        let e875 = package_bits(Sparsity::Eighth, MaskEncoding::AddrInBlock).enhancement();
        assert!((e875 - 3.67).abs() < 0.01, "{e875}");
    }

    #[test]
    fn hybrid_encoding_choice() {
        // Paper: one-hot wins at 50%, addr-in-block at 75%+.
        assert_eq!(best_encoding(Sparsity::Dense), MaskEncoding::None);
        assert_eq!(best_encoding(Sparsity::Half), MaskEncoding::OneHot);
        assert_eq!(best_encoding(Sparsity::Quarter), MaskEncoding::AddrInBlock);
        assert_eq!(best_encoding(Sparsity::Eighth), MaskEncoding::AddrInBlock);
    }

    #[test]
    fn glm_matrix_sizes_match_table2() {
        // Table II, GLM-6B (d=4096, kv=256, ffn=13696):
        // Q dense 8.25 MB; K dense 0.516 MB; O 50% 6.25 MB;
        // h->4h (gate+up) dense 55.23 MB, 75% 25.08 MB;
        // 4h->h dense 27.57 MB, 50% 20.89 MB, 75% 12.54 MB.
        let mb = |b: usize| b as f64 / (1024.0 * 1024.0);
        assert!((mb(matrix_bytes(4096, 4096, Sparsity::Dense)) - 8.25).abs() < 0.01);
        assert!((mb(matrix_bytes(4096, 256, Sparsity::Dense)) - 0.516).abs() < 0.01);
        assert!((mb(matrix_bytes(4096, 4096, Sparsity::Half)) - 6.25).abs() < 0.01);
        let h4h = 2.0 * mb(matrix_bytes(4096, 13696, Sparsity::Dense));
        assert!((h4h - 55.23).abs() < 0.1, "{h4h}");
        let h4h75 = 2.0 * mb(matrix_bytes(4096, 13696, Sparsity::Quarter));
        assert!((h4h75 - 25.11).abs() < 0.1, "{h4h75}");
        let hh4 = mb(matrix_bytes(13696, 4096, Sparsity::Dense));
        // 13696 rows pad to 7 CH_GROUPs (14336): paper's 27.57 MB is
        // unpadded; with padding we get slightly more.
        assert!(hh4 > 27.5 && hh4 < 29.0, "{hh4}");
    }

    #[test]
    fn port_interleave() {
        assert_eq!(port_of(0), 0);
        assert_eq!(port_of(33), 1);
        assert_eq!(seq_in_port(64), 2);
        // every port receives the same number of channels for n % 32 == 0
        let mut counts = [0usize; HBM_PORTS];
        for c in 0..4096 {
            counts[port_of(c)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 128));
    }
}
