//! L3 coordinator: the CPU half of the CPU-FPGA heterogeneous system.
//!
//! * [`engine`] — continuous-batching scheduler: request queue, live
//!   session pool, batched decode rounds, retirement, per-request
//!   streaming token events + cancellation, serving metrics. Drives any
//!   [`Backend`](crate::runtime::backend::Backend) through `LlmRuntime`.
//! * [`server`] — the LAN (TCP/JSON-lines) inference server of Fig. 8,
//!   multi-client: every connection feeds the shared scheduler.
//!   Protocol v1 (whole replies) + v2 (token streaming, `cancel`),
//!   clean shutdown via `ServerHandle`.
//! * [`tokenizer`] — byte-level token ids for the functional tiny model
//! * [`sampler`] — greedy / temperature / top-p sampling

pub mod engine;
pub mod sampler;
pub mod server;
pub mod tokenizer;
