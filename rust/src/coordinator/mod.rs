//! L3 coordinator: the CPU half of the CPU-FPGA heterogeneous system.
//!
//! * [`engine`] — request queue, KV sessions, decode loop, metrics
//! * [`server`] — the LAN (TCP/JSON-lines) inference server of Fig. 8
//! * [`tokenizer`] — byte-level token ids for the functional tiny model
//! * [`sampler`] — greedy / temperature / top-p sampling

pub mod engine;
pub mod sampler;
pub mod server;
pub mod tokenizer;
