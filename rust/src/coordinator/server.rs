//! LAN inference server (paper Fig. 8's deployment: FPGA+LLM as server,
//! a thin client encodes/decodes and talks to users) — multi-client,
//! streaming.
//!
//! Protocol: JSON lines over TCP, two request generations side by side.
//!
//! **v1 — whole response** (unchanged, bit-identical):
//!   request : {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0,
//!              "top_p": 0.9}
//!   response: {"id": 1, "text": "...", "tokens_per_s": ...,
//!              "first_token_ms": ..., "sim_tokens_per_s": ...}
//!   stats   : {"stats": true} →
//!             {"queue_depth": ..., "active_sessions": ...,
//!              "rounds": ..., "decode_tokens": ...,
//!              "tokens_per_s": ..., "sim_tokens_per_s": ...}
//!
//! **v2 — streaming + cancellation**:
//!   request : {"prompt": "...", "stream": true, ...}
//!   replies : {"id": 3, "stream": true}            ← ack, carries the id
//!             {"id": 3, "index": 0, "token": 104, "text": "h"}  ← per token
//!             {"id": 3, "done": true, "text": ..., ...}   ← final stats line
//!   cancel  : {"cancel": 3} → {"cancelled": 3, "found": true}
//!             (any connection may cancel any in-flight id; the cancelled
//!             stream terminates with {"id": 3, "error": "cancelled",
//!             "done": true} and its KV slot is freed for the next
//!             request. Send cancels from a side connection: a cancel
//!             pipelined behind a stream on the *same* socket is only
//!             read after that stream ends — each connection is served
//!             by one blocking thread.)
//!
//! Malformed input never kills a connection: every request line gets a
//! reply, either a completion or `{"error": "..."}`.
//!
//! Each connection runs on its own thread and *enqueues* into the shared
//! continuous-batching scheduler; a dedicated scheduler thread drives
//! `Engine::step_round`. Completions and token events flow back over the
//! per-request channels minted by `Engine::submit` — the server routes
//! nothing itself. Many clients therefore decode concurrently inside one
//! shared batch, and each streaming client sees its tokens the moment
//! the scheduler emits them.
//!
//! [`spawn_on`] returns a [`ServerHandle`] whose `shutdown()` stops the
//! scheduler and accept threads cleanly (and fails in-flight requests
//! with a terminal error event) — tests and embedders never rely on
//! process exit to reap threads.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};

use anyhow::Result;

use super::engine::{Completion, Engine, Event, Priority, RequestHandle, TokenEvent};
use super::sampler::Sampling;
use crate::obs::chrome_trace_json;
use crate::util::json::Json;

/// Protocol-level cap on `max_new_tokens`; requests beyond it are
/// rejected with a structured error (the engine additionally clamps to
/// the model's KV budget).
pub const MAX_NEW_TOKENS_LIMIT: usize = 4096;

/// A parsed protocol request.
pub enum ServerRequest {
    Generate {
        prompt: String,
        max_new_tokens: usize,
        sampling: Sampling,
        /// v2: stream one JSON line per token before the final line
        stream: bool,
        /// scheduling class: `"priority": "latency"` jumps the batch
        /// queue (bounded by the engine's anti-starvation aging)
        priority: Priority,
    },
    /// v2: cancel an in-flight request by id
    Cancel(u64),
    Stats,
    /// v2: export the last N lifecycle spans as one Chrome-trace-format
    /// JSON line (`{"trace": N}` — the `edgellm trace-dump` CLI's query)
    Trace(usize),
}

/// Parse and validate one request line. Pure — no engine needed — so the
/// protocol surface is testable in isolation.
pub fn parse_request(line: &str) -> Result<ServerRequest, String> {
    let req = Json::parse(line).map_err(|e| format!("bad request json: {e}"))?;
    if req.get("stats").and_then(|v| v.as_bool()) == Some(true) {
        return Ok(ServerRequest::Stats);
    }
    if let Some(v) = req.get("cancel") {
        let id = v
            .as_f64()
            .ok_or_else(|| "'cancel' must be a request id".to_string())?;
        if id < 0.0 || id.fract() != 0.0 {
            return Err(format!("'cancel' must be a non-negative integer id: {id}"));
        }
        return Ok(ServerRequest::Cancel(id as u64));
    }
    if let Some(v) = req.get("trace") {
        let n = v
            .as_f64()
            .ok_or_else(|| "'trace' must be a span count".to_string())?;
        if n < 1.0 || n.fract() != 0.0 {
            return Err(format!("'trace' must be a positive integer span count: {n}"));
        }
        return Ok(ServerRequest::Trace(n as usize));
    }
    let prompt = req
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or_else(|| "missing 'prompt'".to_string())?
        .to_string();
    let max_new_tokens = match req.get("max_new_tokens") {
        None => 32,
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| "'max_new_tokens' must be a number".to_string())?;
            if !(1.0..=MAX_NEW_TOKENS_LIMIT as f64).contains(&n) {
                return Err(format!(
                    "'max_new_tokens' out of range: {n} (want 1..={MAX_NEW_TOKENS_LIMIT})"
                ));
            }
            n as usize
        }
    };
    let stream = match req.get("stream") {
        None => false,
        Some(v) => v
            .as_bool()
            .ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    let temperature = req
        .get("temperature")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as f32;
    let sampling = match req.get("top_p").and_then(|v| v.as_f64()) {
        Some(p) if !(0.0..=1.0).contains(&p) => {
            return Err(format!("'top_p' out of range: {p} (want 0..=1)"));
        }
        Some(p) => Sampling::TopP {
            p: p as f32,
            temperature: if temperature > 0.0 { temperature } else { 1.0 },
        },
        None if temperature <= 0.0 => Sampling::Greedy,
        None => Sampling::Temperature(temperature),
    };
    let priority = match req.get("priority") {
        None => Priority::Batch,
        Some(v) => match v.as_str() {
            Some("latency") => Priority::Latency,
            Some("batch") => Priority::Batch,
            _ => {
                return Err(
                    "'priority' must be \"latency\" or \"batch\"".to_string()
                );
            }
        },
    };
    Ok(ServerRequest::Generate {
        prompt,
        max_new_tokens,
        sampling,
        stream,
        priority,
    })
}

fn error_json(msg: impl Into<String>) -> Json {
    Json::obj(vec![("error", Json::Str(msg.into()))])
}

fn completion_json(c: &Completion) -> Json {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("text", Json::Str(c.text.clone())),
        ("n_prompt", Json::Num(c.n_prompt as f64)),
        ("n_generated", Json::Num(c.n_generated as f64)),
        ("first_token_ms", Json::Num(c.first_token_s * 1e3)),
        ("tokens_per_s", Json::Num(c.tokens_per_s)),
        ("sim_first_token_ms", Json::Num(c.sim_first_token_ms)),
        ("sim_tokens_per_s", Json::Num(c.sim_tokens_per_s)),
    ])
}

/// v2 stream ack: tells the client its request id before tokens flow
/// (the id is what `{"cancel": id}` takes).
fn ack_json(id: u64) -> Json {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("stream", Json::Bool(true)),
    ])
}

/// v2 per-token chunk.
fn token_json(t: &TokenEvent) -> Json {
    Json::obj(vec![
        ("id", Json::Num(t.request as f64)),
        ("index", Json::Num(t.index as f64)),
        ("token", Json::Num(t.token as f64)),
        ("text", Json::Str(t.text.clone())),
    ])
}

/// v2 final stats line: the v1 completion object plus `"done": true`.
fn done_json(c: &Completion) -> Json {
    let mut j = completion_json(c);
    if let Json::Obj(m) = &mut j {
        m.insert("done".to_string(), Json::Bool(true));
    }
    j
}

/// v2 terminal error line for a stream (cancellation lands here).
fn stream_error_json(id: u64, msg: &str) -> Json {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(msg.to_string())),
        ("done", Json::Bool(true)),
    ])
}

fn cancel_json(id: u64, found: bool) -> Json {
    Json::obj(vec![
        ("cancelled", Json::Num(id as f64)),
        ("found", Json::Bool(found)),
    ])
}

fn stats_json(engine: &Engine) -> Json {
    let m = engine.metrics();
    let mut pairs = vec![
        ("queue_depth", Json::Num(engine.pending() as f64)),
        ("active_sessions", Json::Num(engine.active_sessions() as f64)),
        ("submitted", Json::Num(m.submitted as f64)),
        ("completed", Json::Num(m.completed as f64)),
        ("cancelled", Json::Num(m.cancelled as f64)),
        ("rejected", Json::Num(m.rejected as f64)),
        ("preempted", Json::Num(m.preempted as f64)),
        ("requeued", Json::Num(m.requeued as f64)),
        ("rounds", Json::Num(m.rounds as f64)),
        ("decode_tokens", Json::Num(m.decode_tokens as f64)),
        ("peak_active", Json::Num(m.peak_active as f64)),
        ("tokens_per_s", Json::Num(m.tokens_per_s())),
        ("sim_tokens_per_s", Json::Num(m.sim_tokens_per_s())),
    ];
    // which kernel tier produced these numbers (reference backend only;
    // all tiers are bit-identical, so this is provenance, not behavior)
    if let Some(t) = engine.runtime().kernel_tier() {
        pairs.push(("kernel_tier", Json::Str(t)));
    }
    // KV-arena accounting when the backend pages its session memory
    // (for a bridged backend these are the *device's* arena figures,
    // fetched over the wire; the query also flushes any pipelined
    // CloseSession frames, so the numbers it returns are current)
    if let Some(k) = engine.runtime().memory() {
        pairs.push(("kv_blocks_total", Json::Num(k.blocks_total as f64)));
        pairs.push(("kv_blocks_free", Json::Num(k.blocks_free as f64)));
        pairs.push(("kv_block_tokens", Json::Num(k.block_tokens as f64)));
        pairs.push(("kv_reuse_hits", Json::Num(k.reuse_hits as f64)));
        pairs.push(("kv_reserved_bytes", Json::Num(k.reserved_bytes as f64)));
        pairs.push(("kv_prefix_hits", Json::Num(k.prefix_hits as f64)));
        pairs.push((
            "kv_prefix_cached_blocks",
            Json::Num(k.prefix_cached_blocks as f64),
        ));
    }
    // transport counters when the backend sits across a device bridge:
    // the serving-level view of bytes/token next to tokens/s
    if let Some(t) = engine.runtime().transfer_meter() {
        pairs.push(("device_tx_bytes", Json::Num(t.tx_bytes as f64)));
        pairs.push(("device_rx_bytes", Json::Num(t.rx_bytes as f64)));
        pairs.push(("device_calls", Json::Num(t.calls as f64)));
        pairs.push(("device_reconnects", Json::Num(t.reconnects as f64)));
    }
    // arena pressure counters plus — for a bridged backend — the device
    // daemon's own frame-service summary. One query per deployment
    // shape: a remote backend answers `device_obs()` (a single `Info`
    // round trip carries pressure and service percentiles together),
    // an in-process backend answers `kv_pressure()` straight from its
    // arena and has no device section.
    if let Some(o) = engine.runtime().device_obs() {
        pairs.push(("kv_alloc_stalls", Json::Num(o.alloc_stalls as f64)));
        pairs.push(("kv_cow_copies", Json::Num(o.cow_copies as f64)));
        pairs.push(("device", o.to_json()));
    } else if let Some(p) = engine.runtime().kv_pressure() {
        pairs.push(("kv_alloc_stalls", Json::Num(p.alloc_stalls as f64)));
        pairs.push(("kv_cow_copies", Json::Num(p.cow_copies as f64)));
    }
    // serving-side latency histograms (always present; empty hists
    // report count 0 with zeroed percentiles)
    pairs.push(("latency", engine.obs().latency_json()));
    Json::obj(pairs)
}

/// Synchronous protocol entry point: parse one request line, run it on a
/// dedicated engine, serialize the reply. Always returns a reply object
/// — protocol or engine failures become `{"error": ...}`.
///
/// The threaded server uses the shared scheduler instead (`serve`); this
/// path backs the CLI and the protocol tests. It serves the v1 whole
/// response shape: `stream` is accepted but answered with the final
/// object only (line-at-a-time streaming needs the threaded server),
/// and `cancel` finds nothing in flight by construction.
pub fn process_line(engine: &mut Engine, line: &str) -> Json {
    match parse_request(line) {
        Err(msg) => error_json(msg),
        Ok(ServerRequest::Stats) => stats_json(engine),
        Ok(ServerRequest::Trace(n)) => chrome_trace_json(&engine.obs().trace.last(n)),
        Ok(ServerRequest::Cancel(id)) => {
            let found = engine.cancel(id);
            cancel_json(id, found)
        }
        Ok(ServerRequest::Generate {
            prompt,
            max_new_tokens,
            sampling,
            stream: _,
            priority,
        }) => {
            // consume through the handle, not step()'s return value: a
            // bounded-queue refusal never enqueues, so its structured
            // "server busy" error exists only as the handle's terminal
            // event
            let handle =
                engine.submit_with_priority(&prompt, max_new_tokens, sampling, priority);
            if let Err(e) = engine.run_all() {
                return error_json(format!("{e:#}"));
            }
            match handle.wait() {
                Ok(c) => completion_json(&c),
                Err(msg) => error_json(msg),
            }
        }
    }
}

/// State shared between connection threads and the scheduler thread.
struct Shared {
    engine: Mutex<Engine>,
    /// wakes the scheduler when work arrives (paired with `engine`)
    work: Condvar,
    /// set by `ServerHandle::shutdown`; checked by both loops
    shutdown: AtomicBool,
}

/// Running server: address + the threads to reap.
///
/// `shutdown()` signals both loops, unblocks them, fails in-flight
/// requests with a terminal error event, and joins the scheduler and
/// accept threads. Connection threads exit when their client hangs up
/// (their in-flight requests have already been answered with an error).
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    scheduler: JoinHandle<()>,
    acceptor: JoinHandle<()>,
}

impl ServerHandle {
    /// The bound address (useful with an ephemeral port 0 listener).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the server: scheduler and accept threads are signalled,
    /// unblocked, and joined; queued and live requests receive a
    /// terminal `Event::Error`.
    pub fn shutdown(self) {
        {
            // set the flag and notify *under the engine lock*: the
            // scheduler checks the flag with the lock held, so this
            // serializes with its predicate check and the wakeup cannot
            // be lost between "predicate evaluated" and "parked"
            let _engine = crate::util::lock_unpoisoned(&self.shared.engine);
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.work.notify_all();
        }
        // unblock the accept loop with a throwaway connection
        // (util::poke_acceptor rewrites an unspecified bind address to
        // loopback, which is what is actually connectable)
        let unblocked = crate::util::poke_acceptor(self.addr);
        let _ = self.scheduler.join();
        if unblocked {
            let _ = self.acceptor.join();
        } else {
            // the acceptor may still be parked in accept(); leak it
            // rather than hang the caller — it holds no engine state
            eprintln!(
                "server shutdown: could not poke {}, leaving acceptor parked",
                self.addr
            );
        }
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7077").
pub fn serve(engine: Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(engine, listener)
}

/// Serve on an already-bound listener, blocking the calling thread until
/// the server shuts down (lets callers bind port 0 and learn the
/// ephemeral address first — used by tests and examples).
pub fn serve_on(engine: Engine, listener: TcpListener) -> Result<()> {
    let handle = spawn_on(engine, listener)?;
    let _ = handle.acceptor.join();
    let _ = handle.scheduler.join();
    Ok(())
}

/// Start the server in the background and return its [`ServerHandle`].
pub fn spawn_on(engine: Engine, listener: TcpListener) -> Result<ServerHandle> {
    let addr = listener.local_addr()?;
    eprintln!("edgellm server listening on {addr} (continuous batching, protocol v1+v2)");
    let shared = Arc::new(Shared {
        engine: Mutex::new(engine),
        work: Condvar::new(),
        shutdown: AtomicBool::new(false),
    });
    let scheduler = {
        let shared = Arc::clone(&shared);
        thread::spawn(move || scheduler_loop(&shared))
    };
    let acceptor = {
        let shared = Arc::clone(&shared);
        thread::spawn(move || accept_loop(&shared, listener))
    };
    Ok(ServerHandle {
        addr,
        shared,
        scheduler,
        acceptor,
    })
}

/// Drive the shared engine: one `step_round` per iteration while work is
/// pending, sleeping on the condvar when idle. Completions and token
/// events reach the waiting connections through the per-request channels
/// `step_round` feeds — no routing table here.
fn scheduler_loop(shared: &Shared) {
    loop {
        let mut engine = crate::util::lock_unpoisoned(&shared.engine);
        while !engine.has_work() && !shared.shutdown.load(Ordering::SeqCst) {
            engine = crate::util::wait_unpoisoned(&shared.work, engine);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            // fail in-flight work so no connection blocks on its channel
            engine.abort_all("server shutting down");
            return;
        }
        if let Err(e) = engine.step_round() {
            // a runtime failure poisons the whole round; fail every
            // queued/live request rather than wedging its client (each
            // one's channel receives the error event)
            let msg = format!("engine error: {e:#}");
            eprintln!("{msg}");
            engine.abort_all(&msg);
        }
    }
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match stream {
            Ok(stream) => {
                let shared = Arc::clone(shared);
                thread::spawn(move || {
                    if let Err(e) = handle_client(&shared, stream) {
                        eprintln!("client error: {e:#}");
                    }
                });
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
}

/// Write one v2 stream to the client: ack, token lines, terminal line.
fn stream_reply(writer: &mut TcpStream, handle: &RequestHandle) -> Result<()> {
    writeln!(writer, "{}", ack_json(handle.id()))?;
    writer.flush()?;
    loop {
        match handle.recv() {
            Some(Event::Token(t)) => {
                writeln!(writer, "{}", token_json(&t))?;
                writer.flush()?;
            }
            Some(Event::Done(c)) => {
                writeln!(writer, "{}", done_json(&c))?;
                return Ok(());
            }
            Some(Event::Error(msg)) => {
                writeln!(writer, "{}", stream_error_json(handle.id(), &msg))?;
                return Ok(());
            }
            None => {
                writeln!(
                    writer,
                    "{}",
                    stream_error_json(handle.id(), "server shutting down")
                )?;
                return Ok(());
            }
        }
    }
}

/// Handle one client connection: each request line is enqueued into the
/// shared scheduler. v1 requests are answered when the session retires;
/// v2 (`"stream": true`) requests get an ack line, one line per token,
/// and a final `"done": true` line. `{"cancel": id}` may target any
/// connection's request. A write failure mid-stream cancels the
/// in-flight request (the client is gone).
fn handle_client(shared: &Shared, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    eprintln!("client connected: {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Err(msg) => writeln!(writer, "{}", error_json(msg))?,
            Ok(ServerRequest::Stats) => {
                let engine = crate::util::lock_unpoisoned(&shared.engine);
                let reply = stats_json(&engine);
                drop(engine);
                writeln!(writer, "{reply}")?;
            }
            Ok(ServerRequest::Trace(n)) => {
                // clone the Arc under the lock, snapshot the ring after
                // dropping it — exporting a big trace must not stall
                // the scheduler round in progress
                let obs = {
                    let engine = crate::util::lock_unpoisoned(&shared.engine);
                    Arc::clone(engine.obs())
                };
                writeln!(writer, "{}", chrome_trace_json(&obs.trace.last(n)))?;
            }
            Ok(ServerRequest::Cancel(id)) => {
                let found = crate::util::lock_unpoisoned(&shared.engine).cancel(id);
                writeln!(writer, "{}", cancel_json(id, found))?;
            }
            Ok(ServerRequest::Generate {
                prompt,
                max_new_tokens,
                sampling,
                stream,
                priority,
            }) => {
                let handle = {
                    let mut engine = crate::util::lock_unpoisoned(&shared.engine);
                    // checked under the engine lock: shutdown() sets the
                    // flag under the same lock, so either we see it here
                    // (and refuse), or the scheduler is still alive and
                    // its shutdown pass will abort this request
                    if shared.shutdown.load(Ordering::SeqCst) {
                        drop(engine);
                        writeln!(writer, "{}", error_json("server shutting down"))?;
                        continue;
                    }
                    let h = engine.submit_with_priority(&prompt, max_new_tokens, sampling, priority);
                    shared.work.notify_one();
                    h
                };
                if stream {
                    // a failed write means the client vanished: cancel
                    // the request so its KV slot frees at the next round
                    // instead of decoding max_new tokens for nobody
                    if let Err(e) = stream_reply(&mut writer, &handle) {
                        handle.cancel();
                        return Err(e);
                    }
                } else {
                    let reply = match handle.wait() {
                        Ok(c) => completion_json(&c),
                        Err(msg) => error_json(msg),
                    };
                    writeln!(writer, "{reply}")?;
                }
            }
        }
    }
    eprintln!("client disconnected: {peer}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn request_json_shape_parses() {
        let j = Json::parse(r#"{"prompt":"hi","max_new_tokens":8,"temperature":0.7}"#)
            .unwrap();
        assert_eq!(j.get("prompt").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("max_new_tokens").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn parse_request_validates() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no_prompt": 1}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":0}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":-3}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":100000}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":"много"}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","top_p":1.5}"#).is_err());
        assert!(matches!(
            parse_request(r#"{"stats": true}"#),
            Ok(ServerRequest::Stats)
        ));
    }

    #[test]
    fn parse_request_v2_surface() {
        // stream flag: absent → v1, true → v2, non-bool → error
        assert!(matches!(
            parse_request(r#"{"prompt":"x"}"#),
            Ok(ServerRequest::Generate { stream: false, .. })
        ));
        assert!(matches!(
            parse_request(r#"{"prompt":"x","stream":true}"#),
            Ok(ServerRequest::Generate { stream: true, .. })
        ));
        assert!(parse_request(r#"{"prompt":"x","stream":1}"#).is_err());
        // cancel: id must be a non-negative integer
        assert!(matches!(
            parse_request(r#"{"cancel": 7}"#),
            Ok(ServerRequest::Cancel(7))
        ));
        assert!(parse_request(r#"{"cancel": -1}"#).is_err());
        assert!(parse_request(r#"{"cancel": 1.5}"#).is_err());
        assert!(parse_request(r#"{"cancel": "x"}"#).is_err());
    }

    #[test]
    fn parse_request_trace_surface() {
        assert!(matches!(
            parse_request(r#"{"trace": 256}"#),
            Ok(ServerRequest::Trace(256))
        ));
        assert!(parse_request(r#"{"trace": 0}"#).is_err());
        assert!(parse_request(r#"{"trace": -4}"#).is_err());
        assert!(parse_request(r#"{"trace": 1.5}"#).is_err());
        assert!(parse_request(r#"{"trace": true}"#).is_err());
    }

    #[test]
    fn stats_line_carries_latency_and_trace_exports_lifecycle() {
        use super::super::engine::{Engine, EngineConfig};
        use crate::runtime::model::LlmRuntime;

        let mut eng = Engine::new(LlmRuntime::reference_tiny(), EngineConfig::default());
        let reply = process_line(&mut eng, r#"{"prompt":"observable","max_new_tokens":4}"#);
        assert!(reply.get("error").is_none(), "generate failed: {reply}");

        // stats: nested latency histograms with one admission recorded
        let stats = process_line(&mut eng, r#"{"stats": true}"#);
        let lat = stats.get("latency").expect("stats carries latency");
        for h in ["queue_wait_us", "ttft_us", "itl_us", "round_us"] {
            let c = lat
                .get(h)
                .and_then(|v| v.get("count"))
                .and_then(|v| v.as_f64())
                .expect("histogram summary shape");
            assert!(c >= 1.0, "{h} recorded nothing");
        }
        // in-process backend: arena pressure counters, no device section
        assert!(stats.get("kv_alloc_stalls").is_some());
        assert!(stats.get("device").is_none());

        // trace: the request's lifecycle is exportable as Chrome JSON
        let trace = process_line(&mut eng, r#"{"trace": 64}"#);
        let events = trace
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("chrome trace shape");
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        for want in ["submitted", "queued", "admitted", "first_token", "decode_round", "done"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
    }

    #[test]
    fn parse_request_priority_classes() {
        assert!(matches!(
            parse_request(r#"{"prompt":"x"}"#),
            Ok(ServerRequest::Generate {
                priority: Priority::Batch,
                ..
            })
        ));
        assert!(matches!(
            parse_request(r#"{"prompt":"x","priority":"latency"}"#),
            Ok(ServerRequest::Generate {
                priority: Priority::Latency,
                ..
            })
        ));
        assert!(matches!(
            parse_request(r#"{"prompt":"x","priority":"batch"}"#),
            Ok(ServerRequest::Generate {
                priority: Priority::Batch,
                ..
            })
        ));
        assert!(parse_request(r#"{"prompt":"x","priority":"vip"}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","priority":1}"#).is_err());
    }

    #[test]
    fn parse_request_sampling_policies() {
        let greedy = parse_request(r#"{"prompt":"x"}"#).unwrap();
        assert!(matches!(
            greedy,
            ServerRequest::Generate {
                sampling: Sampling::Greedy,
                max_new_tokens: 32,
                ..
            }
        ));
        let temp = parse_request(r#"{"prompt":"x","temperature":0.7}"#).unwrap();
        assert!(matches!(
            temp,
            ServerRequest::Generate {
                sampling: Sampling::Temperature(_),
                ..
            }
        ));
        let nucleus = parse_request(r#"{"prompt":"x","top_p":0.9}"#).unwrap();
        assert!(matches!(
            nucleus,
            ServerRequest::Generate {
                sampling: Sampling::TopP { .. },
                ..
            }
        ));
    }

    #[test]
    fn v2_json_lines_roundtrip() {
        // serialize → parse: the line shapes clients depend on
        let ack = Json::parse(&ack_json(3).to_string()).unwrap();
        assert_eq!(ack.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(ack.get("stream").unwrap().as_bool(), Some(true));

        let tok = token_json(&TokenEvent {
            request: 3,
            index: 1,
            token: 104,
            text: "h".to_string(),
        });
        let tok = Json::parse(&tok.to_string()).unwrap();
        assert_eq!(tok.get("index").unwrap().as_usize(), Some(1));
        assert_eq!(tok.get("token").unwrap().as_usize(), Some(104));
        assert_eq!(tok.get("text").unwrap().as_str(), Some("h"));
        assert!(tok.get("done").is_none(), "token lines carry no done flag");

        let cancel = Json::parse(&cancel_json(9, false).to_string()).unwrap();
        assert_eq!(cancel.get("cancelled").unwrap().as_usize(), Some(9));
        assert_eq!(cancel.get("found").unwrap().as_bool(), Some(false));

        let err = Json::parse(&stream_error_json(4, "cancelled").to_string()).unwrap();
        assert_eq!(err.get("error").unwrap().as_str(), Some("cancelled"));
        assert_eq!(err.get("done").unwrap().as_bool(), Some(true));
    }
}
