//! LAN inference server (paper Fig. 8's deployment: FPGA+LLM as server,
//! a thin client encodes/decodes and talks to users).
//!
//! Protocol: JSON lines over TCP.
//!   request : {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0}
//!   response: {"id": 1, "text": "...", "tokens_per_s": ...,
//!              "first_token_ms": ..., "sim_tokens_per_s": ...}
//! One request per line; the server answers in order (batch-1 decode, as
//! in the paper's edge operating point).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::Result;

use super::engine::Engine;
use super::sampler::Sampling;
use crate::util::json::Json;

/// Serve forever on `addr` (e.g. "127.0.0.1:7077").
pub fn serve(engine: &mut Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("edgellm server listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        if let Err(e) = handle_client(engine, stream) {
            eprintln!("client error: {e:#}");
        }
    }
    Ok(())
}

/// Handle one client connection (sequential requests).
pub fn handle_client(engine: &mut Engine, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    eprintln!("client connected: {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match process_line(engine, &line) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
    }
    eprintln!("client disconnected: {peer}");
    Ok(())
}

/// Parse one request line, run it, serialize the completion.
pub fn process_line(engine: &mut Engine, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    let prompt = req
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing 'prompt'"))?
        .to_string();
    let max_new = req
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(32);
    let temperature = req
        .get("temperature")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as f32;
    let sampling = if temperature <= 0.0 {
        Sampling::Greedy
    } else {
        Sampling::Temperature(temperature)
    };
    engine.submit(&prompt, max_new, sampling);
    let c = engine
        .step()?
        .ok_or_else(|| anyhow::anyhow!("queue empty after submit"))?;
    Ok(Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("text", Json::Str(c.text)),
        ("n_prompt", Json::Num(c.n_prompt as f64)),
        ("n_generated", Json::Num(c.n_generated as f64)),
        ("first_token_ms", Json::Num(c.first_token_s * 1e3)),
        ("tokens_per_s", Json::Num(c.tokens_per_s)),
        ("sim_first_token_ms", Json::Num(c.sim_first_token_ms)),
        ("sim_tokens_per_s", Json::Num(c.sim_tokens_per_s)),
    ]))
}

#[cfg(test)]
mod tests {
    use crate::util::json::Json;

    #[test]
    fn request_json_shape_parses() {
        let j = Json::parse(r#"{"prompt":"hi","max_new_tokens":8,"temperature":0.7}"#)
            .unwrap();
        assert_eq!(j.get("prompt").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("max_new_tokens").unwrap().as_usize(), Some(8));
    }
}
