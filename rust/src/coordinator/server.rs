//! LAN inference server (paper Fig. 8's deployment: FPGA+LLM as server,
//! a thin client encodes/decodes and talks to users) — multi-client.
//!
//! Protocol: JSON lines over TCP.
//!   request : {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0,
//!              "top_p": 0.9}
//!   response: {"id": 1, "text": "...", "tokens_per_s": ...,
//!              "first_token_ms": ..., "sim_tokens_per_s": ...}
//!   stats   : {"stats": true} →
//!             {"queue_depth": ..., "active_sessions": ...,
//!              "rounds": ..., "decode_tokens": ...,
//!              "tokens_per_s": ..., "sim_tokens_per_s": ...}
//!
//! Malformed input never kills a connection: every request line gets a
//! reply, either a completion or `{"error": "..."}`.
//!
//! Unlike the original one-blocking-client loop, each connection runs on
//! its own thread and *enqueues* into the shared continuous-batching
//! scheduler; a dedicated scheduler thread drives `Engine::step_round`
//! and routes retired completions back to the waiting connections. Many
//! clients therefore decode concurrently inside one shared batch.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::Result;

use super::engine::{Completion, Engine};
use super::sampler::Sampling;
use crate::util::json::Json;

/// Protocol-level cap on `max_new_tokens`; requests beyond it are
/// rejected with a structured error (the engine additionally clamps to
/// the model's KV budget).
pub const MAX_NEW_TOKENS_LIMIT: usize = 4096;

/// A parsed protocol request.
pub enum ServerRequest {
    Generate {
        prompt: String,
        max_new_tokens: usize,
        sampling: Sampling,
    },
    Stats,
}

/// Parse and validate one request line. Pure — no engine needed — so the
/// protocol surface is testable in isolation.
pub fn parse_request(line: &str) -> Result<ServerRequest, String> {
    let req = Json::parse(line).map_err(|e| format!("bad request json: {e}"))?;
    if req.get("stats").and_then(|v| v.as_bool()) == Some(true) {
        return Ok(ServerRequest::Stats);
    }
    let prompt = req
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or_else(|| "missing 'prompt'".to_string())?
        .to_string();
    let max_new_tokens = match req.get("max_new_tokens") {
        None => 32,
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| "'max_new_tokens' must be a number".to_string())?;
            if !(1.0..=MAX_NEW_TOKENS_LIMIT as f64).contains(&n) {
                return Err(format!(
                    "'max_new_tokens' out of range: {n} (want 1..={MAX_NEW_TOKENS_LIMIT})"
                ));
            }
            n as usize
        }
    };
    let temperature = req
        .get("temperature")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as f32;
    let sampling = match req.get("top_p").and_then(|v| v.as_f64()) {
        Some(p) if !(0.0..=1.0).contains(&p) => {
            return Err(format!("'top_p' out of range: {p} (want 0..=1)"));
        }
        Some(p) => Sampling::TopP {
            p: p as f32,
            temperature: if temperature > 0.0 { temperature } else { 1.0 },
        },
        None if temperature <= 0.0 => Sampling::Greedy,
        None => Sampling::Temperature(temperature),
    };
    Ok(ServerRequest::Generate {
        prompt,
        max_new_tokens,
        sampling,
    })
}

fn error_json(msg: impl Into<String>) -> Json {
    Json::obj(vec![("error", Json::Str(msg.into()))])
}

fn completion_json(c: &Completion) -> Json {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("text", Json::Str(c.text.clone())),
        ("n_prompt", Json::Num(c.n_prompt as f64)),
        ("n_generated", Json::Num(c.n_generated as f64)),
        ("first_token_ms", Json::Num(c.first_token_s * 1e3)),
        ("tokens_per_s", Json::Num(c.tokens_per_s)),
        ("sim_first_token_ms", Json::Num(c.sim_first_token_ms)),
        ("sim_tokens_per_s", Json::Num(c.sim_tokens_per_s)),
    ])
}

fn stats_json(engine: &Engine) -> Json {
    let m = engine.metrics();
    Json::obj(vec![
        ("queue_depth", Json::Num(engine.pending() as f64)),
        ("active_sessions", Json::Num(engine.active_sessions() as f64)),
        ("submitted", Json::Num(m.submitted as f64)),
        ("completed", Json::Num(m.completed as f64)),
        ("rounds", Json::Num(m.rounds as f64)),
        ("decode_tokens", Json::Num(m.decode_tokens as f64)),
        ("peak_active", Json::Num(m.peak_active as f64)),
        ("tokens_per_s", Json::Num(m.tokens_per_s())),
        ("sim_tokens_per_s", Json::Num(m.sim_tokens_per_s())),
    ])
}

/// Synchronous protocol entry point: parse one request line, run it on a
/// dedicated engine, serialize the reply. Always returns a reply object
/// — protocol or engine failures become `{"error": ...}`.
///
/// The threaded server uses the shared scheduler instead (`serve`); this
/// path backs the CLI and the protocol tests.
pub fn process_line(engine: &mut Engine, line: &str) -> Json {
    match parse_request(line) {
        Err(msg) => error_json(msg),
        Ok(ServerRequest::Stats) => stats_json(engine),
        Ok(ServerRequest::Generate {
            prompt,
            max_new_tokens,
            sampling,
        }) => {
            engine.submit(&prompt, max_new_tokens, sampling);
            match engine.step() {
                Ok(Some(c)) => completion_json(&c),
                Ok(None) => error_json("queue empty after submit"),
                Err(e) => error_json(format!("{e:#}")),
            }
        }
    }
}

type Reply = Result<Completion, String>;

/// State shared between connection threads and the scheduler thread.
/// Lock order: `engine` before `waiters` — both threads keep it.
struct Shared {
    engine: Mutex<Engine>,
    /// wakes the scheduler when work arrives (paired with `engine`)
    work: Condvar,
    waiters: Mutex<HashMap<u64, mpsc::Sender<Reply>>>,
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7077").
pub fn serve(engine: Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(engine, listener)
}

/// Serve forever on an already-bound listener (lets callers bind port 0
/// and learn the ephemeral address first — used by tests and examples).
pub fn serve_on(engine: Engine, listener: TcpListener) -> Result<()> {
    eprintln!(
        "edgellm server listening on {} (continuous batching)",
        listener.local_addr()?
    );
    let shared = Arc::new(Shared {
        engine: Mutex::new(engine),
        work: Condvar::new(),
        waiters: Mutex::new(HashMap::new()),
    });

    {
        let shared = Arc::clone(&shared);
        thread::spawn(move || scheduler_loop(&shared));
    }

    for stream in listener.incoming() {
        match stream {
            Ok(stream) => {
                let shared = Arc::clone(&shared);
                thread::spawn(move || {
                    if let Err(e) = handle_client(&shared, stream) {
                        eprintln!("client error: {e:#}");
                    }
                });
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Drive the shared engine: one `step_round` per iteration while work is
/// pending, sleeping on the condvar when idle.
fn scheduler_loop(shared: &Shared) {
    loop {
        let mut engine = shared.engine.lock().unwrap();
        while !engine.has_work() {
            engine = shared.work.wait(engine).unwrap();
        }
        match engine.step_round() {
            Ok(done) => {
                if done.is_empty() {
                    continue;
                }
                let mut waiters = shared.waiters.lock().unwrap();
                for c in done {
                    if let Some(tx) = waiters.remove(&c.id) {
                        let _ = tx.send(Ok(c));
                    }
                }
            }
            Err(e) => {
                // a runtime failure poisons the whole round; fail every
                // registered waiter rather than wedging its client. A
                // failing round can also discard completions it had
                // already retired (e.g. an admission-time retirement
                // followed by a decode error), so draining abort_all()'s
                // queued/live ids alone would leave those clients
                // blocked forever — clear the whole map. No new waiter
                // can register while we hold the engine lock.
                let msg = format!("engine error: {e:#}");
                eprintln!("{msg}");
                engine.abort_all();
                let mut waiters = shared.waiters.lock().unwrap();
                for (_, tx) in waiters.drain() {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Handle one client connection: each request line is enqueued into the
/// shared scheduler; the reply is written when the session retires.
fn handle_client(shared: &Shared, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    eprintln!("client connected: {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Err(msg) => error_json(msg),
            Ok(ServerRequest::Stats) => {
                let engine = shared.engine.lock().unwrap();
                stats_json(&engine)
            }
            Ok(ServerRequest::Generate {
                prompt,
                max_new_tokens,
                sampling,
            }) => {
                let (tx, rx) = mpsc::channel::<Reply>();
                {
                    let mut engine = shared.engine.lock().unwrap();
                    let id = engine.submit(&prompt, max_new_tokens, sampling);
                    // register the waiter before releasing the engine
                    // lock so the scheduler can't retire the id first
                    shared.waiters.lock().unwrap().insert(id, tx);
                    shared.work.notify_one();
                }
                match rx.recv() {
                    Ok(Ok(c)) => completion_json(&c),
                    Ok(Err(msg)) => error_json(msg),
                    Err(_) => error_json("server shutting down"),
                }
            }
        };
        writeln!(writer, "{reply}")?;
    }
    eprintln!("client disconnected: {peer}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn request_json_shape_parses() {
        let j = Json::parse(r#"{"prompt":"hi","max_new_tokens":8,"temperature":0.7}"#)
            .unwrap();
        assert_eq!(j.get("prompt").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("max_new_tokens").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn parse_request_validates() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no_prompt": 1}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":0}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":-3}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":100000}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","max_new_tokens":"много"}"#).is_err());
        assert!(parse_request(r#"{"prompt":"x","top_p":1.5}"#).is_err());
        assert!(matches!(
            parse_request(r#"{"stats": true}"#),
            Ok(ServerRequest::Stats)
        ));
    }

    #[test]
    fn parse_request_sampling_policies() {
        let greedy = parse_request(r#"{"prompt":"x"}"#).unwrap();
        assert!(matches!(
            greedy,
            ServerRequest::Generate {
                sampling: Sampling::Greedy,
                max_new_tokens: 32,
                ..
            }
        ));
        let temp = parse_request(r#"{"prompt":"x","temperature":0.7}"#).unwrap();
        assert!(matches!(
            temp,
            ServerRequest::Generate {
                sampling: Sampling::Temperature(_),
                ..
            }
        ));
        let nucleus = parse_request(r#"{"prompt":"x","top_p":0.9}"#).unwrap();
        assert!(matches!(
            nucleus,
            ServerRequest::Generate {
                sampling: Sampling::TopP { .. },
                ..
            }
        ));
    }
}
