//! The serving engine: request queue, session/KV management, decode loop,
//! and metrics — the CPU-side runtime of the CPU-FPGA system.
//!
//! The paper serves batch-1 edge requests (Table V's operating point);
//! the engine processes a FIFO of requests, each = prefill + autoregressive
//! decode against its own KV session. Functional numerics run through the
//! PJRT runtime on the AOT artifacts; for each request we also report the
//! *simulated VCU128* latency of the same token counts, tying the serving
//! path to the performance model.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::sampler::{sample, Sampling};
use super::tokenizer;
use crate::models::{LlmArch, SparseStrategy, DENSE};
use crate::runtime::model::LlmRuntime;
use crate::sim::engine::Simulator;
use crate::sim::Memory;
use crate::util::rng::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

/// Completed request with measured + simulated metrics.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt: String,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    /// wall-clock first-token latency (prefill), seconds
    pub first_token_s: f64,
    /// wall-clock decode time, seconds
    pub decode_s: f64,
    /// measured functional decode throughput, tokens/s
    pub tokens_per_s: f64,
    /// simulated VCU128 first-token latency (ms) for the same shape
    pub sim_first_token_ms: f64,
    /// simulated VCU128 decode throughput (token/s)
    pub sim_tokens_per_s: f64,
}

/// Engine configuration.
pub struct EngineConfig {
    /// architecture simulated for the VCU128-side metrics
    pub sim_arch: LlmArch,
    pub sim_strategy: SparseStrategy,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            sim_arch: crate::models::TINY,
            sim_strategy: DENSE,
            seed: 0,
        }
    }
}

pub struct Engine {
    runtime: LlmRuntime,
    sim: Simulator,
    queue: VecDeque<Request>,
    rng: Rng,
    next_id: u64,
    pub completions: Vec<Completion>,
}

impl Engine {
    pub fn new(runtime: LlmRuntime, cfg: EngineConfig) -> Self {
        let sim = Simulator::new(&cfg.sim_arch, &cfg.sim_strategy, Memory::Hbm);
        Engine {
            runtime,
            sim,
            queue: VecDeque::new(),
            rng: Rng::new(cfg.seed),
            next_id: 1,
            completions: Vec::new(),
        }
    }

    pub fn runtime(&self) -> &LlmRuntime {
        &self.runtime
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, prompt: &str, max_new_tokens: usize, sampling: Sampling) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            prompt: prompt.to_string(),
            max_new_tokens,
            sampling,
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Process one queued request to completion (batch-1 decode loop).
    pub fn step(&mut self) -> Result<Option<Completion>> {
        let Some(req) = self.queue.pop_front() else {
            return Ok(None);
        };
        let completion = self.run_request(&req)?;
        self.completions.push(completion.clone());
        Ok(Some(completion))
    }

    /// Drain the whole queue.
    pub fn run_all(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while let Some(c) = self.step()? {
            out.push(c);
        }
        Ok(out)
    }

    fn run_request(&mut self, req: &Request) -> Result<Completion> {
        let mut tokens = tokenizer::encode(&req.prompt);
        if tokens.is_empty() {
            tokens.push(0);
        }
        let info = &self.runtime.info;
        // clamp prompt to the largest prefill bucket
        let max_prompt = self
            .runtime
            .prefill_buckets()
            .last()
            .copied()
            .unwrap_or(info.max_tokens);
        if tokens.len() > max_prompt {
            tokens.truncate(max_prompt);
        }
        let budget = info.max_tokens - tokens.len();
        let max_new = req.max_new_tokens.min(budget);

        let t0 = Instant::now();
        let (logits, mut session) = self.runtime.prefill(&tokens)?;
        let first_token_s = t0.elapsed().as_secs_f64();

        let mut generated = Vec::with_capacity(max_new);
        let mut cur = sample(&logits, req.sampling, &mut self.rng);
        let t1 = Instant::now();
        for _ in 0..max_new {
            generated.push(cur);
            let logits = self.runtime.decode(&mut session, cur)?;
            cur = sample(&logits, req.sampling, &mut self.rng);
        }
        let decode_s = t1.elapsed().as_secs_f64();

        // simulated VCU128 metrics for the same token counts
        let sim_gen = self.sim.generate(tokens.len().max(1), generated.len().max(1));

        Ok(Completion {
            id: req.id,
            prompt: req.prompt.clone(),
            text: tokenizer::decode(&generated),
            n_prompt: tokens.len(),
            n_generated: generated.len(),
            first_token_s,
            decode_s,
            tokens_per_s: generated.len() as f64 / decode_s.max(1e-9),
            sim_first_token_ms: sim_gen.first_token_us / 1e3,
            sim_tokens_per_s: sim_gen.tokens_per_s,
        })
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need artifacts live in rust/tests/serving.rs;
    // here we test the queue mechanics with no runtime dependency.
    use super::*;

    #[test]
    fn sampling_enum_is_copy() {
        let s = Sampling::Greedy;
        let _t = s;
        let _u = s; // Copy: both usable
    }

    #[test]
    fn request_fields() {
        let r = Request {
            id: 7,
            prompt: "hi".into(),
            max_new_tokens: 4,
            sampling: Sampling::Greedy,
        };
        assert_eq!(r.id, 7);
    }
}
