//! The serving engine: continuous-batching scheduler, session/KV
//! management, decode loop, streaming token events, cancellation, and
//! metrics — the CPU-side runtime of the CPU-FPGA system.
//!
//! The paper operates at the batch-1 edge point (Table V); scaling that
//! serving path to many live users means interleaving sessions, not
//! queueing them. The engine therefore runs a **step-wise scheduler**:
//!
//! * [`Engine::submit`] enqueues a request (cheap, callable any time)
//!   and returns a [`RequestHandle`]: a per-request event channel
//!   ([`Event::Token`] per generated token, then [`Event::Done`] with
//!   the full [`Completion`], or [`Event::Error`]) plus
//!   [`RequestHandle::cancel`];
//! * [`Engine::step_round`] is one scheduler round — reap cancelled
//!   sessions (freeing their KV slots *before* admission), admit queued
//!   requests into the active pool (prefill) while there are free slots,
//!   run **one batched decode step** over every live session
//!   ([`LlmRuntime::decode_batch`]), then retire sessions that hit EOS,
//!   their `max_new_tokens`, or the KV budget. Each session's token is
//!   streamed out the moment it is emitted (fed back to the model), so
//!   thin clients see tokens as they decode — the Fig. 8 LAN deployment;
//! * retired [`Completion`]s carry both measured wall-clock metrics and
//!   the simulated VCU128 cost of the same token counts, where each
//!   decode round is charged **once** for the whole batch
//!   (`Simulator::decode_round`) — the weight stream is shared, only the
//!   per-session KV work multiplies.
//!
//! `step()` / `run_all()` keep the original run-to-completion call
//! shape for the CLI and tests, implemented on top of `step_round`.
//! The engine sees the runtime only through the object-safe
//! [`Backend`](crate::runtime::backend::Backend) trait, so any backend
//! — reference, PJRT, latency model, mock — schedules identically.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use super::sampler::{sample, Sampling};
use super::tokenizer;
use crate::bridge::client::BridgeError;
use crate::models::{LlmArch, SparseStrategy, DENSE};
use crate::obs::{Obs, SpanKind};
use crate::runtime::kv::{KvExhausted, MemoryStats, KV_EXHAUSTED_MARKER};
use crate::runtime::model::{LlmRuntime, Session};
use crate::sim::engine::Simulator;
use crate::sim::Memory;
use crate::util::rng::Rng;

/// Scheduling class of a request. The queue is two-class: a
/// `Latency` request is admitted ahead of earlier `Batch` requests,
/// bounded by an anti-starvation aging rule (a `Batch` request that has
/// waited [`EngineConfig::batch_aging_rounds`] scheduler rounds can no
/// longer be jumped).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// interactive / latency-sensitive: jumps the batch class
    Latency,
    /// throughput work — the default for `submit`
    #[default]
    Batch,
}

/// One generation request (the queue-level descriptor).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

/// Completed request with measured + simulated metrics.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt: String,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    /// wall-clock first-token latency (prefill), seconds
    pub first_token_s: f64,
    /// wall-clock decode time, seconds (sum of the rounds this session
    /// was live in — under batching this is per-session latency, not
    /// aggregate throughput; see `EngineMetrics` for the aggregate)
    pub decode_s: f64,
    /// measured functional decode throughput, tokens/s
    pub tokens_per_s: f64,
    /// simulated VCU128 first-token latency (ms) for the same shape
    pub sim_first_token_ms: f64,
    /// simulated VCU128 decode throughput (token/s) as experienced by
    /// this session inside its batch
    pub sim_tokens_per_s: f64,
}

/// One generated token, streamed while the session is still decoding.
#[derive(Debug, Clone)]
pub struct TokenEvent {
    /// request id this token belongs to
    pub request: u64,
    /// 0-based position in the generated sequence
    pub index: usize,
    pub token: i32,
    /// lossy single-token text preview (byte-level vocab: a multi-byte
    /// UTF-8 character split across tokens renders as U+FFFD here); the
    /// token ids — and the final `Completion::text` — are authoritative
    pub text: String,
}

/// Events delivered on a request's channel, in order: zero or more
/// `Token`s, then exactly one terminal `Done` or `Error`.
#[derive(Debug, Clone)]
pub enum Event {
    Token(TokenEvent),
    Done(Completion),
    Error(String),
}

/// Client-side handle to an in-flight request: the token-event stream
/// plus cancellation. Dropping the handle never blocks the engine —
/// events for a dropped handle are discarded.
pub struct RequestHandle {
    id: u64,
    cancel: Arc<AtomicBool>,
    events: mpsc::Receiver<Event>,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the engine to drop this request. Honored at the next round
    /// boundary: a queued request is discarded before prefill, a live
    /// session is reaped and its KV slot freed before the round's
    /// admissions. The terminal event is `Event::Error("cancelled")`.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Next event, blocking until one arrives. `None` once the channel
    /// is closed (terminal event already consumed, or engine dropped).
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Next event if one is ready (non-blocking).
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Drain events until the terminal one; returns the completion or
    /// the error message. Token events are discarded — the whole-reply
    /// (protocol v1) consumption shape.
    pub fn wait(&self) -> Result<Completion, String> {
        loop {
            match self.events.recv() {
                Ok(Event::Token(_)) => continue,
                Ok(Event::Done(c)) => return Ok(c),
                Ok(Event::Error(msg)) => return Err(msg),
                Err(_) => return Err("engine dropped the request".to_string()),
            }
        }
    }
}

/// Engine configuration.
pub struct EngineConfig {
    /// architecture simulated for the VCU128-side metrics
    pub sim_arch: LlmArch,
    pub sim_strategy: SparseStrategy,
    pub seed: u64,
    /// continuous batching: max sessions decoded per round
    pub max_active: usize,
    /// admission control: max requests waiting in the queue. `submit`
    /// beyond this bound refuses the request immediately — its handle
    /// receives a terminal "server busy" `Event::Error`, which both
    /// protocol paths surface as a structured error (v1 `{"error": ...}`,
    /// v2 `{"id": .., "error": .., "done": true}`). `0` refuses all new
    /// work (drain mode).
    pub max_queued: usize,
    /// max admissions (prefills) per round, bounding head-of-line
    /// blocking of in-flight decodes behind long prefills
    pub prefills_per_round: usize,
    /// retire a session when it samples this token (None: generate to
    /// `max_new_tokens`/budget — byte-level vocab has no natural EOS)
    pub eos_token: Option<i32>,
    /// chunked prefill: a prompt longer than this is warmed into the
    /// prefix cache `prefill_chunk_tokens` tokens per admission slot
    /// before the real admission, so one huge prompt cannot stall live
    /// decodes for a whole monolithic prefill. `0` disables slicing.
    /// Per-round prefill compute is bounded by
    /// `prefills_per_round × prefill_chunk_tokens` only when the
    /// backend caches prefixes at block granularity `<=` the chunk
    /// (`--kv-block-tokens`); on cache-less backends slicing is
    /// correct but the final prefill recomputes the whole prompt.
    pub prefill_chunk_tokens: usize,
    /// anti-starvation bound for the two-class queue: a batch-class
    /// request that has waited this many scheduler rounds can no
    /// longer be jumped by latency-class arrivals
    pub batch_aging_rounds: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            sim_arch: crate::models::TINY,
            sim_strategy: DENSE,
            seed: 0,
            max_active: 8,
            max_queued: 1024,
            prefills_per_round: 2,
            eos_token: None,
            prefill_chunk_tokens: 0,
            batch_aging_rounds: 32,
        }
    }
}

/// Aggregate serving counters, updated every scheduler round.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub submitted: u64,
    pub completed: u64,
    /// requests dropped by cancellation (queued or live)
    pub cancelled: u64,
    /// requests refused outright: at `submit` because the queue was
    /// full (not counted in `submitted`), or at admission because their
    /// worst-case KV block count exceeds the whole arena
    pub rejected: u64,
    /// live sessions evicted mid-decode because the KV arena was
    /// exhausted; stays 0 whenever admission's worst-case accounting
    /// holds. Eviction is not failure: each victim is requeued (see
    /// `requeued`) and its stream resumes after a recompute
    pub preempted: u64,
    /// preemption victims put back at the queue front as recompute
    /// requests — their event channel and already-emitted tokens
    /// survive, so the client sees a latency stall, not an error
    pub requeued: u64,
    /// batched decode rounds executed
    pub rounds: u64,
    /// decode tokens emitted across all sessions
    pub decode_tokens: u64,
    /// most sessions ever live in one round
    pub peak_active: usize,
    /// wall-clock seconds spent in batched decode rounds
    pub decode_wall_s: f64,
    /// simulated VCU128 µs across all decode rounds (each round charged
    /// once, shared weight stream)
    pub sim_decode_us: f64,
}

impl EngineMetrics {
    /// Measured aggregate decode throughput across all sessions.
    pub fn tokens_per_s(&self) -> f64 {
        self.decode_tokens as f64 / self.decode_wall_s.max(1e-9)
    }

    /// Simulated VCU128 aggregate decode throughput. This is the number
    /// continuous batching improves: tokens from *all* sessions per unit
    /// of simulated accelerator time.
    pub fn sim_tokens_per_s(&self) -> f64 {
        if self.sim_decode_us <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / (self.sim_decode_us * 1e-6)
    }
}

/// A queued request plus its event channel and cancellation flag.
struct QueuedRequest {
    req: Request,
    events: mpsc::Sender<Event>,
    cancel: Arc<AtomicBool>,
    /// tokenized-and-clamped admission plan `(tokens, max_new)`,
    /// computed once when the request first reaches the head of the
    /// queue — a head waiting at the memory gate is not re-tokenized
    /// every round, and a requeued request keeps its plan
    plan: Option<(Vec<i32>, usize)>,
    class: Priority,
    /// `round_seq` when the entry (re-)entered the queue — the aging
    /// clock for the batch class and the resume grace window
    enqueued_seq: u64,
    /// obs-clock nanoseconds when the entry (re-)entered the queue —
    /// feeds the queue-wait histogram per waiting *episode* (a
    /// preemption victim's requeue starts a fresh episode, so queue
    /// wait never absorbs the decode time it already spent live)
    enqueued_ns: u64,
    /// prompt tokens already warmed into the prefix cache by chunked
    /// prefill; admission resumes slicing from here
    warmed: usize,
    /// present iff this entry is a preempted victim resuming
    resume: Option<ResumeState>,
}

/// Decode state of a preempted victim, carried through the queue so the
/// request *resumes* — same channel, same already-emitted tokens —
/// instead of failing. `generated` includes the token that was streamed
/// to the client but not yet fed to the model when the round failed.
struct ResumeState {
    prompt_tokens: Vec<i32>,
    generated: Vec<i32>,
    /// the already-clamped original budget
    max_new: usize,
    first_token_s: f64,
    decode_wall_s: f64,
    sim_first_token_ms: f64,
    sim_decode_us: f64,
}

/// Rounds a resumed victim may wait at the admission gate with *no*
/// live sessions before the engine gives up on outside holders
/// releasing blocks and refuses it (a fresh request in the same spot is
/// refused immediately — see the gate comments).
const RESUME_GRACE_ROUNDS: u64 = 64;

/// A live session inside the scheduler's active pool.
struct ActiveSession {
    id: u64,
    prompt: String,
    sampling: Sampling,
    max_new: usize,
    n_prompt: usize,
    /// worst-case KV footprint in tokens (`n_prompt + max_new`, already
    /// clamped to the model budget) — what the memory-aware admission
    /// gate holds against the arena for sessions still growing
    worst_tokens: usize,
    session: Session,
    /// the planned (tokenized, clamped) prompt — kept so a preemption
    /// can requeue the session as a recompute request
    prompt_tokens: Vec<i32>,
    generated: Vec<i32>,
    /// sampled but not yet emitted/fed token
    next_token: i32,
    /// token events already streamed; a resumed session re-walks
    /// `generated` indices below this without re-emitting them
    emitted: usize,
    class: Priority,
    first_token_s: f64,
    decode_wall_s: f64,
    sim_first_token_ms: f64,
    sim_decode_us: f64,
    events: mpsc::Sender<Event>,
    /// cleared on the first failed send (handle dropped), so the hot
    /// decode loop stops building events nobody will read
    events_open: bool,
    cancel: Arc<AtomicBool>,
}

impl ActiveSession {
    fn send(&mut self, ev: Event) {
        if self.events_open && self.events.send(ev).is_err() {
            self.events_open = false;
        }
    }
}

enum Admitted {
    Active(Box<ActiveSession>),
    /// retired at admission (zero token budget, or immediate EOS)
    Done(Completion),
    /// prefill could not reserve KV blocks (arena shared with work the
    /// gate cannot see, or a stale stats snapshot): hand the request
    /// back so it retries after retirements — one transient per-request
    /// condition must not fail the whole round
    Requeue(QueuedRequest),
}

/// True when `e` is the arena's typed exhaustion error — directly
/// (in-process backends return [`KvExhausted`] un-wrapped) or carried
/// across the bridge as a typed [`BridgeError::Backend`] frame whose
/// message keeps the stable marker. Both arms match on *typed* errors;
/// no formatted-chain substring scans.
fn is_kv_exhausted(e: &anyhow::Error) -> bool {
    if e.downcast_ref::<KvExhausted>().is_some() {
        return true;
    }
    matches!(
        e.downcast_ref::<BridgeError>(),
        Some(BridgeError::Backend { message, .. }) if message.contains(KV_EXHAUSTED_MARKER)
    )
}

/// Preemption victim among the live pool: the **youngest** session
/// (fewest sunk tokens — highest index, admission order) whose
/// remaining budget is more than one token. Evicting a session that is
/// one round from completion trades an entire prefix recompute for a
/// single token, so such sessions are skipped; when *every* session is
/// about to finish, fall back to the youngest outright.
fn pick_victim(remaining: &[usize]) -> usize {
    remaining
        .iter()
        .rposition(|&r| r > 1)
        .unwrap_or(remaining.len() - 1)
}

/// Fold a preempted live session back into a queue entry that resumes
/// — same channel, same emitted tokens — instead of starting over.
fn requeue_victim(victim: ActiveSession, seq: u64, now_ns: u64) -> QueuedRequest {
    QueuedRequest {
        req: Request {
            id: victim.id,
            prompt: victim.prompt,
            max_new_tokens: victim.max_new,
            sampling: victim.sampling,
        },
        events: victim.events,
        cancel: victim.cancel,
        plan: None,
        class: victim.class,
        enqueued_seq: seq,
        enqueued_ns: now_ns,
        warmed: 0,
        resume: Some(ResumeState {
            prompt_tokens: victim.prompt_tokens,
            generated: victim.generated,
            max_new: victim.max_new,
            first_token_s: victim.first_token_s,
            decode_wall_s: victim.decode_wall_s,
            sim_first_token_ms: victim.sim_first_token_ms,
            sim_decode_us: victim.sim_decode_us,
        }),
    }
}

pub struct Engine {
    runtime: LlmRuntime,
    sim: Simulator,
    cfg_max_active: usize,
    cfg_max_queued: usize,
    cfg_prefills_per_round: usize,
    cfg_prefill_chunk: usize,
    cfg_batch_aging: u64,
    eos_token: Option<i32>,
    queue: VecDeque<QueuedRequest>,
    active: Vec<ActiveSession>,
    /// completions produced by `step_round` but not yet returned by
    /// `step()`
    ready: VecDeque<Completion>,
    /// per-round scratch (tokens fed / context lengths), reused so the
    /// steady-state scheduler round allocates nothing of its own
    round_tokens: Vec<i32>,
    round_ctxs: Vec<usize>,
    rng: Rng,
    next_id: u64,
    /// scheduler-round clock (every `step_round`, decode or not) —
    /// drives batch-class aging and the resume grace window
    round_seq: u64,
    metrics: EngineMetrics,
    /// latency histograms + lifecycle trace ring; `Arc` so the server
    /// can export stats/traces without borrowing the engine, and so
    /// the backend (via `attach_obs`) can feed frame RTTs into the
    /// same registry
    obs: Arc<Obs>,
}

impl Engine {
    pub fn new(runtime: LlmRuntime, cfg: EngineConfig) -> Self {
        let sim = Simulator::new(&cfg.sim_arch, &cfg.sim_strategy, Memory::Hbm);
        let obs = Arc::new(Obs::new());
        // remote backends record per-frame RTTs and reconnect spans
        // into the engine's registry; in-process backends ignore this
        runtime.attach_obs(&obs);
        Engine {
            runtime,
            sim,
            cfg_max_active: cfg.max_active.max(1),
            cfg_max_queued: cfg.max_queued,
            cfg_prefills_per_round: cfg.prefills_per_round.max(1),
            cfg_prefill_chunk: cfg.prefill_chunk_tokens,
            cfg_batch_aging: cfg.batch_aging_rounds.max(1),
            eos_token: cfg.eos_token,
            queue: VecDeque::new(),
            active: Vec::new(),
            ready: VecDeque::new(),
            round_tokens: Vec::new(),
            round_ctxs: Vec::new(),
            rng: Rng::new(cfg.seed),
            next_id: 1,
            round_seq: 0,
            metrics: EngineMetrics::default(),
            obs,
        }
    }

    pub fn runtime(&self) -> &LlmRuntime {
        &self.runtime
    }

    /// Enqueue a request and hand back its streaming handle. Requests
    /// are admitted into the active pool by subsequent scheduler rounds;
    /// the handle's channel then carries one `Event::Token` per
    /// generated token and a terminal `Event::Done`/`Event::Error`.
    pub fn submit(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        sampling: Sampling,
    ) -> RequestHandle {
        self.submit_with_priority(prompt, max_new_tokens, sampling, Priority::Batch)
    }

    /// [`Engine::submit`] with an explicit scheduling class:
    /// [`Priority::Latency`] requests are admitted ahead of earlier
    /// [`Priority::Batch`] ones, bounded by the aging rule (see
    /// [`EngineConfig::batch_aging_rounds`]).
    pub fn submit_with_priority(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        sampling: Sampling,
        class: Priority,
    ) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        // bounded admission: refuse rather than queue without bound.
        // The refusal is the request's terminal event, so every
        // consumption shape (wait, streaming, try_recv) sees a
        // structured "server busy" instead of a silent hang.
        if self.queue.len() >= self.cfg_max_queued {
            self.metrics.rejected += 1;
            let _ = tx.send(Event::Error(format!(
                "server busy: queue full ({} queued, max_queued={})",
                self.queue.len(),
                self.cfg_max_queued
            )));
            return RequestHandle { id, cancel, events: rx };
        }
        self.metrics.submitted += 1;
        let now = self.obs.now_ns();
        // detail = queue depth at arrival, so a trace shows the
        // backlog each request landed behind
        self.obs.trace.mark(id, SpanKind::Submitted, now, self.queue.len() as u64);
        self.queue.push_back(QueuedRequest {
            req: Request {
                id,
                prompt: prompt.to_string(),
                max_new_tokens,
                sampling,
            },
            events: tx,
            cancel: Arc::clone(&cancel),
            plan: None,
            class,
            enqueued_seq: self.round_seq,
            enqueued_ns: now,
            warmed: 0,
            resume: None,
        });
        RequestHandle { id, cancel, events: rx }
    }

    /// Flag a request (queued or live) for cancellation by id — the
    /// server's `{"cancel": id}` path, equivalent to
    /// [`RequestHandle::cancel`]. Returns false for unknown/finished
    /// ids. Honored at the next round boundary.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(q) = self.queue.iter().find(|q| q.req.id == id) {
            q.cancel.store(true, Ordering::Relaxed);
            return true;
        }
        if let Some(a) = self.active.iter().find(|a| a.id == id) {
            a.cancel.store(true, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Requests waiting for admission (not yet prefilled).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Sessions currently live in the decode pool.
    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    /// True if any request is still queued or live in the pool.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// The engine's observability registry: latency histograms
    /// (queue wait, TTFT, inter-token, round duration, frame RTTs)
    /// plus the request-lifecycle trace ring. Cloning the `Arc` lets
    /// the server export stats and traces while the engine runs.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Drop every queued and live request (server error recovery /
    /// shutdown); each one's channel receives `Event::Error(msg)`, so
    /// no waiting client needs an id-indexed routing table.
    pub fn abort_all(&mut self, msg: &str) {
        for q in self.queue.drain(..) {
            let _ = q.events.send(Event::Error(msg.to_string()));
        }
        for mut a in self.active.drain(..) {
            self.runtime.end_session(&mut a.session);
            let _ = a.events.send(Event::Error(msg.to_string()));
        }
    }

    /// Remove cancelled requests everywhere they can sit: queued
    /// requests are dropped before they ever prefill (their client gets
    /// the terminal event this round, even when the pool is full and
    /// admission would not have popped them), and live sessions are
    /// reaped with their KV slots freed. Runs at the top of every
    /// round, *before* admission, so a cancellation makes its slot
    /// reusable in the same round.
    fn reap_cancelled(&mut self) {
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].cancel.load(Ordering::Relaxed) {
                let q = self.queue.remove(i).expect("index in bounds");
                self.metrics.cancelled += 1;
                self.obs.trace.mark(q.req.id, SpanKind::Cancelled, self.obs.now_ns(), 0);
                let _ = q.events.send(Event::Error("cancelled".to_string()));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].cancel.load(Ordering::Relaxed) {
                let mut a = self.active.remove(i);
                self.metrics.cancelled += 1;
                self.runtime.end_session(&mut a.session);
                self.obs
                    .trace
                    .mark(a.id, SpanKind::Cancelled, self.obs.now_ns(), a.generated.len() as u64);
                a.send(Event::Error("cancelled".to_string()));
            } else {
                i += 1;
            }
        }
    }

    /// Tokenize and clamp one queued request the way admission will:
    /// prompt truncated to the largest prefill bucket, `max_new` to the
    /// KV budget. Used by the admission gate (worst-case block count)
    /// and by `admit` itself, so the two can never disagree.
    fn plan_request(&self, req: &Request) -> (Vec<i32>, usize) {
        let mut tokens = tokenizer::encode(&req.prompt);
        if tokens.is_empty() {
            tokens.push(0);
        }
        let max_prompt = self
            .runtime
            .prefill_buckets()
            .last()
            .copied()
            .unwrap_or(self.runtime.info.max_tokens);
        if tokens.len() > max_prompt {
            tokens.truncate(max_prompt);
        }
        let budget = self.runtime.info.max_tokens.saturating_sub(tokens.len());
        let max_new = req.max_new_tokens.min(budget);
        (tokens, max_new)
    }

    /// Arena blocks still owed to the live pool: every active session
    /// may grow to its worst case, and admission must leave those
    /// blocks untouched or decode-time growth would collide.
    fn outstanding_growth_blocks(&self, block_tokens: usize) -> usize {
        self.active
            .iter()
            .map(|a| {
                let worst = a.worst_tokens.div_ceil(block_tokens);
                let held = a.session.pos.max(1).div_ceil(block_tokens);
                worst.saturating_sub(held)
            })
            .sum()
    }

    /// Pick the next queued entry for admission. Preempted resumees go
    /// first regardless of class (their client is mid-stream), then the
    /// earliest latency-class request — unless the queue head is a
    /// batch-class request that has already waited
    /// `batch_aging_rounds`, which can no longer be jumped — then the
    /// plain FIFO head.
    fn select_queued(&self) -> Option<usize> {
        if let Some(i) = self.queue.iter().position(|q| q.resume.is_some()) {
            return Some(i);
        }
        let head = self.queue.front()?;
        let head_aged =
            self.round_seq.saturating_sub(head.enqueued_seq) >= self.cfg_batch_aging;
        if !head_aged {
            if let Some(i) = self.queue.iter().position(|q| q.class == Priority::Latency) {
                return Some(i);
            }
        }
        Some(0)
    }

    /// A resumed victim stuck at the gate with nothing live waits a
    /// bounded number of rounds for outside holders to release blocks;
    /// a fresh request in the same spot is refused immediately.
    fn within_resume_grace(&self, q: &QueuedRequest) -> bool {
        q.resume.is_some()
            && self.round_seq.saturating_sub(q.enqueued_seq) < RESUME_GRACE_ROUNDS
    }

    /// One scheduler round: reap cancellations, admit, batch-decode,
    /// retire.
    ///
    /// Returns the completions retired by this round (possibly empty —
    /// e.g. every live session still has budget left). Streaming
    /// consumers observe the same round through their handles' events.
    pub fn step_round(&mut self) -> Result<Vec<Completion>> {
        let mut retired = Vec::new();
        self.round_seq += 1;

        // 0. cancellation: free slots before admitting new work
        self.reap_cancelled();

        // 1. admission: fill free decode slots from the queue. When the
        // backend reports a paged KV arena, admission is *memory-aware*:
        // a request enters the pool only while the arena can still cover
        // its worst-case block count on top of what the live pool may
        // still grow into — `max_active` is a cap, the arena is the
        // allocator. Backends without memory accounting (mocks, latency
        // models) keep the pure slot-counting behavior.
        // one stats snapshot per round, and only when admission can
        // actually happen — for a bridged backend every fetch is a
        // device round trip, so a full pool or an empty queue costs none
        let mut mem: Option<MemoryStats> =
            if self.queue.is_empty() || self.active.len() >= self.cfg_max_active {
                None
            } else {
                self.runtime.memory().filter(|m| m.block_tokens > 0)
            };
        let mut admitted = 0;
        while self.active.len() < self.cfg_max_active && admitted < self.cfg_prefills_per_round {
            let Some(idx) = self.select_queued() else { break };
            if self.queue[idx].cancel.load(Ordering::Relaxed) {
                // cancelled while queued: never prefilled, costs nothing
                let q = self.queue.remove(idx).expect("index in bounds");
                self.metrics.cancelled += 1;
                self.obs.trace.mark(q.req.id, SpanKind::Cancelled, self.obs.now_ns(), 0);
                let _ = q.events.send(Event::Error("cancelled".to_string()));
                continue;
            }
            if self.queue[idx].plan.is_none() {
                let plan = match &self.queue[idx].resume {
                    // recompute plan for a preempted victim: re-prefill
                    // the prompt plus everything generated *except* the
                    // emitted-but-unfed tail token, which stays budgeted
                    // as one still-to-come token — so the worst case is
                    // exactly the original `prompt + max_new` and the
                    // gate needs no special-casing
                    Some(r) => {
                        let mut prefix = r.prompt_tokens.clone();
                        prefix.extend_from_slice(&r.generated[..r.generated.len() - 1]);
                        (prefix, r.max_new + 1 - r.generated.len())
                    }
                    None => self.plan_request(&self.queue[idx].req),
                };
                self.queue[idx].plan = Some(plan);
            }
            let entry = &self.queue[idx];
            let (prompt_len, max_new, shared) = {
                let (tokens, max_new) = entry.plan.as_ref().expect("just planned");
                // resident-prefix length: blocks the backend already
                // holds for this prompt are accounted once, not
                // per-session (0 for backends without a prefix cache)
                (tokens.len(), *max_new, self.runtime.shared_prefix_len(tokens))
            };
            if let Some(m) = &mem {
                let bt = m.block_tokens as usize;
                let needed = (prompt_len + max_new).max(1).div_ceil(bt);
                if needed as u64 > m.blocks_total {
                    // can never fit, at any load: structured refusal
                    let q = self.queue.remove(idx).expect("index in bounds");
                    self.metrics.rejected += 1;
                    let _ = q.events.send(Event::Error(format!(
                        "request needs {needed} KV blocks but the arena holds {} \
                         (raise --kv-pool-blocks or lower max_new_tokens)",
                        m.blocks_total
                    )));
                    continue;
                }
                // full blocks covered by a resident shared prefix are
                // already physically allocated — prefill will adopt
                // them by refcount, not take new ones. Only the suffix
                // (plus the CoW boundary copy, which the ceil already
                // counts) draws on the pool, so the gate charges
                // `needed - saved`, and K sessions sharing one system
                // prompt are admitted against one physical copy. The
                // whole-arena refusal above stays on the raw `needed`:
                // a cache entry can be evicted any time, so "fits only
                // thanks to the cache" is not "fits at any load".
                let saved = shared / bt;
                let outstanding = self.outstanding_growth_blocks(bt);
                if (m.blocks_free as usize) < needed.saturating_sub(saved) + outstanding {
                    if self.active.is_empty() && !self.within_resume_grace(entry) {
                        // blocks are held by work the engine does not
                        // own (another coordinator on a shared device,
                        // a directly-driven session): nothing the
                        // engine does will free them, so waiting would
                        // spin forever — refuse this request instead
                        // and let smaller queued requests try. A
                        // resumed victim gets a bounded grace first:
                        // its blocks were taken by exactly such an
                        // outside holder, which may release them.
                        let q = self.queue.remove(idx).expect("index in bounds");
                        self.metrics.rejected += 1;
                        let _ = q.events.send(Event::Error(format!(
                            "request needs {needed} KV blocks but only {} are \
                             free and no live sessions will retire; retry later",
                            m.blocks_free
                        )));
                        continue;
                    }
                    // selected entry waits for retirements to free blocks
                    break;
                }
            }
            // chunked prefill: warm a long prompt's KV into the prefix
            // cache one chunk per admission slot instead of paying one
            // monolithic prefill; the real admission happens once the
            // unwarmed tail fits in a single chunk. Resumed victims
            // skip this — their prefix is largely cache-resident.
            if self.cfg_prefill_chunk > 0 && self.queue[idx].resume.is_none() {
                let (tokens, _) = self.queue[idx].plan.as_ref().expect("just planned");
                let warmed = self.queue[idx].warmed.max(shared).min(tokens.len());
                if tokens.len() - warmed > self.cfg_prefill_chunk {
                    let target = warmed + self.cfg_prefill_chunk;
                    let slice = tokens[..target].to_vec();
                    admitted += 1;
                    let t_chunk = self.obs.now_ns();
                    match self.runtime.prefill_from(&slice, shared.min(target)) {
                        Ok((_, mut s)) => {
                            // release immediately: the slice's full
                            // blocks stay resident in the prefix index,
                            // so the next slice (and the final
                            // admission) adopt instead of recomputing
                            self.runtime.end_session(&mut s);
                            self.queue[idx].warmed = target;
                            // detail = prompt tokens warmed so far
                            self.obs.trace.record(
                                self.queue[idx].req.id,
                                SpanKind::PrefillChunk,
                                t_chunk,
                                self.obs.now_ns(),
                                target as u64,
                            );
                            continue;
                        }
                        Err(e) if is_kv_exhausted(&e) => {
                            if self.active.is_empty() {
                                let q = self.queue.remove(idx).expect("index in bounds");
                                self.metrics.rejected += 1;
                                let _ = q.events.send(Event::Error(
                                    "kv arena exhausted at prefill with no live \
                                     sessions to wait for; retry later"
                                        .to_string(),
                                ));
                                continue;
                            }
                            break;
                        }
                        Err(e) => {
                            let q = self.queue.remove(idx).expect("index in bounds");
                            let _ = q
                                .events
                                .send(Event::Error(format!("prefill failed: {e:#}")));
                            return Err(e);
                        }
                    }
                }
            }
            let mut q = self.queue.remove(idx).expect("index in bounds");
            admitted += 1;
            let (tokens, max_new) = q.plan.take().expect("planned above");
            let enq_ns = q.enqueued_ns;
            let was_resume = q.resume.is_some();
            match self.admit(q, tokens, max_new, shared)? {
                Admitted::Active(a) => {
                    let now = self.obs.now_ns();
                    self.obs.queue_wait_us.record(now.saturating_sub(enq_ns) / 1_000);
                    self.obs.trace.record(a.id, SpanKind::Queued, enq_ns, now, 0);
                    if was_resume {
                        // the whole requeue→re-prefill stall, so a trace
                        // shows what the preemption cost the client;
                        // detail = tokens already generated pre-eviction
                        self.obs.trace.record(
                            a.id,
                            SpanKind::Resumed,
                            enq_ns,
                            now,
                            a.generated.len() as u64 + 1,
                        );
                    } else {
                        self.obs.trace.mark(a.id, SpanKind::Admitted, now, 0);
                        // TTFT = submit → prefill done (the first token
                        // streams at the next decode round, but it was
                        // sampled here); resumes keep their original TTFT
                        self.obs.ttft_us.record(now.saturating_sub(enq_ns) / 1_000);
                        self.obs.trace.record(a.id, SpanKind::FirstToken, enq_ns, now, 0);
                    }
                    self.active.push(*a);
                    if let Some(m) = &mut mem {
                        // prefill drew ceil(prompt/bt) blocks from the
                        // pool, minus the full blocks it adopted from a
                        // resident prefix; decrement the snapshot
                        // locally instead of re-querying (a wire round
                        // trip per admit on a bridged backend). When the
                        // adopted prefix was cache-only (donor already
                        // retired) this undercounts — pinning a cached
                        // block also shrinks blocks_free — but the
                        // snapshot is refreshed next round and a
                        // too-optimistic admission lands in the Requeue
                        // path, never in client-visible failure
                        let bt = m.block_tokens as usize;
                        let held =
                            (prompt_len.max(1).div_ceil(bt).saturating_sub(shared / bt)) as u64;
                        m.blocks_free = m.blocks_free.saturating_sub(held);
                    }
                }
                // instant retirement released its blocks; snapshot holds
                Admitted::Done(c) => {
                    let now = self.obs.now_ns();
                    self.obs.queue_wait_us.record(now.saturating_sub(enq_ns) / 1_000);
                    self.obs.trace.record(c.id, SpanKind::Queued, enq_ns, now, 0);
                    self.obs.trace.mark(c.id, SpanKind::Done, now, c.n_generated as u64);
                    retired.push(c);
                }
                Admitted::Requeue(q) => {
                    // the arena refused prefill despite the gate (blocks
                    // held by work the gate cannot see, or a stale
                    // snapshot). With sessions live, retirements will
                    // free blocks — put the request back and retry next
                    // round. With nothing live, nothing the engine does
                    // will ever free blocks: refuse rather than wedge —
                    // except a resumed victim inside its grace window,
                    // which keeps waiting for the outside holder.
                    if self.active.is_empty() && !self.within_resume_grace(&q) {
                        self.metrics.rejected += 1;
                        let _ = q.events.send(Event::Error(
                            "kv arena exhausted at prefill with no live sessions \
                             to wait for; retry later"
                                .to_string(),
                        ));
                    } else {
                        self.queue.push_front(q);
                    }
                    break;
                }
            }
        }
        self.metrics.peak_active = self.metrics.peak_active.max(self.active.len());

        // 2. one batched decode step across the live pool
        if !self.active.is_empty() {
            // each session's sampled token is emitted now — streamed to
            // its handle and fed to the model to advance its KV state
            self.round_tokens.clear();
            self.round_ctxs.clear();
            for a in self.active.iter() {
                self.round_tokens.push(a.next_token);
                self.round_ctxs.push(a.session.pos);
            }
            for a in self.active.iter_mut() {
                let index = a.generated.len();
                a.generated.push(a.next_token);
                // a resumed session re-walks indices it streamed before
                // preemption; only genuinely new positions emit events,
                // so the client-visible stream stays dense and ordered
                if index >= a.emitted {
                    a.emitted = index + 1;
                    if a.events_open {
                        let ev = Event::Token(TokenEvent {
                            request: a.id,
                            index,
                            token: a.next_token,
                            text: tokenizer::decode(&[a.next_token]),
                        });
                        a.send(ev);
                    }
                }
            }

            let t0 = Instant::now();
            let round_start_ns = self.obs.now_ns();
            // decode with a preemption loop: a KV-exhausted round (the
            // arena could not grow a session — only reachable when the
            // arena is over-committed behind the admission gate's back)
            // evicts a victim, requeues it for resumption, and retries.
            // Growth is all-or-nothing *before* any compute, so the
            // retry recomputes the identical round for the survivors.
            let logits = loop {
                let result = {
                    let mut sessions: Vec<&mut Session> =
                        self.active.iter_mut().map(|a| &mut a.session).collect();
                    self.runtime.decode_batch(&mut sessions, &self.round_tokens)
                };
                match result {
                    Ok(l) => break l,
                    Err(e) if is_kv_exhausted(&e) => {
                        // the paged-KV contract (Backend::decode_batch
                        // docs) says a failed round advanced nobody —
                        // verify rather than trust, because retrying
                        // after a partial advance would silently
                        // double-feed the surviving sessions
                        if self
                            .active
                            .iter()
                            .zip(&self.round_ctxs)
                            .any(|(a, &ctx)| a.session.pos != ctx)
                        {
                            return Err(e.context(
                                "backend advanced sessions before reporting KV \
                                 exhaustion; the round cannot be retried",
                            ));
                        }
                        // preempt-and-requeue: release the victim's KV
                        // and fold it back into the queue front as a
                        // recompute request. Its channel and every
                        // already-emitted token survive — eviction costs
                        // the client a latency stall, never the stream.
                        let remaining: Vec<usize> = self
                            .active
                            .iter()
                            .map(|a| a.max_new.saturating_sub(a.generated.len()))
                            .collect();
                        let idx = pick_victim(&remaining);
                        let mut victim = self.active.remove(idx);
                        self.round_tokens.remove(idx);
                        self.round_ctxs.remove(idx);
                        self.metrics.preempted += 1;
                        self.metrics.requeued += 1;
                        self.runtime.end_session(&mut victim.session);
                        let now = self.obs.now_ns();
                        // Preempted covers the failed round up to the
                        // eviction; Requeued marks the instant the
                        // victim re-enters the queue (front)
                        self.obs.trace.record(
                            victim.id,
                            SpanKind::Preempted,
                            round_start_ns,
                            now,
                            victim.generated.len() as u64,
                        );
                        self.obs.trace.mark(victim.id, SpanKind::Requeued, now, 0);
                        let seq = self.round_seq;
                        self.queue.push_front(requeue_victim(victim, seq, now));
                        if self.active.is_empty() {
                            break Vec::new();
                        }
                    }
                    Err(e) => return Err(e),
                }
            };
            let round_wall = t0.elapsed().as_secs_f64();

            if !self.active.is_empty() {
                // simulated VCU128 cost: one shared round for the batch
                let round = self.sim.decode_round(&self.round_ctxs);
                let round_us = round.total_us();
                self.metrics.rounds += 1;
                self.metrics.decode_tokens += self.round_tokens.len() as u64;
                self.metrics.decode_wall_s += round_wall;
                self.metrics.sim_decode_us += round_us;

                // round duration + one ITL sample per live session:
                // under continuous batching every session's inter-token
                // gap *is* the round it decoded in (plus any preemption
                // retries, which the wall clock already includes).
                // detail = batch size, req_id 0 = engine-level span.
                let wall_us = (round_wall * 1e6) as u64;
                self.obs.round_us.record(wall_us);
                for _ in 0..self.round_tokens.len() {
                    self.obs.itl_us.record(wall_us);
                }
                self.obs.trace.record(
                    0,
                    SpanKind::DecodeRound,
                    round_start_ns,
                    self.obs.now_ns(),
                    self.round_tokens.len() as u64,
                );

                // 3. sample next tokens, retire finished sessions
                let mut still_active = Vec::with_capacity(self.active.len());
                for (mut a, l) in self.active.drain(..).zip(logits) {
                    a.decode_wall_s += round_wall;
                    a.sim_decode_us += round_us;
                    a.next_token = sample(&l, a.sampling, &mut self.rng);
                    let budget_left = a.session.pos < self.runtime.info.max_tokens;
                    let done = a.generated.len() >= a.max_new
                        || Some(a.next_token) == self.eos_token
                        || !budget_left;
                    if done {
                        // release backend-side state (the bridge closes the
                        // device session) before the completion is built
                        self.runtime.end_session(&mut a.session);
                        self.obs.trace.mark(
                            a.id,
                            SpanKind::Done,
                            self.obs.now_ns(),
                            a.generated.len() as u64,
                        );
                        retired.push(Self::finish(a));
                    } else {
                        still_active.push(a);
                    }
                }
                self.active = still_active;
            }
        }

        retired.sort_by_key(|c| c.id);
        self.metrics.completed += retired.len() as u64;
        Ok(retired)
    }

    /// Prefill one request and stage it for decoding (or retire it
    /// immediately if it has no token budget / instant EOS). `tokens` /
    /// `max_new` come from [`Engine::plan_request`] on the same request;
    /// `shared` is the resident-prefix length the admission gate
    /// sampled, forwarded as the (advisory) `prefill_from` hint so a
    /// prefix-caching backend skips straight to the divergence point.
    fn admit(
        &mut self,
        q: QueuedRequest,
        tokens: Vec<i32>,
        max_new: usize,
        shared: usize,
    ) -> Result<Admitted> {
        let QueuedRequest {
            req,
            events,
            cancel,
            class,
            enqueued_seq,
            enqueued_ns,
            warmed,
            resume,
            plan: _,
        } = q;

        let t0 = Instant::now();
        let (logits, session) = match self.runtime.prefill_from(&tokens, shared) {
            Ok(v) => v,
            Err(e) if is_kv_exhausted(&e) => {
                // out of blocks right now, not broken: requeue instead
                // of erroring the client or poisoning the round (the
                // plan rides along so the retry does not re-tokenize,
                // and resume state rides along so a victim stays one)
                return Ok(Admitted::Requeue(QueuedRequest {
                    req,
                    events,
                    cancel,
                    plan: Some((tokens, max_new)),
                    class,
                    enqueued_seq,
                    // same waiting episode: the gate bounced it back,
                    // the client has seen nothing yet
                    enqueued_ns,
                    warmed,
                    resume,
                }));
            }
            Err(e) => {
                // tell the waiting client before failing the round
                let _ = events.send(Event::Error(format!("prefill failed: {e:#}")));
                return Err(e);
            }
        };
        let first_token_s = t0.elapsed().as_secs_f64();
        let sim_first_token_ms = self.sim.prefill(tokens.len()).breakdown.total_us() / 1e3;

        if let Some(r) = resume {
            // seamless resumption: the re-prefill recomputed the KV for
            // prompt + generated[..g-1] (mostly by adopting
            // prefix-cached blocks), and the emitted-but-unfed tail
            // token becomes `next_token` again. The prefill logits are
            // deliberately ignored and nothing is re-sampled: the
            // pending token already streamed to the client, and leaving
            // the RNG untouched keeps greedy resumption bit-identical.
            let mut generated = r.generated;
            let next_token = generated.pop().expect("preempted after at least one emission");
            let emitted = generated.len() + 1;
            let n_prompt = r.prompt_tokens.len();
            let a = ActiveSession {
                id: req.id,
                prompt: req.prompt,
                sampling: req.sampling,
                max_new: r.max_new,
                n_prompt,
                worst_tokens: n_prompt + r.max_new,
                session,
                prompt_tokens: r.prompt_tokens,
                generated,
                next_token,
                emitted,
                class,
                first_token_s: r.first_token_s,
                // the recompute stall lands in decode time — the
                // client saw its first token long ago
                decode_wall_s: r.decode_wall_s + first_token_s,
                sim_first_token_ms: r.sim_first_token_ms,
                sim_decode_us: r.sim_decode_us,
                events,
                events_open: true,
                cancel,
            };
            return Ok(Admitted::Active(Box::new(a)));
        }

        let next_token = sample(&logits, req.sampling, &mut self.rng);
        let n_prompt = tokens.len();
        let a = ActiveSession {
            id: req.id,
            prompt: req.prompt,
            sampling: req.sampling,
            max_new,
            n_prompt,
            worst_tokens: n_prompt + max_new,
            session,
            prompt_tokens: tokens,
            generated: Vec::with_capacity(max_new),
            next_token,
            emitted: 0,
            class,
            first_token_s,
            decode_wall_s: 0.0,
            sim_first_token_ms,
            sim_decode_us: 0.0,
            events,
            events_open: true,
            cancel,
        };
        if max_new == 0 || Some(next_token) == self.eos_token {
            let mut a = a;
            self.runtime.end_session(&mut a.session);
            return Ok(Admitted::Done(Self::finish(a)));
        }
        Ok(Admitted::Active(Box::new(a)))
    }

    fn finish(a: ActiveSession) -> Completion {
        let n_generated = a.generated.len();
        let sim_tokens_per_s = if a.sim_decode_us > 0.0 {
            n_generated as f64 / (a.sim_decode_us * 1e-6)
        } else {
            0.0
        };
        let c = Completion {
            id: a.id,
            prompt: a.prompt,
            text: tokenizer::decode(&a.generated),
            n_prompt: a.n_prompt,
            n_generated,
            first_token_s: a.first_token_s,
            decode_s: a.decode_wall_s,
            tokens_per_s: n_generated as f64 / a.decode_wall_s.max(1e-9),
            sim_first_token_ms: a.sim_first_token_ms,
            sim_tokens_per_s,
        };
        if a.events_open {
            let _ = a.events.send(Event::Done(c.clone()));
        }
        c
    }

    /// Run scheduler rounds until the next completion retires.
    ///
    /// Compatibility shape for single-request callers (CLI `generate`,
    /// the synchronous protocol path): with an otherwise idle engine,
    /// `submit` + `step` behaves like the old run-to-completion loop.
    pub fn step(&mut self) -> Result<Option<Completion>> {
        loop {
            if let Some(c) = self.ready.pop_front() {
                return Ok(Some(c));
            }
            if !self.has_work() {
                return Ok(None);
            }
            let done = self.step_round()?;
            self.ready.extend(done);
        }
    }

    /// Drain queue and pool, returning completions in retirement order.
    pub fn run_all(&mut self) -> Result<Vec<Completion>> {
        let mut out: Vec<Completion> = self.ready.drain(..).collect();
        while self.has_work() {
            out.extend(self.step_round()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Scheduler tests with a live runtime are in rust/tests/scheduler.rs
    // and rust/tests/backend_trait.rs; here we test queue mechanics with
    // no runtime dependency.
    use super::*;

    #[test]
    fn sampling_enum_is_copy() {
        let s = Sampling::Greedy;
        let _t = s;
        let _u = s; // Copy: both usable
    }

    #[test]
    fn request_fields() {
        let r = Request {
            id: 7,
            prompt: "hi".into(),
            max_new_tokens: 4,
            sampling: Sampling::Greedy,
        };
        assert_eq!(r.id, 7);
    }

    #[test]
    fn metrics_default_rates_are_zero() {
        let m = EngineMetrics::default();
        assert_eq!(m.sim_tokens_per_s(), 0.0);
        assert_eq!(m.decode_tokens, 0);
        assert_eq!(m.cancelled, 0);
    }

    #[test]
    fn handle_reports_engine_drop() {
        // an engine dropped with requests still queued must not wedge
        // a waiting client
        let mut eng = Engine::new(
            LlmRuntime::reference_tiny(),
            EngineConfig::default(),
        );
        let h = eng.submit("never served", 4, Sampling::Greedy);
        drop(eng);
        assert!(h.wait().is_err());
    }

    #[test]
    fn victim_selection_skips_sessions_one_token_from_done() {
        // youngest (highest index) eligible session wins
        assert_eq!(pick_victim(&[5, 3, 2]), 2);
        // a session with <= 1 token remaining is skipped: evicting it
        // trades a whole prefix recompute for a single token
        assert_eq!(pick_victim(&[5, 3, 1]), 1);
        assert_eq!(pick_victim(&[4, 1, 0]), 0);
        // every session about to finish: fall back to the youngest
        assert_eq!(pick_victim(&[1, 1, 0]), 2);
        assert_eq!(pick_victim(&[1]), 0);
    }

    #[test]
    fn latency_class_is_selected_before_batch_until_the_head_ages() {
        let mut eng = Engine::new(LlmRuntime::reference_tiny(), EngineConfig::default());
        eng.submit("batch head", 4, Sampling::Greedy);
        eng.submit_with_priority("vip", 4, Sampling::Greedy, Priority::Latency);
        assert_eq!(eng.select_queued(), Some(1), "latency jumps the batch head");
        // once the batch head has waited out the aging bound it can no
        // longer be jumped
        eng.round_seq += eng.cfg_batch_aging;
        assert_eq!(eng.select_queued(), Some(0), "aged batch head holds its turn");
    }

    #[test]
    fn cancel_unknown_id_is_false() {
        let mut eng = Engine::new(
            LlmRuntime::reference_tiny(),
            EngineConfig::default(),
        );
        assert!(!eng.cancel(42));
        let h = eng.submit("queued", 4, Sampling::Greedy);
        assert!(eng.cancel(h.id()));
    }
}
