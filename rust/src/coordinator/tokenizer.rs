//! Byte-level tokenizer for the functional (tiny, vocab=256) model.
//!
//! The paper's client side "encodes and decodes the token ids"; for the
//! end-to-end example we use raw UTF-8 bytes as token ids — lossless,
//! deterministic, and vocabulary-complete for any input.

/// Encode text to token ids (one byte = one token).
pub fn encode(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

/// Decode token ids back to text (lossy on invalid UTF-8 boundaries).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .map(|&t| (t.clamp(0, 255)) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Hello, EdgeLLM!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo ✓";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn tokens_bounded_by_vocab() {
        assert!(encode("any text å").iter().all(|&t| (0..256).contains(&t)));
    }
}
