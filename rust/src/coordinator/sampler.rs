//! Token sampling policies for the decode loop.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    /// argmax — deterministic, used by the golden tests
    Greedy,
    /// softmax with temperature
    Temperature(f32),
    /// nucleus sampling
    TopP { p: f32, temperature: f32 },
}

/// Sample the next token id from logits.
pub fn sample(logits: &[f32], policy: Sampling, rng: &mut Rng) -> i32 {
    match policy {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let probs = softmax_t(logits, t);
            draw(&probs, rng)
        }
        Sampling::TopP { p, temperature } => {
            let probs = softmax_t(logits, temperature);
            let mut order: Vec<usize> = (0..probs.len()).collect();
            order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut cum = 0.0f32;
            let mut kept = Vec::new();
            for &i in &order {
                cum += probs[i];
                kept.push(i);
                if cum >= p {
                    break;
                }
            }
            let total: f32 = kept.iter().map(|&i| probs[i]).sum();
            let mut x = rng.f32() * total;
            for &i in &kept {
                x -= probs[i];
                if x <= 0.0 {
                    return i as i32;
                }
            }
            *kept.last().unwrap() as i32
        }
    }
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

fn softmax_t(logits: &[f32], t: f32) -> Vec<f32> {
    let t = t.max(1e-4);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&v| ((v - m) / t).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

fn draw(probs: &[f32], rng: &mut Rng) -> i32 {
    let mut x = rng.f32();
    for (i, &p) in probs.iter().enumerate() {
        x -= p;
        if x <= 0.0 {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut Rng::new(0)), 1);
    }

    #[test]
    fn temperature_zero_approaches_greedy() {
        let logits = vec![0.1, 5.0, -1.0];
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            assert_eq!(sample(&logits, Sampling::Temperature(1e-6), &mut rng), 1);
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // one dominant logit: top-p 0.5 must always pick it
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let t = sample(&logits, Sampling::TopP { p: 0.5, temperature: 1.0 }, &mut rng);
            assert_eq!(t, 0);
        }
    }

    #[test]
    fn sampling_is_distributed() {
        // uniform logits: every token should appear eventually
        let logits = vec![1.0f32; 8];
        let mut rng = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..500 {
            let t = sample(&logits, Sampling::Temperature(1.0), &mut rng);
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
