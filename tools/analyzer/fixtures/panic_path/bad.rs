// panic-path bad fixture: the constructs the lint must flag.
pub fn decode(v: &[u8]) -> u8 {
    let first = v[0];
    let s = std::str::from_utf8(v).unwrap();
    let n: u8 = s.parse().expect("digit");
    if n > 9 {
        panic!("bad digit");
    }
    first + n
}

pub fn later() {
    unimplemented!()
}
