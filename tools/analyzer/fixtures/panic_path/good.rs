// panic-path good fixture: checked alternatives pass clean.
pub fn decode(v: &[u8]) -> Option<u8> {
    let first = *v.first()?;
    let tail = v.get(1..)?;
    let head = &v[..2.min(v.len())];
    let n = u8::try_from(head.len()).unwrap_or(0);
    Some(first + n + tail.len() as u8)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(super::decode(&[1, 2]).unwrap(), 4);
    }
}
