# wire-drift bad fixture: the mirror drifted from good_protocol.rs —
# the Error opcode moved and MEMORY_FIELDS lost its last entry.
PROTOCOL_VERSION = 1
MAX_FRAME_BYTES = 16 << 20

OPS = {
    "Info": 0x01,
    "InfoResp": 0x81,
    "Error": 0xEF,
}
ERR_CODES = {"Protocol": 1, "Backend": 3}

MEMORY_FIELDS = [
    "total_bytes", "free_bytes",
]

OBS_FIELDS = [
    "frames_served", "frame_p99_us",
]
