# wire-drift good fixture: the Python mirror matching good_protocol.rs.
PROTOCOL_VERSION = 1
MAX_FRAME_BYTES = 16 << 20

OPS = {
    "Info": 0x01,
    "InfoResp": 0x81,
    "Error": 0xEE,
}
ERR_CODES = {"Protocol": 1, "Backend": 3}

MEMORY_FIELDS = [
    "total_bytes", "free_bytes", "reserved_bytes",
]

OBS_FIELDS = [
    "frames_served", "frame_p99_us",
]
