// wire-drift good fixture: a minimal codec matching good_mirror.py
// field for field. Never compiled — only parsed by the analyzer.
pub const PROTOCOL_VERSION: u8 = 1;
pub const MAX_FRAME_BYTES: usize = 16 << 20;

const OP_INFO: u8 = 0x01;
const OP_INFO_RESP: u8 = 0x81;
const OP_ERROR: u8 = 0xEE;

impl ErrCode {
    fn to_u8(self) -> u8 {
        match self {
            ErrCode::Protocol => 1,
            ErrCode::Backend => 3,
        }
    }

    fn from_u8(v: u8) -> Option<ErrCode> {
        Some(match v {
            1 => ErrCode::Protocol,
            3 => ErrCode::Backend,
            _ => return None,
        })
    }
}

fn encode_memory(e: &mut Enc, m: &MemoryStats) {
    e.u64(m.total_bytes);
    e.u64(m.free_bytes);
    e.u64(m.reserved_bytes);
}

fn decode_memory(d: &mut Dec) -> Option<MemoryStats> {
    Some(MemoryStats {
        total_bytes: d.u64()?,
        free_bytes: d.u64()?,
        reserved_bytes: d.u64()?,
    })
}

fn encode_obs(e: &mut Enc, o: &ObsStats) {
    e.u64(o.frames_served);
    e.u64(o.frame_p99_us);
}

fn decode_obs(d: &mut Dec) -> Option<ObsStats> {
    Some(ObsStats {
        frames_served: d.u64()?,
        frame_p99_us: d.u64()?,
    })
}
