// cfg-containment good fixture: gating under runtime/ is allowed.
#[cfg(feature = "pjrt")]
pub fn fast_path() {}
