// cfg-containment bad fixture: pjrt gating outside runtime/.
#[cfg(feature = "pjrt")]
pub fn fast_path() {}

#[cfg(not(feature = "pjrt"))]
pub fn slow_path() {}
