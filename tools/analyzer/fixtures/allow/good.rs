// allow good fixture: justified suppressions, leading and trailing.
pub fn f(v: &[u8]) -> u8 {
    debug_assert!(!v.is_empty());
    // analyzer: allow(panic-path) — caller guarantees non-empty input
    let a = v[0];
    let b = v[v.len() - 1]; // analyzer: allow(panic-path) — same guarantee
    a + b
}
