// allow bad fixture: annotations that must themselves be flagged.
pub fn f(v: &[u8]) -> u8 {
    // analyzer: allow(panic-path)
    let a = v[0];
    // analyzer: allow(not-a-lint) — bogus name
    let b = v[1];
    // analyzer: allow(wire-drift) — suppresses nothing here
    let c = 3;
    a + b + c
}
