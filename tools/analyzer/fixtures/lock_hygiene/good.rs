// lock-hygiene good fixture: drop before I/O, or extract and release.
pub fn respond(t: &std::sync::Mutex<u32>, w: &mut Vec<u8>) {
    let guard = t.lock().unwrap();
    let v = *guard;
    drop(guard);
    write_frame(w, v);
}

pub fn respond_len(t: &std::sync::Mutex<Vec<u8>>, w: &mut Vec<u8>) {
    let n = t.lock().unwrap().len() as u32;
    write_frame(w, n);
}

fn write_frame(_w: &mut Vec<u8>, _v: u32) {}
