// lock-hygiene bad fixture: a guard held across bridge I/O.
pub fn respond(t: &std::sync::Mutex<u32>, w: &mut Vec<u8>) {
    let guard = t.lock().unwrap();
    write_frame(w, *guard);
}

fn write_frame(_w: &mut Vec<u8>, _v: u32) {}
