// error-discipline bad fixture: substring-matching stringified errors.
pub fn is_exhausted(failure: &anyhow::Error) -> bool {
    failure.to_string().contains("out of KV blocks")
}

pub fn is_busy(msg: &str) -> bool {
    msg.starts_with("busy:")
}
