// error-discipline good fixture: typed checks and const markers pass.
pub const KV_EXHAUSTED_MARKER: &str = "kv-arena-exhausted";

pub fn is_exhausted(msg: &str) -> bool {
    msg.contains(KV_EXHAUSTED_MARKER)
}

pub fn is_flag(v: &str) -> bool {
    v.starts_with("--")
}
