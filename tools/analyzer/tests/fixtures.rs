//! Fixture corpus: every lint has a known-bad file that must be
//! flagged at exact lines and a known-good file that must pass clean.
//! The wire-drift pair additionally proves the acceptance criterion:
//! an InfoResp tail-arity disagreement between the Rust codec and the
//! Python mirror fails the run.

use edgellm_analyzer::{check, Config, Finding};
use std::path::{Path, PathBuf};

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

/// Config whose walked tree is one fixture directory; the wire pair
/// points at the shared good codec/mirror so wire-drift stays quiet.
fn cfg_for(dir: &str, hostile: &[&str]) -> Config {
    Config {
        src_dir: fixtures().join(dir),
        hostile: hostile.iter().map(|s| s.to_string()).collect(),
        protocol: fixtures().join("wire_drift").join("good_protocol.rs"),
        mirror: fixtures().join("wire_drift").join("good_mirror.py"),
        pjrt_allowed_prefix: "runtime/".to_string(),
        marker_module: "runtime/kv.rs".to_string(),
    }
}

/// (line, lint) pairs for findings in the file whose path ends with
/// `file`, in report order.
fn hits(findings: &[Finding], file: &str) -> Vec<(usize, String)> {
    findings
        .iter()
        .filter(|f| f.path.ends_with(file))
        .map(|f| (f.line, f.lint.clone()))
        .collect()
}

fn lint_lines(findings: &[Finding], file: &str, lint: &str) -> Vec<usize> {
    findings
        .iter()
        .filter(|f| f.path.ends_with(file) && f.lint == lint)
        .map(|f| f.line)
        .collect()
}

#[test]
fn panic_path_fixture() {
    let report = check(&cfg_for("panic_path", &["bad.rs", "good.rs"])).unwrap();
    assert_eq!(
        lint_lines(&report.findings, "bad.rs", "panic-path"),
        vec![3, 4, 5, 7, 13],
        "bad.rs: index, unwrap, expect, panic!, unimplemented!"
    );
    assert!(hits(&report.findings, "good.rs").is_empty(), "{:?}", report.findings);
    assert_eq!(report.findings.len(), 5);
}

#[test]
fn cfg_containment_fixture() {
    let report = check(&cfg_for("cfg_containment", &[])).unwrap();
    assert_eq!(
        lint_lines(&report.findings, "bad.rs", "cfg-containment"),
        vec![2, 5]
    );
    assert!(hits(&report.findings, "good.rs").is_empty(), "{:?}", report.findings);
    assert_eq!(report.findings.len(), 2);
}

#[test]
fn error_discipline_fixture() {
    let report = check(&cfg_for("error_discipline", &[])).unwrap();
    assert_eq!(
        lint_lines(&report.findings, "bad.rs", "error-discipline"),
        vec![3, 7],
        "to_string() chain and error-ish receiver"
    );
    assert!(hits(&report.findings, "good.rs").is_empty(), "{:?}", report.findings);
    assert_eq!(report.findings.len(), 2);
}

#[test]
fn lock_hygiene_fixture() {
    let report = check(&cfg_for("lock_hygiene", &[])).unwrap();
    assert_eq!(
        lint_lines(&report.findings, "bad.rs", "lock-hygiene"),
        vec![4],
        "guard from line 3 held across write_frame"
    );
    assert!(hits(&report.findings, "good.rs").is_empty(), "{:?}", report.findings);
    assert_eq!(report.findings.len(), 1);
}

#[test]
fn allow_machinery_fixture() {
    let report = check(&cfg_for("allow", &["bad.rs", "good.rs"])).unwrap();
    let expected: Vec<(usize, String)> = vec![
        (3, "malformed-allow".to_string()), // reasonless
        (4, "panic-path".to_string()),      // ... so the finding still fires
        (5, "malformed-allow".to_string()), // unknown lint name
        (6, "panic-path".to_string()),
        (7, "unused-allow".to_string()), // valid but suppresses nothing
    ];
    assert_eq!(hits(&report.findings, "bad.rs"), expected);
    // good.rs: both indexings suppressed, annotations consumed
    assert!(hits(&report.findings, "good.rs").is_empty(), "{:?}", report.findings);
    assert_eq!(report.findings.len(), 5);
}

#[test]
fn wire_drift_tail_arity_fails() {
    let mut cfg = cfg_for("wire_drift", &[]);
    cfg.protocol = fixtures().join("wire_drift").join("bad_protocol.rs");
    let report = check(&cfg).unwrap();
    let arity: Vec<&Finding> = report
        .findings
        .iter()
        .filter(|f| f.lint == "wire-drift" && f.message.contains("arity"))
        .collect();
    // decode (2) vs encode (3), and decode (2) vs MEMORY_FIELDS (3)
    assert_eq!(arity.len(), 2, "{:?}", report.findings);
    assert!(arity.iter().all(|f| f.path.ends_with("bad_protocol.rs")));
    assert_eq!(report.findings.len(), 2, "{:?}", report.findings);
}

#[test]
fn wire_drift_mirror_drift_fails() {
    let mut cfg = cfg_for("wire_drift", &[]);
    cfg.mirror = fixtures().join("wire_drift").join("bad_mirror.py");
    let report = check(&cfg).unwrap();
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.lint == "wire-drift" && f.message.contains("`Error`")),
        "opcode value drift must be flagged: {:?}",
        report.findings
    );
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.lint == "wire-drift" && f.message.contains("arity")),
        "tail arity drift must be flagged: {:?}",
        report.findings
    );
}

#[test]
fn wire_drift_good_pair_is_clean() {
    let report = check(&cfg_for("wire_drift", &[])).unwrap();
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let report = check(&Config::repo(&root)).unwrap();
    assert!(
        report.findings.is_empty(),
        "the committed tree must pass its own analyzer:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.lint, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files > 20, "walked only {} files", report.files);
}
