//! The five repo lints. Each takes a scanned [`SourceFile`] (or, for
//! wire-drift, the protocol file plus the raw Python mirror text) and
//! appends [`Finding`]s. Lints are lexical by design: they scan the
//! comment-and-string-blanked `code` view (or `stripped`, where a
//! pattern lives inside a string literal), so they can be wrong only in
//! ways a reviewer can see on the flagged line.

use crate::scan::{is_ident, SourceFile};

/// Lint names accepted by `// analyzer: allow(<lint>)`.
pub const LINTS: &[&str] = &[
    "panic-path",
    "wire-drift",
    "cfg-containment",
    "error-discipline",
    "lock-hygiene",
];

/// One diagnostic: a file, a 1-based line, the lint that fired, and a
/// human-readable message.
#[derive(Debug, Clone)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub lint: String,
    pub message: String,
}

fn push(out: &mut Vec<Finding>, sf: &SourceFile, line: usize, lint: &str, message: String) {
    out.push(Finding { path: sf.path.clone(), line, lint: lint.to_string(), message });
}

// ---------------------------------------------------------------- panic-path

/// No `unwrap`/`expect`/panicking macro/`[i]`-indexing in hostile-input
/// surfaces outside `#[cfg(test)]`. Bounds-checked slicing (`&x[a..b]`,
/// which the codebase validates lengths for up front) is carved out:
/// an index expression whose top level contains `..` is a range.
pub fn panic_path(sf: &SourceFile, out: &mut Vec<Finding>) {
    for (i, line) in sf.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let ln = i + 1;
        let code = line.code.as_str();
        for (pat, what) in [
            (".unwrap()", "`.unwrap()` can panic on hostile input; bubble a typed error"),
            (".expect(", "`.expect()` can panic on hostile input; bubble a typed error"),
        ] {
            let mut from = 0;
            while let Some(p) = code[from..].find(pat) {
                push(out, sf, ln, "panic-path", what.to_string());
                from += p + pat.len();
            }
        }
        for mac in ["panic!", "unimplemented!", "todo!", "unreachable!"] {
            let b = code.as_bytes();
            let mut from = 0;
            while let Some(p) = code[from..].find(mac) {
                let at = from + p;
                if at == 0 || !is_ident(b[at - 1]) {
                    push(
                        out,
                        sf,
                        ln,
                        "panic-path",
                        format!("`{mac}` aborts the daemon thread; return an error frame instead"),
                    );
                }
                from = at + mac.len();
            }
        }
        let b = code.as_bytes();
        for p in 0..b.len() {
            if b[p] != b'[' || p == 0 {
                continue;
            }
            let prev = b[p - 1];
            if !(is_ident(prev) || prev == b')' || prev == b']' || prev == b'?') {
                continue;
            }
            if let Some(end) = matching_bracket(b, p) {
                if !has_toplevel_range(&b[p + 1..end]) {
                    push(
                        out,
                        sf,
                        ln,
                        "panic-path",
                        "`[i]` indexing can panic; use `.get()` or validate the length first"
                            .to_string(),
                    );
                }
            }
        }
    }
}

/// Index of the `]` matching the `[` at `b[open]`, same line only.
fn matching_bracket(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'[' => depth += 1,
            b']' => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

/// Does the bracket content contain a `..` outside any nested grouping?
/// That makes the expression a slice, not an index.
fn has_toplevel_range(s: &[u8]) -> bool {
    let mut depth = 0i32;
    for j in 0..s.len() {
        match s[j] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b'.' if depth == 0 && j + 1 < s.len() && s[j + 1] == b'.' => return true,
            _ => {}
        }
    }
    false
}

// ------------------------------------------------------------ cfg-containment

/// `cfg(feature = "pjrt")` may appear only under the allowed prefix
/// (`runtime/`): the scheduler, bridge, and coordinator must stay
/// backend-agnostic so the reference backend exercises the same paths.
pub fn cfg_containment(sf: &SourceFile, rel: &str, allowed_prefix: &str, out: &mut Vec<Finding>) {
    if rel.starts_with(allowed_prefix) {
        return;
    }
    for (i, line) in sf.lines.iter().enumerate() {
        let compact: String = line.stripped.chars().filter(|c| !c.is_whitespace()).collect();
        if compact.contains("feature=\"pjrt\"") {
            push(
                out,
                sf,
                i + 1,
                "cfg-containment",
                format!(
                    "`cfg(feature = \"pjrt\")` outside `{allowed_prefix}`; \
                     backend-specific code belongs in the runtime layer"
                ),
            );
        }
    }
}

// ----------------------------------------------------------- error-discipline

/// No substring-matching on stringified error values: `.contains("...")`
/// / `.starts_with("...")` with a string *literal* argument on an
/// error-ish receiver (`e`, `err`, `msg`, ... or a `.to_string()`
/// chain). Matching on a shared `const` marker (the
/// `KV_EXHAUSTED_MARKER` pattern) does not fire — the argument is an
/// identifier, not a literal.
pub fn error_discipline(sf: &SourceFile, out: &mut Vec<Finding>) {
    for (i, line) in sf.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        for pat in [".contains(\"", ".starts_with(\""] {
            let mut from = 0;
            while let Some(p) = code[from..].find(pat) {
                let at = from + p;
                if receiver_is_errorish(code.as_bytes(), at) {
                    push(
                        out,
                        sf,
                        i + 1,
                        "error-discipline",
                        "substring match on a stringified error; use a typed error \
                         or the shared const marker"
                            .to_string(),
                    );
                }
                from = at + pat.len();
            }
        }
    }
}

/// Is the receiver before the `.` at `b[dot]` an error-like identifier
/// or a `.to_string()` chain?
fn receiver_is_errorish(b: &[u8], dot: usize) -> bool {
    if dot == 0 {
        return false;
    }
    if b[dot - 1] == b')' {
        let want = b"to_string()";
        return dot >= want.len() && &b[dot - want.len()..dot] == want;
    }
    let mut s = dot;
    while s > 0 && is_ident(b[s - 1]) {
        s -= 1;
    }
    let name = String::from_utf8_lossy(&b[s..dot]).to_ascii_lowercase();
    matches!(name.as_str(), "e" | "err" | "error" | "msg" | "message")
        || name.ends_with("_err")
        || name.ends_with("_error")
        || name.ends_with("_msg")
        || name.ends_with("_message")
}

// -------------------------------------------------------------- lock-hygiene

const LOCK_PATS: &[&str] = &[
    ".lock()",
    ".try_lock()",
    ".borrow_mut()",
    ".try_borrow_mut()",
    "lock_unpoisoned(",
];
const TRIGGERS: &[&str] = &["write_frame(", "read_frame(", "TcpStream::connect"];

struct Guard {
    name: String,
    depth: i32,
    line: usize,
}

/// Flag a `let`-bound lock/borrow guard that is still live when a
/// bridge I/O call (`write_frame`/`read_frame`/`TcpStream::connect`)
/// runs in the same lexical scope: holding the engine lock across
/// blocking socket I/O stalls every other session. `drop(guard)`
/// before the call, or extracting the needed value in the same
/// statement (`...lock().unwrap().len()`), both pass.
pub fn lock_hygiene(sf: &SourceFile, out: &mut Vec<Finding>) {
    let mut guards: Vec<Guard> = Vec::new();
    for (i, line) in sf.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let ln = i + 1;
        // a guard dies when its enclosing block closes
        guards.retain(|g| line.depth >= g.depth);
        let code = line.code.as_str();
        guards.retain(|g| !code.contains(&format!("drop({})", g.name)));
        let trig = TRIGGERS.iter().filter_map(|t| code.find(t)).min();
        if trig.is_some() {
            for g in &guards {
                push(
                    out,
                    sf,
                    ln,
                    "lock-hygiene",
                    format!(
                        "guard `{}` (acquired at line {}) is held across blocking \
                         bridge I/O; drop it first",
                        g.name, g.line
                    ),
                );
            }
        }
        if let Some((name, lock_end)) = guard_binding(code) {
            if let Some(tp) = trig {
                if tp > lock_end {
                    push(
                        out,
                        sf,
                        ln,
                        "lock-hygiene",
                        format!(
                            "guard `{name}` is held across blocking bridge I/O on the \
                             same line"
                        ),
                    );
                }
            }
            guards.push(Guard { name, depth: line.depth, line: ln });
        }
    }
}

/// If this line binds a lock/borrow guard that stays live past the
/// statement, return its name and the offset where the lock chain ends.
/// `let n = t.lock().unwrap().len();` extracts a value from a temporary
/// guard (dropped at the `;`) and returns `None`.
fn guard_binding(code: &str) -> Option<(String, usize)> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let nb = rest.as_bytes();
    let mut n = 0;
    while n < nb.len() && is_ident(nb[n]) {
        n += 1;
    }
    if n == 0 {
        return None;
    }
    let name = rest[..n].to_string();
    if name == "_" {
        // `let _ = ...` drops the value immediately
        return None;
    }
    let b = code.as_bytes();
    let mut end: Option<usize> = None;
    for pat in LOCK_PATS {
        if let Some(p) = code.find(pat) {
            let e = if pat.ends_with('(') {
                skip_balanced(b, p + pat.len() - 1)? + 1
            } else {
                p + pat.len()
            };
            end = Some(end.map_or(e, |x: usize| x.max(e)));
        }
    }
    let mut end = end?;
    // `.unwrap()` / `.expect(..)` / `?` after the lock still yield a guard
    loop {
        let r = &code[end..];
        let trimmed = r.trim_start();
        let pad = r.len() - trimmed.len();
        if trimmed.starts_with(".unwrap()") {
            end += pad + ".unwrap()".len();
        } else if trimmed.starts_with(".expect(") {
            end = skip_balanced(b, end + pad + ".expect".len())? + 1;
        } else if trimmed.starts_with('?') {
            end += pad + 1;
        } else {
            break;
        }
    }
    let tail = code[end..].trim();
    if tail == ";" || tail.is_empty() {
        Some((name, end))
    } else {
        None
    }
}

/// Index of the `)` matching the `(` at `b[open]`, same line only.
fn skip_balanced(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

// ---------------------------------------------------------------- wire-drift

/// What the Rust codec declares, parsed from `protocol.rs`.
#[derive(Default)]
struct RustWire {
    version: Option<(u64, usize)>,
    max_frame: Option<(u64, usize)>,
    /// CamelCase op name → (value, line)
    ops: Vec<(String, u64, usize)>,
    err_to: Vec<(String, u64, usize)>,
    err_from: Vec<(String, u64, usize)>,
    /// InfoResp memory-tail field names in encode order
    enc: Vec<(String, usize)>,
    /// ... and in decode order
    dec: Vec<(String, usize)>,
    /// InfoResp obs-tail field names in encode order
    enc_obs: Vec<(String, usize)>,
    /// ... and in decode order
    dec_obs: Vec<(String, usize)>,
}

/// What the Python mirror declares.
#[derive(Default)]
struct PyWire {
    version: Option<u64>,
    max_frame: Option<u64>,
    ops: Vec<(String, u64)>,
    errs: Vec<(String, u64)>,
    mem: Vec<String>,
    obs: Vec<String>,
}

/// Cross-check the Rust codec against the Python mirror: protocol
/// version, frame cap, opcode table, error-code table (both `to_u8`
/// and `from_u8` directions), and the `InfoResp` memory-tail field
/// list — names AND order, in the encoder, the decoder, and the
/// mirror. Any anchor the parser cannot find is itself a finding, so
/// a refactor cannot silently disable the lint.
pub fn wire_drift(proto: &SourceFile, py_text: &str, py_path: &str, out: &mut Vec<Finding>) {
    let rw = parse_rust_wire(proto);
    let pw = parse_py_wire(py_text);
    let mut missing = |what: &str, path: &str| {
        out.push(Finding {
            path: path.to_string(),
            line: 1,
            lint: "wire-drift".to_string(),
            message: format!(
                "could not locate {what} — the wire-drift parse anchors rotted; \
                 update tools/analyzer"
            ),
        });
    };
    if rw.version.is_none() {
        missing("`const PROTOCOL_VERSION`", &proto.path);
    }
    if rw.max_frame.is_none() {
        missing("`const MAX_FRAME_BYTES`", &proto.path);
    }
    if rw.ops.is_empty() {
        missing("the `const OP_*` opcode table", &proto.path);
    }
    if rw.err_to.is_empty() || rw.err_from.is_empty() {
        missing("the `ErrCode` to_u8/from_u8 arms", &proto.path);
    }
    if rw.enc.is_empty() {
        missing("the `e.u64(m.<field>)` InfoResp memory-tail encoder", &proto.path);
    }
    if rw.dec.is_empty() {
        missing("the `Some(MemoryStats { .. })` decode tail", &proto.path);
    }
    if rw.enc_obs.is_empty() {
        missing("the `e.u64(o.<field>)` InfoResp obs-tail encoder", &proto.path);
    }
    if rw.dec_obs.is_empty() {
        missing("the `Some(ObsStats { .. })` decode tail", &proto.path);
    }
    if pw.version.is_none() {
        missing("`PROTOCOL_VERSION`", py_path);
    }
    if pw.max_frame.is_none() {
        missing("`MAX_FRAME_BYTES`", py_path);
    }
    if pw.ops.is_empty() {
        missing("the `OPS` dict", py_path);
    }
    if pw.errs.is_empty() {
        missing("the `ERR_CODES` dict", py_path);
    }
    if pw.mem.is_empty() {
        missing("the `MEMORY_FIELDS` list", py_path);
    }
    if pw.obs.is_empty() {
        missing("the `OBS_FIELDS` list", py_path);
    }

    let mut drift = |line: usize, message: String| {
        out.push(Finding {
            path: proto.path.clone(),
            line,
            lint: "wire-drift".to_string(),
            message,
        });
    };
    if let (Some((rv, rl)), Some(pv)) = (&rw.version, pw.version) {
        if *rv != pv {
            drift(*rl, format!("PROTOCOL_VERSION is {rv} here but {pv} in {py_path}"));
        }
    }
    if let (Some((rv, rl)), Some(pv)) = (&rw.max_frame, pw.max_frame) {
        if *rv != pv {
            drift(*rl, format!("MAX_FRAME_BYTES is {rv} here but {pv} in {py_path}"));
        }
    }
    // opcode table, both directions
    for (name, val, line) in &rw.ops {
        match pw.ops.iter().find(|(n, _)| n == name) {
            None => drift(
                *line,
                format!("opcode `{name}` (0x{val:02X}) has no entry in {py_path}'s OPS"),
            ),
            Some((_, pv)) if pv != val => drift(
                *line,
                format!("opcode `{name}` is 0x{val:02X} here but 0x{pv:02X} in {py_path}"),
            ),
            _ => {}
        }
    }
    for (name, val) in &pw.ops {
        if !rw.ops.iter().any(|(n, _, _)| n == name) {
            drift(
                1,
                format!(
                    "{py_path} lists opcode `{name}` (0x{val:02X}) with no Rust \
                     `const OP_*` counterpart"
                ),
            );
        }
    }
    // error codes: to_u8 vs from_u8 must agree, then vs the mirror
    for (name, val, line) in &rw.err_to {
        match rw.err_from.iter().find(|(n, _, _)| n == name) {
            None => drift(*line, format!("ErrCode::{name} has a to_u8 arm but no from_u8 arm")),
            Some((_, fv, _)) if fv != val => drift(
                *line,
                format!("ErrCode::{name} maps to {val} in to_u8 but {fv} in from_u8"),
            ),
            _ => {}
        }
        match pw.errs.iter().find(|(n, _)| n == name) {
            None => drift(*line, format!("ErrCode::{name} has no entry in {py_path}'s ERR_CODES")),
            Some((_, pv)) if pv != val => drift(
                *line,
                format!("ErrCode::{name} is {val} here but {pv} in {py_path}"),
            ),
            _ => {}
        }
    }
    for (name, _, line) in &rw.err_from {
        if !rw.err_to.iter().any(|(n, _, _)| n == name) {
            drift(*line, format!("ErrCode::{name} has a from_u8 arm but no to_u8 arm"));
        }
    }
    for (name, val) in &pw.errs {
        if !rw.err_to.iter().any(|(n, _, _)| n == name) {
            drift(1, format!("{py_path} lists ErrCode `{name}` ({val}) with no Rust counterpart"));
        }
    }
    // InfoResp memory tail: encoder vs decoder vs mirror, names and order
    let enc: Vec<&str> = rw.enc.iter().map(|(n, _)| n.as_str()).collect();
    let dec: Vec<&str> = rw.dec.iter().map(|(n, _)| n.as_str()).collect();
    let mem: Vec<&str> = pw.mem.iter().map(|s| s.as_str()).collect();
    let enc_line = rw.enc.first().map_or(1, |(_, l)| *l);
    let dec_line = rw.dec.first().map_or(1, |(_, l)| *l);
    if !enc.is_empty() && !dec.is_empty() && enc != dec {
        drift(
            enc_line,
            tail_diff("memory-tail", "the encode tail", &enc, "the decode tail", &dec),
        );
    }
    if !dec.is_empty() && !mem.is_empty() && dec != mem {
        drift(
            dec_line,
            tail_diff(
                "memory-tail",
                "the decode tail",
                &dec,
                &format!("{py_path}'s MEMORY_FIELDS"),
                &mem,
            ),
        );
    }
    // ... and the obs tail, held to the identical discipline
    let enc_obs: Vec<&str> = rw.enc_obs.iter().map(|(n, _)| n.as_str()).collect();
    let dec_obs: Vec<&str> = rw.dec_obs.iter().map(|(n, _)| n.as_str()).collect();
    let obs: Vec<&str> = pw.obs.iter().map(|s| s.as_str()).collect();
    let enc_obs_line = rw.enc_obs.first().map_or(1, |(_, l)| *l);
    let dec_obs_line = rw.dec_obs.first().map_or(1, |(_, l)| *l);
    if !enc_obs.is_empty() && !dec_obs.is_empty() && enc_obs != dec_obs {
        drift(
            enc_obs_line,
            tail_diff("obs-tail", "the encode tail", &enc_obs, "the decode tail", &dec_obs),
        );
    }
    if !dec_obs.is_empty() && !obs.is_empty() && dec_obs != obs {
        drift(
            dec_obs_line,
            tail_diff(
                "obs-tail",
                "the decode tail",
                &dec_obs,
                &format!("{py_path}'s OBS_FIELDS"),
                &obs,
            ),
        );
    }
}

fn tail_diff(what: &str, aname: &str, a: &[&str], bname: &str, b: &[&str]) -> String {
    if a.len() != b.len() {
        format!(
            "InfoResp {what} arity drift: {aname} carries {} u64s but {bname} carries {}",
            a.len(),
            b.len()
        )
    } else {
        let i = a.iter().zip(b).position(|(x, y)| x != y).unwrap_or(0);
        format!(
            "InfoResp {what} field {} is `{}` in {aname} but `{}` in {bname}",
            i, a[i], b[i]
        )
    }
}

fn parse_rust_wire(sf: &SourceFile) -> RustWire {
    let mut w = RustWire::default();
    let mut in_dec = false;
    let mut in_dec_obs = false;
    for (i, line) in sf.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let ln = i + 1;
        let t = line.stripped.trim();
        if t.contains("const PROTOCOL_VERSION") {
            if let Some(v) = t.split('=').nth(1).and_then(parse_int) {
                w.version = Some((v, ln));
            }
        } else if t.contains("const MAX_FRAME_BYTES") {
            if let Some(v) = t.split('=').nth(1).and_then(parse_int) {
                w.max_frame = Some((v, ln));
            }
        } else if let Some(rest) = t
            .strip_prefix("const OP_")
            .or_else(|| t.strip_prefix("pub const OP_"))
        {
            if let Some(colon) = rest.find(':') {
                let name = camel(rest[..colon].trim());
                if let Some(v) = rest.split('=').nth(1).and_then(parse_int) {
                    w.ops.push((name, v, ln));
                }
            }
        }
        // ErrCode arms, both directions
        let arm = t.trim_end_matches(',');
        if let Some((lhs, rhs)) = arm.split_once("=>") {
            let (lhs, rhs) = (lhs.trim(), rhs.trim());
            if let Some(name) = lhs.strip_prefix("ErrCode::") {
                if let Some(v) = parse_int(rhs) {
                    w.err_to.push((name.trim().to_string(), v, ln));
                }
            } else if let (Some(v), Some(name)) = (parse_int(lhs), rhs.strip_prefix("ErrCode::")) {
                w.err_from.push((name.trim().to_string(), v, ln));
            }
        }
        // InfoResp memory tail, encode side
        if let Some(rest) = t.strip_prefix("e.u64(m.") {
            if let Some(close) = rest.find(')') {
                w.enc.push((rest[..close].trim().to_string(), ln));
            }
        }
        // InfoResp obs tail, encode side
        if let Some(rest) = t.strip_prefix("e.u64(o.") {
            if let Some(close) = rest.find(')') {
                w.enc_obs.push((rest[..close].trim().to_string(), ln));
            }
        }
        // ... and decode side (first non-test MemoryStats literal)
        if in_dec {
            if t.starts_with("})") || t.starts_with('}') {
                in_dec = false;
            } else if let Some((name, rhs)) = t.split_once(':') {
                let name = name.trim();
                let rhs = rhs.trim().trim_end_matches(',');
                if !name.is_empty()
                    && name.bytes().all(is_ident)
                    && (rhs == "d.u64()?" || rhs == "d.u64()?,")
                {
                    w.dec.push((name.to_string(), ln));
                }
            }
        } else if w.dec.is_empty() && t.contains("Some(MemoryStats {") {
            in_dec = true;
        }
        // ... and the obs decode tail (first non-test ObsStats literal)
        if in_dec_obs {
            if t.starts_with("})") || t.starts_with('}') {
                in_dec_obs = false;
            } else if let Some((name, rhs)) = t.split_once(':') {
                let name = name.trim();
                let rhs = rhs.trim().trim_end_matches(',');
                if !name.is_empty()
                    && name.bytes().all(is_ident)
                    && (rhs == "d.u64()?" || rhs == "d.u64()?,")
                {
                    w.dec_obs.push((name.to_string(), ln));
                }
            }
        } else if w.dec_obs.is_empty() && t.contains("Some(ObsStats {") {
            in_dec_obs = true;
        }
    }
    w
}

fn parse_py_wire(text: &str) -> PyWire {
    // blank python comments (respecting simple string quoting)
    let mut cleaned = String::with_capacity(text.len());
    for line in text.split('\n') {
        let mut in_str: Option<char> = None;
        for c in line.chars() {
            match in_str {
                Some(q) if c == q => in_str = None,
                Some(_) => {}
                None if c == '"' || c == '\'' => in_str = Some(c),
                None if c == '#' => break,
                None => {}
            }
            cleaned.push(c);
        }
        cleaned.push('\n');
    }
    let mut w = PyWire::default();
    for line in cleaned.split('\n') {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("PROTOCOL_VERSION") {
            if let Some(v) = rest.trim().strip_prefix('=').and_then(parse_int) {
                w.version = Some(v);
            }
        } else if let Some(rest) = t.strip_prefix("MAX_FRAME_BYTES") {
            if let Some(v) = rest.trim().strip_prefix('=').and_then(parse_int) {
                w.max_frame = Some(v);
            }
        }
    }
    if let Some(body) = py_region(&cleaned, "OPS", '{', '}') {
        w.ops = py_pairs(&body);
    }
    if let Some(body) = py_region(&cleaned, "ERR_CODES", '{', '}') {
        w.errs = py_pairs(&body);
    }
    if let Some(body) = py_region(&cleaned, "MEMORY_FIELDS", '[', ']') {
        w.mem = py_strings(&body);
    }
    if let Some(body) = py_region(&cleaned, "OBS_FIELDS", '[', ']') {
        w.obs = py_strings(&body);
    }
    w
}

/// The text between the `open` bracket after `NAME =` and its matching
/// `close`, brackets excluded. Spans lines.
fn py_region(text: &str, name: &str, open: char, close: char) -> Option<String> {
    let mut at = 0usize;
    // the marker must start a line (left-hand side of an assignment)
    let start = loop {
        let p = text[at..].find(name)? + at;
        let line_start = p == 0 || text.as_bytes()[p - 1] == b'\n';
        if line_start {
            break p;
        }
        at = p + name.len();
    };
    let ob = text[start..].find(open)? + start;
    let b = text.as_bytes();
    let mut depth = 0i32;
    for (j, &c) in b.iter().enumerate().skip(ob) {
        if c == open as u8 {
            depth += 1;
        } else if c == close as u8 {
            depth -= 1;
            if depth == 0 {
                return Some(text[ob + 1..j].to_string());
            }
        }
    }
    None
}

/// `"Name": value` pairs out of a python dict body, in order.
fn py_pairs(body: &str) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    for part in body.split(',') {
        if let Some((k, v)) = part.split_once(':') {
            let k = k.trim().trim_matches(['"', '\'']);
            if let Some(v) = parse_int(v) {
                if !k.is_empty() {
                    out.push((k.to_string(), v));
                }
            }
        }
    }
    out
}

/// Quoted strings out of a python list body, in order.
fn py_strings(body: &str) -> Vec<String> {
    body.split(',')
        .map(|s| s.trim().trim_matches(['"', '\'']).to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Parse `1`, `0x83`, or `16 << 20` (with optional trailing `;`).
fn parse_int(s: &str) -> Option<u64> {
    let s = s.trim().trim_end_matches(';').trim();
    if let Some((a, b)) = s.split_once("<<") {
        return Some(parse_int(a)?.checked_shl(parse_int(b)? as u32)?);
    }
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(h) => u64::from_str_radix(h, 16).ok(),
        None => s.parse().ok(),
    }
}

/// `OPEN_SESSION` → `OpenSession` (the Python mirror keys).
fn camel(s: &str) -> String {
    s.split('_')
        .map(|seg| {
            let mut c = seg.chars();
            match c.next() {
                Some(f) => f.to_ascii_uppercase().to_string() + &c.as_str().to_ascii_lowercase(),
                None => String::new(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan;

    #[test]
    fn parse_int_forms() {
        assert_eq!(parse_int(" 1; "), Some(1));
        assert_eq!(parse_int("0xEE"), Some(0xEE));
        assert_eq!(parse_int("16 << 20"), Some(16 << 20));
        assert_eq!(parse_int("wat"), None);
    }

    #[test]
    fn camel_matches_mirror_keys() {
        assert_eq!(camel("INFO"), "Info");
        assert_eq!(camel("OPEN_SESSION"), "OpenSession");
        assert_eq!(camel("INFO_RESP"), "InfoResp");
    }

    #[test]
    fn slicing_is_not_indexing() {
        let sf = scan("f.rs", "let a = &x[1..n];\nlet b = x[i];\nlet c = x[f(a..b)];\n");
        let mut out = Vec::new();
        panic_path(&sf, &mut out);
        let lines: Vec<usize> = out.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![2, 3]);
    }

    #[test]
    fn temporary_guard_is_not_held() {
        assert!(guard_binding("    let n = t.lock().unwrap().len();").is_none());
        assert!(guard_binding("    let g = t.lock().unwrap();").is_some());
        assert!(guard_binding("    let g = lock_unpoisoned(&self.t);").is_some());
        assert!(guard_binding("    let _ = t.lock();").is_none());
    }

    #[test]
    fn errorish_receivers() {
        let sf = scan(
            "f.rs",
            "if e.to_string().contains(\"boom\") {}\nif msg.contains(MARKER) {}\n\
             if v.starts_with(\"--\") {}\nif last_err.contains(\"x\") {}\n",
        );
        let mut out = Vec::new();
        error_discipline(&sf, &mut out);
        let lines: Vec<usize> = out.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![1, 4]);
    }
}
