//! CLI: `cargo run -p edgellm-analyzer -- check [--root PATH]`.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage or environment error.

use std::path::PathBuf;
use std::process::exit;

fn usage() -> ! {
    eprintln!("usage: edgellm-analyzer check [--root PATH]");
    eprintln!();
    eprintln!("Runs the repo invariant lints over <root>/rust/src.");
    eprintln!("PATH defaults to the current directory (falling back to the");
    eprintln!("workspace root when invoked from inside tools/analyzer).");
    exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<&str> = None;
    let mut root: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "check" if cmd.is_none() => cmd = Some("check"),
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => usage(),
                }
            }
            "-h" | "--help" => usage(),
            _ => usage(),
        }
        i += 1;
    }
    if cmd != Some("check") {
        usage();
    }
    let root = root.unwrap_or_else(|| {
        let cwd = PathBuf::from(".");
        if cwd.join("rust").join("src").is_dir() {
            return cwd;
        }
        // `cargo run -p edgellm-analyzer` from inside the crate dir:
        // the workspace root is two levels up from the manifest
        if let Ok(m) = std::env::var("CARGO_MANIFEST_DIR") {
            let up = PathBuf::from(m).join("..").join("..");
            if up.join("rust").join("src").is_dir() {
                return up;
            }
        }
        cwd
    });

    let cfg = edgellm_analyzer::Config::repo(&root);
    match edgellm_analyzer::check(&cfg) {
        Err(e) => {
            eprintln!("analyzer: error: {e}");
            exit(2);
        }
        Ok(report) => {
            for f in &report.findings {
                println!("{}:{}: [{}] {}", f.path, f.line, f.lint, f.message);
            }
            if report.findings.is_empty() {
                println!("analyzer: clean ({} files)", report.files);
                exit(0);
            }
            println!(
                "analyzer: {} finding(s) across {} files — see docs/static-analysis.md",
                report.findings.len(),
                report.files
            );
            exit(1);
        }
    }
}
